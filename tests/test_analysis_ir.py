"""repro.analysis.ir: jaxpr contract checks, donation aliasing, retrace
sentinel, Pallas lints, the golden mixed-modality session, and the ir-*
rule registration.

Like test_analysis.py, every check gets a firing fixture AND a matched
clean fixture.  The golden-context tests are the enforcement point for
the serving stack: the tiny image+video engines must verify clean and
the mixed session must compile NOTHING after warmup.  The context is
built once per process (lru_cache) so the cluster of tests consulting it
pays its cost once.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import all_rules
from repro.analysis.cli import resolve_rules
from repro.analysis.ir import (DEFAULT_CONST_THRESHOLD, PallasCallCapture,
                               RetraceSentinel, check_capture, check_donation,
                               count_aliased_inputs, find_const_bloat,
                               find_f64, find_host_callbacks,
                               lint_pallas_kernels)
from repro.analysis.ir.golden import golden_context

IR_RULE_IDS = ["ir-const-bloat", "ir-donation", "ir-dtype",
               "ir-host-callback", "ir-pallas", "ir-retrace"]


# ---------------------------------------------------------------------------
# jaxpr checks: host callbacks, f64, const bloat
# ---------------------------------------------------------------------------

def test_host_callbacks_fire_on_debug_print():
    def f(x):
        jax.debug.print("x={x}", x=x)
        return x * 2
    issues = find_host_callbacks(jax.make_jaxpr(f)(jnp.zeros((4,))))
    assert issues and issues[0].category == "host-callback"
    assert "debug_callback" in issues[0].message


def test_host_callbacks_silent_on_pure_program():
    closed = jax.make_jaxpr(lambda x: jnp.tanh(x) * 2)(jnp.zeros((4,)))
    assert find_host_callbacks(closed) == []


def test_f64_fires_on_closed_over_f64_table():
    table = np.linspace(0.0, 1.0, 8)          # float64 numpy — the exact
    closed = jax.make_jaxpr(                  # schedule-table bug class
        lambda x: x * table)(jnp.ones((8,), jnp.float32))
    issues = find_f64(closed)
    assert any("float64" in i.message and i.category == "dtype"
               for i in issues)


def test_f64_fires_on_weak_typed_output():
    # a program output built purely from python scalars stays weak-typed
    # and re-promotes whatever downstream program consumes it
    closed = jax.make_jaxpr(
        lambda x: jnp.sin(jnp.asarray(2.0)))(jnp.ones((4,), jnp.float32))
    issues = find_f64(closed)
    assert any("weak-typed" in i.message for i in issues)


def test_f64_silent_on_f32_program():
    table = np.linspace(0.0, 1.0, 8).astype(np.float32)
    closed = jax.make_jaxpr(lambda x: x * table)(jnp.ones((8,), jnp.float32))
    assert find_f64(closed) == []


def test_const_bloat_fires_undeclared_and_respects_declaration():
    big = np.zeros((200, 200), np.float32)    # 160 KB > 64 KiB threshold
    closed = jax.make_jaxpr(
        lambda x: x + jnp.asarray(big))(jnp.zeros((200, 200), jnp.float32))
    fired = find_const_bloat(closed)
    assert len(fired) == 1 and fired[0].category == "const-bloat"
    # the same const declared as a model param leaf is budgeted, not bloat
    assert find_const_bloat(closed, [((200, 200), "float32")]) == []
    # a higher threshold also silences it
    assert find_const_bloat(closed, threshold_bytes=1 << 20) == []
    assert 200 * 200 * 4 > DEFAULT_CONST_THRESHOLD


# ---------------------------------------------------------------------------
# donation aliasing (lowered-HLO ground truth)
# ---------------------------------------------------------------------------

def test_donation_aliases_on_matching_shapes():
    f = jax.jit(lambda x: x + 1.0, donate_argnums=(0,))
    text = f.lower(jnp.zeros((8,), jnp.float32)).as_text()
    assert count_aliased_inputs(text) == 1
    assert check_donation(text, 1) is None


@pytest.mark.filterwarnings("ignore:Some donated buffers were not usable")
def test_donation_fires_on_silent_noop():
    # donated (8,) input, only a scalar output: nothing can alias, the
    # donation silently no-ops — exactly what the check must surface
    f = jax.jit(lambda x: x.sum(), donate_argnums=(0,))
    text = f.lower(jnp.zeros((8,), jnp.float32)).as_text()
    issue = check_donation(text, 1, "scalar-reduce step")
    assert issue is not None and issue.category == "donation"
    assert "scalar-reduce step" in issue.message
    # zero claimed leaves is vacuously fine
    assert check_donation(text, 0) is None


# ---------------------------------------------------------------------------
# retrace sentinel
# ---------------------------------------------------------------------------

def test_sentinel_selftest_detects_a_known_compile():
    assert RetraceSentinel().selftest()


def test_sentinel_zero_on_cache_hit_and_fires_on_retrace():
    fn = jax.jit(lambda x: (x * 2.0).sum())
    a, b = jnp.zeros((4,)), jnp.zeros((5,))
    fn(a)                                  # compile outside any sentinel
    with RetraceSentinel() as s:
        fn(a)                              # cache hit — steady state
    assert s.ok and s.count == 0 and s.compiled_names == []
    with RetraceSentinel() as s:
        fn(b)                              # new shape — deliberate retrace
    assert not s.ok and s.count >= 1


def test_sentinel_nesting_counts_in_both_scopes():
    fn = jax.jit(lambda x: x - 3.0)
    x = jnp.zeros((2, 3))
    with RetraceSentinel() as outer:
        with RetraceSentinel() as inner:
            fn(x)
    assert inner.count >= 1 and outer.count >= 1


# ---------------------------------------------------------------------------
# pallas lints
# ---------------------------------------------------------------------------

def test_repo_kernels_lint_clean():
    assert lint_pallas_kernels() == []


def test_pallas_capture_fires_on_bad_blockspec():
    from jax.experimental import pallas as pl
    cap = PallasCallCapture(
        kernel_name="bad_kernel", grid=(4,),
        in_specs=[pl.BlockSpec((48,), lambda i: (i,))],   # 48 ∤ 100
        out_specs=pl.BlockSpec((48,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((100,), jnp.float32),
        operands=(jax.ShapeDtypeStruct((100,), jnp.float32),))
    issues = check_capture(cap)
    assert any("does not divide" in i.message for i in issues)


def test_pallas_capture_fires_on_index_map_arity():
    from jax.experimental import pallas as pl
    cap = PallasCallCapture(
        kernel_name="bad_arity", grid=(2, 2),
        in_specs=[pl.BlockSpec((4, 4), lambda i: (i, 0))],  # 1 arg, 2 dims
        out_specs=pl.BlockSpec((4, 4), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
        operands=(jax.ShapeDtypeStruct((8, 8), jnp.float32),))
    issues = check_capture(cap)
    assert any("index_map takes 1 args but the grid has 2" in i.message
               for i in issues)


def test_pallas_capture_fires_on_mixed_float_dtypes():
    from jax.experimental import pallas as pl
    spec = pl.BlockSpec((8,), lambda i: (i,))
    cap = PallasCallCapture(
        kernel_name="mixed", grid=(1,), in_specs=[spec, spec],
        out_specs=spec, out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
        operands=(jax.ShapeDtypeStruct((8,), jnp.float32),
                  jax.ShapeDtypeStruct((8,), jnp.bfloat16)))
    issues = check_capture(cap)
    assert any("mixed floating dtypes" in i.message for i in issues)


# ---------------------------------------------------------------------------
# schedule tables: f32 at the NoiseSchedule boundary (satellite 1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", ["linear", "cosine"])
def test_schedule_tables_are_f32_at_the_boundary(make):
    from repro.diffusion import cosine_schedule, linear_schedule
    sched = (linear_schedule if make == "linear" else cosine_schedule)(100)
    assert sched.betas.dtype == np.float32
    assert sched.alphas.dtype == np.float32
    assert sched.alpha_bars.dtype == np.float32
    assert sched.sigma(np.arange(10)).dtype == np.float32
    # the f64->f32 cast must not break the tables' structure
    ab = sched.alpha_bars
    assert np.all(np.diff(ab) < 0) and 0.0 < ab[-1] < ab[0] <= 1.0


# ---------------------------------------------------------------------------
# golden mixed-modality session (tentpole acceptance)
# ---------------------------------------------------------------------------

def test_golden_context_builds_and_serves():
    ctx = golden_context()
    assert ctx.error == "", ctx.error
    assert set(ctx.engines) == {"image", "video", "t2i"}
    assert ctx.requests_served == 8   # 3 image + 2 video + 3 t2i, finished
    # the prompted t2i requests resolved through the golden PromptCache:
    # encoder ran once per unique prompt (2 prompts + 1 negative), repeats
    # were host-side hits
    stats = ctx.engines["t2i"].conditioner.stats
    assert stats["misses"] == 3 and stats["hits"] == 2


def test_golden_session_zero_recompiles_after_warmup():
    ctx = golden_context()
    assert ctx.error == "", ctx.error
    # the sentinel proved it can see compiles BEFORE the session zero is
    # trusted — a vacuous zero from a blind sentinel must not pass here
    assert ctx.sentinel_live
    assert ctx.retrace_count == 0, (
        f"steady-state serving compiled {ctx.retrace_count} program(s): "
        f"{sorted(set(ctx.retrace_names))}")


def test_golden_programs_verify_clean():
    ctx = golden_context()
    assert ctx.error == "", ctx.error
    assert ctx.program_findings == [], [
        (f.rule, f.path, f.message) for f in ctx.program_findings]


def test_warmup_verify_attaches_ir_findings():
    ctx = golden_context()
    assert ctx.error == "", ctx.error
    for eng in ctx.engines.values():
        assert eng.ir_findings == []       # verified clean, not unverified
        assert eng.program_ir              # IR captured per program
        # each warmup profile carries its (empty) per-program findings
        for prof in eng.program_profile.values():
            assert prof.ir_findings == ()
            assert "ir_findings" not in prof.as_dict()  # empty -> omitted


# ---------------------------------------------------------------------------
# registry / CLI integration
# ---------------------------------------------------------------------------

def test_ir_rules_registered_with_metadata():
    by_id = {r.id: r for r in all_rules()}
    for rid in IR_RULE_IDS:
        assert rid in by_id, rid
        assert by_id[rid].description and by_id[rid].rationale


def test_rule_glob_resolves_ir_family():
    assert sorted(r.id for r in resolve_rules(["ir-*"])) == IR_RULE_IDS
    # explicit id + overlapping glob dedups, preserving first-seen order
    rules = resolve_rules(["ir-dtype", "ir-*"])
    assert len(rules) == len(IR_RULE_IDS) and rules[0].id == "ir-dtype"
    with pytest.raises(KeyError):
        resolve_rules(["zz-*"])
