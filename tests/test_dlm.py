"""Diffusion language model (survey §IV-F / dLLM-Cache application)."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import make_policy
from repro.diffusion.dlm import dlm_generate
from repro.models import init_params


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("tinyllama-1.1b").reduced(num_layers=2,
                                                     d_model=64)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_dlm_fills_all_masks(model):
    cfg, params = model
    out, n = dlm_generate(params, cfg, batch=2, seq_len=16, num_steps=6)
    assert n == 6
    assert int(np.max(out)) < cfg.vocab_size - 1, "mask tokens remain"
    assert out.shape == (2, 16)


def test_dlm_deterministic(model):
    cfg, params = model
    a, _ = dlm_generate(params, cfg, batch=1, seq_len=12, num_steps=4)
    b, _ = dlm_generate(params, cfg, batch=1, seq_len=12, num_steps=4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dlm_cache_reduces_computes(model):
    cfg, params = model
    _, n_exact = dlm_generate(params, cfg, batch=1, seq_len=12, num_steps=8)
    pol = make_policy("fora", interval=2)
    out, n_cached = dlm_generate(params, cfg, batch=1, seq_len=12,
                                 num_steps=8, policy=pol)
    assert n_exact == 8 and n_cached == 4
    assert int(np.max(out)) < cfg.vocab_size - 1
