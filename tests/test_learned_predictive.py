"""Learned (LazyDiT gate) and predictive (forecast-basis) policies: gate
training convergence, registry round-trips, forecast basis shapes/masking,
and want_compute mirroring apply — the invariants the serving engine's
fused want pass and the control plane's learned predictor lean on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_policy
from repro.core.learned import (LazyDiTPolicy, gate_score, init_gate,
                                lazy_trajectory_loss, train_lazy_gate)
from repro.core.predictive import (BASES, PredictivePolicy,
                                   forecast_from_diffs, update_diff_stack)

FEAT = 6


# ----------------------------------------------------------------------
# gate training (learned want_compute)
# ----------------------------------------------------------------------

def _trajectory(key, T=10, tokens=4, drift=1.0):
    """Synthetic module trajectory whose outputs drift by `drift` per step
    (drift=0 -> perfectly cacheable)."""
    k1, k2 = jax.random.split(key)
    x0 = jax.random.normal(k1, (tokens, FEAT))
    steps = drift * jax.random.normal(k2, (T, tokens, FEAT))
    inputs = x0[None] + jnp.cumsum(steps, axis=0)
    outputs = 2.0 * inputs + 1.0
    return inputs, outputs


def test_lazy_gate_training_converges():
    inputs, outputs = _trajectory(jax.random.PRNGKey(0))
    gate, hist = train_lazy_gate(jax.random.PRNGKey(1), inputs, outputs,
                                 steps=120)
    assert len(hist) == 120
    assert np.isfinite(hist).all()
    assert hist[-1] < hist[0]


def test_lazy_loss_rewards_skipping_static_trajectories():
    """On a drift-free trajectory every step is cacheable: the soft-skip
    reward dominates, so a high-scoring gate beats a low-scoring one."""
    inputs, outputs = _trajectory(jax.random.PRNGKey(2), drift=0.0)
    skippy = {"w": jnp.zeros((FEAT,)), "b": jnp.full((), 8.0)}   # s ~= 1
    eager = {"w": jnp.zeros((FEAT,)), "b": jnp.full((), -8.0)}   # s ~= 0
    l_skip = float(lazy_trajectory_loss(skippy, inputs, outputs))
    l_eager = float(lazy_trajectory_loss(eager, inputs, outputs))
    assert l_skip < l_eager


def test_lazydit_want_mirrors_apply():
    """want_compute must predict exactly the branch apply takes — that is
    the contract the row-compacted serving planner relies on."""
    gate = init_gate(jax.random.PRNGKey(3), FEAT)
    pol = LazyDiTPolicy(gate, threshold=0.5)
    state = pol.init_state((4, FEAT))
    # lax.cond traces both branches, so compute-vs-reuse is observed via
    # the policy's own n_compute counter, not a Python call count
    for step in range(6):
        x = jax.random.normal(jax.random.PRNGKey(10 + step), (4, FEAT))
        before = int(state["n_compute"])
        want = bool(pol.want_compute(state, step, x))
        y, state = pol.apply(state, step, x, lambda v: 2.0 * v)
        assert (int(state["n_compute"]) - before == 1) == want
        if want:
            np.testing.assert_allclose(np.asarray(y), np.asarray(2.0 * x),
                                       rtol=1e-6)
    assert bool(pol.want_compute(pol.init_state((4, FEAT)), 0, x))  # first step


def test_lazydit_want_metric_is_gate_score():
    gate = init_gate(jax.random.PRNGKey(4), FEAT)
    pol = LazyDiTPolicy(gate, threshold=0.5)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, FEAT))
    m = pol.want_metric(pol.init_state((4, FEAT)), 0, x)
    assert m.shape == () and m.dtype == jnp.float32
    np.testing.assert_allclose(float(m), float(gate_score(gate, x)),
                               rtol=1e-6)


def test_lazydit_registry_requires_gate():
    with pytest.raises(ValueError, match="gate"):
        make_policy("lazydit")
    gate = init_gate(jax.random.PRNGKey(6), FEAT)
    pol = make_policy("lazydit", gate=gate, threshold=0.25)
    assert isinstance(pol, LazyDiTPolicy)
    assert pol.threshold == 0.25
    assert pol.gate is gate


# ----------------------------------------------------------------------
# predictive forecasting (TaylorSeer family)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("basis", BASES)
def test_forecast_shapes_and_finiteness(basis):
    diffs = jnp.zeros((3, 4, FEAT))
    for y in [jnp.ones((4, FEAT)), 2.0 * jnp.ones((4, FEAT)),
              4.0 * jnp.ones((4, FEAT))]:
        diffs = update_diff_stack(diffs, y)
    out = forecast_from_diffs(diffs, 0.5, 3, basis=basis)
    assert out.shape == (4, FEAT)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("basis", [b for b in BASES if b != "foca"])
def test_forecast_masks_unobserved_orders(basis):
    """With one observed compute, every basis must degrade to plain reuse
    (higher-order terms are built from differences that don't exist yet)."""
    diffs = update_diff_stack(jnp.zeros((3, 4, FEAT)),
                              5.0 * jnp.ones((4, FEAT)))
    out = forecast_from_diffs(diffs, 2.0, 1, basis=basis)
    np.testing.assert_allclose(np.asarray(out), 5.0, rtol=1e-6)


def test_foca_falls_back_to_reuse_below_two_computes():
    diffs = update_diff_stack(jnp.zeros((3, 4, FEAT)),
                              3.0 * jnp.ones((4, FEAT)))
    out = forecast_from_diffs(diffs, 1.0, 1, basis="foca")
    np.testing.assert_allclose(np.asarray(out), 3.0, rtol=1e-6)


def test_taylor_forecast_extrapolates_linear_sequence():
    """A linear sequence's first difference is constant: a first-order
    Taylor step must extrapolate it exactly."""
    diffs = jnp.zeros((2, 1, 1))
    for v in (1.0, 2.0, 3.0):
        diffs = update_diff_stack(diffs, jnp.full((1, 1), v))
    out = forecast_from_diffs(diffs, 2.0, 3, basis="taylor")
    np.testing.assert_allclose(np.asarray(out), 5.0, rtol=1e-5)


@pytest.mark.parametrize("name,basis", [
    ("taylorseer", "taylor"), ("newtonseer", "newton"),
    ("hicache", "hermite"), ("abcache", "ab"), ("foca", "foca")])
def test_predictive_registry_round_trip(name, basis):
    pol = make_policy(name, interval=3)
    assert isinstance(pol, PredictivePolicy)
    assert pol.basis == basis
    assert pol.interval == 3
    assert pol.name == name
    # int-step want_compute mirrors the static schedule — what lets the
    # serving engine host these policies on the zero-sync static plan
    sched = pol.static_schedule(7)
    assert sched == [s % 3 == 0 for s in range(7)]
    state = pol.init_state((2, FEAT))
    for s in range(7):
        assert bool(pol.want_compute(state, s, None)) == sched[s]


def test_predictive_want_mirrors_apply():
    pol = PredictivePolicy(interval=2, order=2, basis="taylor")
    state = pol.init_state((2, FEAT))
    for step in range(6):
        x = jnp.ones((2, FEAT)) * (step + 1)
        before = int(state["n_valid"])
        want = bool(pol.want_compute(state, step, x))
        _, state = pol.apply(state, step, x, lambda v: v * 1.5)
        assert (int(state["n_valid"]) - before == 1) == want == (step % 2 == 0)
