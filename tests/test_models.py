"""Per-architecture smoke tests: reduced configs, one forward + prefill +
decode step on CPU; output shapes + no NaNs.  Also the exactness properties
(cross-KV cache, GQA==MHA, scan chunking invariance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          param_count, prefill)
from repro.models import encdec, ssm

B, S = 2, 16


def _inputs(cfg, key):
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    extras = {}
    if cfg.family == "vlm":
        extras["vision_embeds"] = jax.random.normal(
            ks[1], (B, cfg.num_vision_tokens, cfg.vision_dim))
    if cfg.is_encoder_decoder:
        extras["frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder_seq, cfg.d_model))
    return tokens, extras


DECODER_ARCHS = [a for a in ARCH_IDS if a != "whisper-small"]


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_smoke_forward_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, jnp.float32)
    tokens, extras = _inputs(cfg, key)

    # ---- train-style forward ----
    logits, aux = forward(params, tokens, cfg,
                          vision_embeds=extras.get("vision_embeds"))
    T = S + (cfg.num_vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"NaNs in {arch} forward"
    if cfg.is_moe:
        assert float(aux["load_balance_loss"]) > 0.0

    # ---- prefill + one decode step ----
    cache_len = 32
    logits_p, _, cache = prefill(params, tokens, cfg, cache_len,
                                 vision_embeds=extras.get("vision_embeds"))
    new_tok = tokens[:, -1]
    pos = jnp.full((B,), T, jnp.int32)
    logits_d, cache = decode_step(params, new_tok, pos, cache, cfg)
    assert logits_d.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits_d))), f"NaNs in {arch} decode"


def test_smoke_whisper():
    cfg = get_smoke_config("whisper-small")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, jnp.float32)
    tokens, extras = _inputs(cfg, key)
    frames = extras["frames"]
    logits = encdec.forward(params, frames, tokens, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # decode against exact cross-KV cache
    enc_out = encdec.encode(params, frames, cfg)
    xk, xv = encdec.cross_kv(params, enc_out, cfg)
    cache = encdec.init_dec_cache(cfg, B, 32, cfg.encoder_seq, jnp.float32)
    cache["xk"], cache["xv"] = xk, xv
    pos = jnp.zeros((B,), jnp.int32)
    logits_d, cache = encdec.decode_step(params, tokens[:, 0], pos, cache, cfg)
    assert logits_d.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits_d)))


def test_cross_kv_cache_is_exact():
    """Survey §I-C: cross-attention K/V under fixed conditioning are constant
    across steps — caching them is EXACT (bit-identical recompute)."""
    cfg = get_smoke_config("whisper-small")
    params = init_params(jax.random.PRNGKey(1), cfg, jnp.float32)
    frames = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.encoder_seq, cfg.d_model))
    enc_out = encdec.encode(params, frames, cfg)
    kv1 = encdec.cross_kv(params, enc_out, cfg)
    kv2 = encdec.cross_kv(params, enc_out, cfg)
    for a, b in zip(jax.tree_util.tree_leaves(kv1), jax.tree_util.tree_leaves(kv2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_matches_forward_dense():
    """Autoregressive decode must reproduce the full-sequence forward
    logits position by position (KV-cache correctness)."""
    cfg = get_smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(3), cfg, jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, 8), 0, cfg.vocab_size)
    full_logits, _ = forward(params, tokens, cfg)

    # prefill the first 4 tokens, then decode the rest one by one
    n0 = 4
    _, _, cache = prefill(params, tokens[:, :n0], cfg, cache_len=16)
    for i in range(n0, 8):
        pos = jnp.full((B,), i, jnp.int32)
        logits_d, cache = decode_step(params, tokens[:, i], pos, cache, cfg)
        np.testing.assert_allclose(np.asarray(logits_d),
                                   np.asarray(full_logits[:, i]),
                                   rtol=2e-4, atol=2e-4)


def test_decode_matches_forward_mla():
    cfg = get_smoke_config("deepseek-v2-236b")
    params = init_params(jax.random.PRNGKey(5), cfg, jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(6), (B, 8), 0, cfg.vocab_size)
    full_logits, _ = forward(params, tokens, cfg)
    _, _, cache = prefill(params, tokens[:, :4], cfg, cache_len=16)
    for i in range(4, 8):
        pos = jnp.full((B,), i, jnp.int32)
        logits_d, cache = decode_step(params, tokens[:, i], pos, cache, cfg)
        np.testing.assert_allclose(np.asarray(logits_d),
                                   np.asarray(full_logits[:, i]),
                                   rtol=5e-3, atol=5e-3)


def test_decode_matches_forward_ssm():
    cfg = get_smoke_config("falcon-mamba-7b")
    params = init_params(jax.random.PRNGKey(7), cfg, jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(8), (B, 8), 0, cfg.vocab_size)
    full_logits, _ = forward(params, tokens, cfg)
    _, _, cache = prefill(params, tokens[:, :4], cfg, cache_len=16)
    for i in range(4, 8):
        pos = jnp.full((B,), i, jnp.int32)
        logits_d, cache = decode_step(params, tokens[:, i], pos, cache, cfg)
        np.testing.assert_allclose(np.asarray(logits_d),
                                   np.asarray(full_logits[:, i]),
                                   rtol=2e-3, atol=2e-3)


def test_mamba_chunked_scan_invariance():
    """Chunk size must not change the result (associativity property)."""
    cfg = get_smoke_config("falcon-mamba-7b")
    p = ssm.init_mamba1(jax.random.PRNGKey(9), cfg)
    u = jax.random.normal(jax.random.PRNGKey(10), (2, 16, cfg.d_model)) * 0.3
    y4, c4 = ssm.mamba1_forward(p, u, cfg, chunk=4)
    y16, c16 = ssm.mamba1_forward(p, u, cfg, chunk=16)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y16), rtol=2e-4,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(c4["state"]), np.asarray(c16["state"]),
                               rtol=2e-4, atol=2e-5)


def test_ssd_chunked_matches_sequential():
    """Mamba2 SSD chunked algorithm vs direct sequential recurrence."""
    b, s, h, p, n = 2, 12, 3, 4, 8
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B_ = jax.random.normal(ks[3], (b, s, n))
    C_ = jax.random.normal(jax.random.PRNGKey(12), (b, s, n))

    y_chunk, h_fin = ssm.ssd_chunked(x, dt, A, B_, C_, chunk=4)

    # sequential oracle
    hstate = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        dA = jnp.exp(dt[:, t] * A)                       # (b,h)
        hstate = hstate * dA[..., None, None] + \
            dt[:, t][..., None, None] * x[:, t][..., None] * B_[:, t][:, None, None, :]
        ys.append(jnp.einsum("bhpn,bn->bhp", hstate, C_[:, t]))
    y_seq = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_fin), np.asarray(hstate),
                               rtol=1e-4, atol=1e-4)


def test_gqa_equals_mha_when_kv_equals_heads():
    from repro.models.layers import blocked_attention
    key = jax.random.PRNGKey(13)
    q = jax.random.normal(key, (2, 8, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(14), (2, 8, 4, 16))
    v = jax.random.normal(jax.random.PRNGKey(15), (2, 8, 4, 16))
    full = blocked_attention(q, k, v, causal=True)
    # naive reference
    import math
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(16)
    mask = jnp.tril(jnp.ones((8, 8), bool))
    s = jnp.where(mask, s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(full), np.asarray(ref), rtol=1e-4,
                               atol=1e-5)


def test_sliding_window_masks_old_tokens():
    from repro.models.layers import blocked_attention
    key = jax.random.PRNGKey(16)
    q = jax.random.normal(key, (1, 8, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(17), (1, 8, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(18), (1, 8, 2, 8))
    out_w = blocked_attention(q, k, v, causal=True, window=2)
    # manual: position i attends to {i-1, i}
    import math
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(8)
    i = jnp.arange(8)
    ok = (i[None, :] <= i[:, None]) & (i[:, None] - i[None, :] < 2)
    s = jnp.where(ok, s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out_w), np.asarray(ref), rtol=1e-4,
                               atol=1e-5)


def test_param_count_smoke():
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        assert param_count(cfg) > 0
