"""Unit tests for the rule-based sharding system (repro.sharding)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding as shd


class FakeLeaf:
    def __init__(self, shape):
        self.shape = tuple(shape)
        self.ndim = len(shape)
        self.size = int(np.prod(shape))


@pytest.fixture(scope="module")
def mesh():
    # 1-device meshes preserve the axis names; rules only read names/sizes
    dev = np.array(jax.devices()[:1]).reshape(1, 1, 1)
    return jax.sharding.Mesh(dev, ("data", "attn", "ffn"))


@pytest.mark.parametrize("path,shape,expect", [
    ("embed", (32000, 2048), P(("attn", "ffn"), None)),
    ("lm_head", (2048, 32000), P(None, ("attn", "ffn"))),
    ("blocks/attn/wq", (28, 3584, 3584), P(None, None, "attn")),
    ("blocks/attn/wo", (28, 3584, 3584), P(None, "attn", None)),
    ("blocks/mlp/w_up", (28, 3584, 18944), P(None, None, ("attn", "ffn"))),
    ("blocks/mlp/w_down", (28, 18944, 3584), P(None, ("attn", "ffn"), None)),
    ("blocks/moe/w_gate", (60, 160, 5120, 1536),
     P(None, "data", None, ("attn", "ffn"))),
    ("blocks/moe/w_down", (60, 160, 1536, 5120),
     P(None, "data", ("attn", "ffn"), None)),
    ("blocks/moe/router", (60, 5120, 160), P(None, None, None)),
    ("blocks/attn/w_uk", (60, 128, 512, 128), P(None, "attn", None, None)),
    ("blocks/mamba/in_proj", (64, 4096, 16448),
     P(None, None, ("attn", "ffn"))),
    ("blocks/ln1", (28, 3584), P(None, None)),
    ("final_norm", (3584,), P(None)),
])
def test_param_spec_rules(mesh, path, shape, expect):
    got = shd.param_spec(path, FakeLeaf(shape), mesh)
    assert tuple(got) == tuple(expect), (path, got, expect)


def test_sanitize_drops_nondivisible(mesh16=None):
    dev = np.array(jax.devices()[:1]).reshape(1, 1, 1)
    mesh = jax.sharding.Mesh(dev, ("data", "attn", "ffn"))
    # fake sizes via a 16x4x4 abstract view is not possible on 1 device;
    # use _fit directly with a mesh dict stub
    class M:
        axis_names = ("data", "attn", "ffn")
        shape = {"data": 16, "attn": 4, "ffn": 4}
    assert shd._fit(M, ("attn", "ffn"), 51865) is None   # whisper vocab
    assert shd._fit(M, ("attn", "ffn"), 51872) == ("attn", "ffn")
    assert shd._fit(M, "attn", 6) is None
    assert shd._fit(M, ("data",), 32) == ("data",)


def test_add_fsdp_respects_existing_data_axis():
    class M:
        axis_names = ("data", "attn", "ffn")
        shape = {"data": 16, "attn": 4, "ffn": 4}
    # already expert-sharded on data: unchanged
    spec = P("data", None, ("attn", "ffn"))
    leaf = FakeLeaf((160, 5120, 1536))
    assert shd._add_fsdp(M, spec, leaf) == spec
    # large free dim picks up data
    spec2 = P(None, ("attn", "ffn"))
    leaf2 = FakeLeaf((4096, 16384))
    got = shd._add_fsdp(M, spec2, leaf2)
    assert tuple(got) == ("data", ("attn", "ffn"))
    # small leaves untouched
    leaf3 = FakeLeaf((1024,))
    assert shd._add_fsdp(M, P(None), leaf3) == P(None)


def test_cache_spec_batch_fallback_to_sequence():
    """long_500k (B=1): batch axis must drop and the KV sequence axis must
    pick up the data axis."""
    class M:
        axis_names = ("data", "attn", "ffn")
        shape = {"data": 16, "attn": 4, "ffn": 4}
    def norm(ax):
        return (ax,) if isinstance(ax, str) else ax

    kv = FakeLeaf((28, 1, 524288, 4, 128))
    spec = shd.cache_spec("k", kv, M)
    assert spec[1] is None                       # batch replicated
    assert norm(spec[2]) == ("data",)            # sequence-parallel
    kv2 = FakeLeaf((28, 128, 32768, 4, 128))
    spec2 = shd.cache_spec("k", kv2, M)
    assert norm(spec2[1]) == ("data",)           # batch sharded
    assert spec2[2] is None
    assert spec2[3] == "attn"                    # kv heads 4 % 4 == 0
    assert spec2[4] == "ffn"                     # head_dim on ffn


def test_attn_shards_per_arch():
    from repro.configs import get_config
    from repro.launch.mesh import attn_shards
    assert attn_shards(get_config("qwen2-7b")) == 4     # KH=4
    assert attn_shards(get_config("deepseek-v2-236b")) == 16
    assert attn_shards(get_config("whisper-small")) == 4  # H=12 -> 4
