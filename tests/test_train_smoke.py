"""Per-architecture train-step smoke: one optimizer step on the reduced
config, asserting finite loss and updated params (brief §f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.data import frame_embeddings, lm_batches, patch_embeddings
from repro.diffusion import linear_schedule
from repro.models import encdec
from repro.optim import adamw_init, adamw_update
from repro.train.steps import (init_train_state, make_diffusion_train_step,
                               make_lm_train_step)

B, S = 2, 16


def _one_lm_step(cfg, batch_extra=None):
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = make_lm_train_step(cfg, total_steps=10, warmup=0)
    t, y = next(lm_batches(0, B, S, cfg.vocab_size))
    batch = {"tokens": jnp.asarray(t), "targets": jnp.asarray(y)}
    if batch_extra:
        batch.update(batch_extra)
    new_state, metrics = jax.jit(step)(state, batch)
    return state, new_state, metrics


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a != "whisper-small"])
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    extra = None
    if cfg.family == "vlm":
        extra = {"vision_embeds": jnp.asarray(
            patch_embeddings(0, B, cfg.num_vision_tokens, cfg.vision_dim))}
    old, new, metrics = _one_lm_step(cfg, extra)
    assert np.isfinite(float(metrics["loss"])), arch
    # params must actually move
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(old.params),
                        jax.tree_util.tree_leaves(new.params)))
    assert moved, arch


def test_train_step_smoke_whisper():
    cfg = get_smoke_config("whisper-small")
    params = encdec.init_encdec(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    frames = jnp.asarray(frame_embeddings(0, B, cfg.encoder_seq, cfg.d_model))
    t, y = next(lm_batches(0, B, S, cfg.vocab_size))

    def loss_fn(p):
        logits = encdec.forward(p, frames, jnp.asarray(t), cfg)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, jnp.asarray(y)[..., None], -1)
        return nll.mean()

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    new_params, _ = adamw_update(grads, opt, params, lr=1e-3)
    assert np.isfinite(float(loss))
    assert not np.allclose(
        np.asarray(params["lm_head"], np.float32),
        np.asarray(new_params["lm_head"], np.float32))


def test_train_step_smoke_dit():
    cfg = get_smoke_config("dit-xl")
    sched = linear_schedule(50)
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = make_diffusion_train_step(cfg, sched, total_steps=5)
    key = jax.random.PRNGKey(1)
    batch = {
        "latents": jax.random.normal(
            key, (B, cfg.dit_patch_tokens, cfg.dit_in_dim)),
        "labels": jnp.zeros((B,), jnp.int32),
        "key": key,
    }
    _, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
