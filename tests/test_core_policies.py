"""Unit tests for the cache policy library (survey taxonomy)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ClusCaPolicy, DeltaCachePolicy, EasyCachePolicy,
                        FixedIntervalPolicy, FreqCaPolicy, MagCachePolicy,
                        NoCachePolicy, PredictivePolicy, SpeCaPolicy,
                        TeaCachePolicy, BlockCachePolicy, ForesightPolicy,
                        CachedStack, DBCacheStack, cache_state_bytes,
                        compute_fraction, make_policy, POLICY_REGISTRY)

SHAPE = (8, 16)


def run_policy(policy, fn, xs, dynamic=False, **sig_fn):
    """Drive a policy over a trajectory xs; returns outputs and # computes."""
    state = policy.init_state(SHAPE)
    n_computes = [0]

    def wrapped(x):
        n_computes[0] += 1
        return fn(x)

    outs = []
    for step, x in enumerate(xs):
        s = jnp.asarray(step) if dynamic else step
        y, state = policy.apply(state, s, x, wrapped)
        outs.append(y)
    return outs, n_computes[0], state


def make_traj(T=12, seed=0):
    key = jax.random.PRNGKey(seed)
    base = jax.random.normal(key, SHAPE)
    drift = jax.random.normal(jax.random.PRNGKey(seed + 1), SHAPE) * 0.01
    return [base + t * drift for t in range(T)]


def test_nocache_always_computes():
    xs = make_traj()
    outs, n, _ = run_policy(NoCachePolicy(), lambda x: x * 2, xs)
    assert n == len(xs)
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(y, x * 2, rtol=1e-6)


def test_fixed_interval_schedule_and_reuse():
    xs = make_traj(T=8)
    pol = FixedIntervalPolicy(4)
    outs, n, _ = run_policy(pol, lambda x: x * 3, xs)
    assert n == 2  # steps 0 and 4
    np.testing.assert_allclose(outs[1], outs[0], rtol=1e-6)  # verbatim reuse
    np.testing.assert_allclose(outs[4], xs[4] * 3, rtol=1e-6)
    assert pol.static_schedule(8) == [True, False, False, False] * 2
    assert compute_fraction(pol.static_schedule(8)) == 0.25


def test_delta_cache_tracks_input():
    """Δ-DiT: reuse incorporates the fresh input x' + Δ (Eq. residual)."""
    xs = make_traj(T=4)
    pol = DeltaCachePolicy(4)
    outs, n, _ = run_policy(pol, lambda x: x + 1.0, xs)
    assert n == 1
    # for f(x)=x+1, delta = 1 exactly -> reuse is EXACT even as x drifts
    for x, y in zip(xs, outs):
        np.testing.assert_allclose(y, x + 1.0, rtol=1e-5)


@pytest.mark.parametrize("basis,deg", [("newton", 1), ("newton", 2)])
def test_newton_forecast_exact_on_polynomials(basis, deg):
    """Newton finite-difference forecasting is exact for polynomial
    trajectories of degree <= order sampled on the compute grid."""
    T, N = 13, 4
    t = np.arange(T, dtype=np.float32)
    coef = np.random.RandomState(0).randn(deg + 1)
    vals = sum(c * t**i for i, c in enumerate(coef))  # (T,)
    xs = [jnp.full(SHAPE, float(v)) for v in vals]
    pol = PredictivePolicy(N, order=2, basis=basis)
    # identity module: output == input trajectory value
    outs, n, _ = run_policy(pol, lambda x: x, xs)
    assert n == (T + N - 1) // N  # computes at 0, 4, 8, 12
    # after warm-up (2 computes for deg 1, 3 for deg 2), forecasts are exact
    warm = (deg + 1 - 1) * N + 1
    for s in range(warm, T):
        np.testing.assert_allclose(np.asarray(outs[s]), np.full(SHAPE, vals[s]),
                                   rtol=1e-4, atol=1e-4)


def test_taylor_beats_reuse_on_linear_drift():
    xs = make_traj(T=12)
    f = lambda x: x * 1.5
    ref = [f(x) for x in xs]
    _, _, _ = run_policy(FixedIntervalPolicy(4), f, xs)
    outs_reuse, _, _ = run_policy(FixedIntervalPolicy(4), f, xs)
    outs_taylor, _, _ = run_policy(PredictivePolicy(4, 2, "taylor"), f, xs)
    err_reuse = sum(float(jnp.mean((a - b) ** 2)) for a, b in zip(outs_reuse, ref))
    err_taylor = sum(float(jnp.mean((a - b) ** 2)) for a, b in zip(outs_taylor, ref))
    assert err_taylor < err_reuse


def test_hermite_contraction_bounded():
    """HiCache: contracted Hermite forecasts stay bounded where raw
    high-order extrapolation may overshoot."""
    xs = make_traj(T=12)
    pol = PredictivePolicy(4, order=3, basis="hermite", sigma=0.3)
    outs, _, _ = run_policy(pol, lambda x: x, xs)
    for y in outs:
        assert bool(jnp.all(jnp.isfinite(y)))


def test_teacache_accumulates_and_refreshes():
    # NB: dynamic policies run under lax.cond, so the number of *executed*
    # computes is read from the state counter, not a Python-side counter.
    pol = TeaCachePolicy(delta=0.05)
    const = [jnp.ones(SHAPE)] * 6
    _, _, st = run_policy(pol, lambda x: x * 2, const, dynamic=True)
    assert int(st["n_compute"]) == 1
    jumpy = make_traj(T=6, seed=3)
    jumpy = [x * (1 + 0.5 * t) for t, x in enumerate(jumpy)]
    _, _, st2 = run_policy(TeaCachePolicy(delta=0.05), lambda x: x * 2,
                           jumpy, dynamic=True)
    assert int(st2["n_compute"]) > 1


def test_magcache_threshold_controls_refresh_rate():
    xs = make_traj(T=20)
    _, _, st_tight = run_policy(MagCachePolicy(0.02, num_steps=20),
                                lambda x: x, xs, dynamic=True)
    _, _, st_loose = run_policy(MagCachePolicy(0.5, num_steps=20),
                                lambda x: x, xs, dynamic=True)
    assert int(st_tight["n_compute"]) > int(st_loose["n_compute"])


def test_easycache_linear_trajectory_accepts():
    # perfectly linear module on linear inputs -> Delta reuse is exact,
    # so only warmup computes happen for a generous tau
    xs = make_traj(T=10)
    pol = EasyCachePolicy(tau=50.0, warmup=2)
    outs, _, st = run_policy(pol, lambda x: x + 0.5, xs, dynamic=True)
    assert int(st["n_compute"]) <= 4
    ref = [x + 0.5 for x in xs]
    for a, b in zip(outs[2:], ref[2:]):
        np.testing.assert_allclose(a, b, atol=1e-3)


def test_blockcache_schedule_from_profile():
    profile = [0.0, 0.01, 0.01, 0.5, 0.01, 0.01, 0.6, 0.01]
    pol = BlockCachePolicy(profile, delta=0.1)
    sched = pol.static_schedule(8)
    assert sched[0] is True
    assert sched[3] is True and sched[6] is True
    assert sched[1] is False and sched[2] is False
    xs = make_traj(T=8)
    outs, n, _ = run_policy(pol, lambda x: x, xs)
    assert n == sum(sched)


def test_blockcache_overflow_recomputes():
    """Regression: steps past the calibration profile must recompute, not
    clamp to the profile's last scheduled decision (out-of-range gather)."""
    profile = [0.0, 0.01, 0.5, 0.01]            # 4-step calibration
    pol = BlockCachePolicy(profile, delta=0.1)

    # static_schedule no longer asserts; the overflow tail is all-compute
    sched = pol.static_schedule(8)
    assert len(sched) == 8 and all(sched[4:])
    assert sched[:4] == pol.static_schedule(4)

    # concrete-step path (engine static-plan probe): no IndexError
    assert bool(pol.want_compute(None, 6, None)) is True
    y, _ = pol.apply(pol.init_state(SHAPE), 6, jnp.ones(SHAPE),
                     lambda x: x * 3.0)
    np.testing.assert_allclose(np.asarray(y), 3.0)

    # traced-step path (serving device plan): profile[3] is a reuse step but
    # step 6 is past the profile, so the gather must not clamp to it
    state = pol.init_state(SHAPE)
    assert bool(pol.want_compute(state, jnp.asarray(3), jnp.ones(SHAPE))) \
        is False
    assert bool(pol.want_compute(state, jnp.asarray(6), jnp.ones(SHAPE))) \
        is True
    # and apply's traced branch actually computes on overflow steps: with an
    # identity module, a computed step returns its own input, a (wrongly)
    # reused step returns the stale cache
    xs = make_traj(T=8)
    outs, _, _ = run_policy(pol, lambda x: x, xs, dynamic=True)
    for t in (4, 5, 6, 7):
        np.testing.assert_allclose(np.asarray(outs[t]), np.asarray(xs[t]),
                                   atol=1e-6)


def test_foresight_warmup_then_gates():
    xs = [jnp.ones(SHAPE)] * 8  # static input -> after warmup, reuse
    pol = ForesightPolicy(gamma=1.0, warmup=3)
    _, _, st = run_policy(pol, lambda x: x * 2, xs, dynamic=True)
    assert int(st["n_compute"]) == 3


def test_freqca_exact_on_static_features():
    xs = [jnp.ones(SHAPE)] * 8
    pol = FreqCaPolicy(4, cutoff=0.25)
    outs, n, _ = run_policy(pol, lambda x: x * 2 + 1, xs)
    assert n == 2
    for y in outs:
        np.testing.assert_allclose(np.asarray(y), np.full(SHAPE, 3.0), atol=1e-4)


def test_clusca_partial_compute():
    pol = ClusCaPolicy(interval=2, k=4, gamma=1.0)
    f = lambda x: x * 2.0
    state = pol.init_state(SHAPE)
    x0 = jax.random.normal(jax.random.PRNGKey(0), SHAPE)
    y0, state = pol.apply(state, 0, x0, f, subset_fn=f)
    np.testing.assert_allclose(y0, x0 * 2, rtol=1e-5)
    x1 = x0 + 0.01
    y1, state = pol.apply(state, 1, x1, f, subset_fn=f)
    # representative tokens are exact
    reps = np.asarray(state["reps"])
    np.testing.assert_allclose(np.asarray(y1)[reps], np.asarray(x1 * 2)[reps],
                               rtol=1e-4)


def test_kmeans_k_exceeding_tokens_is_clamped():
    """Regression: k > T made the init stride zero — every centroid seeded
    from token 0, collapsing the clustering.  k is clamped to T."""
    from repro.core import kmeans
    tokens = jax.random.normal(jax.random.PRNGKey(0), (4, 6))
    assign, cent, reps = kmeans(tokens, k=16)
    assert cent.shape == (4, 6) and reps.shape == (4,) and assign.shape == (4,)
    # distinct tokens -> distinct centroids (the degenerate init produced
    # one real centroid and zero-vectors for the rest)
    assert len({int(a) for a in np.asarray(assign)}) > 1
    assert float(jnp.abs(cent - cent[0]).max()) > 1e-3


def test_clusca_k_exceeding_tokens():
    """ClusCaPolicy with k > token count serves every token exactly (each
    token becomes its own cluster representative)."""
    T = 6
    pol = ClusCaPolicy(interval=2, k=64, gamma=1.0)
    shape = (T, 8)
    state = pol.init_state(shape)
    assert state["reps"].shape == (T,)
    f = lambda x: x * 2.0
    x0 = jax.random.normal(jax.random.PRNGKey(0), shape)
    y0, state = pol.apply(state, 0, x0, f, subset_fn=f)
    np.testing.assert_allclose(y0, x0 * 2, rtol=1e-5)
    x1 = x0 + 0.01
    y1, state = pol.apply(state, 1, x1, f, subset_fn=f)
    assert np.isfinite(np.asarray(y1)).all()
    # with k >= T every token is a representative -> partial step is exact
    np.testing.assert_allclose(np.asarray(y1), np.asarray(x1 * 2), rtol=1e-4)


def test_speca_accepts_good_and_rejects_bad():
    f = lambda x: x  # identity: taylor forecast of linear drift is exact
    pol = SpeCaPolicy(interval=4, order=2, tau=0.05, probe=4)
    xs = make_traj(T=12)
    outs, n, state = run_policy(pol, f, xs, dynamic=True,)
    # now force rejection with a jumpy trajectory
    pol2 = SpeCaPolicy(interval=6, order=1, tau=1e-6, probe=4)
    state2 = pol2.init_state(SHAPE)
    n2 = [0]

    def g(x):
        n2[0] += 1
        return jnp.sin(x * 10)

    for step, x in enumerate(make_traj(T=12, seed=5)):
        y, state2 = pol2.apply(state2, jnp.asarray(step), x, g,
                               subset_fn=g)
    assert int(state2["rejects"]) > 0


def test_cached_stack_scan():
    L, T = 4, 6
    pol = FixedIntervalPolicy(3)
    block = lambda p, x: x * p["w"]
    stack = CachedStack(block, pol, L)
    params = {"w": jnp.ones((L,)) * 1.1}
    states = stack.init(SHAPE)
    x = jnp.ones(SHAPE)
    for step in range(T):
        y, states = stack(states, step, x, params)
    assert y.shape == SHAPE
    assert bool(jnp.all(jnp.isfinite(y)))


def test_dbcache_stack_probe_gate():
    L = 6
    block = lambda p, x: x + p["b"]
    stack = DBCacheStack(block, L, front_n=2, back_n=2, threshold=0.01)
    params = {"b": jnp.full((L,), 0.1)}
    state = stack.init(SHAPE)
    x = jnp.ones(SHAPE)
    y1, state = stack(state, 0, x, params)
    np.testing.assert_allclose(y1, x + 0.6, rtol=1e-5)
    # same input again -> probe unchanged -> mid reused (still correct here)
    y2, state = stack(state, 1, x, params)
    np.testing.assert_allclose(y2, x + 0.6, rtol=1e-5)


def test_registry_builds_all():
    # entries with no sensible default must say what's missing…
    with pytest.raises(ValueError, match="gate"):
        make_policy("lazydit")
    with pytest.raises(ValueError, match="profile"):
        make_policy("blockcache")
    # …and every entry constructs once its required inputs are supplied
    from repro.core.learned import init_gate
    required = {
        "lazydit": {"gate": init_gate(jax.random.PRNGKey(0), SHAPE[-1])},
        "blockcache": {"profile": [0.0, 0.2, 0.05, 0.2]},
    }
    for name in POLICY_REGISTRY:
        pol = make_policy(name, **required.get(name, {}))
        state = pol.init_state(SHAPE)
        assert isinstance(state, dict)


def test_cache_state_bytes():
    pol = PredictivePolicy(4, order=2)
    state = pol.init_state(SHAPE)
    assert cache_state_bytes(state) >= 3 * 8 * 16 * 4
