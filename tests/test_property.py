"""Property-based tests (hypothesis) for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import make_policy, compute_fraction
from repro.core.predictive import (forecast_from_diffs, update_diff_stack)
from repro.kernels.forecast.ref import basis_coeffs, forecast_ref
from repro.diffusion import linear_schedule, cosine_schedule

SETTINGS = dict(max_examples=25, deadline=None)


# ----------------------------------------------------------------------
# predictive caching: polynomial exactness
# ----------------------------------------------------------------------

@settings(**SETTINGS)
@given(order=st.integers(1, 3),
       coeffs=st.lists(st.floats(-2, 2), min_size=4, max_size=4),
       u=st.floats(0.25, 3.0))
def test_newton_forecast_exact_for_polynomials(order, coeffs, u):
    """The Newton backward-difference basis must reproduce any polynomial
    trajectory of degree <= order exactly on the sampling grid."""
    def traj(t):
        return sum(c * t**i for i, c in enumerate(coeffs[:order + 1]))

    shape = (3, 5)
    diffs = jnp.zeros((order + 1, *shape))
    # observe at t = 0, 1, ..., order (unit grid)
    for t in range(order + 1):
        y = jnp.full(shape, traj(float(t)), jnp.float32)
        diffs = update_diff_stack(diffs, y)
    pred = forecast_from_diffs(diffs, jnp.asarray(u), order + 1, "newton")
    expected = traj(order + u)
    np.testing.assert_allclose(np.asarray(pred),
                               np.full(shape, expected, np.float32),
                               rtol=2e-3, atol=2e-3)


@settings(**SETTINGS)
@given(order=st.integers(1, 4), u=st.floats(0.0, 4.0),
       basis=st.sampled_from(["taylor", "newton", "hermite", "ab"]))
def test_forecast_linear_in_history(order, u, basis):
    """Every polynomial forecast basis is a LINEAR operator on the history
    stack: F(a*d1 + b*d2) == a*F(d1) + b*F(d2)."""
    key = jax.random.PRNGKey(order)
    d1 = jax.random.normal(key, (order + 1, 4, 3))
    d2 = jax.random.normal(jax.random.PRNGKey(order + 1), (order + 1, 4, 3))
    a, b = 0.7, -1.3
    f = lambda d: forecast_from_diffs(d, jnp.asarray(u), order + 1, basis)
    lhs = f(a * d1 + b * d2)
    rhs = a * f(d1) + b * f(d2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-3, atol=1e-4)


@settings(**SETTINGS)
@given(order=st.integers(1, 4),
       basis=st.sampled_from(["taylor", "newton", "ab"]))
def test_forecast_at_zero_offset_returns_cache(order, basis):
    """u=0 must return the newest cached feature exactly (consistency of
    Cache-Then-Forecast with Cache-Then-Reuse at the refresh point).

    Note: the Hermite basis (HiCache Eq. 47) is deliberately excluded —
    physicists' Hermite polynomials do not vanish at 0 for even orders
    (H_2(0) = -2), so HiCache's u=0 forecast differs from the cache by
    O(sigma^2 * d2): a real property of the published method."""
    d = jax.random.normal(jax.random.PRNGKey(0), (order + 1, 6))
    out = forecast_from_diffs(d, jnp.asarray(0.0), order + 1, basis)
    np.testing.assert_allclose(np.asarray(out), np.asarray(d[0]), atol=1e-6)


@settings(**SETTINGS)
@given(order=st.integers(1, 3), n=st.integers(1, 6))
def test_diff_stack_matches_binomial_formula(order, n):
    """After observing y_0..y_{n-1}, diffs[i] must equal the i-th backward
    difference sum_j (-1)^j C(i,j) y_{n-1-j}."""
    import math
    key = jax.random.PRNGKey(n)
    ys = jax.random.normal(key, (n, 4))
    diffs = jnp.zeros((order + 1, 4))
    for t in range(n):
        diffs = update_diff_stack(diffs, ys[t])
    for i in range(min(order, n - 1) + 1):
        expect = sum((-1) ** j * math.comb(i, j) * np.asarray(ys[n - 1 - j])
                     for j in range(i + 1))
        np.testing.assert_allclose(np.asarray(diffs[i]), expect, atol=1e-5)


# ----------------------------------------------------------------------
# kernels: forecast == tensordot for arbitrary coeffs
# ----------------------------------------------------------------------

@settings(**SETTINGS)
@given(order=st.integers(1, 4), n=st.integers(1, 300),
       u=st.floats(0.1, 2.0))
def test_forecast_kernel_arbitrary_shapes(order, n, u):
    from repro.kernels import forecast
    d = jax.random.normal(jax.random.PRNGKey(n), (order + 1, n))
    c = basis_coeffs(order, u, "taylor")
    np.testing.assert_allclose(np.asarray(forecast(d, c, interpret=True)),
                               np.asarray(forecast_ref(d, c)), atol=1e-5)


# ----------------------------------------------------------------------
# schedules
# ----------------------------------------------------------------------

@settings(**SETTINGS)
@given(T=st.integers(10, 500))
def test_noise_schedule_monotone(T):
    for sched in (linear_schedule(T), cosine_schedule(T)):
        ab = sched.alpha_bars
        assert np.all(np.diff(ab) <= 1e-12), "alpha_bar must be decreasing"
        assert 0.0 < ab[-1] < ab[0] <= 1.0


@settings(**SETTINGS)
@given(T=st.integers(20, 300), n=st.integers(2, 20))
def test_spaced_timesteps_descending_cover(T, n):
    n = min(n, T)
    ts = linear_schedule(T).spaced(n)
    assert ts[0] == T - 1 and ts[-1] == 0
    assert np.all(np.diff(ts) < 0)


# ----------------------------------------------------------------------
# policy invariants
# ----------------------------------------------------------------------

@settings(**SETTINGS)
@given(interval=st.integers(1, 8), steps=st.integers(1, 50))
def test_fixed_interval_compute_fraction(interval, steps):
    pol = make_policy("fora", interval=interval)
    sched = pol.static_schedule(steps)
    assert sched[0] is True                      # first step always computes
    assert abs(compute_fraction(sched) - sum(
        1 for s in range(steps) if s % interval == 0) / steps) < 1e-9


@settings(**SETTINGS)
@given(name=st.sampled_from(["fora", "delta_dit", "taylorseer", "hicache",
                             "teacache", "magcache", "easycache", "freqca"]),
       steps=st.integers(2, 12))
def test_policy_first_step_is_exact(name, steps):
    """Every policy must return the exact computation at step 0 (cold
    cache) — the survey's C_t := F(x_t) base case."""
    pol = make_policy(name)
    shape = (2, 8, 4)
    state = pol.init_state(shape)
    x = jax.random.normal(jax.random.PRNGKey(steps), shape)
    fn = lambda v: v * 2.0 + 1.0
    y, state = pol.apply(state, 0, x, fn)
    np.testing.assert_allclose(np.asarray(y), np.asarray(fn(x)), atol=1e-5)


@settings(**SETTINGS)
@given(steps=st.integers(4, 24))
def test_nocache_policy_is_identity_baseline(steps):
    pol = make_policy("none")
    shape = (3, 4)
    state = pol.init_state(shape)
    for s in range(steps):
        x = jax.random.normal(jax.random.PRNGKey(s), shape)
        y, state = pol.apply(state, s, x, lambda v: v + s)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x) + s, atol=1e-6)
