"""repro.conditioning: tokenizer/encoder contracts, PromptCache LRU and
content hashing, cross-attn K/V step-invariance through the serving
engine (text-encoder FLOPs paid once per unique prompt, tick programs
free of text projections), negative-prompt CFG round-trip, refill
isolation of the per-slot text tables, and the pab policy serving its
cross_attn range end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.conditioning import (PromptCache, init_text_encoder,
                                text_encoder_config, tokenize)
from repro.configs import get_config
from repro.core import FasterCacheCFG, make_policy
from repro.core.static_policies import PABPolicy
from repro.diffusion import ddim_step, linear_schedule, sample
from repro.models import dit
from repro.modalities import get_modality, make_workload
from repro.serving.diffusion import DiffusionRequest, request_noise_key

NUM_STEPS = 8


def _tiny_workload(name):
    spec = get_modality(name)
    overrides = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                     d_ff=128, dit_patch_tokens=8, dit_in_dim=4,
                     dit_num_classes=10)
    if spec.temporal:
        overrides.update(dit_patch_tokens=4, dit_num_frames=2)
    if spec.text:
        overrides.update(dit_text_len=4)
    cfg = get_config(spec.arch_id).reduced(**overrides)
    return make_workload(name, cfg=cfg)


@pytest.fixture(scope="module")
def wl():
    return _tiny_workload("t2i")


@pytest.fixture(scope="module")
def wl_image():
    return _tiny_workload("image")


@pytest.fixture(scope="module")
def cache(wl):
    return wl.conditioner(seed=0)


# ----------------------------------------------------------------------
# tokenizer + encoder contracts
# ----------------------------------------------------------------------

def test_tokenize_pads_masks_and_is_deterministic(wl):
    tc = text_encoder_config(wl.cfg)
    ids, mask = tokenize("ab", tc)
    assert ids.shape == (tc.max_len,) and mask.shape == (tc.max_len,)
    assert mask.tolist() == [True, True, False, False]
    assert ids[2:].tolist() == [0, 0]            # padding is zeroed
    ids2, mask2 = tokenize("ab", tc)
    np.testing.assert_array_equal(ids, ids2)
    np.testing.assert_array_equal(mask, mask2)
    # a string and its explicit byte spelling tokenize identically
    ids3, mask3 = tokenize([ord("a"), ord("b")], tc)
    np.testing.assert_array_equal(ids, ids3)
    np.testing.assert_array_equal(mask, mask3)


def test_tokenize_rejects_bad_explicit_sequences(wl):
    tc = text_encoder_config(wl.cfg)
    with pytest.raises(ValueError):                  # overlong explicit seq
        tokenize(list(range(tc.max_len + 1)), tc)
    with pytest.raises(ValueError):                  # out-of-vocab token
        tokenize([0, tc.vocab], tc)
    # strings truncate silently instead (serving-friendly)
    ids, mask = tokenize("x" * (tc.max_len + 3), tc)
    assert mask.all() and len(ids) == tc.max_len


def test_encoder_zeroes_padding_and_pools_masked_mean(cache):
    pe = cache.get("ab")
    assert pe.embed.shape == (cache.tc.max_len, cache.tc.d_model)
    np.testing.assert_array_equal(pe.embed[~pe.mask], 0.0)
    assert np.abs(pe.embed[pe.mask]).max() > 0.0
    np.testing.assert_allclose(pe.pooled, pe.embed[pe.mask].mean(axis=0),
                               atol=1e-6)


def test_fully_masked_text_is_a_noop_branch(wl):
    """The cross-attn branch contract: an all-padding prompt leaves the
    forward bit-for-bit equal to the promptless forward (K/V zeroed at
    masked positions + additive mask => fully-masked rows return 0)."""
    x = jax.random.normal(jax.random.PRNGKey(0), wl.latent_shape(1))
    t = jnp.full((1,), 10.0, jnp.float32)
    y = jnp.zeros((1,), jnp.int32)
    plain = dit.forward(wl.params, x, t, y, wl.cfg)
    Lt = wl.cfg.dit_text_len
    masked = dit.forward(
        wl.params, x, t, y, wl.cfg,
        txt_embed=jnp.zeros((1, Lt, wl.cfg.d_model)),
        txt_mask=jnp.zeros((1, Lt), bool))
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(masked))


def test_prompt_actually_conditions_the_forward(wl, cache):
    x = jax.random.normal(jax.random.PRNGKey(0), wl.latent_shape(1))
    t = jnp.full((1,), 10.0, jnp.float32)
    y = jnp.zeros((1,), jnp.int32)
    plain = dit.forward(wl.params, x, t, y, wl.cfg)
    pe = cache.get("cat")
    prompted = dit.forward(wl.params, x, t, y, wl.cfg,
                           txt_embed=jnp.asarray(pe.embed[None]),
                           txt_mask=jnp.asarray(pe.mask[None]))
    assert np.abs(np.asarray(plain) - np.asarray(prompted)).max() > 1e-5


# ----------------------------------------------------------------------
# PromptCache: hit/miss accounting, LRU bounds, content hashing
# ----------------------------------------------------------------------

def test_prompt_cache_hit_miss_and_lru_eviction(wl):
    tc = text_encoder_config(wl.cfg)
    params = init_text_encoder(jax.random.PRNGKey(0), tc)
    c = PromptCache(params, tc, capacity=2)
    a, b = c.get("aa"), c.get("bb")
    assert (c.misses, c.hits, c.evictions) == (2, 0, 0)
    assert c.get("aa") is a and c.hits == 1     # hit returns the SAME entry
    c.get("cc")                                  # evicts LRU "bb", not "aa"
    assert (c.misses, c.evictions, len(c)) == (3, 1, 2)
    assert c.get("aa") is a                      # survived: recently used
    got_b = c.get("bb")                          # evicted: re-encoded
    assert c.misses == 4 and got_b is not b
    np.testing.assert_array_equal(got_b.embed, b.embed)  # but deterministic
    assert c.stats["hit_rate"] == pytest.approx(2 / 6)


def test_prompt_cache_content_hash_unifies_spellings(cache):
    """A string prompt and its explicit token sequence share one entry."""
    before = cache.misses
    pe = cache.get("hi")
    assert cache.get([ord("h"), ord("i")]) is pe
    assert cache.misses == before + 1
    assert cache.content_key("hi") == cache.content_key([ord("h"), ord("i")])


def test_prompt_cache_rejects_zero_capacity(wl):
    tc = text_encoder_config(wl.cfg)
    params = init_text_encoder(jax.random.PRNGKey(0), tc)
    with pytest.raises(ValueError):
        PromptCache(params, tc, capacity=0)


# ----------------------------------------------------------------------
# serving: K/V step-invariance, CFG round-trip, refill isolation
# ----------------------------------------------------------------------

def test_warmup_profiles_and_encoder_paid_once_per_unique_prompt(wl):
    """Step-invariance through the profile surface: warmup compiles the
    text programs ('text_encoder' once per unique prompt, 'text_kv' once
    per admission) SEPARATE from the tick buckets, and a served session
    with repeated prompts pays the encoder exactly once per unique
    prompt.  The tick programs themselves carry no text-projection work:
    their profiled FLOPs are identical on sessions with 1 vs 3 unique
    prompts (text K/V arrive as operands, never as per-step compute)."""
    cond = wl.conditioner(seed=0)
    eng = wl.engine(make_policy("fora", interval=2), slots=2,
                    max_steps=NUM_STEPS, conditioner=cond)
    profiles = eng.warmup()
    assert "text_kv" in profiles and "text_encoder" in profiles
    tick_flops = {k: v.flops for k, v in profiles.items()
                  if isinstance(k, int)}
    assert tick_flops                      # bucket programs were profiled
    reqs = [DiffusionRequest(i, NUM_STEPS, seed=i,
                             prompt_tokens=("sun", "sun", "sea", "sun")[i])
            for i in range(4)]
    res = eng.serve(reqs)
    assert all(np.isfinite(r.x0).all() for r in res)
    assert cond.misses == 2 and cond.hits == 2   # encoder ran twice, total
    # same-prompt requests share bit-identical embeddings
    assert cond.get("sun") is cond.get([ord(c) for c in "sun"])
    # tick programs did not change or grow because prompts were served
    assert {k: v.flops for k, v in eng.program_profile.items()
            if isinstance(k, int)} == tick_flops


def test_image_engine_has_no_text_programs(wl_image):
    profiles = wl_image.engine("none", slots=1, max_steps=NUM_STEPS).warmup()
    assert "text_kv" not in profiles and "text_encoder" not in profiles


def _reference(wl, req, policy_name, policy_kw, cfg_policy=None, den_kw=None):
    sched = linear_schedule(1000)
    ts = sched.spaced(req.num_steps)
    xT = jax.random.normal(request_noise_key(req),
                           (1, wl.tokens, wl.latent_dim))
    pol = (wl.make_policy(policy_name, num_steps=req.num_steps, **policy_kw)
           if policy_name else None)
    den = wl.denoiser(pol, cfg_scale=req.cfg_scale, cfg_policy=cfg_policy,
                      **(den_kw or {}))
    ref, _ = sample(den, xT, ts, sched, step_fn=ddim_step,
                    denoiser_state=den.init_state(1))
    return np.asarray(ref[0])


def test_negative_prompt_cfg_roundtrip(wl, cache):
    """Engine-served (prompt, negative prompt, CFG) must match the
    single-trajectory CachedDenoiser(text=, neg_text=) reference — the
    negative prompt rides the null-vec tables, the prompt the K/V
    tables, and both survive the guided two-branch tick."""
    req = DiffusionRequest(0, NUM_STEPS, seed=7, cfg_scale=2.5,
                           prompt_tokens="a cat photo",
                           neg_prompt_tokens="blurry")
    eng = wl.engine(make_policy("fora", interval=2), slots=2,
                    max_steps=NUM_STEPS,
                    cfg_policy=FasterCacheCFG(2, NUM_STEPS),
                    conditioner=cache)
    res = eng.serve([req])
    ref = _reference(wl, req, "fora", {"interval": 2},
                     cfg_policy=FasterCacheCFG(2, NUM_STEPS),
                     den_kw={"text": cache.get("a cat photo"),
                             "neg_text": cache.get("blurry")})
    np.testing.assert_allclose(res[0].x0, ref, atol=5e-3, rtol=1e-3)


def test_negative_prompt_changes_output(wl, cache):
    eng = wl.engine("none", slots=1, max_steps=NUM_STEPS, conditioner=cache)
    base = eng.serve([DiffusionRequest(0, NUM_STEPS, seed=4, cfg_scale=2.0,
                                       prompt_tokens="cat")])
    neg = eng.serve([DiffusionRequest(0, NUM_STEPS, seed=4, cfg_scale=2.0,
                                      prompt_tokens="cat",
                                      neg_prompt_tokens="dog")])
    assert np.abs(base[0].x0 - neg[0].x0).max() > 1e-5


def test_refill_isolation_of_text_tables(wl):
    """More prompted requests than slots: every request's output equals
    serving it alone on a fresh engine — slot refill fully resets the
    per-slot text K/V and negative tables (no prompt bleed between the
    requests that share a slot)."""
    cond = wl.conditioner(seed=0)

    def fresh_engine():
        return wl.engine(make_policy("fora", interval=2), slots=2,
                         max_steps=NUM_STEPS,
                         cfg_policy=FasterCacheCFG(2, NUM_STEPS),
                         conditioner=cond)

    prompts = ("cat", "dog", None, "fox", "cat")
    negs = ("bad", None, None, "bad", None)
    reqs = [DiffusionRequest(i, NUM_STEPS, seed=i, class_label=i % 3,
                             cfg_scale=2.0 if i % 2 == 0 else 0.0,
                             prompt_tokens=prompts[i],
                             neg_prompt_tokens=negs[i])
            for i in range(5)]
    res = fresh_engine().serve(reqs)
    assert len(res) == 5
    for req, r in zip(reqs, res):
        solo = fresh_engine().serve([req])[0]
        np.testing.assert_allclose(
            r.x0, solo.x0, atol=5e-4, rtol=1e-3,
            err_msg=f"request {req.request_id} (prompt="
                    f"{req.prompt_tokens!r})")


def test_t2v_prompted_serving_matches_reference(wl_image):
    """The video text path: prompted t2v engine == CachedDenoiser
    reference on the factorized spatial/temporal backbone."""
    wl = _tiny_workload("t2v")
    cond = wl.conditioner(seed=0)
    req = DiffusionRequest(0, NUM_STEPS, seed=5, cfg_scale=2.0,
                           prompt_tokens="waves")
    eng = wl.engine(wl.make_policy("teacache_video", delta=0.1,
                                   num_steps=NUM_STEPS),
                    slots=1, max_steps=NUM_STEPS, conditioner=cond)
    res = eng.serve([req])
    ref = _reference(wl, req, "teacache_video", {"delta": 0.1},
                     den_kw={"text": cond.get("waves")})
    np.testing.assert_allclose(res[0].x0, ref, atol=5e-3, rtol=1e-3)


# ----------------------------------------------------------------------
# pab: the cross_attn range (6) serves for real
# ----------------------------------------------------------------------

def test_pab_registry_entry_serves_cross_attn_range(wl):
    """The pab registry policy keyed on cross_attn must (a) construct with
    the canonical range of 6, (b) actually SAVE compute over a served
    trajectory, and (c) match the CachedDenoiser reference under the same
    policy — the broadcast range gates a branch that exists now that the
    backbone exposes cross-attention."""
    pol = make_policy("pab", module_type="cross_attn")
    assert isinstance(pol, PABPolicy)
    assert PABPolicy.RANGES["cross_attn"] == 6
    sched = pol.static_schedule(NUM_STEPS)
    assert sched[0] and 0 < sum(sched) < NUM_STEPS

    cond = wl.conditioner(seed=0)
    req = DiffusionRequest(0, NUM_STEPS, seed=9, prompt_tokens="a red fox")
    eng = wl.engine(make_policy("pab", module_type="cross_attn"), slots=1,
                    max_steps=NUM_STEPS, conditioner=cond)
    res = eng.serve([req])
    assert res[0].record.computed_steps < NUM_STEPS     # reuse fired
    ref = _reference(wl, req, "pab", {"module_type": "cross_attn"},
                     den_kw={"text": cond.get("a red fox")})
    np.testing.assert_allclose(res[0].x0, ref, atol=5e-3, rtol=1e-3)


# ----------------------------------------------------------------------
# validation: the request/engine/config contracts
# ----------------------------------------------------------------------

def test_prompt_rejected_on_textless_config(wl_image):
    eng = wl_image.engine("none", slots=1, max_steps=NUM_STEPS)
    with pytest.raises(ValueError):
        eng.serve([DiffusionRequest(0, NUM_STEPS, prompt_tokens="cat")])


def test_prompt_rejected_without_conditioner(wl):
    eng = wl.engine("none", slots=1, max_steps=NUM_STEPS)  # no conditioner
    with pytest.raises(ValueError):
        eng.serve([DiffusionRequest(0, NUM_STEPS, prompt_tokens="cat")])


def test_conditioner_rejected_on_textless_config(wl, wl_image, cache):
    with pytest.raises(ValueError):
        wl_image.engine("none", slots=1, max_steps=NUM_STEPS,
                        conditioner=cache)


def test_neg_prompt_conflicts_with_null_vector(wl, cache):
    """Both claim the slot's null-vec table — the engine must refuse the
    ambiguous request instead of silently picking one."""
    eng = wl.engine("none", slots=1, max_steps=NUM_STEPS, conditioner=cache)
    vec = np.zeros((wl.cfg.d_model,), np.float32)
    with pytest.raises(ValueError):
        eng.serve([DiffusionRequest(0, NUM_STEPS, cfg_scale=2.0,
                                    prompt_tokens="cat",
                                    neg_prompt_tokens="dog",
                                    null_label=vec)])


def test_workload_conditioner_requires_text_modality(wl_image):
    with pytest.raises(ValueError):
        wl_image.conditioner()


def test_modality_spec_rejects_text_config_mismatch():
    spec = get_modality("t2i")
    cfg = get_config(spec.arch_id).reduced(num_layers=1, d_model=32,
                                           num_heads=2, num_kv_heads=2,
                                           d_ff=64, dit_text_len=0)
    with pytest.raises(ValueError):
        spec.validate(cfg)
