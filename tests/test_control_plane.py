"""Online control plane (repro.serving.control): telemetry windows fed by
tick hooks, mid-session submission, retuning that never mutates in-flight
slots, SmoothCache static baselines, signal trace logs + the learned
want_compute predictor trained from them, and the telemetry ring buffer."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import make_policy
from repro.core.learned import LazyDiTPolicy
from repro.models import init_params, perturb_zero_init
from repro.serving.control import (ControlPlane, OnlineTuner,
                                   SignalTraceLog, SmoothCacheSchedule,
                                   TelemetryWindow, calibration_profile,
                                   fit_want_gate, probe_training_set)
from repro.serving.diffusion import (SLA, DiffusionRequest,
                                     DiffusionServingEngine, ServingTelemetry,
                                     TickEvent)
from repro.serving.diffusion.telemetry import RequestRecord

NUM_STEPS = 8
CANDS = [("none", {}), ("fora", {"interval": 2}),
         ("teacache", {"threshold": 0.05})]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("dit-xl").reduced(num_layers=2, d_model=64,
                                       num_heads=4, num_kv_heads=4,
                                       d_ff=128, dit_patch_tokens=8,
                                       dit_in_dim=4, dit_num_classes=10)
    params = perturb_zero_init(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _requests(n=3, start=0, modality="image", guided_every=2):
    return [DiffusionRequest(start + i, num_steps=NUM_STEPS, seed=start + i,
                             class_label=i % 5, modality=modality,
                             cfg_scale=2.5 if i % guided_every == 0 else 0.0)
            for i in range(n)]


# ----------------------------------------------------------------------
# TelemetryWindow (synthetic events — no engine needed)
# ----------------------------------------------------------------------

def _event(tick, kind="full", seconds=0.01, rows=4, padding=0, busy=2,
           metric=None, finished=(), modality="image", plan_s=0.0):
    active = np.array([True] * busy + [False] * (4 - busy))
    return TickEvent(
        tick=tick, modality=modality, kind=kind, seconds=seconds,
        plan_seconds=plan_s,
        rows_computed=rows, rows_padding=padding, active=active,
        request_ids=np.where(active, np.arange(4), -1).astype(np.int64),
        steps=np.zeros(4, np.int32), tvals=np.zeros(4, np.float32),
        labels=np.zeros(4, np.int32), guided=np.zeros(4, bool),
        want_cond=active.copy(), want_uncond=np.zeros(4, bool),
        metric=metric, latents=None, admitted=[], finished=list(finished))


def test_window_row_time_and_occupancy():
    w = TelemetryWindow()
    assert w.row_time_ms() is None          # nothing to price with yet
    assert w.occupancy() == 1
    for t in range(4):
        w.observe(_event(t, seconds=0.010, rows=4, padding=1))
    w.observe(_event(4, kind="skip", seconds=0.002, rows=0))
    t_row, t_skip = w.row_time_ms()
    assert t_row == pytest.approx(1e3 * 0.040 / 20)   # 4 ticks x 5 rows
    assert t_skip == pytest.approx(2.0)
    assert w.occupancy() == 2
    assert w.summary()["backbone_ticks"] == 4


def test_window_is_sliding_and_counters_are_monotonic():
    w = TelemetryWindow(max_ticks=3, max_requests=2)
    recs = [RequestRecord(i, NUM_STEPS, computed_steps=4) for i in range(5)]
    for t in range(10):
        w.observe(_event(t, finished=[recs[t % 5]] if t < 5 else []))
    assert len(w.ticks) == 3 and w.ticks_seen == 10
    assert len(w.finished) == 2 and w.requests_seen == 5
    assert w.compute_fraction() == pytest.approx(0.5)


def test_window_metric_and_psnr_proxies():
    w = TelemetryWindow()
    w.observe(_event(0, metric=np.array([0.2, 0.4, 9.0, 9.0]), busy=2))
    assert w.mean_metric() == pytest.approx(0.3)  # inactive slots excluded
    w.note_psnr(0, 30.0)
    w.note_psnr(1, 20.0)
    assert w.psnr_mean() == pytest.approx(25.0)
    assert w.summary()["psnr_proxy_mean"] == pytest.approx(25.0)


# ----------------------------------------------------------------------
# ServingTelemetry ring buffer (satellite: bounded record growth)
# ----------------------------------------------------------------------

def test_telemetry_ring_buffer_counters_stay_exact():
    capped = ServingTelemetry(max_records=4)
    full = ServingTelemetry()
    for t in (capped, full):
        t.start()
    for i in range(12):
        for t in (capped, full):
            t.finish_request(RequestRecord(
                i, NUM_STEPS, computed_steps=4, enqueue_time=0.0,
                admit_time=1.0, finish_time=2.0))
    for t in (capped, full):
        t.stop()
    assert len(capped.records) == 4
    s = capped.summary()
    # aggregate counters cover ALL 12 requests, not just the retained 4
    assert s["requests"] == 12
    assert s["compute_fraction_mean"] == pytest.approx(0.5)
    assert s["queue_wait_mean_s"] == pytest.approx(1.0)
    assert capped.latency_sum_s == pytest.approx(24.0)
    assert len(full.records) == 12 and full.summary()["requests"] == 12


# ----------------------------------------------------------------------
# mid-session submission + engine-driven window
# ----------------------------------------------------------------------

def test_session_submit_midflight_and_window_hook(setup):
    cfg, params = setup
    eng = DiffusionServingEngine(params, cfg, "fora", slots=2,
                                 max_steps=NUM_STEPS)
    w = TelemetryWindow()
    sess = eng.start_session(_requests(2), hooks=[w.observe])
    for _ in range(3):
        sess.tick()
    late = DiffusionRequest(99, num_steps=NUM_STEPS, seed=99)
    sess.submit(late)
    with pytest.raises(ValueError, match="already submitted"):
        sess.submit(late)
    while not sess.done:
        sess.tick()
    res = sess.finish()
    assert sorted(r.request_id for r in res) == [0, 1, 99]
    assert w.ticks_seen == sess.ticks
    assert w.row_time_ms() is not None and w.occupancy() >= 1
    with pytest.raises(RuntimeError, match="finished"):
        sess.submit(DiffusionRequest(100, num_steps=NUM_STEPS, seed=1))


# ----------------------------------------------------------------------
# OnlineTuner: retune swaps at refill boundaries, never in-flight slots
# ----------------------------------------------------------------------

def test_retune_isolates_inflight_requests(setup):
    cfg, params = setup
    tun = OnlineTuner(params, cfg, SLA(min_psnr=10.0), slots=2,
                      max_steps=NUM_STEPS, candidates=CANDS,
                      retune_every=0, seed=0,
                      initial=("none", {}))
    assert tun.current.policy_name == "none"
    tun.submit_all(_requests(2, guided_every=10**9))   # unguided
    for _ in range(3):
        tun.tick()
    old_session = tun.active
    old_policy = old_session.engine.policy
    target = [t for t in tun.swept if t.policy_name == "fora"][0]
    assert tun.maybe_retune(force_to=target) is not None
    # blue/green: the old session drains untouched on its original engine
    assert tun.draining == [old_session]
    assert old_session.engine.policy is old_policy
    assert tun.active is not old_session
    assert tun.active.engine is not old_session.engine
    tun.submit_all(_requests(2, start=10, guided_every=10**9))
    res = tun.drain()
    by_id = {r.request_id: r.record for r in res}
    assert sorted(by_id) == [0, 1, 10, 11]
    # in-flight requests finish under the policy that admitted them (none:
    # every step computes); post-swap requests run fora/2 (half the steps)
    assert by_id[0].computed_steps == NUM_STEPS
    assert by_id[1].computed_steps == NUM_STEPS
    assert by_id[10].computed_steps == NUM_STEPS // 2
    assert by_id[11].computed_steps == NUM_STEPS // 2
    assert len(tun.swaps) == 1
    assert tun.swaps[0]["from"][0] == "none"
    assert tun.swaps[0]["to"][0] == "fora"
    assert tun.summary()["policy"] == "fora"


def test_retune_noop_cases(setup):
    cfg, params = setup
    tun = OnlineTuner(params, cfg, SLA(min_psnr=10.0), slots=2,
                      max_steps=NUM_STEPS, candidates=CANDS,
                      retune_every=0, min_window_ticks=1,
                      initial=("none", {}))
    assert tun.maybe_retune() is None          # empty window: nothing to price
    assert tun.maybe_retune(force_to=tun.current) is None   # same pick: no-op
    assert tun.swaps == [] and tun.draining == []
    tun.finish()


def test_tuner_priced_retune_uses_live_window(setup):
    """Synthetic window states drive the pricing deterministically: while
    device planning looks free the tuner swaps onto the dynamic candidate
    with fewer rows; once the window measures the real per-tick want-pass
    sync, the plan-time surcharge flips the pick and the tuner rolls back
    to the static plan — the self-correction loop."""
    cfg, params = setup
    tun = OnlineTuner(params, cfg, SLA(min_psnr=10.0), slots=2,
                      max_steps=NUM_STEPS, candidates=CANDS,
                      retune_every=0, min_window_ticks=1,
                      initial=("none", {}))
    # warm window: 10 ms/row at occupancy 2, no device-planned ticks seen
    # yet -> plan surcharge 0, and teacache (the only other feasible
    # candidate; fora is below the floor) wins on rows alone
    for t in range(4):
        tun.window.observe(_event(t, seconds=0.080, rows=8, busy=2))
    pick = tun.maybe_retune()
    assert pick is not None and pick.policy_name == "teacache"
    assert not pick.static_plan
    assert tun.current.feasible
    # now the window shows what rows-only pricing missed: every device-
    # planned tick pays a fat want-pass sync (200 ms >> the rows it saves),
    # so the static all-compute plan is cheaper end to end
    for t in range(4, 12):
        tun.window.observe(_event(t, seconds=0.050, rows=8, busy=2,
                                  plan_s=0.200,
                                  metric=np.zeros(4, np.float32)))
    assert tun.window.plan_time_ms() == pytest.approx(200.0)
    pick2 = tun.maybe_retune()
    assert pick2 is not None and pick2.policy_name == "none"
    assert len(tun.swaps) == 2
    assert tun.swaps[1]["plan_time_ms"] == pytest.approx(200.0)
    tun.finish()


# ----------------------------------------------------------------------
# SmoothCache static baseline
# ----------------------------------------------------------------------

def test_smoothcache_calibration_and_static_serving(setup):
    cfg, params = setup
    profile = calibration_profile(params, cfg, NUM_STEPS)
    assert len(profile) == NUM_STEPS
    assert profile[0] == 0.0 and all(p >= 0.0 for p in profile)
    sc = SmoothCacheSchedule(profile, alpha=0.05)
    sched = sc.static_schedule(NUM_STEPS)
    assert sched[0] is True and len(sched) == NUM_STEPS
    assert 0.0 < sc.compute_fraction <= 1.0
    # larger alpha tolerates more accumulated drift -> not more computes
    looser = SmoothCacheSchedule(profile, alpha=0.5)
    assert looser.compute_fraction <= sc.compute_fraction
    # int-step want_compute -> the engine hosts it on the static plan
    eng = DiffusionServingEngine(params, cfg, sc, slots=2,
                                 max_steps=NUM_STEPS)
    assert eng._static_plan is not None
    res = eng.serve(_requests(2, guided_every=10**9))
    want = sum(sched)
    assert all(r.record.computed_steps == want for r in res)


# ----------------------------------------------------------------------
# SignalTraceLog + learned want_compute end-to-end
# ----------------------------------------------------------------------

def test_trace_log_records_and_bounds(setup):
    cfg, params = setup
    trace = SignalTraceLog(max_entries=5, probe_every=0)
    eng = DiffusionServingEngine(params, cfg, "teacache", slots=2,
                                 max_steps=NUM_STEPS)
    eng.serve(_requests(2, guided_every=10**9), hooks=[trace.observe])
    assert trace.wants_latents is False
    assert len(trace.entries) == 5               # ring-bounded
    assert trace.entries_seen == 2 * NUM_STEPS   # but everything was seen
    assert trace.probes == {}
    s = trace.summary()
    assert s["entries"] == 5 and 0.0 <= s["want_cond_rate"] <= 1.0


def test_learned_gate_from_traces_serves_equivalently(setup):
    cfg, params = setup
    trace = SignalTraceLog(probe_every=1, max_probes=4)
    eng = DiffusionServingEngine(params, cfg, "none", slots=2,
                                 max_steps=NUM_STEPS)
    eng.serve(_requests(3, guided_every=10**9), hooks=[trace.observe],
              capture_latents=trace.wants_latents)
    assert len(trace.probes) == 3
    sets = probe_training_set(params, cfg, trace)
    assert len(sets) == 3
    for xs, eps in sets:
        assert xs.shape == (NUM_STEPS, cfg.dit_tokens, cfg.dit_in_dim)
        assert eps.shape == xs.shape
    gate, hist = fit_want_gate(jax.random.PRNGKey(1), sets, steps=60)
    assert hist[-1] < hist[0]
    # the learned predictor serves through the registry on BOTH engine
    # paths, and the row-compacted path reproduces the dense reference
    outs = {}
    for compact in (True, False):
        e = DiffusionServingEngine(
            params, cfg, make_policy("lazydit", gate=gate, threshold=0.5),
            slots=2, max_steps=NUM_STEPS, row_compaction=compact)
        assert isinstance(e.policy, LazyDiTPolicy)
        outs[compact] = e.serve(_requests(3, guided_every=10**9))
    for a, b in zip(outs[True], outs[False]):
        assert a.record.computed_steps == b.record.computed_steps
        np.testing.assert_allclose(a.x0, b.x0, rtol=2e-4, atol=2e-4)


def test_fit_want_gate_requires_probes():
    with pytest.raises(ValueError, match="probe"):
        fit_want_gate(jax.random.PRNGKey(0), [])


# ----------------------------------------------------------------------
# ControlPlane: one tuner per modality
# ----------------------------------------------------------------------

def test_control_plane_routes_by_modality(setup):
    cfg, params = setup
    mk = lambda m: OnlineTuner(params, cfg, SLA(min_psnr=10.0), slots=2,
                               max_steps=NUM_STEPS, modality=m,
                               candidates=[("fora", {"interval": 2})],
                               retune_every=0, initial=("fora", {"interval": 2}))
    plane = ControlPlane({"image": mk("image"), "audio": mk("audio")})
    reqs = (_requests(2, modality="image", guided_every=10**9)
            + _requests(2, start=5, modality="audio", guided_every=10**9))
    plane.submit_all(reqs)
    with pytest.raises(KeyError, match="video"):
        plane.submit(DiffusionRequest(50, num_steps=NUM_STEPS, seed=0,
                                      modality="video"))
    res = plane.drain()
    assert [r.request_id for r in res] == [0, 1, 5, 6]   # submission order
    summ = plane.summary()
    assert set(summ) == {"image", "audio"}
    assert summ["image"]["window"]["ticks_seen"] > 0
    assert summ["audio"]["modality"] == "audio"
    with pytest.raises(ValueError, match="at least one"):
        ControlPlane({})
