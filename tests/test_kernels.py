"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, forecast, ssd_scan
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.forecast.ref import basis_coeffs, forecast_ref
from repro.kernels.ssd.ref import ssd_ref

KEY = jax.random.PRNGKey(7)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("B,Sq,Sk,H,KH,D", [
    (1, 128, 128, 4, 4, 64),    # MHA
    (2, 256, 256, 8, 2, 64),    # GQA group 4
    (1, 128, 256, 4, 1, 32),    # MQA, cross-length (decode-tail window)
    (1, 512, 512, 4, 2, 128),   # MXU-aligned head dim
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_matches_ref(B, Sq, Sk, H, KH, D, causal, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, KH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, KH, D), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_k=128, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 128, 2, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 128, 2, 64)).astype(dtype)
    out = flash_attention(q, k, v, interpret=True)
    ref = attention_ref(q, k, v)
    atol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol, rtol=1e-2)
    assert out.dtype == dtype


def test_flash_attention_block_shapes():
    """Output must be independent of tile sizes."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 32))
    k = jax.random.normal(ks[1], (1, 256, 2, 32))
    v = jax.random.normal(ks[2], (1, 256, 2, 32))
    outs = [flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
            for bq, bk in [(64, 64), (128, 256), (256, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=2e-5)


# ----------------------------------------------------------------------
# forecast
# ----------------------------------------------------------------------

@pytest.mark.parametrize("order", [1, 2, 3, 4])
@pytest.mark.parametrize("basis", ["taylor", "newton", "hermite", "ab"])
def test_forecast_matches_ref(order, basis):
    d = jax.random.normal(KEY, (order + 1, 3, 130, 17))
    c = basis_coeffs(order, 1.75, basis)
    out = forecast(d, c, interpret=True)
    ref = forecast_ref(d, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("n", [1, 127, 4096, 4097, 10_000])
def test_forecast_padding_edges(n):
    """N not divisible by the block must round-trip exactly."""
    d = jax.random.normal(KEY, (3, n))
    c = basis_coeffs(2, 0.5, "taylor")
    out = forecast(d, c, block_n=4096, interpret=True)
    ref = forecast_ref(d, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_forecast_dtypes(dtype):
    d = jax.random.normal(KEY, (3, 1024)).astype(dtype)
    c = basis_coeffs(2, 1.0, "taylor")
    out = forecast(d, c, interpret=True)
    assert out.dtype == dtype
    ref = forecast_ref(d, c)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=1e-5 if dtype == jnp.float32 else 5e-2)


# ----------------------------------------------------------------------
# ssd
# ----------------------------------------------------------------------

def _ssd_inputs(b, s, h, p, n, key=KEY):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.uniform(ks[2], (h,), minval=0.0, maxval=1.0))
    B_ = jax.random.normal(ks[3], (b, s, n))
    C_ = jax.random.normal(ks[4], (b, s, n))
    return x, dt, A, B_, C_


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 16, 8, 32),
    (1, 128, 1, 32, 16, 64),
    (1, 96, 2, 8, 4, 32),       # nc = 3 (odd chunk count)
])
def test_ssd_matches_ref(b, s, h, p, n, chunk):
    x, dt, A, B_, C_ = _ssd_inputs(b, s, h, p, n)
    y, hf = ssd_scan(x, dt, A, B_, C_, chunk=chunk, interpret=True)
    yr, hr = ssd_ref(x, dt, A, B_, C_, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr), atol=2e-4,
                               rtol=1e-3)


def test_ssd_chunk_invariance():
    """The scan result must not depend on the chunking."""
    x, dt, A, B_, C_ = _ssd_inputs(1, 128, 2, 8, 4)
    y1, h1 = ssd_scan(x, dt, A, B_, C_, chunk=16, interpret=True)
    y2, h2 = ssd_scan(x, dt, A, B_, C_, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4,
                               rtol=1e-3)


def test_ssd_state_matches_sequential_decode():
    """Kernel chunk-final state == token-by-token recurrence state."""
    b, s, h, p, n = 1, 32, 2, 8, 4
    x, dt, A, B_, C_ = _ssd_inputs(b, s, h, p, n)
    _, hf = ssd_scan(x, dt, A, B_, C_, chunk=8, interpret=True)
    hstate = jnp.zeros((b, h, p, n))
    for t in range(s):
        dA = jnp.exp(dt[:, t] * A)                           # (b,h)
        upd = (dt[:, t, :, None, None] * x[:, t, :, :, None]
               * B_[:, t, None, None, :])
        hstate = hstate * dA[..., None, None] + upd
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hstate), atol=2e-4,
                               rtol=1e-3)
