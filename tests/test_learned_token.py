"""ToCa token-wise caching (Eq. 19-21) + LazyDiT learned gate (Eq. 26-27)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LazyDiTPolicy, ToCaPolicy, make_policy,
                        train_lazy_gate)
from repro.core.learned import gate_score, init_gate


# ----------------------------------------------------------------------
# ToCa
# ----------------------------------------------------------------------

def test_toca_refresh_step_is_exact():
    pol = ToCaPolicy(interval=4, ratio=0.25)
    shape = (2, 16, 8)
    state = pol.init_state(shape)
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    fn = lambda v: v * 3.0
    y, state = pol.apply(state, 0, x, fn)
    np.testing.assert_allclose(np.asarray(y), np.asarray(fn(x)), atol=1e-6)


def test_toca_partial_step_recomputes_ratio():
    """On a skipped step exactly ceil(ratio*T) tokens take fresh values."""
    T = 16
    pol = ToCaPolicy(interval=4, ratio=0.25)
    shape = (1, T, 4)
    state = pol.init_state(shape)
    key = jax.random.PRNGKey(1)
    x0 = jax.random.normal(key, shape)
    fn = lambda v: v + 100.0
    _, state = pol.apply(state, 0, x0, fn)

    # move a few tokens a lot: they must be selected for recompute
    x1 = x0.at[:, :2].add(5.0)
    y1, state = pol.apply(state, 1, x1, fn)
    fresh = np.asarray(state["stale"][0] == 0.0)   # recomputed this step
    assert fresh[:2].all(), "most-changed tokens must recompute"
    assert fresh.sum() == max(int(0.25 * T), 1)
    # and their outputs reflect the new input
    np.testing.assert_allclose(np.asarray(y1[:, :2]),
                               np.asarray(fn(x1)[:, :2]), atol=1e-5)


def test_toca_staleness_forces_eventual_refresh():
    """With a static input, staleness must rotate recomputation across
    tokens rather than starving any of them."""
    T = 8
    pol = ToCaPolicy(interval=100, ratio=0.25, lambdas=(1.0, 0.0, 1.0, 0.0))
    shape = (1, T, 4)
    state = pol.init_state(shape)
    x = jnp.ones(shape)
    fn = lambda v: v * 2.0
    _, state = pol.apply(state, 0, x, fn)
    seen = np.zeros(T, bool)
    for s in range(1, 9):
        y, state = pol.apply(state, s, x, fn)
        seen |= np.asarray(state["stale"][0] == 0.0)
    assert seen.all(), "every token must be refreshed eventually"


def test_toca_registry_and_pipeline():
    from repro.configs import get_config
    from repro.diffusion import CachedDenoiser, ddim_step, linear_schedule, sample
    from repro.models import init_params, perturb_zero_init
    cfg = get_config("dit-xl").reduced(num_layers=2, d_model=64,
                                       dit_patch_tokens=16)
    params = perturb_zero_init(init_params(jax.random.PRNGKey(0), cfg))
    sched = linear_schedule(100)
    ts = sched.spaced(8)
    xT = jax.random.normal(jax.random.PRNGKey(1),
                           (1, cfg.dit_patch_tokens, cfg.dit_in_dim))
    den = CachedDenoiser(params, cfg, make_policy("toca", interval=2),
                         granularity="model")
    x0, _ = sample(den, xT, ts, sched, step_fn=ddim_step,
                   denoiser_state=den.init_state(1))
    assert bool(jnp.all(jnp.isfinite(x0)))


# ----------------------------------------------------------------------
# LazyDiT
# ----------------------------------------------------------------------

def _make_trajectory(T=24, tokens=8, dim=6, flip_at=12):
    """Module outputs that are constant then jump — a gate can learn that
    the early regime is skippable."""
    key = jax.random.PRNGKey(0)
    base = jax.random.normal(key, (tokens, dim))
    xs, ys = [], []
    for t in range(T):
        phase = 0.0 if t < flip_at else 1.0
        x = base + phase * 3.0 + 0.01 * t
        xs.append(x)
        ys.append(2.0 * x)
    return jnp.stack(xs), jnp.stack(ys)


def test_lazy_gate_training_reduces_loss():
    xs, ys = _make_trajectory()
    gate, hist = train_lazy_gate(jax.random.PRNGKey(2), xs, ys, steps=100)
    assert hist[-1] < hist[0], (hist[0], hist[-1])


def test_lazydit_policy_skips_and_computes():
    xs, ys = _make_trajectory()
    gate, _ = train_lazy_gate(jax.random.PRNGKey(2), xs, ys, steps=150,
                              rho=0.3)
    pol = LazyDiTPolicy(gate, threshold=0.5)
    state = pol.init_state(ys.shape[1:])
    n_comp = 0
    outs = []
    for t in range(xs.shape[0]):
        computed = {}

        def fn(v):
            computed["hit"] = True
            return 2.0 * v

        y, state = pol.apply(state, t, xs[t], fn)
        outs.append(np.asarray(y))
        n_comp += int(computed.get("hit", False))
    assert 0 < n_comp <= xs.shape[0]
    # outputs stay bounded near the exact values
    err = np.mean([np.mean((o - np.asarray(ys[t])) ** 2)
                   for t, o in enumerate(outs)])
    exact = np.mean(np.asarray(ys) ** 2)
    assert err < exact, "gated outputs must beat the trivial zero predictor"


def test_gate_score_in_unit_interval():
    gate = init_gate(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 6)) * 10
    s = gate_score(gate, x)
    assert 0.0 <= float(s) <= 1.0
