"""Pipeline-level cache correctness on the DiT denoiser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import make_policy
from repro.core.metrics import psnr
from repro.core.static_policies import FasterCacheCFG
from repro.diffusion import (CachedDenoiser, ddim_step, linear_schedule,
                             sample)
from repro.diffusion.pipeline import cfg_denoise_fn
from repro.models import init_params, perturb_zero_init

NUM_STEPS = 16


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("dit-xl").reduced(num_layers=4, d_model=128,
                                       num_heads=4, num_kv_heads=4,
                                       d_ff=256, dit_patch_tokens=16,
                                       dit_num_classes=10)
    params = perturb_zero_init(init_params(jax.random.PRNGKey(0), cfg))
    sched = linear_schedule(200)
    ts = sched.spaced(NUM_STEPS)
    xT = jax.random.normal(jax.random.PRNGKey(1),
                           (2, cfg.dit_patch_tokens, cfg.dit_in_dim))
    exact, _ = sample(cfg_denoise_fn(params, cfg, 0.0), xT, ts, sched,
                      step_fn=ddim_step)
    return cfg, params, sched, ts, xT, np.asarray(exact)


def _run(setup, policy, gran="model", cfg_scale=0.0, cfg_policy=None):
    cfg, params, sched, ts, xT, _ = setup
    den = CachedDenoiser(params, cfg, policy, granularity=gran,
                         cfg_scale=cfg_scale, cfg_policy=cfg_policy)
    x0, state = sample(den, xT, ts, sched, step_fn=ddim_step,
                       denoiser_state=den.init_state(2))
    return np.asarray(x0), state


def test_interval_1_is_exact(setup):
    """Every fixed-interval policy at N=1 must reproduce the exact
    trajectory bit-for-bit (never reuses)."""
    exact = setup[-1]
    for name in ("fora", "delta_dit", "taylorseer", "hicache"):
        x0, _ = _run(setup, make_policy(name, interval=1))
        np.testing.assert_allclose(x0, exact, atol=1e-5, err_msg=name)


def test_untrained_dit_not_degenerate(setup):
    """Guard for the AdaLN-zero pitfall: the perturbed model's trajectory
    must actually move (a zero denoiser would leave x0 == scaled x_T)."""
    cfg, params, sched, ts, xT, exact = setup
    assert float(np.abs(exact).std()) > 1e-3
    x0_fora, _ = _run(setup, make_policy("fora", interval=4))
    assert float(np.mean((x0_fora - exact) ** 2)) > 0.0


@pytest.mark.parametrize("gran", ["model", "block", "deepcache"])
def test_granularities_run_and_bounded(setup, gran):
    exact = setup[-1]
    x0, _ = _run(setup, make_policy("taylorseer", interval=4), gran=gran)
    assert np.all(np.isfinite(x0))
    assert float(psnr(jnp.asarray(x0), jnp.asarray(exact))) > 5.0


def test_predictive_beats_reuse_on_pipeline(setup):
    exact = setup[-1]
    x_reuse, _ = _run(setup, make_policy("fora", interval=4))
    x_pred, _ = _run(setup, make_policy("taylorseer", interval=4))
    mse_r = float(np.mean((x_reuse - exact) ** 2))
    mse_p = float(np.mean((x_pred - exact) ** 2))
    assert mse_p < mse_r, (mse_p, mse_r)


def test_adaptive_policies_track_threshold(setup):
    """Tighter TeaCache threshold -> more computes -> closer to exact."""
    exact = setup[-1]
    out = {}
    for delta in (0.05, 0.5):
        x0, state = _run(setup, make_policy("teacache", delta=delta))
        out[delta] = (float(np.mean((x0 - exact) ** 2)),
                      int(state["policy"]["n_compute"]))
    assert out[0.05][1] >= out[0.5][1]
    assert out[0.05][0] <= out[0.5][0] + 1e-6


def test_cfg_cache_matches_full_cfg_shape(setup):
    exact = setup[-1]
    x0, _ = _run(setup, make_policy("fora", interval=2), cfg_scale=2.0,
                 cfg_policy=FasterCacheCFG(2, NUM_STEPS))
    assert np.all(np.isfinite(x0)) and x0.shape == exact.shape


def test_taylorseer_vs_manual_forecast(setup):
    """The pipeline's TaylorSeer state must match a hand-rolled forecast of
    the same model outputs (integration = unit composition)."""
    cfg, params, sched, ts, xT, _ = setup
    from repro.models import dit
    from repro.core.predictive import update_diff_stack, forecast_from_diffs

    pol = make_policy("taylorseer", interval=2, order=1)
    den = CachedDenoiser(params, cfg, pol)
    state = den.init_state(2)
    x = xT
    y = jnp.zeros((2,), jnp.int32)
    outs = []
    for i in range(4):
        t_vec = jnp.full((2,), float(ts[i]), jnp.float32)
        eps, state = den(state, i, x, t_vec)
        outs.append(np.asarray(eps))
    # step 3 (odd) was a forecast from computes at steps 0 and 2
    t0 = jnp.full((2,), float(ts[0]), jnp.float32)
    t2 = jnp.full((2,), float(ts[2]), jnp.float32)
    # reconstruct what the denoiser computed at steps 0 and 2
    # (x evolves outside the denoiser in `sample`; here x was fixed)
    e0 = dit.forward(params, xT, t0, y, cfg)
    e2 = dit.forward(params, xT, t2, y, cfg)
    diffs = jnp.zeros((2, *e0.shape))
    diffs = update_diff_stack(diffs, e0)
    diffs = update_diff_stack(diffs, e2)
    manual = forecast_from_diffs(diffs, 0.5, 2, "taylor")
    np.testing.assert_allclose(outs[3], np.asarray(manual), atol=1e-4,
                               rtol=1e-3)
