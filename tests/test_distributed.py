"""Distribution-layer tests.

These need >1 device, so each runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count set BEFORE jax import
(the main test process keeps its single CPU device).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 16, timeout: int = 520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_moe_ep_matches_dense_dispatch():
    """The expert-parallel scatter/all-to-all path must agree with the dense
    one-hot dispatch on identical routing (drop-free capacity)."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.configs import get_smoke_config
        from repro.models.moe import init_moe, moe_forward, moe_forward_ep
        import dataclasses

        cfg = get_smoke_config("deepseek-v2-236b")
        cfg = dataclasses.replace(cfg, num_experts=8, experts_per_token=2,
                                  capacity_factor=8.0)
        key = jax.random.PRNGKey(0)
        p = init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))

        y_dense, aux_d = moe_forward(p, x, cfg)

        mesh = jax.make_mesh((4, 2, 2), ("data", "attn", "ffn"))
        with mesh:
            fn = jax.jit(lambda p, x: moe_forward_ep(
                p, x, cfg, mesh=mesh, batch_ax=("data",), ep_axis="data",
                inner_axes=("attn", "ffn")))
            y_ep, aux_e = fn(p, x)
        np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ep),
                                   atol=2e-4, rtol=1e-3)
        np.testing.assert_allclose(float(aux_d["load_balance_loss"]),
                                   float(aux_e["load_balance_loss"]),
                                   rtol=1e-3)
        print("EP==dense OK")
    """, devices=16)


def test_sharded_forward_matches_single_device():
    """pjit'd forward on an 8-device mesh == single-device forward."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import init_params
        from repro.models import transformer
        from repro import sharding as shd
        import dataclasses

        cfg = dataclasses.replace(get_smoke_config("qwen2-7b"),
                                  dtype="float32")
        params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                  cfg.vocab_size)
        ref, _ = transformer.forward(params, toks, cfg)

        mesh = jax.make_mesh((2, 2, 2), ("data", "attn", "ffn"))
        with mesh:
            fn = jax.jit(lambda p, t: transformer.forward(p, t, cfg)[0],
                         in_shardings=(shd.params_sharding(params, mesh),
                                       shd.inputs_sharding({"t": toks},
                                                           mesh)["t"]),
                         out_shardings=shd.logits_sharding(
                             mesh, vocab=cfg.vocab_size))
            out = fn(params, toks)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   atol=2e-3, rtol=1e-3)
        print("sharded==local OK")
    """, devices=8)


def test_production_mesh_contract():
    """The brief's make_production_mesh contract: (16,16)=("data","model")
    single-pod and (2,16,16)=("pod","data","model") multi-pod; a smoke
    model must lower+compile on both."""
    run_sub("""
        import jax
        from repro.launch.mesh import make_production_mesh
        from repro.configs import get_smoke_config
        from repro.models import init_params, transformer
        from repro import sharding as shd
        import jax.numpy as jnp

        for mp in (False, True):
            mesh = make_production_mesh(multi_pod=mp)
            assert mesh.devices.size == (512 if mp else 256)
            assert mesh.axis_names == (("pod", "data", "model") if mp
                                       else ("data", "model"))
            cfg = get_smoke_config("tinyllama-1.1b")
            pspec = jax.eval_shape(lambda: init_params(
                jax.random.PRNGKey(0), cfg))
            toks = jax.ShapeDtypeStruct((32, 16), jnp.int32)
            with mesh:
                fn = jax.jit(lambda p, t: transformer.forward(p, t, cfg)[0],
                             in_shardings=(shd.params_sharding(pspec, mesh),
                                           shd.inputs_sharding({"t": toks},
                                                               mesh)["t"]))
                fn.lower(pspec, toks).compile()
            print("mesh", mesh.axis_names, "compiled OK")
    """, devices=512)


def test_logical_mesh_attn_alignment():
    run_sub("""
        from repro.launch.mesh import attn_shards, make_logical_mesh
        from repro.configs import get_config
        expect = {"qwen2-7b": 4, "qwen2.5-14b": 8, "arctic-480b": 8,
                  "minitron-8b": 8, "pixtral-12b": 8, "tinyllama-1.1b": 4,
                  "deepseek-v2-236b": 16, "zamba2-2.7b": 16,
                  "whisper-small": 4, "dit-xl": 16}
        for arch, a in expect.items():
            cfg = get_config(arch)
            got = attn_shards(cfg)
            assert got == a, (arch, got, a)
            mesh = make_logical_mesh(cfg)
            assert mesh.devices.size == 256
            assert cfg.num_kv_heads % got == 0 or cfg.num_kv_heads == 0
        print("attn alignment OK")
    """, devices=512)


def test_dryrun_single_case_end_to_end():
    """The dry-run CLI itself, on the fastest combination."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             "tinyllama-1.1b", "--shape", "train_4k", "--out", d],
            capture_output=True, text=True, env=env, timeout=520)
        assert out.returncode == 0, out.stdout + out.stderr
        rec = json.load(open(os.path.join(
            d, "dryrun_tinyllama-1.1b_train_4k_sp.json")))
        assert rec["status"] == "ok"
        assert rec["roofline"]["dominant"] in ("compute", "memory",
                                               "collective")
        assert rec["fits_16gb_hbm"]
