"""Modality layer: per-modality cached==uncached equivalence across the
whole policy registry, temporal-aware policies (per-frame TeaCache signal,
PAB branch broadcast), mixed-modality serving (refill isolation, per-
modality row accounting, warmup), negative-prompt null conditioning and
the FasterCacheCFG low-frequency residual variant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (POLICY_REGISTRY, FasterCacheCFG, TemporalPABStack,
                        TemporalTeaCachePolicy, make_policy)
from repro.core.learned import init_gate
from repro.diffusion import ddim_step, linear_schedule, sample
from repro.diffusion.pipeline import backbone_fns, cfg_denoise_fn
from repro.modalities import (MODALITIES, MixedModalityEngine, get_modality,
                              make_workload)
from repro.serving.diffusion import DiffusionRequest, request_noise_key

NUM_STEPS = 8

#: always-compute hyperparameters: with these, every registry policy must
#: reproduce the exact uncached trajectory (the survey's C_t := F(x_t) base
#: case extended to whole trajectories) on every modality's shapes
ALWAYS_COMPUTE = {
    "none": {},
    "fora": {"interval": 1},
    "delta_dit": {"interval": 1},
    "teacache": {"delta": 0.0},
    "teacache_video": {"delta": 0.0},
    "magcache": {"delta": 0.0},
    "easycache": {"tau": 0.0},
    "foresight": {"gamma": 0.0},
    "taylorseer": {"interval": 1},
    "newtonseer": {"interval": 1},
    "hicache": {"interval": 1},
    "abcache": {"interval": 1},
    "foca": {"interval": 1},
    "freqca": {"interval": 1},
    "toca": {"interval": 1},
    "clusca": {"interval": 1},
    "speca": {"interval": 1},
    "fastercache_cfg": {"interval": 1},
    # PAB at model granularity: ranges all 1 -> every module type (incl.
    # the text cross-attn branch) recomputes each step
    "pab": {"ranges": dict.fromkeys(
        ("spatial_attn", "temporal_attn", "cross_attn", "mlp"), 1)},
    # constructor-argument policies: callable entries get the workload so
    # the gate/profile can match its latent shapes.  threshold=1.0 makes
    # the learned gate refresh every step (sigmoid <= 1); delta=0.0 under
    # a strictly positive profile recomputes at every calibrated step.
    "lazydit": lambda wl: {"gate": init_gate(jax.random.PRNGKey(0),
                                             wl.latent_dim),
                           "threshold": 1.0},
    "blockcache": lambda wl: {"profile": [1.0] * NUM_STEPS, "delta": 0.0},
}


def _tiny_workload(name):
    spec = get_modality(name)
    overrides = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                     d_ff=128, dit_patch_tokens=8, dit_in_dim=4,
                     dit_num_classes=10)
    if spec.temporal:
        overrides.update(dit_patch_tokens=4, dit_num_frames=2)
    if spec.text:
        overrides.update(dit_text_len=4)
    cfg = get_config(spec.arch_id).reduced(**overrides)
    wl = make_workload(name, cfg=cfg)
    if spec.text:
        # one shared PromptCache per text workload; the sweep conditions
        # every trajectory on the same (prompt, negative-prompt) pair so
        # cached==uncached equivalence covers the cross-attn branch too
        cache = wl.conditioner(seed=0)
        wl.extras["conditioner"] = cache
        wl.extras["text"] = cache.get("tiny smoke prompt")
        wl.extras["neg_text"] = cache.get("bad")
    return wl


@pytest.fixture(scope="module")
def workloads():
    return {name: _tiny_workload(name) for name in MODALITIES}


@pytest.fixture(scope="module")
def exact_cache():
    """Memoized exact (uncached) trajectories keyed by (modality,
    cfg_scale) — the registry sweep would otherwise recompute them per
    policy."""
    return {}


def _exact(exact_cache, workloads, modality, cfg_scale=0.0):
    key = (modality, cfg_scale)
    if key not in exact_cache:
        exact_cache[key], _ = _trajectory(workloads[modality], None,
                                          cfg_scale=cfg_scale)
    return exact_cache[key]


def _trajectory(wl, policy=None, seed=1, batch=1, **den_kw):
    if wl.spec.text:                 # text modalities denoise under prompts
        den_kw.setdefault("text", wl.extras["text"])
        den_kw.setdefault("neg_text", wl.extras["neg_text"])
    sched = linear_schedule(200)
    ts = sched.spaced(NUM_STEPS)
    xT = wl.noise(jax.random.PRNGKey(seed), batch)
    den = wl.denoiser(policy, **den_kw)
    x0, state = sample(den, xT, ts, sched, step_fn=ddim_step,
                       denoiser_state=den.init_state(batch))
    return np.asarray(x0), state


# ----------------------------------------------------------------------
# registry coverage notice + cached==uncached equivalence sweep
# ----------------------------------------------------------------------

def test_always_compute_map_covers_registry():
    """A new registry policy must declare its always-compute point here so
    the modality sweep below keeps covering the whole registry."""
    assert set(ALWAYS_COMPUTE) == set(POLICY_REGISTRY)


@pytest.mark.parametrize("modality", sorted(MODALITIES))
@pytest.mark.parametrize("name", sorted(POLICY_REGISTRY))
def test_always_compute_policies_match_uncached(workloads, exact_cache,
                                                modality, name):
    """Every registry policy, forced to its always-compute operating point,
    must reproduce the exact uncached trajectory on every modality's shapes
    — image latents, video clips (frame axis), audio mel-spectrograms."""
    wl = workloads[modality]
    extras = ALWAYS_COMPUTE[name]
    if callable(extras):
        extras = extras(wl)
    pol = wl.make_policy(name, num_steps=NUM_STEPS, **extras)
    if name == "fastercache_cfg":
        # CFG-branch policy: exercise it in its slot (uncond gate) instead
        exact = _exact(exact_cache, workloads, modality, cfg_scale=2.0)
        cached, _ = _trajectory(wl, None, cfg_scale=2.0, cfg_policy=pol)
    else:
        exact = _exact(exact_cache, workloads, modality)
        cached, _ = _trajectory(wl, pol)
    np.testing.assert_allclose(cached, exact, atol=1e-4, rtol=1e-4,
                               err_msg=f"{name} on {modality}")


@pytest.mark.parametrize("modality", sorted(MODALITIES))
def test_caching_actually_skips_per_modality(workloads, modality):
    """The same interval policy must SAVE compute on every modality (the
    cross-modality claim): n_compute < num_steps, output finite."""
    wl = workloads[modality]
    x0, state = _trajectory(wl, wl.make_policy("taylorseer", interval=4,
                                               num_steps=NUM_STEPS))
    assert np.isfinite(x0).all()
    # predictive policies track validity, interval schedule does the saving
    sched = make_policy("taylorseer", interval=4).static_schedule(NUM_STEPS)
    assert sum(sched) < NUM_STEPS


# ----------------------------------------------------------------------
# temporal-aware policies (core/temporal.py)
# ----------------------------------------------------------------------

def test_temporal_teacache_per_frame_reduction_fires_on_one_frame():
    """Motion concentrated in ONE frame must refresh the max-reduced policy
    while the clip-mean signal distance stays below threshold."""
    F, P, d = 4, 6, 8
    shape = (1, F * P, d)
    base = jnp.ones(shape)
    moved = base.at[:, :P, :].add(2.0)          # only frame 0 changes
    pol_max = TemporalTeaCachePolicy(delta=0.2, frames=F, reduce="max")
    pol_mean = TemporalTeaCachePolicy(delta=0.2, frames=F, reduce="mean")
    d_max = float(pol_max._signal_distance(moved, base))
    d_mean = float(pol_mean._signal_distance(moved, base))
    assert d_max > 0.2 > d_mean     # per-frame max sees it, clip mean doesn't
    # plain TeaCache's clip-level distance agrees with the mean view's scale
    from repro.core import TeaCachePolicy
    d_plain = float(TeaCachePolicy(0.2)._signal_distance(moved, base))
    assert abs(d_plain - d_mean) < d_max / 2


def test_temporal_teacache_want_compute_mirrors_apply(workloads):
    """The serving engine trusts want_compute to mirror apply's branch."""
    wl = workloads["video"]
    pol = wl.make_policy("teacache_video", num_steps=NUM_STEPS, delta=0.15)
    shape = (1, wl.tokens, wl.latent_dim)
    state = pol.init_state(shape, signal_shape=(1, wl.tokens, 8))
    key = jax.random.PRNGKey(0)
    for step in range(6):
        key, k1, k2 = jax.random.split(key, 3)
        x = jax.random.normal(k1, shape)
        sig = jax.random.normal(k2, (1, wl.tokens, 8)) * 0.05 * step
        want = bool(pol.want_compute(state, jnp.asarray(step), x, signal=sig))
        before = int(state["n_compute"])
        _, state = pol.apply(state, jnp.asarray(step), x, lambda v: v + 1.0,
                             signal=sig)
        assert (int(state["n_compute"]) - before == 1) == want


def test_temporal_pab_broadcasts_temporal_attention_longer(workloads):
    """PAB-faithful broadcast: over a trajectory the temporal-attention
    branch recomputes at a LONGER interval than the spatial branch, and the
    all-compute step (step 0) is exact."""
    wl = workloads["video"]
    calls = {"spatial_attn": 0, "temporal_attn": 0, "mlp": 0}
    from repro.models import video_dit
    counted = {
        name: (lambda p, x, c, fn=fn, n=name:
               (calls.__setitem__(n, calls[n] + 1),
                fn(p, x, c, wl.cfg))[1])
        for name, fn in video_dit.BRANCH_FNS.items()}
    stack = TemporalPABStack(counted, wl.cfg.num_layers)
    assert stack.intervals["temporal_attn"] > stack.intervals["spatial_attn"]

    feat = (1, wl.tokens, wl.cfg.d_model)
    state = stack.init(feat)
    x = jax.random.normal(jax.random.PRNGKey(0), feat)
    c = jax.random.normal(jax.random.PRNGKey(1), (1, wl.cfg.d_model))
    for step in range(8):
        calls_before = dict(calls)
        _, state = stack(state, step, x, wl.params["blocks"], c)
        for name in calls:
            computed = calls[name] > calls_before[name]
            assert computed == (step % stack.intervals[name] == 0), (name, step)
    # tracing calls each branch once per concrete-step compute step (the
    # scan traces the layer body once); spatial fired on more steps
    assert calls["spatial_attn"] > calls["temporal_attn"]


def test_pab_video_granularity_step0_exact(workloads):
    """At step 0 every PAB branch computes, so the pab_video denoiser's
    first backbone output must equal the plain forward."""
    wl = workloads["video"]
    den = wl.denoiser(granularity="pab_video")
    x = wl.noise(jax.random.PRNGKey(3), 1)
    t_vec = jnp.full((1,), 10.0, jnp.float32)
    eps, _ = den(den.init_state(1), 0, x, t_vec)
    fwd, _ = backbone_fns(wl.params, wl.cfg)
    ref = fwd(x, t_vec, jnp.zeros((1,), jnp.int32))
    np.testing.assert_allclose(np.asarray(eps), np.asarray(ref), atol=1e-5)


def test_pab_video_reduces_compute_and_stays_finite(workloads):
    wl = workloads["video"]
    x0, _ = _trajectory(wl, granularity="pab_video")
    assert np.isfinite(x0).all()
    stack = wl.pab_stack()
    assert 0.0 < stack.compute_fraction(NUM_STEPS) < 1.0


# ----------------------------------------------------------------------
# serving: engine == single-trajectory reference per modality
# ----------------------------------------------------------------------

def _engine_vs_reference(wl, policy_name, policy_kw, cfg_policy=None,
                         cfg_scale=0.0):
    pol = wl.make_policy(policy_name, num_steps=NUM_STEPS, **policy_kw)
    eng = wl.engine(pol, slots=2, max_steps=NUM_STEPS, cfg_policy=cfg_policy)
    req = DiffusionRequest(0, NUM_STEPS, seed=7, cfg_scale=cfg_scale)
    res = eng.serve([req])
    sched = linear_schedule(1000)
    ts = sched.spaced(NUM_STEPS)
    xT = jax.random.normal(request_noise_key(req),
                           (1, wl.tokens, wl.latent_dim))
    ref_pol = wl.make_policy(policy_name, num_steps=NUM_STEPS, **policy_kw)
    den = wl.denoiser(ref_pol, cfg_scale=cfg_scale, cfg_policy=cfg_policy)
    ref, _ = sample(den, xT, ts, sched, step_fn=ddim_step,
                    denoiser_state=den.init_state(1))
    np.testing.assert_allclose(res[0].x0, np.asarray(ref[0]), atol=5e-3,
                               rtol=1e-3)
    return eng, res


@pytest.mark.parametrize("modality,policy,kw", [
    ("image", "teacache", {"delta": 0.1}),
    ("video", "teacache_video", {"delta": 0.1}),
    ("video", "fora", {"interval": 3}),
    ("audio", "taylorseer", {"interval": 2}),
])
def test_serving_matches_reference_per_modality(workloads, modality, policy,
                                                kw):
    _engine_vs_reference(workloads[modality], policy, kw)


def test_video_serving_temporal_cache_saves_rows(workloads):
    """Acceptance: temporal caching reduces backbone rows on the video
    workload at equal output vs the request's own reference trajectory."""
    wl = workloads["video"]
    eng, res = _engine_vs_reference(wl, "teacache_video", {"delta": 0.3})
    s = eng.telemetry.summary()
    assert s["backbone_rows_saved"] > 0
    assert res[0].record.computed_steps < NUM_STEPS


# ----------------------------------------------------------------------
# mixed-modality pools
# ----------------------------------------------------------------------

def _mixed_engine(workloads, slots=2, cfg_policy_image=None):
    return MixedModalityEngine({
        "image": workloads["image"].engine(
            make_policy("teacache", delta=0.1), slots=slots,
            max_steps=NUM_STEPS, cfg_policy=cfg_policy_image),
        "video": workloads["video"].engine(
            workloads["video"].make_policy("teacache_video", delta=0.1,
                                           num_steps=NUM_STEPS),
            slots=slots, max_steps=NUM_STEPS),
        "audio": workloads["audio"].engine(
            make_policy("fora", interval=2), slots=slots,
            max_steps=NUM_STEPS),
    })


def _mixed_requests(n):
    mods = ("image", "video", "audio")
    return [DiffusionRequest(i, num_steps=NUM_STEPS - 2 * (i % 2), seed=i,
                             class_label=i % 5, modality=mods[i % 3])
            for i in range(n)]


def test_mixed_pool_end_to_end_with_per_modality_telemetry(workloads):
    eng = _mixed_engine(workloads)
    reqs = _mixed_requests(9)
    res = eng.serve(reqs)
    assert [r.request_id for r in res] == list(range(9))
    assert all(np.isfinite(r.x0).all() for r in res)
    # per-modality shapes survived the pool
    shapes = {r.record.modality: r.x0.shape for r in res}
    assert shapes["video"][0] == workloads["video"].tokens
    assert shapes["image"][0] == workloads["image"].tokens

    per = eng.telemetry.by_modality()
    assert set(per) == {"image", "video", "audio"}
    for m, s in per.items():
        assert s["requests"] == 3
        assert s["backbone_rows_computed"] > 0
    top = eng.telemetry.summary()
    assert top["requests"] == 9
    assert top["backbone_rows_computed"] == sum(
        s["backbone_rows_computed"] for s in per.values())
    # token-weighted accounting: video rows are wider than their count
    assert top["backbone_tokens_computed"] > top["backbone_rows_computed"]
    assert set(top["rows_by_modality"]) == {"image", "video", "audio"}


def test_mixed_pool_refill_isolation(workloads):
    """More requests than slots: every request's output must equal serving
    it alone on a fresh engine (reset-on-refill across modality sub-pools —
    slot reuse never leaks cache state between requests)."""
    eng = _mixed_engine(workloads)
    reqs = _mixed_requests(8)              # 8 requests over 3 pools x 2 slots
    res = eng.serve(reqs)
    assert len(res) == 8
    for req, r in zip(reqs, res):
        solo = _mixed_engine(workloads).serve([req])[0]
        np.testing.assert_allclose(r.x0, solo.x0, atol=5e-4, rtol=1e-3,
                                   err_msg=f"request {req.request_id} "
                                           f"({req.modality})")


def test_mixed_pool_rejects_unknown_modality(workloads):
    eng = _mixed_engine(workloads)
    with pytest.raises(KeyError):
        eng.serve([DiffusionRequest(0, NUM_STEPS, modality="3d")])


def test_string_policy_gets_config_frame_count(workloads):
    """The engine's string-policy path must size teacache_video's per-frame
    grouping from the CONFIG, not the registry default."""
    wl = workloads["video"]
    eng = wl.engine("teacache_video", slots=1, max_steps=NUM_STEPS)
    assert eng.policy.frames == wl.frames


def test_one_session_per_engine_enforced(workloads):
    """Interleaved sessions of ONE engine would corrupt its per-slot tables
    — the second start_session must refuse; finish() releases the engine."""
    eng = workloads["image"].engine("none", slots=1, max_steps=NUM_STEPS)
    s1 = eng.start_session([DiffusionRequest(0, NUM_STEPS)])
    with pytest.raises(RuntimeError):
        eng.start_session([DiffusionRequest(1, NUM_STEPS)])
    while not s1.done:
        s1.tick()
    s1.finish()
    assert len(eng.serve([DiffusionRequest(2, NUM_STEPS)])) == 1


def test_mixed_pool_rejects_shared_engine_instance(workloads):
    eng = workloads["image"].engine("none", slots=1, max_steps=NUM_STEPS)
    with pytest.raises(ValueError):
        MixedModalityEngine({"a": eng, "b": eng})


def test_mixed_warmup_precompiles_every_bucket(workloads):
    """engine.warmup() across sub-pools: every bucket program a compacted
    tick can request must already be compiled before the first tick."""
    eng = _mixed_engine(workloads)
    eng.warmup()
    for name, pool in eng.pools.items():
        S = pool.slots
        expected = ({0}
                    | {min(1 << (n - 1).bit_length(), S)
                       for n in range(1, S + 1)}
                    | {min(1 << (n - 1).bit_length(), 2 * S)
                       for n in range(1, 2 * S + 1)})
        assert set(pool._compact_ticks) == expected, name
    # serving dispatches only pre-compiled buckets — nothing new appears
    eng.serve(_mixed_requests(3))
    for name, pool in eng.pools.items():
        S = pool.slots
        expected = ({0}
                    | {min(1 << (n - 1).bit_length(), S)
                       for n in range(1, S + 1)}
                    | {min(1 << (n - 1).bit_length(), 2 * S)
                       for n in range(1, 2 * S + 1)})
        assert set(pool._compact_ticks) == expected, name


def test_compacted_matches_dense_video_pool(workloads):
    """Row compaction must stay output-equal on the video modality."""
    wl = workloads["video"]
    reqs = [DiffusionRequest(i, num_steps=NUM_STEPS, seed=i,
                             cfg_scale=2.0 if i % 2 == 0 else 0.0)
            for i in range(3)]
    out = {}
    for compact in (True, False):
        eng = wl.engine(wl.make_policy("teacache_video", delta=0.1,
                                       num_steps=NUM_STEPS),
                        slots=2, max_steps=NUM_STEPS,
                        cfg_policy=FasterCacheCFG(3, NUM_STEPS),
                        row_compaction=compact)
        out[compact] = eng.serve(reqs)
    for a, b in zip(out[True], out[False]):
        np.testing.assert_allclose(a.x0, b.x0, atol=5e-4, rtol=1e-3)
        assert a.record.computed_steps == b.record.computed_steps


# ----------------------------------------------------------------------
# negative-prompt null conditioning (CFG follow-up #1)
# ----------------------------------------------------------------------

def test_null_vector_conditioning_matches_reference(workloads):
    """A null_label VECTOR must flow through the serving engine and match
    the single-trajectory CachedDenoiser(null_embed=...) path."""
    wl = workloads["image"]
    vec = np.asarray(jax.random.normal(jax.random.PRNGKey(9),
                                       (wl.cfg.d_model,))) * 0.1
    req = DiffusionRequest(0, NUM_STEPS, seed=3, cfg_scale=2.5,
                           null_label=vec)
    eng = wl.engine(make_policy("fora", interval=2), slots=2,
                    max_steps=NUM_STEPS,
                    cfg_policy=FasterCacheCFG(2, NUM_STEPS))
    res = eng.serve([req])
    sched = linear_schedule(1000)
    ts = sched.spaced(NUM_STEPS)
    xT = jax.random.normal(request_noise_key(req),
                           (1, wl.tokens, wl.latent_dim))
    den = wl.denoiser(make_policy("fora", interval=2), cfg_scale=2.5,
                      cfg_policy=FasterCacheCFG(2, NUM_STEPS), null_embed=vec)
    ref, _ = sample(den, xT, ts, sched, step_fn=ddim_step,
                    denoiser_state=den.init_state(1))
    np.testing.assert_allclose(res[0].x0, np.asarray(ref[0]), atol=5e-3,
                               rtol=1e-3)


def test_null_vector_changes_output_vs_null_class(workloads):
    """The vector must actually condition the uncond branch: output differs
    from the default null-class run, and the uncached cfg_denoise_fn
    reference agrees with the engine on both."""
    wl = workloads["image"]
    vec = np.asarray(jax.random.normal(jax.random.PRNGKey(11),
                                       (wl.cfg.d_model,))) * 0.5
    eng = wl.engine("none", slots=1, max_steps=NUM_STEPS)
    base = eng.serve([DiffusionRequest(0, NUM_STEPS, seed=4, cfg_scale=2.0)])
    with_vec = eng.serve([DiffusionRequest(0, NUM_STEPS, seed=4,
                                           cfg_scale=2.0, null_label=vec)])
    assert np.abs(base[0].x0 - with_vec[0].x0).max() > 1e-4

    req = DiffusionRequest(0, NUM_STEPS, seed=4, cfg_scale=2.0,
                           null_label=vec)
    sched = linear_schedule(1000)
    ts = sched.spaced(NUM_STEPS)
    xT = jax.random.normal(request_noise_key(req),
                           (1, wl.tokens, wl.latent_dim))
    exact, _ = sample(cfg_denoise_fn(wl.params, wl.cfg, 2.0, null_embed=vec),
                      xT, ts, sched, step_fn=ddim_step)
    np.testing.assert_allclose(with_vec[0].x0, np.asarray(exact[0]),
                               atol=5e-3, rtol=1e-3)


def test_null_vector_bad_shape_rejected(workloads):
    wl = workloads["image"]
    eng = wl.engine("none", slots=1, max_steps=NUM_STEPS)
    with pytest.raises(ValueError):
        eng.serve([DiffusionRequest(0, NUM_STEPS, cfg_scale=2.0,
                                    null_label=np.zeros(3, np.float32))])


# ----------------------------------------------------------------------
# FasterCacheCFG low-frequency residual variant (CFG follow-up #2)
# ----------------------------------------------------------------------

def test_fastercache_lowfreq_interval1_exact(workloads, exact_cache):
    """At interval=1 the lowfreq variant never reuses: exact guided output
    on every modality."""
    for name, wl in workloads.items():
        exact = _exact(exact_cache, workloads, name, cfg_scale=2.0)
        got, _ = _trajectory(wl, None, cfg_scale=2.0,
                             cfg_policy=FasterCacheCFG(
                                 1, NUM_STEPS, mode="lowfreq"))
        np.testing.assert_allclose(got, exact, atol=1e-4, rtol=1e-4)


def test_fastercache_lowfreq_serving_matches_reference(workloads):
    """Engine == CachedDenoiser on the lowfreq cond-residual mode (the
    cond_out signal must thread identically through both paths)."""
    wl = workloads["image"]
    _engine_vs_reference(wl, "fora", {"interval": 2},
                         cfg_policy=FasterCacheCFG(2, NUM_STEPS,
                                                   mode="lowfreq"),
                         cfg_scale=2.5)


def test_fastercache_lowfreq_differs_from_extrapolate(workloads):
    """The two reconstructions are genuinely different approximations, both
    finite and both cheaper than naive two-branch (same schedule)."""
    wl = workloads["image"]
    outs = {}
    for mode in ("extrapolate", "lowfreq"):
        pol = FasterCacheCFG(3, NUM_STEPS, mode=mode)
        outs[mode], _ = _trajectory(wl, None, cfg_scale=3.0, cfg_policy=pol)
        assert np.isfinite(outs[mode]).all()
        assert pol.static_schedule(NUM_STEPS).count(True) < NUM_STEPS
    assert np.abs(outs["extrapolate"] - outs["lowfreq"]).max() > 1e-5


def test_fastercache_lowfreq_halves_cache_memory():
    shape = (1, 16, 8)
    from repro.core import cache_state_bytes
    extra = FasterCacheCFG(4, 8).init_state(shape)
    low = FasterCacheCFG(4, 8, mode="lowfreq").init_state(shape)
    assert cache_state_bytes(low) == cache_state_bytes(extra) // 2
