"""Diffusion serving subsystem: scheduler lifecycle, batched cache states,
reset-on-refill isolation, serving-vs-reference fidelity (unguided and
CFG-guided), preemption accounting, autotuning."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (POLICY_REGISTRY, BlockCachePolicy, FasterCacheCFG,
                        SlotBatchedPolicy, make_policy)
from repro.diffusion import (CachedDenoiser, ddim_step, linear_schedule,
                             sample)
from repro.diffusion.pipeline import cfg_denoise_fn
from repro.models import init_params, perturb_zero_init
from repro.serving import RequestQueue
from repro.serving.diffusion import (SLA, DiffusionRequest,
                                     DiffusionServingEngine, SlotScheduler,
                                     autotune, request_noise_key)

NUM_STEPS = 12


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("dit-xl").reduced(num_layers=3, d_model=128,
                                       num_heads=4, num_kv_heads=4,
                                       d_ff=256, dit_patch_tokens=16,
                                       dit_in_dim=8, dit_num_classes=10)
    params = perturb_zero_init(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _request_xT(cfg, req):
    """The engine's initial noise for `req` (seed + request_id folded)."""
    return jax.random.normal(request_noise_key(req),
                             (1, cfg.dit_patch_tokens, cfg.dit_in_dim))


def _reference(cfg, params, policy_name, req, **kw):
    """Single-stream CachedDenoiser trajectory on the engine's noise."""
    sched = linear_schedule(1000)
    ts = sched.spaced(req.num_steps)
    xT = _request_xT(cfg, req)
    pol = make_policy(policy_name, num_steps=req.num_steps, **kw)
    den = CachedDenoiser(params, cfg, pol, class_label=req.class_label)
    x0, _ = sample(den, xT, ts, sched, step_fn=ddim_step,
                   denoiser_state=den.init_state(1))
    return np.asarray(x0[0])


def _cfg_reference(cfg, params, req, cfg_policy=None, policy=None):
    """Single-stream guided trajectory (CachedDenoiser CFG path) on the
    engine's noise; cfg_policy=None is the exact two-branch baseline."""
    sched = linear_schedule(1000)
    ts = sched.spaced(req.num_steps)
    xT = _request_xT(cfg, req)
    den = CachedDenoiser(params, cfg, policy, cfg_scale=req.cfg_scale,
                         cfg_policy=cfg_policy, class_label=req.class_label)
    x0, _ = sample(den, xT, ts, sched, step_fn=ddim_step,
                   denoiser_state=den.init_state(1))
    return np.asarray(x0[0])


# ----------------------------------------------------------------------
# host-side machinery (no model, no jit)
# ----------------------------------------------------------------------

def test_request_queue_fifo():
    q = RequestQueue([1, 2, 3])
    q.push(4)
    assert len(q) == 4 and q.submitted == 4
    assert q.pop() == 1
    assert q.pop_many(2) == [2, 3]
    assert q.peek() == 4 and q.pop() == 4
    assert not q and q.pop() is None and q.pop_many(5) == []


def test_scheduler_lifecycle():
    sched = SlotScheduler(num_slots=2)
    reqs = [DiffusionRequest(i, num_steps=2 + i) for i in range(3)]
    sched.submit_all(reqs)

    admitted = sched.admit(tick=0)
    assert [r.request_id for _, r in admitted] == [0, 1]
    assert sched.active_mask() == [True, True]
    assert sched.admit(tick=1) == []          # pool full, req 2 queued
    assert len(sched.queue) == 1

    sched.advance(); sched.advance()          # req 0 (budget 2) finishes
    done = sched.harvest()
    assert [(s.index, r.request_id) for s, r in done] == [(0, 0)]
    assert sched.active_mask() == [False, True]

    # mid-flight refill into the freed slot while slot 1 keeps running
    admitted = sched.admit(tick=2)
    assert [(s.index, r.request_id) for s, r in admitted] == [(0, 2)]
    assert sched.steps() == [0, 2]

    sched.advance()                           # req 1 (budget 3) finishes
    assert [r.request_id for _, r in sched.harvest()] == [1]
    for _ in range(3):
        sched.advance()
    assert [r.request_id for _, r in sched.harvest()] == [2]
    assert sched.idle()


def test_scheduler_phase_aligned_admission():
    sched = SlotScheduler(num_slots=2, align=4)
    sched.submit_all([DiffusionRequest(i, num_steps=4) for i in range(4)])
    assert sched.admit(tick=0) != []          # aligned tick: admits
    for _, r in sched.harvest():
        pass
    for tick in range(1, 4):
        sched.advance()
        sched.harvest()
        assert sched.admit(tick) == []        # off-phase: queue waits
    sched.advance()
    sched.harvest()                           # budgets exhausted at tick 4
    admitted = sched.admit(tick=4)
    assert [r.request_id for _, r in admitted] == [2, 3]


# ----------------------------------------------------------------------
# batched cache states (SlotBatchedPolicy)
# ----------------------------------------------------------------------

def test_slot_batched_policy_reset_on_refill():
    """Resetting one slot restores its fresh state and leaves others alone."""
    pol = make_policy("taylorseer", interval=2)
    batched = SlotBatchedPolicy(pol, slots=3)
    shape = (4, 8)
    states = batched.init_state(shape)
    fresh = batched.init_slot_state(shape)

    xs = jax.random.normal(jax.random.PRNGKey(0), (3, *shape))
    steps = jnp.zeros((3,), jnp.int32)
    _, states = batched.apply(states, steps, xs, lambda x: x * 2.0)
    assert float(jnp.abs(states["diffs"]).max()) > 0.0  # all slots dirty

    states2 = SlotBatchedPolicy.reset_slot(states, 1, fresh)
    for leaf, fresh_leaf, orig in zip(
            jax.tree_util.tree_leaves(states2),
            jax.tree_util.tree_leaves(fresh),
            jax.tree_util.tree_leaves(states)):
        np.testing.assert_array_equal(np.asarray(leaf[1]),
                                      np.asarray(fresh_leaf))
        np.testing.assert_array_equal(np.asarray(leaf[0]),
                                      np.asarray(orig[0]))
        np.testing.assert_array_equal(np.asarray(leaf[2]),
                                      np.asarray(orig[2]))


@pytest.mark.parametrize("name,kw", [
    ("fora", {"interval": 3}),
    ("taylorseer", {"interval": 3}),
    ("teacache", {"delta": 0.15}),
    ("magcache", {"delta": 0.1, "num_steps": 10}),
    ("easycache", {"tau": 3.0}),
    ("foresight", {}),
])
def test_want_compute_mirrors_apply(name, kw):
    """The serving engine dispatches the dummy-compute program whenever
    want_compute is all-False, so the prediction must match the branch
    `apply` actually takes (counted via the policy's compute counters)."""
    pol = make_policy(name, **kw)
    shape = (1, 6, 4)
    state = pol.init_state(shape)
    key = jax.random.PRNGKey(0)
    predicted = actual = 0
    for step in range(10):
        key, sub = jax.random.split(key)
        x = jax.random.normal(sub, shape)
        w = bool(pol.want_compute(state, jnp.asarray(step), x))
        y, state = pol.apply(state, jnp.asarray(step), x,
                             lambda xx: jnp.tanh(xx) * 3.0)
        predicted += int(w)
    for counter in ("n_compute", "n_valid"):
        if counter in state:
            actual = int(state[counter])
            break
    else:
        sched = pol.static_schedule(10)
        actual = sum(map(bool, sched))
    assert predicted == actual, (name, predicted, actual)


# ----------------------------------------------------------------------
# end-to-end serving
# ----------------------------------------------------------------------

def test_refill_resets_cache_state(setup):
    """Slot reuse must not leak cache state: request B served after A
    through the same slot must equal B served alone (bitwise)."""
    cfg, params = setup
    a = DiffusionRequest(0, NUM_STEPS, seed=1)
    b = DiffusionRequest(1, NUM_STEPS, seed=2)

    eng = DiffusionServingEngine(params, cfg, "taylorseer", slots=1,
                                 max_steps=16)
    both = eng.serve([a, b])
    eng2 = DiffusionServingEngine(params, cfg, "taylorseer", slots=1,
                                  max_steps=16)
    alone = eng2.serve([b])
    np.testing.assert_array_equal(both[1].x0, alone[0].x0)


@pytest.mark.parametrize("name", ["none", "fora", "taylorseer", "teacache",
                                  "toca"])
def test_serving_matches_cached_denoiser(setup, name):
    """One request through the slot machinery must match the single-
    trajectory CachedDenoiser path (same policy, same grid).  `toca` guards
    the plan-derivation rule: its partial branch calls compute_fn, so the
    engine must never hand it a skip tick despite its interval
    static_schedule."""
    cfg, params = setup
    pol = make_policy(name, num_steps=NUM_STEPS)
    eng = DiffusionServingEngine(params, cfg, pol, slots=2, max_steps=16)
    req = DiffusionRequest(0, NUM_STEPS, seed=7)
    res = eng.serve([req])
    ref = _reference(cfg, params, name, req)
    np.testing.assert_allclose(res[0].x0, ref, atol=5e-3, rtol=1e-3)


def test_e2e_mixed_budget_serving_smoke(setup):
    """16 mixed-budget requests through 4 slots: all complete, telemetry is
    populated, and interval caching actually skips backbone ticks."""
    cfg, params = setup
    reqs = [DiffusionRequest(i, num_steps=(8, 12, 16)[i % 3], seed=i,
                             traffic_class=("interactive", "quality")[i % 2])
            for i in range(16)]
    eng = DiffusionServingEngine(params, cfg, "taylorseer", slots=4,
                                 max_steps=16)
    res = eng.serve(reqs)
    assert len(res) == 16
    assert all(np.isfinite(r.x0).all() for r in res)
    assert [r.request_id for r in res] == list(range(16))

    s = eng.telemetry.summary()
    assert s["requests"] == 16
    assert s["requests_preempted"] == 0
    assert s["throughput_rps"] > 0
    assert 0.0 < s["compute_fraction_mean"] < 1.0
    # interval=4: most ticks skip; unguided pools never need the 2S-row
    # both-branch program (that is what tick_cond_only exists for)
    assert eng.telemetry.ticks_skip > eng.telemetry.ticks_cond > 0
    assert eng.telemetry.ticks_full == 0
    assert s["cache_state_bytes_per_slot"] > 0
    # the autotune latency pair must see backbone time even though unguided
    # pools record it all under cond-only ticks
    t_back, t_skip = eng.telemetry.step_time_ms()
    assert t_back > 0 and t_back == s["tick_ms_backbone_mean"]
    for r in res:
        assert r.record.latency > 0
        assert r.record.queue_wait >= 0
        assert 0.0 < r.record.compute_fraction <= 1.0
    by_class = eng.telemetry.by_traffic_class()
    assert set(by_class) == {"interactive", "quality"}


def test_serving_rejects_over_budget_request(setup):
    cfg, params = setup
    eng = DiffusionServingEngine(params, cfg, "none", slots=1, max_steps=8)
    with pytest.raises(ValueError):
        eng.serve([DiffusionRequest(0, num_steps=9)])


def test_default_seed_requests_draw_distinct_noise(setup):
    """Regression: PRNGKey(req.seed) alone gave every default-seeded request
    identical initial noise (identical samples); the request id must be
    folded into the key."""
    cfg, params = setup
    ka = request_noise_key(DiffusionRequest(0, 8))
    kb = request_noise_key(DiffusionRequest(1, 8))
    assert not np.array_equal(np.asarray(ka), np.asarray(kb))

    eng = DiffusionServingEngine(params, cfg, "none", slots=2, max_steps=8)
    res = eng.serve([DiffusionRequest(0, num_steps=8),
                     DiffusionRequest(1, num_steps=8)])
    assert np.abs(res[0].x0 - res[1].x0).max() > 1e-3


def test_max_ticks_reports_preempted_requests(setup):
    """Regression: serve(max_ticks=...) silently dropped unfinished requests;
    they must surface as preempted records, excluded from latency stats."""
    cfg, params = setup
    eng = DiffusionServingEngine(params, cfg, "none", slots=1, max_steps=8)
    # slot pool of 1: request 0 is mid-flight at tick 4, request 1 queued
    res = eng.serve([DiffusionRequest(0, num_steps=8),
                     DiffusionRequest(1, num_steps=8)], max_ticks=4)
    assert res == []
    tele = eng.telemetry
    assert len(tele.records) == 0
    assert sorted(r.request_id for r in tele.preempted_records) == [0, 1]
    assert all(r.preempted for r in tele.preempted_records)
    s = tele.summary()
    assert s["requests"] == 0 and s["requests_preempted"] == 2
    # preempted records don't poison it; an empty latency window is nan
    assert math.isnan(s["latency_p50_s"])

    # a full run of the same engine reports zero preemptions
    res = eng.serve([DiffusionRequest(2, num_steps=8)])
    assert len(res) == 1
    assert eng.telemetry.summary()["requests_preempted"] == 0


def test_engine_static_plan_survives_short_blockcache_profile(setup):
    """Regression: BlockCachePolicy with a profile shorter than max_steps
    raised IndexError in the static-plan builder (silent device fallback
    whose gather clamped to the last entry).  Overflow steps now recompute,
    and served output matches the single-stream path on the same policy."""
    cfg, params = setup
    profile = [0.0, 0.01, 0.5, 0.01, 0.5, 0.01]          # 6-step calibration
    pol = BlockCachePolicy(profile, delta=0.1)
    eng = DiffusionServingEngine(params, cfg, pol, slots=1, max_steps=16)
    assert eng._static_plan is not None                   # no IndexError
    assert eng._static_plan[len(profile):].all()          # overflow: compute

    req = DiffusionRequest(0, num_steps=NUM_STEPS, seed=5)   # 12 > 6
    res = eng.serve([req])
    sched = linear_schedule(1000)
    ts = sched.spaced(NUM_STEPS)
    den = CachedDenoiser(params, cfg, pol)
    ref, _ = sample(den, _request_xT(cfg, req), ts, sched, step_fn=ddim_step,
                    denoiser_state=den.init_state(1))
    np.testing.assert_allclose(res[0].x0, np.asarray(ref[0]),
                               atol=5e-3, rtol=1e-3)


# ----------------------------------------------------------------------
# CFG serving (classifier-free guidance, per-slot FasterCacheCFG)
# ----------------------------------------------------------------------

def test_serving_cfg_matches_exact_baseline(setup):
    """A guided request with no CFG cache (naive two-branch) must match the
    exact single-stream cfg_denoise_fn trajectory."""
    cfg, params = setup
    req = DiffusionRequest(0, NUM_STEPS, seed=3, class_label=4, cfg_scale=2.5)
    eng = DiffusionServingEngine(params, cfg, "none", slots=2, max_steps=16)
    res = eng.serve([req])
    sched = linear_schedule(1000)
    ts = sched.spaced(NUM_STEPS)
    ref, _ = sample(cfg_denoise_fn(params, cfg, 2.5, 4), _request_xT(cfg, req),
                    ts, sched, step_fn=ddim_step)
    np.testing.assert_allclose(res[0].x0, np.asarray(ref[0]),
                               atol=5e-3, rtol=1e-3)
    # naive mode: every backbone tick carries both branches
    assert eng.telemetry.ticks_full == NUM_STEPS
    assert eng.telemetry.ticks_cond == 0
    assert res[0].record.uncond_computed_steps == NUM_STEPS
    assert res[0].record.uncond_saved_steps == 0


def test_serving_cfg_matches_fastercache_denoiser(setup):
    """Engine-served FasterCacheCFG must match the single-stream
    CachedDenoiser(cfg_policy=FasterCacheCFG) path on the same grid."""
    cfg, params = setup
    req = DiffusionRequest(0, NUM_STEPS, seed=3, class_label=4, cfg_scale=2.5)
    eng = DiffusionServingEngine(params, cfg, "none", slots=2, max_steps=16,
                                 cfg_policy=FasterCacheCFG(3, NUM_STEPS))
    res = eng.serve([req])
    ref = _cfg_reference(cfg, params, req,
                         cfg_policy=FasterCacheCFG(3, NUM_STEPS))
    np.testing.assert_allclose(res[0].x0, ref, atol=5e-3, rtol=1e-3)
    # interval=3 over 12 steps: 4 both-branch ticks, 8 cond-only ticks
    tele = eng.telemetry
    assert tele.ticks_full == 4 and tele.ticks_cond == 8
    assert res[0].record.uncond_computed_steps == 4
    assert res[0].record.uncond_saved_steps == 8
    assert tele.summary()["uncond_rows_saved"] > 0


def test_serving_cfg_mixed_budgets_use_per_slot_blend_weight(setup):
    """Two guided requests with different step budgets share the pool; each
    must match its own single-stream FasterCacheCFG reference (the blend
    weight w = step/(num_steps-1) is per-slot, not engine-global)."""
    cfg, params = setup
    reqs = [DiffusionRequest(0, 12, seed=3, class_label=1, cfg_scale=2.0),
            DiffusionRequest(1, 8, seed=4, class_label=2, cfg_scale=3.0)]
    eng = DiffusionServingEngine(params, cfg, "none", slots=2, max_steps=16,
                                 cfg_policy=FasterCacheCFG(4, 16))
    res = eng.serve(reqs)
    for r, req in zip(res, reqs):
        ref = _cfg_reference(cfg, params, req,
                             cfg_policy=FasterCacheCFG(4, req.num_steps))
        np.testing.assert_allclose(r.x0, ref, atol=5e-3, rtol=1e-3)


def test_serving_cfg_refill_resets_cfg_cache(setup):
    """Mid-flight refill isolation of the per-slot CFG cache: guided request
    B served after A through the same slot must equal B served alone."""
    cfg, params = setup
    a = DiffusionRequest(0, NUM_STEPS, seed=1, class_label=1, cfg_scale=3.0)
    b = DiffusionRequest(1, NUM_STEPS, seed=2, class_label=2, cfg_scale=2.0)
    eng = DiffusionServingEngine(params, cfg, "fora", slots=1, max_steps=16,
                                 cfg_policy=FasterCacheCFG(4, NUM_STEPS))
    both = eng.serve([a, b])
    eng2 = DiffusionServingEngine(params, cfg, "fora", slots=1, max_steps=16,
                                  cfg_policy=FasterCacheCFG(4, NUM_STEPS))
    alone = eng2.serve([b])
    np.testing.assert_array_equal(both[1].x0, alone[0].x0)


def test_serving_mixed_guided_unguided_pool(setup):
    """Guided and unguided requests share slots; each matches its own
    single-stream reference and CFG accounting stays per-request."""
    cfg, params = setup
    guided = DiffusionRequest(0, NUM_STEPS, seed=3, class_label=4,
                              cfg_scale=2.5)
    plain = DiffusionRequest(1, NUM_STEPS, seed=5, class_label=2)
    eng = DiffusionServingEngine(params, cfg, "fora", slots=2, max_steps=16,
                                 cfg_policy=FasterCacheCFG(4, NUM_STEPS))
    res = eng.serve([guided, plain])

    ref_g = _cfg_reference(cfg, params, guided, policy=make_policy("fora"),
                           cfg_policy=FasterCacheCFG(4, NUM_STEPS))
    ref_p = _reference(cfg, params, "fora", plain)
    np.testing.assert_allclose(res[0].x0, ref_g, atol=5e-3, rtol=1e-3)
    np.testing.assert_allclose(res[1].x0, ref_p, atol=5e-3, rtol=1e-3)

    assert res[0].record.guided and not res[1].record.guided
    assert 0 < res[0].record.uncond_computed_steps < NUM_STEPS
    assert res[1].record.uncond_computed_steps == 0
    s = eng.telemetry.summary()
    assert s["guided_requests"] == 1
    assert s["uncond_saved_steps_total"] == res[0].record.uncond_saved_steps


def test_serving_cfg_saves_uncond_rows_vs_naive(setup):
    """With FasterCacheCFG the engine dispatches measurably fewer uncond
    backbone rows than naive two-branch serving of the same queue."""
    cfg, params = setup
    reqs = [DiffusionRequest(i, 8, seed=i, class_label=i % 5, cfg_scale=3.0)
            for i in range(4)]
    rows = {}
    for mode, cfg_pol in (("naive", None),
                          ("fastercache", FasterCacheCFG(4, 8))):
        eng = DiffusionServingEngine(params, cfg, "fora", slots=2,
                                     max_steps=8, cfg_policy=cfg_pol)
        eng.serve(reqs)
        rows[mode] = eng.telemetry.summary()["uncond_rows_computed"]
    assert rows["fastercache"] < rows["naive"]


# ----------------------------------------------------------------------
# autotuning
# ----------------------------------------------------------------------

def test_autotune_respects_sla(setup):
    cfg, params = setup
    cands = [("none", {}), ("fora", {"interval": 4}),
             ("taylorseer", {"interval": 4, "order": 2})]
    strict = autotune(params, cfg, SLA("strict", min_psnr=50.0),
                      candidates=cands, num_steps=NUM_STEPS)
    loose = autotune(params, cfg, SLA("loose", min_psnr=-100.0),
                     candidates=cands, num_steps=NUM_STEPS)
    assert strict.policy_name == "none" and strict.feasible
    # everything is feasible under the loose SLA: cheapest candidate wins
    assert loose.compute_fraction <= strict.compute_fraction
    assert loose.policy_name in ("fora", "taylorseer")
    assert loose.align == 4
    assert loose.make() is not None


def test_autotune_cfg_aware_sweep(setup):
    """Guided tuning crosses candidates with uncond-reuse intervals; under a
    loose SLA the CFG-cached variant wins on row-weighted compute fraction,
    and the tuned choice reconstructs an engine-ready cfg_policy."""
    cfg, params = setup
    cands = [("none", {}), ("fora", {"interval": 4})]
    loose = autotune(params, cfg, SLA("loose", min_psnr=-100.0),
                     candidates=cands, num_steps=NUM_STEPS,
                     cfg_scale=2.0, cfg_intervals=(None, 4))
    assert loose.cfg_interval == 4
    assert loose.uncond_compute_fraction < 1.0
    assert loose.compute_fraction < 1.0
    pol = loose.make_cfg_policy(NUM_STEPS)
    assert isinstance(pol, FasterCacheCFG) and pol.interval == 4
    assert loose.align == 4

    strict = autotune(params, cfg, SLA("strict", min_psnr=200.0),
                      candidates=[("none", {})], num_steps=NUM_STEPS,
                      cfg_scale=2.0, cfg_intervals=(None, 4))
    # infeasible SLA falls back to the highest-PSNR candidate: the naive
    # two-branch exact server (uncond recomputed every step)
    assert strict.policy_name == "none" and strict.cfg_interval is None
    assert not strict.feasible
    assert strict.make_cfg_policy(NUM_STEPS) is None


def test_policy_registry_covers_taxonomy():
    """dbcache is deliberately structural (not in make_policy); the registry
    plus STRUCTURAL_POLICIES must cover it with a pointed error."""
    from repro.core import STRUCTURAL_POLICIES
    assert "dbcache" in STRUCTURAL_POLICIES
    with pytest.raises(KeyError, match="structural"):
        make_policy("dbcache")
    # every registry entry constructs (the learned gate needs its trained
    # params and the calibrated schedule its measured profile — neither has
    # a meaningful default, and the registry says so instead of silently
    # serving a random gate / an uncalibrated schedule)
    from repro.core.learned import init_gate
    with pytest.raises(ValueError, match="gate"):
        make_policy("lazydit")
    with pytest.raises(ValueError, match="profile"):
        make_policy("blockcache")
    required = {"lazydit": {"gate": init_gate(jax.random.PRNGKey(0), 4)},
                "blockcache": {"profile": [0.0, 0.2, 0.05, 0.2]}}
    for name in POLICY_REGISTRY:
        assert make_policy(name, **required.get(name, {})) is not None
