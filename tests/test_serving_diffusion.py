"""Diffusion serving subsystem: scheduler lifecycle, batched cache states,
reset-on-refill isolation, serving-vs-reference fidelity, autotuning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import POLICY_REGISTRY, SlotBatchedPolicy, make_policy
from repro.diffusion import (CachedDenoiser, ddim_step, linear_schedule,
                             sample)
from repro.models import init_params, perturb_zero_init
from repro.serving import RequestQueue
from repro.serving.diffusion import (SLA, DiffusionRequest,
                                     DiffusionServingEngine, SlotScheduler,
                                     autotune)

NUM_STEPS = 12


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("dit-xl").reduced(num_layers=3, d_model=128,
                                       num_heads=4, num_kv_heads=4,
                                       d_ff=256, dit_patch_tokens=16,
                                       dit_in_dim=8, dit_num_classes=10)
    params = perturb_zero_init(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _reference(cfg, params, policy_name, num_steps, seed, **kw):
    sched = linear_schedule(1000)
    ts = sched.spaced(num_steps)
    xT = jax.random.normal(jax.random.PRNGKey(seed),
                           (1, cfg.dit_patch_tokens, cfg.dit_in_dim))
    pol = make_policy(policy_name, num_steps=num_steps, **kw)
    den = CachedDenoiser(params, cfg, pol)
    x0, _ = sample(den, xT, ts, sched, step_fn=ddim_step,
                   denoiser_state=den.init_state(1))
    return np.asarray(x0[0])


# ----------------------------------------------------------------------
# host-side machinery (no model, no jit)
# ----------------------------------------------------------------------

def test_request_queue_fifo():
    q = RequestQueue([1, 2, 3])
    q.push(4)
    assert len(q) == 4 and q.submitted == 4
    assert q.pop() == 1
    assert q.pop_many(2) == [2, 3]
    assert q.peek() == 4 and q.pop() == 4
    assert not q and q.pop() is None and q.pop_many(5) == []


def test_scheduler_lifecycle():
    sched = SlotScheduler(num_slots=2)
    reqs = [DiffusionRequest(i, num_steps=2 + i) for i in range(3)]
    sched.submit_all(reqs)

    admitted = sched.admit(tick=0)
    assert [r.request_id for _, r in admitted] == [0, 1]
    assert sched.active_mask() == [True, True]
    assert sched.admit(tick=1) == []          # pool full, req 2 queued
    assert len(sched.queue) == 1

    sched.advance(); sched.advance()          # req 0 (budget 2) finishes
    done = sched.harvest()
    assert [(s.index, r.request_id) for s, r in done] == [(0, 0)]
    assert sched.active_mask() == [False, True]

    # mid-flight refill into the freed slot while slot 1 keeps running
    admitted = sched.admit(tick=2)
    assert [(s.index, r.request_id) for s, r in admitted] == [(0, 2)]
    assert sched.steps() == [0, 2]

    sched.advance()                           # req 1 (budget 3) finishes
    assert [r.request_id for _, r in sched.harvest()] == [1]
    for _ in range(3):
        sched.advance()
    assert [r.request_id for _, r in sched.harvest()] == [2]
    assert sched.idle()


def test_scheduler_phase_aligned_admission():
    sched = SlotScheduler(num_slots=2, align=4)
    sched.submit_all([DiffusionRequest(i, num_steps=4) for i in range(4)])
    assert sched.admit(tick=0) != []          # aligned tick: admits
    for _, r in sched.harvest():
        pass
    for tick in range(1, 4):
        sched.advance()
        sched.harvest()
        assert sched.admit(tick) == []        # off-phase: queue waits
    sched.advance()
    sched.harvest()                           # budgets exhausted at tick 4
    admitted = sched.admit(tick=4)
    assert [r.request_id for _, r in admitted] == [2, 3]


# ----------------------------------------------------------------------
# batched cache states (SlotBatchedPolicy)
# ----------------------------------------------------------------------

def test_slot_batched_policy_reset_on_refill():
    """Resetting one slot restores its fresh state and leaves others alone."""
    pol = make_policy("taylorseer", interval=2)
    batched = SlotBatchedPolicy(pol, slots=3)
    shape = (4, 8)
    states = batched.init_state(shape)
    fresh = batched.init_slot_state(shape)

    xs = jax.random.normal(jax.random.PRNGKey(0), (3, *shape))
    steps = jnp.zeros((3,), jnp.int32)
    _, states = batched.apply(states, steps, xs, lambda x: x * 2.0)
    assert float(jnp.abs(states["diffs"]).max()) > 0.0  # all slots dirty

    states2 = SlotBatchedPolicy.reset_slot(states, 1, fresh)
    for leaf, fresh_leaf, orig in zip(
            jax.tree_util.tree_leaves(states2),
            jax.tree_util.tree_leaves(fresh),
            jax.tree_util.tree_leaves(states)):
        np.testing.assert_array_equal(np.asarray(leaf[1]),
                                      np.asarray(fresh_leaf))
        np.testing.assert_array_equal(np.asarray(leaf[0]),
                                      np.asarray(orig[0]))
        np.testing.assert_array_equal(np.asarray(leaf[2]),
                                      np.asarray(orig[2]))


@pytest.mark.parametrize("name,kw", [
    ("fora", {"interval": 3}),
    ("taylorseer", {"interval": 3}),
    ("teacache", {"delta": 0.15}),
    ("magcache", {"delta": 0.1, "num_steps": 10}),
    ("easycache", {"tau": 3.0}),
    ("foresight", {}),
])
def test_want_compute_mirrors_apply(name, kw):
    """The serving engine dispatches the dummy-compute program whenever
    want_compute is all-False, so the prediction must match the branch
    `apply` actually takes (counted via the policy's compute counters)."""
    pol = make_policy(name, **kw)
    shape = (1, 6, 4)
    state = pol.init_state(shape)
    key = jax.random.PRNGKey(0)
    predicted = actual = 0
    for step in range(10):
        key, sub = jax.random.split(key)
        x = jax.random.normal(sub, shape)
        w = bool(pol.want_compute(state, jnp.asarray(step), x))
        y, state = pol.apply(state, jnp.asarray(step), x,
                             lambda xx: jnp.tanh(xx) * 3.0)
        predicted += int(w)
    for counter in ("n_compute", "n_valid"):
        if counter in state:
            actual = int(state[counter])
            break
    else:
        sched = pol.static_schedule(10)
        actual = sum(map(bool, sched))
    assert predicted == actual, (name, predicted, actual)


# ----------------------------------------------------------------------
# end-to-end serving
# ----------------------------------------------------------------------

def test_refill_resets_cache_state(setup):
    """Slot reuse must not leak cache state: request B served after A
    through the same slot must equal B served alone (bitwise)."""
    cfg, params = setup
    a = DiffusionRequest(0, NUM_STEPS, seed=1)
    b = DiffusionRequest(1, NUM_STEPS, seed=2)

    eng = DiffusionServingEngine(params, cfg, "taylorseer", slots=1,
                                 max_steps=16)
    both = eng.serve([a, b])
    eng2 = DiffusionServingEngine(params, cfg, "taylorseer", slots=1,
                                  max_steps=16)
    alone = eng2.serve([b])
    np.testing.assert_array_equal(both[1].x0, alone[0].x0)


@pytest.mark.parametrize("name", ["none", "fora", "taylorseer", "teacache",
                                  "toca"])
def test_serving_matches_cached_denoiser(setup, name):
    """One request through the slot machinery must match the single-
    trajectory CachedDenoiser path (same policy, same grid).  `toca` guards
    the plan-derivation rule: its partial branch calls compute_fn, so the
    engine must never hand it a skip tick despite its interval
    static_schedule."""
    cfg, params = setup
    pol = make_policy(name, num_steps=NUM_STEPS)
    eng = DiffusionServingEngine(params, cfg, pol, slots=2, max_steps=16)
    res = eng.serve([DiffusionRequest(0, NUM_STEPS, seed=7)])
    ref = _reference(cfg, params, name, NUM_STEPS, seed=7)
    np.testing.assert_allclose(res[0].x0, ref, atol=5e-3, rtol=1e-3)


def test_e2e_mixed_budget_serving_smoke(setup):
    """16 mixed-budget requests through 4 slots: all complete, telemetry is
    populated, and interval caching actually skips backbone ticks."""
    cfg, params = setup
    reqs = [DiffusionRequest(i, num_steps=(8, 12, 16)[i % 3], seed=i,
                             traffic_class=("interactive", "quality")[i % 2])
            for i in range(16)]
    eng = DiffusionServingEngine(params, cfg, "taylorseer", slots=4,
                                 max_steps=16)
    res = eng.serve(reqs)
    assert len(res) == 16
    assert all(np.isfinite(r.x0).all() for r in res)
    assert [r.request_id for r in res] == list(range(16))

    s = eng.telemetry.summary()
    assert s["requests"] == 16
    assert s["throughput_rps"] > 0
    assert 0.0 < s["compute_fraction_mean"] < 1.0
    assert eng.telemetry.ticks_skip > eng.telemetry.ticks_full  # interval=4
    assert s["cache_state_bytes_per_slot"] > 0
    for r in res:
        assert r.record.latency > 0
        assert r.record.queue_wait >= 0
        assert 0.0 < r.record.compute_fraction <= 1.0
    by_class = eng.telemetry.by_traffic_class()
    assert set(by_class) == {"interactive", "quality"}


def test_serving_rejects_over_budget_request(setup):
    cfg, params = setup
    eng = DiffusionServingEngine(params, cfg, "none", slots=1, max_steps=8)
    with pytest.raises(ValueError):
        eng.serve([DiffusionRequest(0, num_steps=9)])


# ----------------------------------------------------------------------
# autotuning
# ----------------------------------------------------------------------

def test_autotune_respects_sla(setup):
    cfg, params = setup
    cands = [("none", {}), ("fora", {"interval": 4}),
             ("taylorseer", {"interval": 4, "order": 2})]
    strict = autotune(params, cfg, SLA("strict", min_psnr=50.0),
                      candidates=cands, num_steps=NUM_STEPS)
    loose = autotune(params, cfg, SLA("loose", min_psnr=-100.0),
                     candidates=cands, num_steps=NUM_STEPS)
    assert strict.policy_name == "none" and strict.feasible
    # everything is feasible under the loose SLA: cheapest candidate wins
    assert loose.compute_fraction <= strict.compute_fraction
    assert loose.policy_name in ("fora", "taylorseer")
    assert loose.align == 4
    assert loose.make() is not None


def test_policy_registry_covers_taxonomy():
    """dbcache is deliberately structural (not in make_policy); the registry
    plus STRUCTURAL_POLICIES must cover it with a pointed error."""
    from repro.core import STRUCTURAL_POLICIES
    assert "dbcache" in STRUCTURAL_POLICIES
    with pytest.raises(KeyError, match="structural"):
        make_policy("dbcache")
    # every registry entry constructs
    for name in POLICY_REGISTRY:
        assert make_policy(name) is not None
