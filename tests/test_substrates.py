"""Integration tests: data pipeline, optimizer, checkpointing, training
loop, serving engine, diffusion samplers."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import get_smoke_config
from repro.data import (LMBatchIterator, frame_embeddings, latent_batches,
                        lm_batches, patch_embeddings)
from repro.diffusion import (CachedDenoiser, cosine_schedule, ddim_step,
                             ddpm_step, dpmpp_2m_step, linear_schedule,
                             rf_euler_step, rectified_flow_times, sample)
from repro.diffusion.pipeline import cfg_denoise_fn
from repro.core import make_policy
from repro.models import init_params
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_warmup_schedule, global_norm)
from repro.serving import ServingEngine, greedy_generate
from repro.train import train_loop
from repro.train.steps import (init_train_state, make_diffusion_train_step,
                               make_lm_train_step)


# ----------------------------------------------------------------------
# data
# ----------------------------------------------------------------------

def test_lm_batches_deterministic_and_learnable():
    a = next(lm_batches(7, 4, 16, 100))
    b = next(lm_batches(7, 4, 16, 100))
    np.testing.assert_array_equal(a[0], b[0])
    # targets follow the planted bigram table: successor sets are small
    toks, tgts = next(lm_batches(7, 64, 64, 100))
    succ = {}
    for row_t, row_y in zip(toks, tgts):
        for t, y in zip(row_t, row_y):
            succ.setdefault(int(t), set()).add(int(y))
    branching = max(len(v) for v in succ.values())
    assert branching <= 8, "bigram structure violated"


def test_lm_iterator_checkpointable():
    it = LMBatchIterator(3, 2, 8, 50)
    next(it)
    s = it.state_dict()
    x1 = next(it)
    it2 = LMBatchIterator.from_state(s, 2, 8, 50)
    x2 = next(it2)
    np.testing.assert_array_equal(x1[0], x2[0])


def test_stub_frontends_shapes():
    assert frame_embeddings(0, 2, 100, 64).shape == (2, 100, 64)
    assert patch_embeddings(0, 2, 16, 32).shape == (2, 16, 32)
    assert latent_batches(0, 4, 8, 16, 10) is not None


# ----------------------------------------------------------------------
# optimizer
# ----------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt = adamw_update(grads, opt, params, lr=5e-2,
                                   weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(20.0)


def test_cosine_warmup_schedule():
    lr0 = cosine_warmup_schedule(0, peak_lr=1.0, warmup_steps=10,
                                 total_steps=100)
    lr_peak = cosine_warmup_schedule(10, peak_lr=1.0, warmup_steps=10,
                                     total_steps=100)
    lr_end = cosine_warmup_schedule(100, peak_lr=1.0, warmup_steps=10,
                                    total_steps=100)
    assert float(lr0) == 0.0
    assert float(lr_peak) == pytest.approx(1.0)
    assert float(lr_end) == pytest.approx(0.1, rel=1e-3)


# ----------------------------------------------------------------------
# checkpointing
# ----------------------------------------------------------------------

def test_checkpoint_roundtrip_and_prune():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        for step in (1, 2, 3, 4, 5):
            ckpt.save(d, step, tree, keep=2)
        assert ckpt.latest_step(d) == 5
        restored, step, _ = ckpt.restore(d, tree)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert restored["b"]["c"].dtype == jnp.bfloat16
        kept = [n for n in os.listdir(d) if n.startswith("step_")]
        assert len(kept) == 2


# ----------------------------------------------------------------------
# training
# ----------------------------------------------------------------------

def test_lm_training_reduces_loss():
    cfg = get_smoke_config("tinyllama-1.1b")
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = make_lm_train_step(cfg, peak_lr=3e-3, warmup=5, total_steps=60)
    batches = ({"tokens": jnp.asarray(t), "targets": jnp.asarray(y)}
               for t, y in lm_batches(0, 16, 32, cfg.vocab_size))
    state, hist = train_loop(step, state, batches, 60, log_every=10,
                             log_fn=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.2, hist


def test_grad_accumulation_matches_full_batch():
    cfg = get_smoke_config("tinyllama-1.1b")
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    t, y = next(lm_batches(1, 8, 16, cfg.vocab_size))
    batch = {"tokens": jnp.asarray(t), "targets": jnp.asarray(y)}
    s1, m1 = jax.jit(make_lm_train_step(cfg, accum=1))(state, batch)
    s2, m2 = jax.jit(make_lm_train_step(cfg, accum=4))(state, batch)
    # same data, same params -> losses equal, updates near-equal
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    d1 = jax.tree_util.tree_leaves(s1.params)
    d2 = jax.tree_util.tree_leaves(s2.params)
    for a, b in zip(d1, d2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3)


def test_diffusion_training_smoke():
    cfg = get_smoke_config("dit-xl")
    sched = linear_schedule(100)
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = make_diffusion_train_step(cfg, sched, total_steps=10)
    lat = latent_batches(0, 8, cfg.dit_patch_tokens, cfg.dit_in_dim,
                         cfg.dit_num_classes)

    def batches():
        key = jax.random.PRNGKey(1)
        for x, y in lat:
            key, sub = jax.random.split(key)
            yield {"latents": jnp.asarray(x), "labels": jnp.asarray(y),
                   "key": sub}

    state, hist = train_loop(step, state, batches(), 5, log_every=1,
                             log_fn=lambda *_: None)
    assert np.isfinite(hist[-1]["loss"])


# ----------------------------------------------------------------------
# samplers
# ----------------------------------------------------------------------

@pytest.mark.parametrize("step_fn", [ddpm_step, ddim_step, dpmpp_2m_step])
def test_samplers_finite(step_fn):
    cfg = get_smoke_config("dit-xl")
    params = init_params(jax.random.PRNGKey(0), cfg)
    sched = cosine_schedule(100)
    ts = sched.spaced(8)
    xT = jax.random.normal(jax.random.PRNGKey(1),
                           (2, cfg.dit_patch_tokens, cfg.dit_in_dim))
    fn = cfg_denoise_fn(params, cfg, cfg_scale=0.0)
    x0, _ = sample(fn, xT, ts, sched, step_fn=step_fn)
    assert bool(jnp.all(jnp.isfinite(x0)))


def test_rectified_flow_euler():
    cfg = get_smoke_config("dit-xl")
    params = init_params(jax.random.PRNGKey(0), cfg)
    times = rectified_flow_times(8)
    xT = jax.random.normal(jax.random.PRNGKey(1),
                           (2, cfg.dit_patch_tokens, cfg.dit_in_dim))
    fn = cfg_denoise_fn(params, cfg, cfg_scale=0.0)
    x0, _ = sample(fn, xT, times, None, step_fn=rf_euler_step)
    assert bool(jnp.all(jnp.isfinite(x0)))


def test_ddim_is_deterministic():
    cfg = get_smoke_config("dit-xl")
    params = init_params(jax.random.PRNGKey(0), cfg)
    sched = linear_schedule(100)
    ts = sched.spaced(6)
    xT = jax.random.normal(jax.random.PRNGKey(1),
                           (1, cfg.dit_patch_tokens, cfg.dit_in_dim))
    fn = cfg_denoise_fn(params, cfg, cfg_scale=0.0)
    a, _ = sample(fn, xT, ts, sched, step_fn=ddim_step,
                  key=jax.random.PRNGKey(5))
    b, _ = sample(fn, xT, ts, sched, step_fn=ddim_step,
                  key=jax.random.PRNGKey(9))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ----------------------------------------------------------------------
# serving
# ----------------------------------------------------------------------

def test_serving_engine_matches_manual_decode():
    cfg = get_smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = greedy_generate(params, cfg, [1, 2, 3, 4], max_new_tokens=6,
                           cache_len=32)
    toks2 = greedy_generate(params, cfg, [1, 2, 3, 4], max_new_tokens=6,
                            cache_len=32)
    assert toks == toks2 and len(toks) == 6


def test_serving_engine_batching_isolation():
    """Slot batching must not leak state across requests: the same prompt
    must decode identically alone and alongside other requests."""
    cfg = get_smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, slots=4, cache_len=64, max_prompt=8)
    solo = eng.generate([[5, 6, 7]], max_new_tokens=5)[0].tokens
    batch = eng.generate([[9, 9], [5, 6, 7], [1, 2, 3, 4]],
                         max_new_tokens=5)
    assert batch[1].tokens == solo


def test_eos_stops_generation():
    cfg = get_smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, slots=1, cache_len=64, max_prompt=8)
    ref = eng.generate([[1, 2, 3]], max_new_tokens=8)[0].tokens
    eos = ref[2]
    eng2 = ServingEngine(params, cfg, slots=1, cache_len=64, max_prompt=8,
                         eos_id=eos)
    out = eng2.generate([[1, 2, 3]], max_new_tokens=8)[0].tokens
    assert out == ref[:3]
