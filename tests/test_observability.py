"""repro.obs: metrics registry semantics, golden Chrome-trace/JSONL
reconciliation against ServingTelemetry, warmup program profiling, the
redundancy ratio, mixed-modality row invariants, and the clock lint."""
import json
import math
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import FasterCacheCFG
from repro.models import init_params, perturb_zero_init
from repro.obs import (MetricsRegistry, ProgramProfile, TraceRecorder,
                       flops_per_row, load_cache_events, monotonic,
                       redundancy_ratio, signal_trace_from_files,
                       validate_chrome_trace)
from repro.serving.diffusion import (DiffusionRequest,
                                     DiffusionServingEngine)

NUM_STEPS = 8
SLOTS = 2


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("dit-xl").reduced(num_layers=2, d_model=64,
                                       num_heads=4, num_kv_heads=4,
                                       d_ff=128, dit_patch_tokens=8,
                                       dit_in_dim=4, dit_num_classes=10)
    params = perturb_zero_init(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _mixed_requests(n=4):
    """Mixed guided/unguided, mixed budgets (the golden-session shape)."""
    return [DiffusionRequest(i, num_steps=(NUM_STEPS, NUM_STEPS - 2)[i % 2],
                             seed=i, class_label=i % 5,
                             cfg_scale=2.5 if i % 2 == 0 else 0.0)
            for i in range(n)]


@pytest.fixture(scope="module")
def golden_session(setup):
    """One 2-slot teacache + FasterCacheCFG session observed by every
    surface at once: TraceRecorder, MetricsRegistry, ServingTelemetry."""
    cfg, params = setup
    eng = DiffusionServingEngine(params, cfg, "teacache", slots=SLOTS,
                                 max_steps=NUM_STEPS,
                                 cfg_policy=FasterCacheCFG(3, NUM_STEPS))
    profiles = eng.warmup()
    recorder = TraceRecorder(policy=eng.policy)
    registry = MetricsRegistry()
    results = eng.serve(_mixed_requests(), hooks=[recorder],
                        metrics=registry)
    recorder.finish()
    return eng, results, recorder, registry, profiles


# ----------------------------------------------------------------------
# clock
# ----------------------------------------------------------------------

def test_monotonic_clock_advances():
    a = monotonic()
    b = monotonic()
    assert b >= a


def test_clock_lint_passes():
    """src/repro/serving and src/repro/modalities must route every wall
    time through repro.obs.clock (the clock-discipline rule of
    repro.analysis, also run in CI)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         "--rule", "clock-discipline", "-q"],
        capture_output=True, text=True, cwd=root,
        env={**os.environ, "PYTHONPATH": os.path.join(root, "src")})
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ----------------------------------------------------------------------
# metrics registry
# ----------------------------------------------------------------------

def test_counter_gauge_histogram_semantics():
    reg = MetricsRegistry()
    c = reg.counter("repro_test_things_total", "things")
    c.inc(kind="a")
    c.inc(2, kind="a")
    c.inc(kind="b")
    assert c.value(kind="a") == 3 and c.value(kind="b") == 1
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("repro_test_depth")
    g.set(5)
    g.add(-2)
    assert g.value() == 3
    h = reg.histogram("repro_test_seconds", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    assert h.count() == 3
    assert h.sum() == pytest.approx(5.55)
    counts, _, _ = h.values[()]
    assert counts == [1, 2, 3]        # cumulative, +Inf == total
    # get-or-create returns the same instrument; type clashes raise
    assert reg.counter("repro_test_things_total") is c
    with pytest.raises(TypeError):
        reg.gauge("repro_test_things_total")


def test_prometheus_text_and_snapshot_roundtrip():
    reg = MetricsRegistry()
    reg.counter("repro_t_total", "help text").inc(3, modality="video")
    reg.gauge("repro_t_depth").set(2.5)
    reg.histogram("repro_t_s", buckets=(1.0,)).observe(0.5)
    reg.event("control.swap", policy_to="fora")
    text = reg.prometheus_text()
    assert '# TYPE repro_t_total counter' in text
    assert 'repro_t_total{modality="video"} 3' in text
    assert '# HELP repro_t_total help text' in text
    assert 'repro_t_s_bucket{le="+Inf"} 1' in text
    assert 'repro_t_s_count 1' in text
    snap = reg.snapshot()
    json.dumps(snap)                  # JSON-able as claimed
    assert snap["metrics"]["repro_t_depth"]["values"][0]["value"] == 2.5
    assert snap["events"][0]["event"] == "control.swap"
    assert snap["events_seen"] == 1


# ----------------------------------------------------------------------
# golden trace + JSONL reconciliation
# ----------------------------------------------------------------------

def test_chrome_trace_is_valid(golden_session):
    """Schema, monotonic per-track timestamps, span nesting — the trace
    must load in Perfetto without repair."""
    _, _, recorder, _, _ = golden_session
    trace = recorder.chrome_trace()
    assert validate_chrome_trace(trace) == []
    events = trace["traceEvents"]
    phases = {e["ph"] for e in events}
    assert {"M", "X", "B", "E"} <= phases
    names = {e["name"] for e in events}
    assert "plan" in names and any(n.startswith("tick:") for n in names)
    # every request opened AND closed a lifecycle span
    begins = [e for e in events if e["ph"] == "B"]
    ends = [e for e in events if e["ph"] == "E"]
    assert len(begins) == len(ends) == 4
    # cache spans carry the signal-vs-threshold annotation
    cache = [e for e in events if e.get("cat") == "cache"]
    assert cache and all("signal" in e["args"] and "threshold" in e["args"]
                         for e in cache)


def test_validate_chrome_trace_flags_problems():
    bad = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 1, "tid": 0, "ts": 10.0, "dur": 1},
        {"ph": "X", "name": "b", "pid": 1, "tid": 0, "ts": 5.0, "dur": 1},
        {"ph": "B", "name": "req 1", "pid": 1, "tid": 2, "ts": 6.0},
        {"ph": "E", "name": "req 2", "pid": 1, "tid": 2, "ts": 7.0},
        {"ph": "X", "name": "c", "pid": 1, "tid": 3},
    ]}
    problems = validate_chrome_trace(bad)
    assert any("backwards" in p for p in problems)
    assert any("crosses" in p for p in problems)
    assert any("without ts" in p for p in problems)
    assert validate_chrome_trace({}) == ["traceEvents missing or not a list"]


def test_jsonl_reconciles_with_telemetry_exactly(golden_session, tmp_path):
    """The cache-event log's per-request computed-step counts must equal
    ServingTelemetry's RequestRecord counters EXACTLY — both for the cond
    branch and the uncond (CFG) branch."""
    eng, results, recorder, _, _ = golden_session
    path = tmp_path / "cache_events.jsonl"
    recorder.write_cache_events(str(path))
    events = load_cache_events(str(path))
    assert events == recorder.cache_events
    by_req = recorder.computed_steps_by_request()
    uncond_by_req = recorder.uncond_steps_by_request()
    assert len(eng.telemetry.records) == len(results) == 4
    for rec in eng.telemetry.records:
        assert by_req[rec.request_id] == rec.computed_steps
        assert uncond_by_req[rec.request_id] == rec.uncond_computed_steps
    # every (request, step) pair appears exactly once
    seen = {(e["request_id"], e["step"]) for e in events}
    assert len(seen) == len(events)
    assert len(events) == sum(r.num_steps for r in _mixed_requests())


def test_metrics_match_telemetry(golden_session):
    eng, _, _, registry, _ = golden_session
    tele = eng.telemetry
    rows = registry.counter("repro_engine_rows_computed_total")
    assert int(sum(rows.values.values())) == tele.backbone_rows_computed
    fin = registry.counter("repro_engine_requests_finished_total")
    assert int(sum(fin.values.values())) == tele.requests_finished
    ticks = registry.counter("repro_engine_ticks_total")
    assert int(sum(ticks.values.values())) == \
        tele.ticks_full + tele.ticks_cond + tele.ticks_skip
    uncond = registry.counter("repro_engine_uncond_rows_computed_total")
    assert int(sum(uncond.values.values())) == tele.uncond_rows_computed


def test_signal_trace_rebuilds_from_files(golden_session, tmp_path):
    """The JSONL is the durable SignalTraceLog: rebuilt entries must carry
    the same want decisions the in-memory ring would have recorded."""
    _, _, recorder, _, _ = golden_session
    path = tmp_path / "cache_events.jsonl"
    recorder.write_cache_events(str(path))
    log = signal_trace_from_files(str(path))
    assert len(log.entries) == len(recorder.cache_events)
    assert sum(e.want_cond for e in log.entries) == \
        sum(ev["want_compute"] for ev in recorder.cache_events)
    per_req = {}
    for e in log.entries:
        per_req[e.request_id] = per_req.get(e.request_id, 0) + int(e.want_cond)
    assert per_req == recorder.computed_steps_by_request()


def test_telemetry_publish_view(golden_session):
    eng, _, _, _, _ = golden_session
    reg = MetricsRegistry()
    eng.telemetry.publish(reg, modality="image")
    s = eng.telemetry.summary()
    g = reg.gauge("repro_serving_backbone_rows_computed")
    assert g.value(modality="image") == s["backbone_rows_computed"]
    assert reg.gauge("repro_serving_requests").value(modality="image") == \
        s["requests"]
    # re-publishing overwrites (a view, not an accumulator)
    eng.telemetry.publish(reg, modality="image")
    assert g.value(modality="image") == s["backbone_rows_computed"]


# ----------------------------------------------------------------------
# program profiling + redundancy
# ----------------------------------------------------------------------

def test_warmup_profiles_programs(golden_session):
    eng, _, _, _, profiles = golden_session
    # bucket 0 (skip), every pow-2 bucket up to 2*slots, and the want pass
    assert {0, 1, 2, 4, "want"} <= set(profiles)
    for key, p in profiles.items():
        assert isinstance(p, ProgramProfile)
        assert p.compile_seconds > 0.0
        assert p.flops > 0 or math.isnan(p.flops)
    # on CPU the cost model reports flops; larger buckets cost more
    if not math.isnan(profiles[1].flops):
        assert profiles[4].flops > profiles[1].flops > profiles[0].flops
    # warmup is idempotent: second call returns the same dict, no recompile
    assert eng.warmup() is profiles


def test_redundancy_ratio_math():
    profiles = {0: ProgramProfile(0, 0.1, 100.0, 0.0),
                4: ProgramProfile(4, 0.1, 500.0, 0.0)}
    assert flops_per_row(profiles) == pytest.approx(100.0)
    rr = redundancy_ratio(profiles, rows_computed=60, rows_padding=10,
                          rows_saved=40)
    assert rr["flops_per_row"] == pytest.approx(100.0)
    assert rr["dense_flops"] == pytest.approx(100.0 * 100)
    assert rr["flops_avoided"] == pytest.approx(100.0 * 30)
    assert rr["redundancy_ratio"] == pytest.approx(0.30)
    # no cost model -> nan, never a made-up number
    nan_prof = {0: ProgramProfile(0, 0.1, math.nan, math.nan),
                4: ProgramProfile(4, 0.1, math.nan, math.nan)}
    assert math.isnan(redundancy_ratio(nan_prof, 1, 0, 1)
                      ["redundancy_ratio"])


# ----------------------------------------------------------------------
# row invariants (single-pool and mixed-modality)
# ----------------------------------------------------------------------

def test_uncond_rows_equal_sum_of_uncond_steps(golden_session):
    """uncond_rows_computed counts exactly the per-request uncond-branch
    refreshes — no slot-count inflation, no padding leakage."""
    eng, results, _, _, _ = golden_session
    tele = eng.telemetry
    assert tele.uncond_rows_computed == \
        sum(r.record.uncond_computed_steps for r in results)
    assert tele.backbone_rows_computed == \
        sum(r.record.computed_steps + r.record.uncond_computed_steps
            for r in results)


def test_mixed_modality_token_weighted_totals(setup):
    """MixedTelemetry's token-weighted totals must equal the per-pool
    rows x that pool's tokens-per-row, summed — the invariant that keeps
    wide video rows from hiding inside raw row counts."""
    pytest.importorskip("repro.modalities")
    from repro.modalities import MixedModalityEngine, make_workload
    workloads = {m: make_workload(m, smoke=True)
                 for m in ("image", "audio")}
    pools = {name: wl.engine("fora", slots=SLOTS, max_steps=NUM_STEPS)
             for name, wl in workloads.items()}
    engine = MixedModalityEngine(pools)
    reg = MetricsRegistry()
    mods = ("image", "audio")
    reqs = [DiffusionRequest(i, num_steps=NUM_STEPS, seed=i,
                             modality=mods[i % 2]) for i in range(4)]
    results = engine.serve(reqs, metrics=reg)
    assert len(results) == 4
    mixed = engine.telemetry
    s = mixed.summary()
    per = {m: t for m, t in mixed.pools.items()}
    assert s["backbone_rows_computed"] == \
        sum(t.backbone_rows_computed for t in per.values())
    assert s["backbone_tokens_computed"] == sum(
        t.backbone_rows_computed * mixed.row_tokens[m]
        for m, t in per.items())
    assert s["backbone_tokens_saved"] == sum(
        t.backbone_rows_saved * mixed.row_tokens[m]
        for m, t in per.items())
    # per-pool: rows == sum of per-request computed steps (fora is
    # unguided here, so no uncond term)
    for m, t in per.items():
        assert t.backbone_rows_computed == \
            sum(r.computed_steps for r in t.records)
        assert t.uncond_rows_computed == 0
    # the shared registry kept the pools apart by modality label
    rows = reg.counter("repro_engine_rows_computed_total")
    for m, t in per.items():
        assert int(rows.value(modality=m)) == t.backbone_rows_computed


# ----------------------------------------------------------------------
# empty-window percentile contract
# ----------------------------------------------------------------------

def test_summary_empty_window_is_nan():
    from repro.serving.diffusion import ServingTelemetry
    tele = ServingTelemetry()
    s = tele.summary()
    assert math.isnan(s["latency_p50_s"]) and math.isnan(s["latency_p95_s"])
    assert s["requests"] == 0
