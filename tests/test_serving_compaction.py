"""Row-compacted backbone ticks: the compacted engine must reproduce the
dense whole-pool engine (per-slot outputs within fp tolerance, computed-step
counts exactly) for every registry policy, bucket planning must handle the
edge cases, refill isolation must survive compaction, and the telemetry /
percentile fixes that rode along with it."""
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import POLICY_REGISTRY, FasterCacheCFG, make_policy
from repro.core.learned import init_gate
from repro.models import init_params, perturb_zero_init
from repro.serving.diffusion import (SLA, DiffusionRequest,
                                     DiffusionServingEngine, autotune,
                                     compact_rows)
from repro.serving.diffusion.telemetry import _pct

NUM_STEPS = 8


@pytest.fixture(scope="module")
def setup():
    # smaller than test_serving_diffusion's model: this file serves every
    # registry policy twice (compacted + dense), so compile time dominates
    cfg = get_config("dit-xl").reduced(num_layers=2, d_model=64,
                                       num_heads=4, num_kv_heads=4,
                                       d_ff=128, dit_patch_tokens=8,
                                       dit_in_dim=4, dit_num_classes=10)
    params = perturb_zero_init(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _mixed_requests(n=3):
    """Mixed guided/unguided, mixed budgets — the pool shape whole-pool
    ticks handled worst."""
    return [DiffusionRequest(i, num_steps=(NUM_STEPS, NUM_STEPS - 2)[i % 2],
                             seed=i, class_label=i % 5,
                             cfg_scale=2.5 if i % 2 == 0 else 0.0)
            for i in range(n)]


def _serve(cfg, params, policy, reqs, *, compact, cfg_policy=None, slots=2):
    eng = DiffusionServingEngine(params, cfg, policy, slots=slots,
                                 max_steps=NUM_STEPS, cfg_policy=cfg_policy,
                                 row_compaction=compact)
    return eng, eng.serve(reqs)


# ----------------------------------------------------------------------
# bucket planning (pure host-side)
# ----------------------------------------------------------------------

def test_compact_rows_zero_rows_is_skip():
    b, rs, ru, rd = compact_rows(np.zeros(4, bool), np.zeros(4, bool), 4)
    assert b == 0 and rs.shape == (0,) and ru.shape == (0,) and rd.shape == (0,)


def test_compact_rows_layout_and_padding():
    want_c = np.array([True, False, True, False])
    want_u = np.array([False, False, True, False])
    b, rs, ru, rd = compact_rows(want_c, want_u, 4)
    assert b == 4                                   # 3 rows -> bucket 4
    # cond rows first (dest = slot), then uncond (dest = slot + S),
    # padding points at the 2S dump row
    np.testing.assert_array_equal(rs, [0, 2, 2, 0])
    np.testing.assert_array_equal(ru, [False, False, True, False])
    np.testing.assert_array_equal(rd, [0, 2, 6, 8])


@pytest.mark.parametrize("n_rows,bucket", [
    (1, 1), (2, 2), (3, 4), (4, 4), (5, 8), (8, 8), (9, 16),
])
def test_compact_rows_next_pow2_bucket(n_rows, bucket):
    """S rows stay in the S bucket; S+1 spills to the next power of two."""
    slots = 16
    want_c = np.zeros(slots, bool)
    want_c[:n_rows] = True
    b, rs, ru, rd = compact_rows(want_c, np.zeros(slots, bool), slots)
    assert b == bucket
    assert (rd[n_rows:] == 2 * slots).all()         # padding -> dump row


def test_compact_rows_bucket_capped_at_dense_batch():
    """Non-power-of-two pools: the bucket must clamp to the tick's dense
    batch — S for cond-only ticks, 2S with uncond rows — never dispatching
    MORE rows than the whole-pool tick it replaces."""
    slots = 6
    want = np.ones(slots, bool)                     # 12 wanted rows
    b, rs, ru, rd = compact_rows(want, want, slots)
    assert b == 2 * slots                           # 12, not 16
    assert (rd != 2 * slots).all()                  # no padding at the cap
    # cond-only busy tick: dense dispatches S=6 rows, so pow2 8 must clamp
    b, _, _, _ = compact_rows(want, np.zeros(slots, bool), slots)
    assert b == slots
    # one uncond row joins: the dense comparison is the 2S full batch again
    one_u = np.zeros(slots, bool)
    one_u[0] = True
    b, _, _, _ = compact_rows(want, one_u, slots)
    assert b == 8                                   # 7 rows -> pow2 8 < 12


# ----------------------------------------------------------------------
# compacted == dense equivalence, every registry policy
# ----------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(POLICY_REGISTRY))
def test_compacted_matches_dense_engine(setup, name):
    """Per-request x0 within fp tolerance and EXACT computed-step counts:
    compaction only changes which rows are batched through the backbone,
    never the per-slot policy step."""
    cfg, params = setup
    reqs = _mixed_requests()
    # the learned gate has no untrained default and the calibrated schedule
    # no default profile: give them fixed stand-ins (the decision sequence
    # is deterministic either way, which is all the equivalence check needs)
    extra = {"lazydit": {"gate": init_gate(jax.random.PRNGKey(7),
                                           cfg.dit_in_dim)},
             "blockcache": {"profile": [0.0, 0.08, 0.02, 0.08, 0.02, 0.08,
                                        0.02, 0.08], "delta": 0.09}
             }.get(name, {})
    results = {}
    for compact in (True, False):
        pol = make_policy(name, num_steps=NUM_STEPS, **extra)
        _, results[compact] = _serve(cfg, params, pol, reqs, compact=compact,
                                     cfg_policy=FasterCacheCFG(3, NUM_STEPS))
    for a, b in zip(results[True], results[False]):
        np.testing.assert_allclose(a.x0, b.x0, atol=5e-4, rtol=1e-3)
        assert a.record.computed_steps == b.record.computed_steps
        assert a.record.uncond_computed_steps == b.record.uncond_computed_steps


def test_compacted_matches_dense_teacache_naive_cfg(setup):
    """Signal policy + naive two-branch guidance (no CFG cache): the dense
    engine's worst case — every uncond row recomputes — must still agree."""
    cfg, params = setup
    reqs = _mixed_requests()
    results = {}
    for compact in (True, False):
        _, results[compact] = _serve(cfg, params, "teacache", reqs,
                                     compact=compact)
    for a, b in zip(results[True], results[False]):
        np.testing.assert_allclose(a.x0, b.x0, atol=5e-4, rtol=1e-3)
        assert a.record.computed_steps == b.record.computed_steps
        assert a.record.uncond_computed_steps == b.record.uncond_computed_steps


# ----------------------------------------------------------------------
# row accounting
# ----------------------------------------------------------------------

def test_row_telemetry_counts_only_wanted_rows(setup):
    """backbone_rows_computed must equal the sum of per-request computed
    steps (cond + uncond): no slot-count inflation from inactive or
    unguided slots, skip ticks contribute zero rows."""
    cfg, params = setup
    reqs = _mixed_requests()
    eng, res = _serve(cfg, params, "fora", reqs, compact=True,
                      cfg_policy=FasterCacheCFG(4, NUM_STEPS))
    tele = eng.telemetry
    cond_steps = sum(r.record.computed_steps for r in res)
    uncond_steps = sum(r.record.uncond_computed_steps for r in res)
    assert tele.backbone_rows_computed == cond_steps + uncond_steps
    assert tele.uncond_rows_computed == uncond_steps
    assert tele.backbone_rows_saved > 0        # vs a dense whole-pool tick
    assert tele.backbone_rows_padding >= 0
    s = tele.summary()
    assert s["backbone_rows_computed"] == tele.backbone_rows_computed
    assert s["backbone_rows_per_tick_mean"] > 0
    assert tele.row_time_ms()[0] > 0    # the autotune row-pricing input


def test_dense_engine_row_accounting_matches_batch(setup):
    """The dense engine reports its true whole-pool batches (S or 2S rows
    per backbone tick) and the same fixed uncond accounting: only rows that
    refreshed an active guided slot's CFG cache."""
    cfg, params = setup
    req = DiffusionRequest(0, NUM_STEPS, seed=3, class_label=4, cfg_scale=2.5)
    eng, res = _serve(cfg, params, "none", [req], compact=False,
                      cfg_policy=FasterCacheCFG(4, NUM_STEPS), slots=2)
    tele = eng.telemetry
    S = 2
    assert tele.backbone_rows_computed == (2 * S * tele.ticks_full +
                                           S * tele.ticks_cond)
    assert tele.backbone_rows_padding == 0
    # one active guided slot: uncond rows == its uncond refreshes, NOT
    # `slots` per full tick (the pre-fix inflation)
    assert tele.uncond_rows_computed == res[0].record.uncond_computed_steps
    assert tele.uncond_rows_computed == tele.ticks_full


def test_compaction_dispatches_fewer_rows_than_dense(setup):
    """The acceptance claim at test scale: equal output, strictly fewer
    backbone rows (padding included) on a mixed signal-policy + CFG pool."""
    cfg, params = setup
    reqs = _mixed_requests(4)
    rows = {}
    for compact in (True, False):
        eng, _ = _serve(cfg, params, "teacache", reqs, compact=compact,
                        cfg_policy=FasterCacheCFG(3, NUM_STEPS))
        t = eng.telemetry
        rows[compact] = t.backbone_rows_computed + t.backbone_rows_padding
    assert rows[True] < rows[False]


# ----------------------------------------------------------------------
# engine behaviour under compaction
# ----------------------------------------------------------------------

def test_refill_isolation_under_compaction(setup):
    """Reset-on-refill still holds when ticks are row-compacted: a guided
    request served after another through the same slot must equal it served
    alone (bitwise)."""
    cfg, params = setup
    a = DiffusionRequest(0, NUM_STEPS, seed=1, class_label=1, cfg_scale=3.0)
    b = DiffusionRequest(1, NUM_STEPS, seed=2, class_label=2, cfg_scale=2.0)
    _, both = _serve(cfg, params, "teacache", [a, b], compact=True,
                     cfg_policy=FasterCacheCFG(3, NUM_STEPS), slots=1)
    _, alone = _serve(cfg, params, "teacache", [b], compact=True,
                      cfg_policy=FasterCacheCFG(3, NUM_STEPS), slots=1)
    np.testing.assert_array_equal(both[1].x0, alone[0].x0)


def test_zero_row_tick_skips_backbone(setup):
    """Interval-4 over an aligned pool: 3 of 4 ticks gather zero rows and
    must dispatch the bucket-0 (skip) program — kinds stay compatible."""
    cfg, params = setup
    reqs = [DiffusionRequest(i, NUM_STEPS, seed=i) for i in range(2)]
    eng, _ = _serve(cfg, params, make_policy("fora", interval=4), reqs,
                    compact=True)
    tele = eng.telemetry
    assert tele.ticks_skip == 3 * tele.ticks_cond
    assert tele.ticks_full == 0
    assert 0 in eng._compact_ticks              # the skip program ran


def test_warmup_precompiles_every_bucket(setup):
    cfg, params = setup
    eng = DiffusionServingEngine(params, cfg, "teacache", slots=3,
                                 max_steps=NUM_STEPS,
                                 cfg_policy=FasterCacheCFG(3, NUM_STEPS))
    eng.warmup()
    # slots=3: cond-only ticks pad 1..3 capped at S -> {1, 2, 3}; ticks with
    # uncond rows pad 1..6 capped at 2S -> {1, 2, 4, 6}; plus the skip
    # program
    assert set(eng._compact_ticks) == {0, 1, 2, 3, 4, 6}


def test_string_policy_gets_engine_max_steps(setup):
    """Regression: policy="magcache" was built without num_steps, sizing its
    gamma curve for the registry default 50 steps regardless of max_steps."""
    cfg, params = setup
    eng = DiffusionServingEngine(params, cfg, "magcache", slots=1,
                                 max_steps=24)
    assert eng.policy.gammas.shape[0] == 24
    eng = DiffusionServingEngine(params, cfg, "magcache", slots=1,
                                 max_steps=24, cfg_policy="fastercache_cfg")
    assert eng.cfg_policy.num_steps == 24


# ----------------------------------------------------------------------
# autotune row-priced latency
# ----------------------------------------------------------------------

def test_autotune_prices_latency_in_backbone_rows(setup):
    """With row_time_ms the estimate is T * (rows_per_step * ms_per_row +
    tick_overhead): a guided fora/4 + cfg-interval-4 candidate gathers
    0.25 + 0.25 rows per step, half a naive candidate's cond row alone."""
    cfg, params = setup
    t = autotune(params, cfg, SLA("loose", min_psnr=-100.0),
                 candidates=[("fora", {"interval": 4})], num_steps=NUM_STEPS,
                 row_time_ms=(100.0, 1.0), cfg_scale=2.0, cfg_intervals=(4,))
    # cf = cf_u = 1/4 -> 8 * (0.5 * 100 + 1) = 408 ms
    assert t.est_latency_ms == pytest.approx(408.0)
    # a loaded pool's co-resident slots share every tick: occupancy scales
    # the row term (4x here), not the per-tick overhead
    t4 = autotune(params, cfg, SLA("loose", min_psnr=-100.0),
                  candidates=[("fora", {"interval": 4})], num_steps=NUM_STEPS,
                  row_time_ms=(100.0, 1.0), occupancy=4,
                  cfg_scale=2.0, cfg_intervals=(4,))
    assert t4.est_latency_ms == pytest.approx(8 * (4 * 0.5 * 100 + 1))
    # a max_latency_ms between the row-priced estimates separates candidates
    sla = SLA("tight", min_psnr=-100.0, max_latency_ms=500.0)
    tuned = autotune(params, cfg, sla,
                     candidates=[("none", {}), ("fora", {"interval": 4})],
                     num_steps=NUM_STEPS, row_time_ms=(100.0, 1.0),
                     cfg_scale=2.0, cfg_intervals=(None, 4))
    assert tuned.policy_name == "fora" and tuned.cfg_interval == 4
    assert tuned.feasible and tuned.est_latency_ms <= 500.0


# ----------------------------------------------------------------------
# percentile fix
# ----------------------------------------------------------------------

def test_pct_matches_np_percentile():
    """Regression: nearest-rank-truncated p95 over 10 samples returned the
    ~p89 sample; _pct must interpolate exactly like np.percentile."""
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 10, 17, 100):
        xs = rng.exponential(size=n).tolist()
        for q in (0.0, 0.5, 0.9, 0.95, 0.99, 1.0):
            np.testing.assert_allclose(
                _pct(xs, q), np.percentile(xs, 100 * q), rtol=1e-12)
    # an empty window has no percentile — nan, not a fake "fast" 0.0
    assert math.isnan(_pct([], 0.95))
