"""Shared pytest plumbing.

XLA's CPU backend JIT-compiles every program into the live process and
never unloads the code.  A full suite run compiles thousands of programs
(84 modality cases alone re-trace the pipeline per policy x modality),
and on some hosts the accumulated executables eventually push the
in-process compiler into a native crash (segfault inside
``backend_compile``, site varies run to run).  Dropping the compilation
caches every few dozen tests releases the executables and keeps the
process well under the cliff; the cost is a handful of re-compiles per
boundary, which is noise next to the suite's runtime.
"""
import gc
import os

import jax
import pytest

#: tests between cache drops; 0 disables (REPRO_TEST_CLEAR_EVERY overrides)
_CLEAR_EVERY = int(os.environ.get("REPRO_TEST_CLEAR_EVERY", "50"))
_counter = {"n": 0}


@pytest.fixture(autouse=True)
def _periodic_jax_cache_clear():
    yield
    _counter["n"] += 1
    if _CLEAR_EVERY and _counter["n"] % _CLEAR_EVERY == 0:
        jax.clear_caches()
        gc.collect()
