"""repro.analysis: rule fixtures, suppressions, baseline, CLI, repo run.

Every rule gets a firing fixture AND a matched non-firing fixture (the
negative is the same shape as the positive minus the defect), so a rule
that degenerates into "always fire" or "never fire" breaks a test either
way.  The whole-repo test is the enforcement point: the tree must lint
clean with zero unsuppressed, unbaselined findings.
"""
import json
import os
import textwrap

import pytest

from repro.analysis import all_rules, get_rule, run_analysis
from repro.analysis.baseline import Baseline
from repro.analysis.cli import main as cli_main
from repro.analysis.report import to_json, to_text
from repro.analysis.source import ModuleSource

REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), os.pardir))


def lint_snippet(tmp_path, code, rule_id, relpath="src/repro/serving/snip.py"):
    """Write `code` at `relpath` under a scratch root and run one rule."""
    path = tmp_path / relpath
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(code))
    result = run_analysis(root=str(tmp_path), paths=[str(path)],
                          rules=[get_rule(rule_id)],
                          baseline_path=str(tmp_path / "no_baseline.json"))
    return result


# ---------------------------------------------------------------------------
# host-sync-in-hot-path
# ---------------------------------------------------------------------------

def test_host_sync_fires_on_tainted_conversions(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax, jax.numpy as jnp, numpy as np

        def tick(x):
            y = jnp.sum(x)
            a = float(y)            # sync: jnp-derived
            b = np.asarray(y * 2)   # sync: propagated through BinOp
            c = y.item()            # sync: method sink
            d = jax.device_get(x)   # sync: unconditional
            return a, b, c, d
    """, "host-sync-in-hot-path")
    lines = sorted(f.line for f in res.findings)
    assert len(res.findings) == 4, to_text(res)
    assert lines == [6, 7, 8, 9]


def test_host_sync_silent_on_host_values(tmp_path):
    res = lint_snippet(tmp_path, """
        import numpy as np, jax.numpy as jnp

        def tick(n, xs):
            a = float(n)                  # python scalar
            b = np.asarray(xs)            # host list/array
            y = jnp.zeros((4,))
            c = int(y.shape[0])           # host metadata attr
            hist = [1.0, 2.0]
            d = float(np.percentile(hist, 99))  # host-side telemetry
            return a, b, c, d
    """, "host-sync-in-hot-path")
    assert res.findings == [], to_text(res)


def test_host_sync_taints_through_jitted_callable(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax

        def run(params, x):
            step = jax.jit(lambda p, v: v)
            out = step(params, x)
            return float(out)
    """, "host-sync-in-hot-path")
    assert len(res.findings) == 1
    assert res.findings[0].line == 7


def test_host_sync_scoped_to_hot_trees(tmp_path):
    code = """
        import jax.numpy as jnp

        def f(x):
            return float(jnp.sum(x))
    """
    hot = lint_snippet(tmp_path, code, "host-sync-in-hot-path",
                       relpath="src/repro/core/snip.py")
    cold = lint_snippet(tmp_path, code, "host-sync-in-hot-path",
                        relpath="src/repro/diffusion/snip.py")
    assert len(hot.findings) == 1
    assert cold.findings == []  # benchmarks/diffusion may sync freely


# ---------------------------------------------------------------------------
# clock-discipline
# ---------------------------------------------------------------------------

def test_clock_fires_on_wall_clock_reads(tmp_path):
    res = lint_snippet(tmp_path, """
        import time

        def tick(self):
            t0 = time.perf_counter()
            t1 = time.time()
            return t1 - t0
    """, "clock-discipline")
    assert len(res.findings) == 2


def test_clock_silent_on_injected_clock_and_strings(tmp_path):
    res = lint_snippet(tmp_path, """
        def tick(self, clock):
            now = clock()
            msg = "never call time.time() here"  # prose, not a call
            return now, msg
    """, "clock-discipline")
    assert res.findings == []


def test_clock_not_scoped_to_core(tmp_path):
    res = lint_snippet(tmp_path, """
        import time

        def f():
            return time.time()
    """, "clock-discipline", relpath="src/repro/core/snip.py")
    assert res.findings == []  # core/ is allowed to read the wall clock


# ---------------------------------------------------------------------------
# rng-key-reuse
# ---------------------------------------------------------------------------

def test_rng_fires_on_reused_key(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax

        def sample(key, shape):
            a = jax.random.normal(key, shape)
            b = jax.random.uniform(key, shape)  # reuse!
            return a + b
    """, "rng-key-reuse")
    assert len(res.findings) == 1
    assert res.findings[0].line == 6
    assert "'key'" in res.findings[0].message


def test_rng_silent_with_split(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax

        def sample(key, shape):
            key, sub = jax.random.split(key)
            a = jax.random.normal(sub, shape)
            key, sub = jax.random.split(key)
            b = jax.random.uniform(sub, shape)
            return a + b
    """, "rng-key-reuse")
    assert res.findings == [], to_text(res)


def test_rng_fires_on_loop_carried_reuse(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax

        def sample(key, shape):
            out = []
            for _ in range(4):
                out.append(jax.random.normal(key, shape))  # no resplit
            return out
    """, "rng-key-reuse")
    assert len(res.findings) == 1


def test_rng_silent_on_loop_with_split_or_fold_in(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax

        def sample(key, shape):
            out = []
            for i in range(4):
                key, sub = jax.random.split(key)
                out.append(jax.random.normal(sub, shape))
            for i in range(4):
                k = jax.random.fold_in(key, i)   # idiomatic stream derive
                out.append(jax.random.normal(k, shape))
            return out
    """, "rng-key-reuse")
    assert res.findings == [], to_text(res)


def test_rng_branches_do_not_conflict(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax

        def sample(key, shape, greedy):
            if greedy:
                x = jax.random.normal(key, shape)
            else:
                x = jax.random.uniform(key, shape)  # exclusive branch: ok
            return x
    """, "rng-key-reuse")
    assert res.findings == []


def test_rng_fires_after_either_branch_consumed(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax

        def sample(key, shape, greedy):
            if greedy:
                x = jax.random.normal(key, shape)
            else:
                x = jax.random.uniform(key, shape)
            y = jax.random.normal(key, shape)  # key spent on every path
            return x + y
    """, "rng-key-reuse")
    assert len(res.findings) == 1
    assert res.findings[0].line == 9


# ---------------------------------------------------------------------------
# jit-hygiene
# ---------------------------------------------------------------------------

def test_jit_hygiene_fires_on_all_three_patterns(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax

        _CACHE = {}

        @jax.jit
        def f(x, opts=[]):       # mutable default
            return x, _CACHE     # closure over mutable global

        def loop(xs):
            out = []
            for x in xs:
                g = jax.jit(lambda v: v + 1)   # jit per iteration
                out.append(g(x))
            return out
    """, "jit-hygiene")
    msgs = " ".join(f.message for f in res.findings)
    assert len(res.findings) == 3, to_text(res)
    assert "mutable default" in msgs
    assert "mutable module global" in msgs
    assert "inside a loop" in msgs


def test_jit_hygiene_silent_on_clean_patterns(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax

        _SCALE = 2.0          # immutable global: fine

        @jax.jit
        def f(x, opts=None):
            return x * _SCALE

        g = jax.jit(lambda v: v + 1)   # hoisted: fine

        def loop(xs):
            return [g(x) for x in xs]
    """, "jit-hygiene")
    assert res.findings == [], to_text(res)


# ---------------------------------------------------------------------------
# pytree-registration
# ---------------------------------------------------------------------------

def test_pytree_fires_on_unregistered_dataclass_into_jit(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax
        from dataclasses import dataclass

        @dataclass
        class State:
            x: float

        @jax.jit
        def step(s):
            return s

        def run():
            s = State(1.0)
            return step(s), step(State(2.0))
    """, "pytree-registration")
    assert len(res.findings) == 2, to_text(res)
    assert "State" in res.findings[0].message


def test_pytree_silent_when_registered(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax
        from dataclasses import dataclass

        @dataclass
        class State:
            x: float

        jax.tree_util.register_dataclass(State)

        @jax.jit
        def step(s):
            return s

        def run():
            return step(State(1.0))
    """, "pytree-registration")
    assert res.findings == [], to_text(res)


def test_pytree_silent_when_not_passed_to_jit(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax
        from dataclasses import dataclass

        @dataclass
        class Config:          # host-side config object: fine
            n: int

        @jax.jit
        def step(x):
            return x

        def run(cfg: Config, x):
            return step(x)
    """, "pytree-registration")
    assert res.findings == []


# ---------------------------------------------------------------------------
# policy-registry-conformance (runtime introspection)
# ---------------------------------------------------------------------------

def test_policy_conformance_clean_on_real_registry():
    rule = get_rule("policy-registry-conformance")
    findings = rule.check_project(REPO_ROOT)
    assert findings == [], [f.message for f in findings]


def test_policy_conformance_catches_broken_policy(monkeypatch):
    import jax.numpy as jnp
    import repro.core as core

    class NeverComputes(core.CachePolicy):
        name = "never"

        def init_state(self, shape, dtype=jnp.float32):
            return {"cache": jnp.zeros(shape, dtype)}

        def apply(self, state, step, x, compute_fn, **signals):
            return state["cache"], state      # serves zeros forever

        def want_compute(self, state, step, x, **signals):
            return jnp.asarray(False)         # fresh state refuses compute

    monkeypatch.setitem(core.POLICY_REGISTRY, "never",
                        lambda **kw: NeverComputes())
    rule = get_rule("policy-registry-conformance")
    findings = [f for f in rule.check_project(REPO_ROOT)
                if "'never'" in f.message]
    assert findings, "synthetic contract-breaking policy must fail lint"
    msgs = " ".join(f.message for f in findings)
    assert "FRESH state" in msgs or "compute_fn" in msgs


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_same_line_and_next_line_suppressions(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax.numpy as jnp

        def f(x):
            y = jnp.sum(x)
            a = float(y)  # repro-lint: disable=host-sync-in-hot-path -- why
            # repro-lint: disable-next-line=host-sync-in-hot-path -- why
            b = float(y * 2)
            c = float(y * 3)   # NOT suppressed
            return a, b, c
    """, "host-sync-in-hot-path")
    assert len(res.findings) == 1
    assert res.findings[0].line == 9
    assert len(res.suppressed) == 2


def test_disable_all_suppresses_every_rule(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax.numpy as jnp

        def f(x):
            return float(jnp.sum(x))  # repro-lint: disable=all -- escape hatch
    """, "host-sync-in-hot-path")
    assert res.findings == []
    assert len(res.suppressed) == 1


def test_suppression_for_other_rule_does_not_mask(tmp_path):
    res = lint_snippet(tmp_path, """
        import jax.numpy as jnp

        def f(x):
            return float(jnp.sum(x))  # repro-lint: disable=clock-discipline
    """, "host-sync-in-hot-path")
    assert len(res.findings) == 1


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

BASELINE_SNIPPET = """
    import jax.numpy as jnp

    def f(x):
        return float(jnp.sum(x))
"""


def test_baseline_filters_and_survives_line_drift(tmp_path):
    res = lint_snippet(tmp_path, BASELINE_SNIPPET, "host-sync-in-hot-path")
    assert len(res.findings) == 1
    bl_path = tmp_path / "baseline.json"
    Baseline.write(str(bl_path), res.findings, justification="test fixture")

    # same file: finding is baselined, run is clean
    snip = tmp_path / "src/repro/serving/snip.py"
    res2 = run_analysis(root=str(tmp_path), paths=[str(snip)],
                        rules=[get_rule("host-sync-in-hot-path")],
                        baseline_path=str(bl_path))
    assert res2.findings == [] and len(res2.baselined) == 1
    assert res2.exit_code == 0

    # unrelated lines added above: fingerprint (content-keyed) still matches
    snip.write_text("import os\nimport sys\n" + snip.read_text())
    res3 = run_analysis(root=str(tmp_path), paths=[str(snip)],
                        rules=[get_rule("host-sync-in-hot-path")],
                        baseline_path=str(bl_path))
    assert res3.findings == [] and len(res3.baselined) == 1


def test_baseline_invalidated_by_editing_the_offending_line(tmp_path):
    res = lint_snippet(tmp_path, BASELINE_SNIPPET, "host-sync-in-hot-path")
    bl_path = tmp_path / "baseline.json"
    Baseline.write(str(bl_path), res.findings)

    snip = tmp_path / "src/repro/serving/snip.py"
    snip.write_text(snip.read_text().replace(
        "float(jnp.sum(x))", "float(jnp.sum(x) * 2)"))
    res2 = run_analysis(root=str(tmp_path), paths=[str(snip)],
                        rules=[get_rule("host-sync-in-hot-path")],
                        baseline_path=str(bl_path))
    # edited line -> new fingerprint -> finding resurfaces + entry stale
    assert len(res2.findings) == 1
    assert len(res2.stale_baseline) == 1
    assert res2.exit_code == 1


def test_repo_baseline_has_no_unjustified_entries():
    path = os.path.join(REPO_ROOT, "tools", "lint_baseline.json")
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    for entry in data["findings"]:
        assert entry.get("justification"), f"unjustified: {entry}"


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_exits_1_on_synthetic_violation_and_writes_json(tmp_path):
    snip = tmp_path / "src/repro/serving/snip.py"
    snip.parent.mkdir(parents=True)
    snip.write_text(textwrap.dedent(BASELINE_SNIPPET))
    report = tmp_path / "report.json"
    rc = cli_main(["--root", str(tmp_path), "--baseline",
                   str(tmp_path / "none.json"), "--json", str(report),
                   "--rule", "host-sync-in-hot-path", "-q", str(snip)])
    assert rc == 1
    data = json.loads(report.read_text())
    assert data["exit_code"] == 1
    assert data["findings"][0]["rule"] == "host-sync-in-hot-path"
    assert data["findings"][0]["fingerprint"]


def test_cli_write_baseline_then_clean(tmp_path, capsys):
    snip = tmp_path / "src/repro/serving/snip.py"
    snip.parent.mkdir(parents=True)
    snip.write_text(textwrap.dedent(BASELINE_SNIPPET))
    bl = tmp_path / "bl.json"
    rc = cli_main(["--root", str(tmp_path), "--baseline", str(bl),
                   "--write-baseline", "--rule", "host-sync-in-hot-path",
                   str(snip)])
    assert rc == 0 and bl.exists()
    rc2 = cli_main(["--root", str(tmp_path), "--baseline", str(bl),
                    "--rule", "host-sync-in-hot-path", "-q", str(snip)])
    assert rc2 == 0


def test_cli_unknown_rule_is_usage_error(capsys):
    assert cli_main(["--rule", "no-such-rule"]) == 2


def test_syntax_error_is_a_finding(tmp_path):
    snip = tmp_path / "src/repro/serving/broken.py"
    snip.parent.mkdir(parents=True)
    snip.write_text("def f(:\n")
    res = run_analysis(root=str(tmp_path), paths=[str(snip)],
                       rules=[get_rule("clock-discipline")],
                       baseline_path=str(tmp_path / "none.json"))
    assert len(res.findings) == 1
    assert res.findings[0].rule == "syntax-error"


# ---------------------------------------------------------------------------
# framework plumbing
# ---------------------------------------------------------------------------

def test_all_six_rules_registered_with_metadata():
    rules = {r.id: r for r in all_rules()}
    expected = {"host-sync-in-hot-path", "clock-discipline",
                "rng-key-reuse", "jit-hygiene", "pytree-registration",
                "policy-registry-conformance"}
    assert expected <= set(rules)
    for rid in expected:
        assert rules[rid].description and rules[rid].rationale


def test_report_json_roundtrip(tmp_path):
    res = lint_snippet(tmp_path, BASELINE_SNIPPET, "host-sync-in-hot-path")
    data = to_json(res)
    assert data["version"] == 1 and len(data["findings"]) == 1
    text = to_text(res)
    assert "host-sync-in-hot-path" in text


def test_suppression_parser_ignores_justification_text():
    mod = ModuleSource(
        "x.py", "x.py",
        "a = 1  # repro-lint: disable=rule-a,rule-b -- because reasons\n")
    assert mod.suppressed(1, "rule-a") and mod.suppressed(1, "rule-b")
    assert not mod.suppressed(1, "because")


# ---------------------------------------------------------------------------
# the enforcement point: the repo itself must lint clean
# ---------------------------------------------------------------------------

def test_whole_repo_lints_clean():
    result = run_analysis(root=REPO_ROOT)
    assert result.findings == [], to_text(result)
    assert result.exit_code == 0
    # the rules actually looked at the tree
    assert result.files_scanned > 50
    # every inline suppression in the repo carries a justification comment
    for f in result.suppressed:
        src = os.path.join(REPO_ROOT, f.path)
        with open(src, encoding="utf-8") as fh:
            lines = fh.read().splitlines()
        window = "\n".join(lines[max(0, f.line - 2):f.line])
        assert "--" in window.split("repro-lint:")[-1], (
            f"{f.path}:{f.line} suppression lacks a -- justification")
