#!/usr/bin/env python
"""Clock-discipline lint: serving code must use repro.obs.clock.

Every wall time measured under `src/repro/serving/` and
`src/repro/modalities/` must go through `repro.obs.clock.monotonic()` (one
clock source -> cross-subsystem timestamps are comparable and trace spans
never go backwards).  This lint fails CI on any direct `time.time()` or
`time.perf_counter()` call in those trees; `repro/obs/clock.py` itself is
the single allowed call site.

Usage:  python tools/check_clock.py   (exit 1 on violations, listing them)
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LINTED_TREES = ("src/repro/serving", "src/repro/modalities")
PATTERN = re.compile(r"\btime\.(time|perf_counter|monotonic)\s*\(")


def violations():
    out = []
    for tree in LINTED_TREES:
        root = os.path.join(REPO, tree)
        for dirpath, _, filenames in os.walk(root):
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path) as f:
                    for lineno, line in enumerate(f, 1):
                        code = line.split("#", 1)[0]   # ignore comments
                        if PATTERN.search(code):
                            rel = os.path.relpath(path, REPO)
                            out.append((rel, lineno, line.rstrip()))
    return out


def main() -> int:
    bad = violations()
    if not bad:
        print(f"clock lint: OK ({', '.join(LINTED_TREES)} use "
              f"repro.obs.clock)")
        return 0
    print("clock lint: direct time.* calls in serving code — use "
          "repro.obs.clock.monotonic() instead:", file=sys.stderr)
    for rel, lineno, line in bad:
        print(f"  {rel}:{lineno}: {line.strip()}", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
