"""End-to-end driver: train a ~100M-param DiT for a few hundred steps, then
sample from it with and without caching.

    PYTHONPATH=src python examples/train_dit.py [--steps 300] [--small]

The data pipeline is the synthetic class-conditional latent generator from
repro.data (deterministic, offline).  Training uses the full substrate:
AdamW + cosine schedule + grad clipping, checkpointing, the train loop.
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.core import make_policy
from repro.data import latent_batches
from repro.diffusion import (CachedDenoiser, ddim_step, linear_schedule,
                             sample)
from repro.train import train_loop
from repro.train.steps import init_train_state, make_diffusion_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--small", action="store_true",
                    help="2-layer debug model instead of ~100M")
    args = ap.parse_args()

    if args.small:
        cfg = get_config("dit-xl").reduced(num_layers=2, d_model=128,
                                           dit_patch_tokens=16)
    else:
        # ~100M params: 12 layers x d_model 768 (DiT-B-ish)
        cfg = get_config("dit-xl").reduced(
            num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
            d_ff=3072, dit_patch_tokens=64, dit_in_dim=16,
            dit_num_classes=10, vocab_size=0)
    from repro.models import param_count
    print(f"model: {cfg.num_layers}L d={cfg.d_model} "
          f"({param_count(cfg)/1e6:.0f}M params)")

    sched = linear_schedule(1000)
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step_fn = make_diffusion_train_step(cfg, sched, peak_lr=2e-4, warmup=50,
                                        total_steps=args.steps)

    lat = latent_batches(0, args.batch, cfg.dit_patch_tokens, cfg.dit_in_dim,
                         cfg.dit_num_classes)

    def batches():
        key = jax.random.PRNGKey(2)
        for x, y in lat:
            key, sub = jax.random.split(key)
            yield {"latents": jnp.asarray(x), "labels": jnp.asarray(y),
                   "key": sub}

    with tempfile.TemporaryDirectory() as d:
        state, hist = train_loop(step_fn, state, batches(), args.steps,
                                 log_every=max(args.steps // 10, 1),
                                 ckpt_dir=d, ckpt_every=max(args.steps // 2, 1))
        restored, at_step, _ = ckpt.restore(d, state)
        print(f"checkpoint restored from step {at_step}")

    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss: {first:.4f} -> {last:.4f}")
    assert last < first, "training should reduce the loss"

    # sample from the trained model, cached vs exact
    ts = sched.spaced(40)
    x_T = jax.random.normal(jax.random.PRNGKey(3),
                            (4, cfg.dit_patch_tokens, cfg.dit_in_dim))
    den = CachedDenoiser(state.params, cfg,
                         make_policy("taylorseer", interval=4))
    x0, _ = sample(den, x_T, ts, sched, step_fn=ddim_step,
                   denoiser_state=den.init_state(4))
    print(f"cached sample stats: mean={float(x0.mean()):.3f} "
          f"std={float(x0.std()):.3f} finite={bool(jnp.all(jnp.isfinite(x0)))}")
    print("OK")


if __name__ == "__main__":
    main()
