"""Batched LLM serving across the architecture zoo.

    PYTHONPATH=src python examples/serving_llm.py

Runs the ServingEngine (prefill + rolling-KV greedy decode) over one
architecture from each family — dense GQA, MoE+MLA, pure SSM, hybrid — at
smoke scale, demonstrating that decode_step/prefill and the cache
containers work uniformly across families.
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving import ServingEngine

ARCHS = ["tinyllama-1.1b", "deepseek-v2-236b", "falcon-mamba-7b",
         "zamba2-2.7b", "whisper-small"]

rng = np.random.default_rng(0)
for arch in ARCHS:
    cfg = get_smoke_config(arch)
    if cfg.is_encoder_decoder:
        print(f"{arch:18s}: enc-dec — served via decode_step with exact "
              f"cross-KV (see tests/test_models.py)")
        continue
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(params, cfg, slots=4, cache_len=64, max_prompt=16)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=rng.integers(3, 12)))
               for _ in range(6)]
    t0 = time.perf_counter()
    out = engine.generate(prompts, max_new_tokens=12)
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in out)
    print(f"{arch:18s}: {len(out)} reqs, {toks} tokens, {toks/dt:5.1f} tok/s "
          f"| e.g. {out[0].tokens[:8]}")
print("OK")
