"""Diffusion language model + caching (the survey's §IV-F, dLLM-Cache).

    PYTHONPATH=src python examples/diffusion_lm.py

Runs LLaDA-style mask-denoising generation on the tinyllama smoke backbone,
exact vs FORA vs TaylorSeer cached, and reports full-compute counts and
token agreement — diffusion caching applied to a *language* model, closing
the loop between the survey's domain and the assigned LLM architectures.
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import make_policy
from repro.diffusion.dlm import dlm_generate
from repro.models import init_params

cfg = get_smoke_config("tinyllama-1.1b")
params = init_params(jax.random.PRNGKey(0), cfg)
B, S, T = 2, 24, 8

ref, n_ref = dlm_generate(params, cfg, batch=B, seq_len=S, num_steps=T)
print(f"exact: {n_ref}/{T} full computes | canvas[0,:12] = {np.asarray(ref)[0,:12]}")

for name, kw in [("fora", {"interval": 2}), ("taylorseer", {"interval": 2}),
                 ("teacache", {"delta": 0.3})]:
    pol = make_policy(name, **kw)
    out, n = dlm_generate(params, cfg, batch=B, seq_len=S, num_steps=T,
                          policy=pol)
    agree = float(np.mean(np.asarray(out) == np.asarray(ref)))
    print(f"{name:11s}: {n}/{T} full computes, token agreement {agree:.2f}")
print("OK")
