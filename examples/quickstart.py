"""Quickstart: cached diffusion sampling in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a small DiT, samples once exactly and once under TaylorSeer
("Cache-Then-Forecast", the survey's headline method), and reports the
compute saving and output agreement.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import make_policy
from repro.diffusion import CachedDenoiser, ddim_step, linear_schedule, sample
from repro.diffusion.pipeline import cfg_denoise_fn
from repro.models import init_params, perturb_zero_init

# 1. a small DiT (the zoo's dit-xl config, reduced for CPU)
cfg = get_config("dit-xl").reduced(num_layers=6, d_model=256, num_heads=4,
                                   num_kv_heads=4, d_ff=1024,
                                   dit_patch_tokens=64, dit_num_classes=10)
params = perturb_zero_init(init_params(jax.random.PRNGKey(0), cfg))

# 2. a 40-step DDIM trajectory
sched = linear_schedule(1000)
timesteps = sched.spaced(40)
x_T = jax.random.normal(jax.random.PRNGKey(1),
                        (2, cfg.dit_patch_tokens, cfg.dit_in_dim))

# 3. exact baseline
exact_fn = cfg_denoise_fn(params, cfg, cfg_scale=0.0)
x0_exact, _ = sample(exact_fn, x_T, timesteps, sched, step_fn=ddim_step)

# 4. cached: TaylorSeer forecasts 3 of every 4 steps (survey Eq. 42)
policy = make_policy("taylorseer", interval=4, order=2)
denoiser = CachedDenoiser(params, cfg, policy, granularity="model")
x0_cached, _ = sample(denoiser, x_T, timesteps, sched, step_fn=ddim_step,
                      denoiser_state=denoiser.init_state(2))

mse = float(jnp.mean((x0_cached - x0_exact) ** 2))
sched_mask = policy.static_schedule(40)
print(f"full model evaluations: {sum(sched_mask)}/40 "
      f"(speedup ~{40/sum(sched_mask):.1f}x)")
print(f"output MSE vs exact: {mse:.2e}")
assert np.isfinite(mse) and mse < 1.0
print("OK")
