"""Policy zoo tour: every surveyed cache family on one sampling problem.

    PYTHONPATH=src python examples/cached_generation.py

Static (FORA, Δ-DiT), timestep-adaptive (TeaCache, MagCache, EasyCache),
predictive (TaylorSeer, HiCache, FoCa, AB-Cache, FreqCa) and hybrid
(ClusCa, SpeCa) policies, plus DeepCache-style structural splitting and
CFG-branch caching (FasterCache) — each sampled on the same seed and scored
against the exact trajectory.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import make_policy
from repro.core.metrics import psnr
from repro.core.static_policies import FasterCacheCFG
from repro.diffusion import CachedDenoiser, ddim_step, linear_schedule, sample
from repro.diffusion.pipeline import cfg_denoise_fn
from repro.models import init_params, perturb_zero_init

NUM_STEPS = 40

cfg = get_config("dit-xl").reduced(num_layers=6, d_model=256, num_heads=4,
                                   num_kv_heads=4, d_ff=1024,
                                   dit_patch_tokens=64, dit_num_classes=10)
params = perturb_zero_init(init_params(jax.random.PRNGKey(0), cfg))
sched = linear_schedule(1000)
ts = sched.spaced(NUM_STEPS)
x_T = jax.random.normal(jax.random.PRNGKey(1),
                        (2, cfg.dit_patch_tokens, cfg.dit_in_dim))

exact, _ = sample(cfg_denoise_fn(params, cfg, 1.5), x_T, ts, sched,
                  step_fn=ddim_step)

ZOO = [
    ("fora (static, N=4)", "fora", {"interval": 4}, "model"),
    ("delta-dit (residual, deepcache split)", "delta_dit", {"interval": 4},
     "deepcache"),
    ("teacache (adaptive, d=0.15)", "teacache", {"delta": 0.15}, "model"),
    ("magcache (d=0.06)", "magcache", {"delta": 0.06}, "model"),
    ("easycache (tau=3)", "easycache", {"tau": 3.0}, "model"),
    ("taylorseer (N=4, m=2)", "taylorseer", {"interval": 4}, "model"),
    ("hicache (hermite)", "hicache", {"interval": 4}, "model"),
    ("foca (BDF2+Heun)", "foca", {"interval": 4}, "model"),
    ("abcache (adams-bashforth)", "abcache", {"interval": 4}, "model"),
    ("freqca (freq split + CRF)", "freqca", {"interval": 4}, "model"),
    ("toca (token-wise, Eq. 19-21)", "toca", {"interval": 4, "ratio": 0.25},
     "model"),
    ("clusca (token clusters)", "clusca", {"interval": 4, "k": 8}, "block"),
    ("speca (speculative)", "speca", {"interval": 4, "tau": 0.1}, "model"),
]

print(f"{'policy':42s} {'PSNR vs exact':>14s}")
for label, name, kw, gran in ZOO:
    pol = make_policy(name, **kw)
    den = CachedDenoiser(params, cfg, pol, granularity=gran, cfg_scale=1.5)
    x0, _ = sample(den, x_T, ts, sched, step_fn=ddim_step,
                   denoiser_state=den.init_state(2))
    print(f"{label:42s} {float(psnr(x0, exact)):14.1f}")

# CFG-branch caching on top of a feature cache (FasterCache §III-C)
den = CachedDenoiser(params, cfg, make_policy("taylorseer", interval=4),
                     cfg_scale=1.5, cfg_policy=FasterCacheCFG(2, NUM_STEPS))
x0, _ = sample(den, x_T, ts, sched, step_fn=ddim_step,
               denoiser_state=den.init_state(2))
print(f"{'taylorseer + fastercache-CFG':42s} {float(psnr(x0, exact)):14.1f}")
print("OK")
