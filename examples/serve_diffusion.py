"""Cache-aware diffusion serving: continuous batching with per-slot caches.

    PYTHONPATH=src python examples/serve_diffusion.py

A queue of 20 latent-generation requests with mixed step budgets (interactive
previews at 8 steps, quality renders at 16) flows through 6 slots.  Each slot
is one in-flight request at its own denoising step; a single triple of
compiled programs advances all of them per tick, and the SLA autotuner picks
the cache policy per traffic class before serving.

Part 2 serves *guided* traffic: classifier-free guidance doubles backbone
cost, so each slot additionally carries a FasterCacheCFG state that reuses
the unconditional branch.  Every tick is row-compacted: the engine gathers
exactly the cond and uncond rows whose per-slot policies want a compute into
one power-of-two bucket, runs the backbone over those rows only, and
scatters the outputs back — a slot refreshing its uncond cache costs one
extra row, not a doubled batch, and the telemetry reports the backbone rows
actually computed vs what dense whole-pool ticks would have dispatched.
Guided and unguided requests share one slot pool.
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import FasterCacheCFG
from repro.models import init_params, perturb_zero_init
from repro.diffusion import linear_schedule
from repro.serving.diffusion import (SLA, DiffusionRequest,
                                     DiffusionServingEngine,
                                     autotune_traffic_classes)

# -- a CPU-friendly DiT ----------------------------------------------------
cfg = get_config("dit-xl").reduced(num_layers=6, d_model=256, num_heads=4,
                                   num_kv_heads=4, d_ff=1024,
                                   dit_patch_tokens=64, dit_in_dim=16,
                                   dit_num_classes=10)
params = perturb_zero_init(init_params(jax.random.PRNGKey(0), cfg))
noise_sched = linear_schedule(1000)

# -- 1. autotune: pick a policy per traffic class against its SLA ----------
slas = {
    "interactive": SLA("interactive", min_psnr=-100.0),   # latency over quality
    "quality": SLA("quality", min_psnr=40.0),             # stay near-exact
}
print("== autotuning policies per traffic class ==")
tuned = autotune_traffic_classes(params, cfg, slas, num_steps=16,
                                 noise_schedule=noise_sched, verbose=True)
for tc, t in tuned.items():
    print(f"  {tc:12s} -> {t.policy_name} {t.kwargs} "
          f"(psnr={t.psnr:.1f}dB, compute_fraction={t.compute_fraction:.2f})")

# -- 2. serve a mixed-budget queue per traffic class -----------------------
requests = [DiffusionRequest(i,
                             num_steps=8 if i % 2 == 0 else 16,
                             seed=i, class_label=i % cfg.dit_num_classes,
                             traffic_class="interactive" if i % 2 == 0
                             else "quality")
            for i in range(20)]

for tc, t in tuned.items():
    batch = [r for r in requests if r.traffic_class == tc]
    eng = DiffusionServingEngine(params, cfg, t.make(), slots=6,
                                 max_steps=16, noise_schedule=noise_sched,
                                 align=t.align)
    results = eng.serve(batch)
    s = eng.telemetry.summary()
    assert len(results) == len(batch)
    assert all(np.isfinite(r.x0).all() for r in results)
    print(f"\n== {tc}: {len(batch)} requests via {t.policy_name} ==")
    print(f"  throughput      : {s['throughput_rps']:.2f} req/s")
    print(f"  latency p50/p95 : {s['latency_p50_s']:.3f}s / "
          f"{s['latency_p95_s']:.3f}s")
    print(f"  compute fraction: {s['compute_fraction_mean']:.3f} "
          f"(cache hit rate {s['cache_hit_rate_mean']:.3f})")
    print(f"  ticks           : {s['ticks']} "
          f"({100 * s['full_tick_fraction']:.0f}% ran the backbone; "
          f"backbone {s['tick_ms_cond_mean']:.1f}ms vs "
          f"skip {s['tick_ms_skip_mean']:.1f}ms)")
    print(f"  cache state     : {s['cache_state_bytes_per_slot']} B/slot")
    for r in results[:4]:
        rec = r.record
        print(f"    req {rec.request_id:2d}: {rec.num_steps:2d} steps, "
              f"latency {rec.latency:.3f}s (queued {rec.queue_wait:.3f}s), "
              f"computed {rec.computed_steps}/{rec.num_steps}")

# -- 3. guided + unguided requests through one CFG-aware slot pool ---------
# cfg_scale > 0 makes a request guided: the engine runs a second
# (unconditional) backbone branch and blends eps = e_u + s (e_c - e_u).
# FasterCacheCFG per slot reuses the uncond branch between refreshes, so
# most backbone ticks drop the uncond rows (cond-only program).
guided_requests = [
    DiffusionRequest(100 + i, num_steps=16, seed=i,
                     class_label=i % cfg.dit_num_classes,
                     cfg_scale=4.0 if i % 2 == 0 else 0.0)
    for i in range(12)]

eng = DiffusionServingEngine(params, cfg, "fora", slots=6, max_steps=16,
                             noise_schedule=noise_sched,
                             cfg_policy=FasterCacheCFG(interval=4,
                                                       num_steps=16))
results = eng.serve(guided_requests)
s = eng.telemetry.summary()
assert len(results) == len(guided_requests)
assert all(np.isfinite(r.x0).all() for r in results)
print(f"\n== mixed guided/unguided: {len(guided_requests)} requests "
      f"({s['guided_requests']} guided @ cfg_scale=4.0) ==")
print(f"  throughput      : {s['throughput_rps']:.2f} req/s")
print(f"  tick mix        : {eng.telemetry.ticks_full} w/ uncond rows / "
      f"{eng.telemetry.ticks_cond} cond-only / "
      f"{eng.telemetry.ticks_skip} skip")
print(f"  backbone rows   : {s['backbone_rows_computed']} computed "
      f"(+{s['backbone_rows_padding']} bucket padding), "
      f"{s['backbone_rows_saved']} saved vs dense whole-pool ticks "
      f"({s['backbone_rows_per_tick_mean']:.1f} rows/backbone tick)")
print(f"  uncond rows     : {s['uncond_rows_computed']} dispatched, "
      f"{s['uncond_rows_saved']} saved by CFG reuse "
      f"({s['uncond_saved_steps_total']} uncond computes saved "
      f"across guided requests)")
for r in results[:4]:
    rec = r.record
    tag = (f"guided, uncond {rec.uncond_computed_steps}/{rec.num_steps}"
           if rec.guided else "unguided")
    print(f"    req {rec.request_id:3d}: computed "
          f"{rec.computed_steps}/{rec.num_steps} cond ({tag})")
print("\nOK")
