"""Online control plane: live policy retuning + a want_compute gate
learned from serving traces.

    PYTHONPATH=src python examples/online_control_plane.py

Three acts on one small DiT:

1. SmoothCache — profile the model once (rel-L1 change of consecutive
   exact outputs), derive a static compute/reuse schedule, serve it on the
   engine's zero-sync host plan.  The strongest offline baseline.
2. OnlineTuner — quality-sweep a candidate menu once (the SmoothCache
   schedule family plus dynamic policies), then serve while a
   TelemetryWindow hook watches every tick; each retune window re-prices
   the menu with live row timings, occupancy, and the measured plan-time
   surcharge for device-planned policies, and rolls the pool over
   blue/green at a refill boundary when a different candidate wins —
   in-flight requests always drain under the policy that admitted them.
3. Learned want_compute — a SignalTraceLog hook on the same sessions
   records per-slot signals and probes latent trajectories; the probes
   become teacher pairs for a LazyDiT gate trained in-framework, which
   then serves through `make_policy("lazydit", gate=...)` on the
   row-compacted path.
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import make_policy
from repro.core.metrics import psnr
from repro.models import init_params, perturb_zero_init
from repro.serving.control import (OnlineTuner, SignalTraceLog,
                                   SmoothCacheSchedule, TelemetryWindow,
                                   calibration_profile, fit_want_gate,
                                   probe_training_set)
from repro.serving.diffusion import (SLA, DiffusionRequest,
                                     DiffusionServingEngine)

# -- a tiny CPU-friendly DiT ----------------------------------------------
cfg = get_config("dit-xl").reduced(num_layers=2, d_model=64, num_heads=4,
                                   num_kv_heads=4, d_ff=256,
                                   dit_patch_tokens=16, dit_in_dim=8,
                                   dit_num_classes=10)
params = perturb_zero_init(init_params(jax.random.PRNGKey(0), cfg))
STEPS, SLOTS = 8, 2


def queue(n, base=0):
    return [DiffusionRequest(base + i, num_steps=STEPS, seed=base + i,
                             class_label=i % 10) for i in range(n)]


# -- 1. SmoothCache: calibrate once, serve statically ----------------------
print("== 1. SmoothCache static schedule ==")
profile = calibration_profile(params, cfg, STEPS)
sc = SmoothCacheSchedule(profile, alpha=0.05)
print(f"profile (rel-L1/step): {[f'{p:.3f}' for p in profile]}")
print(f"schedule alpha={sc.alpha}: {sc.static_schedule(STEPS)} "
      f"(compute fraction {sc.compute_fraction:.2f})")

# -- 2. OnlineTuner: sweep once, re-price live, roll over blue/green -------
print("\n== 2. online tuner ==")
menu = [("none", {}), ("teacache", {"delta": 0.06}), ("fora", {"interval": 2}),
        ("blockcache", {"profile": profile, "delta": 0.05}),
        ("blockcache", {"profile": profile, "delta": 0.2})]
window = TelemetryWindow(max_ticks=128)
trace = SignalTraceLog(probe_every=2, max_probes=6, max_probe_steps=STEPS)
tuner = OnlineTuner(params, cfg, SLA(min_psnr=15.0), slots=SLOTS,
                    max_steps=STEPS, candidates=menu, retune_every=6,
                    min_window_ticks=4, initial=("none", {}),
                    window=window, trace=trace, verbose=True)
tuner.submit_all(queue(10))
results = tuner.drain()
print(f"served {len(results)} requests; policy now "
      f"'{tuner.current.policy_name}' after {len(tuner.swaps)} swap(s)")
for sw in tuner.swaps:
    print(f"  swap @tick {sw['tick']}: {sw['from'][0]} -> {sw['to'][0]} "
          f"(row_time={sw['row_time_ms']}, plan={sw['plan_time_ms']:.2f}ms)")
w = window.summary()
print(f"window: row_time={w['row_time_ms']:.2f}ms occupancy={w['occupancy']} "
      f"plan_time={w['plan_time_ms']:.2f}ms "
      f"compute_fraction={w['compute_fraction']:.2f}")

# -- 3. learned want_compute from the serving traces -----------------------
print("\n== 3. learned want_compute gate from logged traces ==")
print(f"trace: {trace.summary()}")
pairs = probe_training_set(params, cfg, trace)
gate, hist = fit_want_gate(jax.random.PRNGKey(1), pairs, steps=120)
print(f"trained on {len(pairs)} probe trajectories: "
      f"loss {hist[0]:.4f} -> {hist[-1]:.4f}")

learned = make_policy("lazydit", gate=gate, threshold=0.5)
eng = DiffusionServingEngine(params, cfg, learned, slots=SLOTS,
                             max_steps=STEPS)
ref_eng = DiffusionServingEngine(params, cfg, "none", slots=SLOTS,
                                 max_steps=STEPS)
reqs = queue(6, base=100)
got = {r.request_id: r for r in eng.serve(reqs)}
ref = {r.request_id: r.x0 for r in ref_eng.serve(reqs)}
cf = np.mean([g.record.compute_fraction for g in got.values()])
q = np.mean([psnr(ref[i], got[i].x0) for i in got])
print(f"learned gate served {len(got)} requests: "
      f"compute fraction {cf:.2f}, {q:.1f}dB vs exact")
