"""Text-to-image cache-aware serving demo.

Builds the t2i workload (image DiT + AdaLN-zero-gated cross-attention
over prompt embeddings) at smoke scale, wraps its text encoder in a
PromptCache, and serves a prompted CFG queue where a few popular prompts
repeat — the shape of real T2I traffic.  The demo prints what the
conditioning stack pays at each frequency:

  * text encoder: once per UNIQUE prompt (PromptCache content-hash LRU),
  * cross-attn K/V projection: once per admission (per-slot text tables),
  * per tick/step: nothing — text K/V are operands of the tick programs.

    PYTHONPATH=src python examples/text_to_image_serving.py
"""
import numpy as np

from repro.core import FasterCacheCFG, make_policy
from repro.modalities import make_workload
from repro.serving.diffusion import DiffusionRequest

NUM_STEPS = 12
SLOTS = 2

PROMPTS = [
    "a photo of a red fox in the snow",
    "a watercolor painting of a lighthouse",
    "a photo of a red fox in the snow",       # repeat: cache hit
    "an isometric render of a tiny city",
    "a watercolor painting of a lighthouse",  # repeat: cache hit
    "a photo of a red fox in the snow",       # repeat: cache hit
]


def main():
    wl = make_workload("t2i", smoke=True)
    print(f"t2i latent {wl.latent_shape()}  backbone={wl.cfg.name}  "
          f"text_len={wl.cfg.dit_text_len}")

    conditioner = wl.conditioner()            # PromptCache + text encoder
    engine = wl.engine(make_policy("teacache", delta=0.1), slots=SLOTS,
                       max_steps=NUM_STEPS,
                       cfg_policy=FasterCacheCFG(4, NUM_STEPS),
                       conditioner=conditioner)
    profiles = engine.warmup()   # buckets + want + text_kv + text_encoder
    text_programs = sorted(k for k in profiles
                           if isinstance(k, str) and k.startswith("text"))
    print(f"warmup compiled {len(profiles)} programs "
          f"(text-side: {text_programs})")

    # a prompted guided queue; one request adds a negative prompt, which
    # rides the uncond branch's null-vec + text tables under CFG
    reqs = [DiffusionRequest(
        i, num_steps=NUM_STEPS, seed=i, cfg_scale=3.0,
        prompt_tokens=p,
        neg_prompt_tokens="blurry, low quality" if i == 0 else None)
        for i, p in enumerate(PROMPTS)]
    results = engine.serve(reqs)
    assert all(np.isfinite(r.x0).all() for r in results)

    s = engine.telemetry.summary()
    print(f"\nserved {s['requests']} prompted requests in "
          f"{s['elapsed_s']:.2f}s ({s['throughput_rps']:.2f} req/s)")
    print(f"backbone rows computed {s['backbone_rows_computed']} "
          f"(saved {s['backbone_rows_saved']})")

    st = conditioner.stats
    print(f"\nprompt cache: {st['misses']} encoder runs for "
          f"{len(reqs) + 1} prompt resolutions "
          f"({st['hits']} hits, hit rate {st['hit_rate']:.2f})")
    # the same prompt, re-submitted, is a host-side dict hit — the
    # embedding (and the per-slot K/V built from it) never recompute
    pe = conditioner.get(PROMPTS[0])
    assert conditioner.get(PROMPTS[0]) is pe


if __name__ == "__main__":
    main()
