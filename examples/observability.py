"""Observability end to end: trace, metrics, and program profiles from one
mixed-modality serving session.

    PYTHONPATH=src python examples/observability.py [OUTDIR]

Serves a mixed image+video queue (TeaCache cond policy, FasterCacheCFG
uncond reuse on the image pool) with the full repro.obs surface attached,
then writes to OUTDIR (default /tmp/repro_obs):

  trace.json          Chrome/Perfetto trace — one process per modality
                      sub-pool, plan/backbone tracks, per-slot cache
                      lifecycle spans (admit -> compute/reuse annotated
                      with signal vs threshold -> finish).  Open it at
                      https://ui.perfetto.dev or chrome://tracing.
  cache_events.jsonl  one line per active slot per tick — the durable
                      SignalTraceLog: `signal_trace_from_files` rebuilds
                      a trainable trace from it after the process exits.
  metrics.prom        Prometheus text exposition of every counter/gauge/
                      histogram the engines + schedulers published.
  metrics.json        the same registry as a JSON snapshot (+ event ring).

It also prints warmup's per-program compile time + XLA-costed FLOPs and
the measured redundancy ratio (FLOPs the caches avoided over the dense
FLOPs a no-cache pool would have dispatched), and reconciles the JSONL
against ServingTelemetry: per-request computed-step counts must agree
EXACTLY (tests/test_observability.py asserts the same).
"""
import json
import os
import sys

import numpy as np

from repro.modalities import MixedModalityEngine, make_workload
from repro.obs import (MetricsRegistry, TraceRecorder, flops_per_row,
                       redundancy_ratio, validate_chrome_trace)
from repro.serving.diffusion import DiffusionRequest

NUM_STEPS = 8
SLOTS = 2


def main(outdir: str = "/tmp/repro_obs"):
    os.makedirs(outdir, exist_ok=True)
    workloads = {m: make_workload(m, smoke=True) for m in ("image", "video")}
    from repro.core import FasterCacheCFG
    pools = {
        name: wl.engine("teacache", slots=SLOTS, max_steps=NUM_STEPS,
                        cfg_policy=(FasterCacheCFG(4, NUM_STEPS)
                                    if name == "image" else None))
        for name, wl in workloads.items()}
    engine = MixedModalityEngine(pools)

    # -- warmup doubles as the program profiler ------------------------
    profiles = engine.warmup()
    print("== program profiles (per-bucket jit compile + XLA cost) ==")
    for modality, prof in sorted(profiles.items()):
        for key, p in sorted(prof.items(), key=lambda kv: str(kv[0])):
            print(f"  {modality:6s} program {str(key):>5s}: "
                  f"compile {p.compile_seconds:6.2f}s  "
                  f"flops {p.flops:12.3e}  bytes {p.bytes_accessed:10.3e}")
        print(f"  {modality:6s} marginal FLOPs/row: "
              f"{flops_per_row(prof):.3e}")

    # -- serve with the full observability surface attached ------------
    registry = MetricsRegistry()
    recorders = {m: TraceRecorder(policy=pools[m].policy)
                 for m in pools}
    mods = ("image", "video")
    # stagger num_steps WITHIN each pool: uniform queues tick in lockstep
    # (every slot wants compute on the same ticks), which hides the row
    # savings the redundancy ratio below prices
    reqs = [DiffusionRequest(i, num_steps=NUM_STEPS - 2 * ((i // 2) % 2),
                             seed=i, class_label=i % 5, modality=mods[i % 2],
                             cfg_scale=3.0 if mods[i % 2] == "image" else 0.0)
            for i in range(8)]
    results = engine.serve(reqs, hooks={m: [rec] for m, rec
                                        in recorders.items()},
                           metrics=registry)
    assert all(np.isfinite(r.x0).all() for r in results)
    for m, tele in engine.telemetry.pools.items():
        tele.publish(registry, modality=m)     # telemetry as a metrics view

    # -- artifacts -----------------------------------------------------
    # merge the per-pool recorders into one Perfetto trace (events carry
    # their own pid per modality, so concatenation is safe after remapping
    # pids to stay distinct)
    merged = {"traceEvents": [], "displayTimeUnit": "ms"}
    pid_base = 0
    for m in sorted(recorders):
        rec = recorders[m]
        rec.finish()
        trace = rec.chrome_trace()
        problems = validate_chrome_trace(trace)
        assert not problems, (m, problems)
        for ev in trace["traceEvents"]:
            ev = dict(ev)
            ev["pid"] += pid_base
            merged["traceEvents"].append(ev)
        pid_base += 1 + max(
            (e["pid"] for e in trace["traceEvents"]), default=0)
    trace_path = os.path.join(outdir, "trace.json")
    with open(trace_path, "w") as f:
        json.dump(merged, f, default=float)

    jsonl_path = os.path.join(outdir, "cache_events.jsonl")
    with open(jsonl_path, "w") as f:
        for m in sorted(recorders):
            for ev in recorders[m].cache_events:
                f.write(json.dumps(ev, default=float) + "\n")

    registry.write_prometheus(os.path.join(outdir, "metrics.prom"))
    registry.write_snapshot(os.path.join(outdir, "metrics.json"))

    # -- reconcile: JSONL == telemetry, exactly ------------------------
    print("\n== reconciliation (cache-event JSONL vs ServingTelemetry) ==")
    ok = True
    for m, rec in sorted(recorders.items()):
        by_req = rec.computed_steps_by_request()
        tele = engine.telemetry.pools[m]
        for r in tele.records:
            traced = by_req.get(r.request_id)
            match = traced == r.computed_steps
            ok &= match
            print(f"  {m:6s} req {r.request_id}: telemetry "
                  f"{r.computed_steps} computed steps, trace {traced} "
                  f"{'OK' if match else 'MISMATCH'}")
    assert ok, "cache-event log diverged from telemetry"

    # -- the survey's redundancy claim, measured in FLOPs --------------
    print("\n== measured redundancy ratio ==")
    for m, tele in sorted(engine.telemetry.pools.items()):
        rr = redundancy_ratio(profiles[m], tele.backbone_rows_computed,
                              tele.backbone_rows_padding,
                              tele.backbone_rows_saved)
        print(f"  {m:6s} {rr['redundancy_ratio']:.3f} "
              f"({rr['flops_avoided']:.3e} of {rr['dense_flops']:.3e} "
              f"dense FLOPs avoided)")

    s = engine.telemetry.summary()
    print(f"\nserved {s['requests']} requests "
          f"({s['throughput_rps']:.2f} req/s); wrote")
    for name in ("trace.json", "cache_events.jsonl", "metrics.prom",
                 "metrics.json"):
        print(f"  {os.path.join(outdir, name)}")
    print("open trace.json at https://ui.perfetto.dev")


if __name__ == "__main__":
    main(*sys.argv[1:2])
