"""Mixed-modality cache-aware serving demo.

Builds three denoise workloads — image latents (DiT-XL shape), video latent
clips (factorized spatio-temporal DiT) and audio mel-spectrograms — at
smoke scale, autotunes a cache policy per modality against one SLA, then
serves a mixed image+video+audio queue through per-modality sub-pools under
the MixedModalityEngine umbrella, printing per-modality row accounting.

    PYTHONPATH=src python examples/mixed_modality_serving.py
"""
import numpy as np

from repro.core import FasterCacheCFG
from repro.modalities import (MixedModalityEngine, autotune_pools,
                              make_workload)
from repro.serving.diffusion import SLA, DiffusionRequest

NUM_STEPS = 12
SLOTS = 2


def main():
    workloads = {m: make_workload(m, smoke=True)
                 for m in ("image", "video", "audio")}
    for name, wl in workloads.items():
        print(f"{name:6s} latent {wl.latent_shape()}  frames={wl.frames}  "
              f"backbone={wl.cfg.name}")

    # one SLA-driven sweep per modality (video adds a temporal candidate).
    # smoke-scale untrained backbones cache poorly, so the demo SLA floor
    # is permissive — tighten it on real weights
    print("\nautotuning per modality ...")
    tuned = autotune_pools(workloads, SLA(min_psnr=12.0),
                           num_steps=NUM_STEPS)
    for name, t in tuned.items():
        print(f"  {name:6s} -> {t.policy_name} {t.kwargs} "
              f"(psnr={t.psnr:.1f}dB cf={t.compute_fraction:.2f})")

    pools = {
        name: wl.engine(tuned[name].make(), slots=SLOTS,
                        max_steps=NUM_STEPS,
                        # guided image requests reuse the uncond branch
                        cfg_policy=(FasterCacheCFG(4, NUM_STEPS)
                                    if name == "image" else None))
        for name, wl in workloads.items()}
    engine = MixedModalityEngine(pools)
    engine.warmup()          # pre-compile every sub-pool's bucket programs

    # a mixed queue: unguided video/audio + CFG image requests, one image
    # request carrying a negative-prompt conditioning VECTOR
    mods = ("image", "video", "audio")
    neg = np.random.RandomState(0).randn(
        workloads["image"].cfg.d_model).astype(np.float32) * 0.1
    reqs = [
        DiffusionRequest(i, num_steps=NUM_STEPS - 4 * (i % 2), seed=i,
                         class_label=i % 5, modality=mods[i % 3],
                         cfg_scale=3.0 if mods[i % 3] == "image" else 0.0,
                         null_label=neg if i == 0 else None)
        for i in range(9)]
    results = engine.serve(reqs)

    s = engine.telemetry.summary()
    print(f"\nserved {s['requests']} requests in {s['elapsed_s']:.2f}s "
          f"({s['throughput_rps']:.2f} req/s)")
    print(f"backbone rows computed {s['backbone_rows_computed']} "
          f"(saved {s['backbone_rows_saved']}); token-weighted "
          f"{s['backbone_tokens_computed']} "
          f"(saved {s['backbone_tokens_saved']})")
    print("\nper-modality pools:")
    for m, ms in engine.telemetry.by_modality().items():
        print(f"  {m:6s} reqs={ms['requests']} "
              f"rows={ms['backbone_rows_computed']:4d} "
              f"saved={ms['backbone_rows_saved']:4d} "
              f"cf={ms['compute_fraction_mean']:.2f} "
              f"p50={ms['latency_p50_s']:.3f}s")
    assert all(np.isfinite(r.x0).all() for r in results)


if __name__ == "__main__":
    main()
