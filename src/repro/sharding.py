"""Sharding rules: param/input/cache PartitionSpecs for the production mesh.

Two mesh layouts are supported transparently:

  contract mesh  ("data", "model")               [+ leading "pod"]
  logical mesh   ("data", "attn", "ffn")         [+ leading "pod"]

The logical mesh (launch.mesh.make_logical_mesh) factors the 16-chip tensor
axis per architecture so attention-head sharding stays head-aligned
(attn | KV-heads); "attn"+"ffn" composed recover the full 16-way tensor
parallelism for FFN / vocab / expert-inner dims.  On the contract mesh the
single "model" axis plays both roles (and _sanitize drops it wherever the
dim is not divisible — the involuntary-remat fallback measured in
EXPERIMENTS §Perf).

Rules (DESIGN §5):
  * attention projections: head axis on ATTN
  * MLP / expert-inner / vocab / mamba-inner dims: on TP (= attn+ffn)
  * MoE expert axis: on "data" (expert parallelism; also shards optimizer
    moments 256-way, ZeRO-equivalent — what lets the 236B/480B MoEs fit)
  * activations: batch on ("pod","data")
  * KV caches: batch on data, kv-heads on ATTN, head_dim on "ffn"
  * optimizer moments: same spec as their param
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def batch_axes(mesh: Mesh):
    """The composed batch axis: ("pod","data") on multi-pod meshes."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def attn_axis(mesh: Mesh) -> str:
    return "attn" if "attn" in mesh.axis_names else "model"


def tp_axes(mesh: Mesh):
    """Full tensor-parallel axis (attn+ffn composed, or plain model)."""
    return ("attn", "ffn") if "attn" in mesh.axis_names else ("model",)


def ffn_axis(mesh: Mesh) -> str:
    return "ffn" if "ffn" in mesh.axis_names else "model"


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(mesh: Mesh, axes, dim: int):
    """axes if dim is divisible by their product, else None (replicate)."""
    return axes if dim % _axes_size(mesh, axes) == 0 else None


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


# ----------------------------------------------------------------------
# parameter sharding rules
# ----------------------------------------------------------------------

def param_spec(path: str, leaf, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf (unstacked suffix rules; a
    leading None is prepended for scan-stacked block params)."""
    ATTN, TP = attn_axis(mesh), tp_axes(mesh)
    stacked = bool(re.search(r"(^|/)(blocks|enc_blocks|dec_blocks)/", path))
    ndim = leaf.ndim - (1 if stacked else 0)

    def out(*spec):
        spec = list(spec)
        spec = spec[:ndim] + [None] * max(0, ndim - len(spec))
        if stacked:
            spec = [None] + spec
        return P(*spec)

    # --- embeddings / vocab projections: vocab on the full tensor axis ---
    if re.search(r"(^|/)embed$", path):
        return out(TP, None)                 # (vocab, d)
    if re.search(r"(^|/)lm_head$", path):
        return out(None, TP)                 # (d, vocab)

    # --- MoE experts: expert axis on data + inner ff on tensor axis ---
    if re.search(r"/moe/w_(gate|up)$", path):
        return out("data", None, TP)         # (E, d, ff)
    if re.search(r"/moe/w_down$", path):
        return out("data", TP, None)         # (E, ff, d)
    if re.search(r"/moe/router$", path):
        return out(None, None)               # small; replicate for routing
    if re.search(r"/moe/(shared|dense_res)/", path):
        if re.search(r"w_down$", path):
            return out(TP, None)
        return out(None, TP)

    # --- attention projections: whole heads on ATTN ---
    if re.search(r"(attn|self|cross)/w[qkv]$", path):
        return out(None, ATTN)               # (d, H*hd), head-aligned
    if re.search(r"(attn|self|cross)/wo$", path):
        return out(ATTN, None)               # (H*hd, d)
    if re.search(r"(attn|self|cross)/b[qkv]$", path):
        return out(ATTN)

    # --- MLA (deepseek) ---
    if re.search(r"attn/(w_dkv|w_kr)$", path):
        return out(None, None)               # small lora-down: replicate
    if re.search(r"attn/(w_uk|w_uv)$", path):
        return out(ATTN, None, None)         # (H, r, d): heads on ATTN

    # --- MLP ---
    if re.search(r"mlp/(w_up|w_gate)$", path):
        return out(None, TP)
    if re.search(r"mlp/w_down$", path):
        return out(TP, None)

    # --- mamba: inner channels on the full tensor axis ---
    if re.search(r"mamba/in_proj$", path):
        return out(None, TP)
    if re.search(r"mamba/out_proj$", path):
        return out(TP, None)
    if re.search(r"mamba/(x_proj|dt_proj)$", path):
        return out(None, None)
    if re.search(r"mamba/(conv_w|conv_b|A_log|D|dt_bias|norm_w)$", path):
        return out(None)

    # --- DiT ---
    if re.search(r"(ada_w|final_ada_w)$", path):
        return out(None, TP)
    if re.search(r"patch_out$", path):
        return out(TP, None)
    if re.search(r"(patch_in|t_mlp1|t_mlp2|vision_proj|class_embed)$", path):
        return out(None, None)

    # norms, biases, everything small: replicate
    return out()


def _sanitize(mesh: Mesh, spec: P, shape) -> P:
    """Drop mesh axes whose size does not divide the dim (whisper's 51865
    vocab, GQA kv-heads < shards, ...)."""
    fixed = []
    for i, axes in enumerate(spec):
        fixed.append(_fit(mesh, axes, int(shape[i])) if axes else None)
    return P(*fixed)


def _add_fsdp(mesh: Mesh, spec: P, leaf) -> P:
    """ZeRO/FSDP: additionally shard a large leaf over "data" on its first
    free divisible dim (weights are all-gathered at use; optimizer moments
    inherit the spec and shrink 16x)."""
    if leaf.size < 1 << 20 or any("data" in (ax if isinstance(ax, tuple)
                                             else (ax,))
                                  for ax in spec if ax):
        return spec
    fixed = list(spec)
    for i, ax in enumerate(fixed):
        if ax is None and int(leaf.shape[i]) % mesh.shape["data"] == 0                 and leaf.shape[i] >= 1024:
            fixed[i] = "data"
            return P(*fixed)
    return spec


def params_sharding(params: PyTree, mesh: Mesh, fsdp: bool = False) -> PyTree:
    """NamedSharding pytree matching `params`.

    fsdp=True additionally shards big weights over the data axis (used by
    the >10B-param train cases so params + AdamW moments fit 16 GB HBM)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for p, l in flat:
        spec = _sanitize(mesh, param_spec(_path_str(p), l, mesh), l.shape)
        if fsdp:
            spec = _add_fsdp(mesh, spec, l)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params), out)


# ----------------------------------------------------------------------
# activations / inputs / caches
# ----------------------------------------------------------------------

def inputs_sharding(inputs: PyTree, mesh: Mesh) -> PyTree:
    """Batch-shard every input leaf on its leading axis (replicate if the
    batch does not divide the mesh, e.g. long_500k's global_batch=1)."""
    ba = batch_axes(mesh)

    def spec(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, P(_fit(mesh, ba, leaf.shape[0]), *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map(spec, inputs)


def cache_spec(path: str, leaf, mesh: Mesh) -> P:
    """KV/state caches: batch on data, kv-heads on ATTN, head_dim on ffn.

    Layouts: k/v (L,B,W,KH,hd); ckv/kr (L,B,W,r); pos (B,W);
    conv (L,B,W,C); state (L,B,...,n); encdec xk/xv (L,B,S,H,hd).

    When batch cannot shard (long_500k B=1) the KV *sequence* axis takes
    the data axis instead — sequence-parallel cache, XLA inserts the
    softmax-reduction collectives."""
    ba = batch_axes(mesh)
    ATTN, FFN, TP = attn_axis(mesh), ffn_axis(mesh), tp_axes(mesh)
    name = path.split("/")[-1]
    if name == "pos":
        b = _fit(mesh, ba, leaf.shape[0])
        w = ba if b is None and leaf.shape[1] % _axes_size(mesh, ba) == 0 else None
        return P(b, w)
    if name in ("k", "v", "xk", "xv", "ckv", "kr"):
        b = _fit(mesh, ba, leaf.shape[1])
        w = ba if b is None and leaf.shape[2] % _axes_size(mesh, ba) == 0 else None
        if name in ("ckv", "kr"):
            # MLA compressed cache has no head axis: the sequence axis takes
            # the tensor axis (sequence-parallel; scores psum over shards)
            wm = _fit(mesh, TP, leaf.shape[2])
            return P(None, b, wm if w is None else w, None)
        kh = _fit(mesh, ATTN, leaf.shape[3])
        hd = _fit(mesh, FFN, leaf.shape[4]) if FFN != ATTN else None
        return P(None, b, w, kh, hd)
    if name == "conv":
        return P(None, _fit(mesh, ba, leaf.shape[1]), None,
                 _fit(mesh, TP, leaf.shape[3]))
    if name == "state":
        spec = [None, _fit(mesh, ba, leaf.shape[1])] + [None] * (leaf.ndim - 2)
        if leaf.ndim >= 3:
            spec[2] = _fit(mesh, TP, leaf.shape[2])   # heads/din axis
        return P(*spec)
    # predictive-cache diff stacks (order+1, B, ...): batch on axis 1
    if name == "diffs":
        spec = [None, _fit(mesh, ba, leaf.shape[1])] + [None] * (leaf.ndim - 2)
        return P(*spec)
    return P(*([None] * leaf.ndim))


def cache_sharding(cache: PyTree, mesh: Mesh) -> PyTree:
    flat, _ = jax.tree_util.tree_flatten_with_path(cache)
    out = [NamedSharding(mesh, cache_spec(_path_str(p), l, mesh)) for p, l in flat]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(cache), out)


def logits_sharding(mesh: Mesh, ndim: int = 3, batch: Optional[int] = None,
                    vocab: Optional[int] = None) -> NamedSharding:
    """(B, ..., vocab) -> (batch-axes, ..., tensor-axes)."""
    ba = batch_axes(mesh)
    if batch is not None:
        ba = _fit(mesh, ba, batch)
    tp = tp_axes(mesh)
    if vocab is not None:
        tp = _fit(mesh, tp, vocab)   # whisper's 51865 does not divide 16
    spec = [ba] + [None] * (ndim - 2) + [tp]
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
