"""Zamba2-2.7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    mamba_version=2, ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    hybrid_attn_every=6,          # shared attn+MLP block every 6 mamba2 layers
    source="arXiv:2411.15242",
)
SMOKE = CONFIG.reduced()
