"""Whisper-small — encoder-decoder; the mel+conv frontend is a STUB per the
brief: input_specs() provides precomputed 1500-frame embeddings
[arXiv:2212.04356]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    is_encoder_decoder=True, num_encoder_layers=12, encoder_seq=1500,
    source="arXiv:2212.04356",
)
SMOKE = CONFIG.reduced()
