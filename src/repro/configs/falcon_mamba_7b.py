"""Falcon-Mamba-7B — pure Mamba1, attention-free [arXiv:2410.05355]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=65024,
    mamba_version=1, ssm_state=16, ssm_expand=2,
    source="arXiv:2410.05355",
)
SMOKE = CONFIG.reduced(num_heads=0, num_kv_heads=0, d_ff=0)
