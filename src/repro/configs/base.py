"""Architecture config system.

One frozen dataclass describes every architecture in the zoo; each assigned
architecture ships a module `repro/configs/<id>.py` exposing `CONFIG` (the
exact published shape) and `SMOKE` (a reduced same-family variant: <=2
layers, d_model<=512, <=4 experts) used by the CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio | dit
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0      # deepseek-v2: always-on shared experts
    moe_dense_residual: bool = False # arctic: parallel dense FFN residual
    dense_ff: int = 0                # width of the dense residual FFN
    capacity_factor: float = 1.25

    # --- MLA (deepseek-v2) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- SSM ---
    mamba_version: int = 0           # 0 = no ssm, 1 = mamba1, 2 = mamba2 (SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64           # mamba2 only

    # --- hybrid (zamba2) ---
    hybrid_attn_every: int = 0       # shared attn block applied every k ssm layers

    # --- attention variants ---
    sliding_window: int = 0          # 0 = full; >0 = sliding-window attention

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 0             # stub frontend emits this many frames

    # --- VLM (pixtral) ---
    num_vision_tokens: int = 0       # stub ViT emits this many patch embeddings
    vision_dim: int = 0

    # --- DiT (diffusion) ---
    is_dit: bool = False
    dit_patch_tokens: int = 0        # latent patches (per frame for video)
    dit_in_dim: int = 0              # patchified latent channel dim
    dit_num_classes: int = 1000
    # video DiT (factorized spatio-temporal attention): > 0 selects the
    # repro.models.video_dit backbone over (frames * patch) latent clips
    dit_num_frames: int = 0
    # text conditioning (T2I/T2V): > 0 adds an AdaLN-zero-gated cross-attn
    # branch to every block, attending over a prompt-embedding table padded
    # to exactly this many tokens (repro.conditioning)
    dit_text_len: int = 0

    # --- numerics ---
    dtype: str = "bfloat16"          # activation/param dtype on TPU
    source: str = ""                 # citation

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_ssm_only(self) -> bool:
        return self.mamba_version > 0 and self.hybrid_attn_every == 0

    @property
    def is_hybrid(self) -> bool:
        return self.mamba_version > 0 and self.hybrid_attn_every > 0

    @property
    def dit_tokens(self) -> int:
        """Total latent tokens per sample: per-frame patches x frames (1 for
        image/audio DiTs, dit_num_frames for video clips)."""
        return self.dit_patch_tokens * max(self.dit_num_frames, 1)

    @property
    def supports_long_context(self) -> bool:
        """Can serve long_500k: SSM/hybrid natively, attention via window."""
        return self.mamba_version > 0 or self.sliding_window > 0

    def reduced(self, **overrides) -> "ArchConfig":
        """Smoke-test-scale variant of the same family."""
        base = dict(
            num_layers=min(self.num_layers, 2),
            d_model=min(self.d_model, 128),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=0,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.experts_per_token else 0,
            num_shared_experts=min(self.num_shared_experts, 1) if self.num_shared_experts else 0,
            dense_ff=min(self.dense_ff, 128) if self.dense_ff else 0,
            # generous capacity so smoke tests are drop-free (decode-vs-forward
            # exactness checks depend on no routed-token drops)
            capacity_factor=8.0 if self.num_experts else self.capacity_factor,
            kv_lora_rank=min(self.kv_lora_rank, 32) if self.kv_lora_rank else 0,
            qk_rope_head_dim=16 if self.use_mla else self.qk_rope_head_dim,
            qk_nope_head_dim=32 if self.use_mla else self.qk_nope_head_dim,
            v_head_dim=32 if self.use_mla else self.v_head_dim,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=min(self.ssm_head_dim, 16),
            hybrid_attn_every=1 if self.hybrid_attn_every else 0,
            num_encoder_layers=min(self.num_encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32) if self.encoder_seq else 0,
            num_vision_tokens=min(self.num_vision_tokens, 16) if self.num_vision_tokens else 0,
            vision_dim=min(self.vision_dim, 64) if self.vision_dim else 0,
            dit_patch_tokens=min(self.dit_patch_tokens, 16) if self.dit_patch_tokens else 0,
            dit_in_dim=min(self.dit_in_dim, 16) if self.dit_in_dim else 0,
            dit_num_classes=min(self.dit_num_classes, 10),
            dit_num_frames=min(self.dit_num_frames, 4) if self.dit_num_frames else 0,
            dit_text_len=min(self.dit_text_len, 8) if self.dit_text_len else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            dtype="float32",
            name=self.name + "-smoke",
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        from repro.models import param_count  # lazy, avoids cycle
        return param_count(self)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
