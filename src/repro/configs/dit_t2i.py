"""Text-to-image DiT — the dit-xl backbone with an AdaLN-zero-gated
cross-attention branch per block (survey's central T2I serving scenario).
`dit_text_len` is the padded prompt length every request is normalized to
(CLIP's classic 77): prompt embeddings from repro.conditioning attend
into every block, K/V projected once per admission."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="dit-t2i", family="dit",
    num_layers=28, d_model=1152, num_heads=16, num_kv_heads=16,
    d_ff=4608, vocab_size=0,
    is_dit=True, dit_patch_tokens=256, dit_in_dim=16, dit_num_classes=1000,
    dit_text_len=77,
    source="arXiv:2212.09748 (DiT) + cross-attn conditioning "
           "(PixArt-style; survey T2I scenario)",
)
SMOKE = CONFIG.reduced(num_layers=2, dit_patch_tokens=16, dit_in_dim=8,
                       dit_text_len=8)
