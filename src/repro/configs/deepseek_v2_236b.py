"""DeepSeek-V2 236B — MLA attention (kv_lora=512) + 160-expert top-6 MoE with
2 shared experts [arXiv:2405.04434]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=1536,                  # per routed expert
    vocab_size=102400,
    num_experts=160, experts_per_token=6, num_shared_experts=2,
    use_mla=True, kv_lora_rank=512,
    qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128,
    source="arXiv:2405.04434",
)
SMOKE = CONFIG.reduced()
