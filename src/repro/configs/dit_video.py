"""Video DiT — factorized spatio-temporal diffusion transformer in the
Latte / OpenSora style (survey §IV "video generation" scenarios): spatial
attention over the patches of each frame, temporal attention over the frame
axis at each patch position.  `dit_patch_tokens` is PER FRAME; the latent
clip carries `dit_num_frames * dit_patch_tokens` tokens."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="dit-video", family="dit",
    num_layers=28, d_model=1152, num_heads=16, num_kv_heads=16,
    d_ff=4608, vocab_size=0,
    is_dit=True, dit_patch_tokens=256, dit_in_dim=16, dit_num_classes=1000,
    dit_num_frames=16,
    source="arXiv:2401.03048 (Latte; survey video-DiT scenario)",
)
SMOKE = CONFIG.reduced(num_layers=2, dit_patch_tokens=8, dit_in_dim=8,
                       dit_num_frames=4)
