"""Audio DiT — diffusion transformer over mel-spectrogram latents, the
shape SmoothCache (Geddes et al.) uses to show one caching scheme spanning
image, audio and video DiTs.  Tokens are mel time-frames, the channel dim is
the mel-bin count, and the backbone is the plain isotropic DiT — only the
token semantics change, which is exactly the cross-modality claim."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="dit-audio", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=0,
    is_dit=True, dit_patch_tokens=256, dit_in_dim=80, dit_num_classes=1000,
    source="arXiv:2207.09983 (DiffSound-style mel DiT; SmoothCache audio)",
)
SMOKE = CONFIG.reduced(num_layers=2, dit_patch_tokens=16, dit_in_dim=8)
