"""Text-to-video DiT — the factorized spatio-temporal dit-video backbone
with a per-block cross-attention branch over prompt embeddings (survey's
T2V scenario; Latte/OpenSora-style conditioning).  Cross-attention runs
on the flat (frames x patches) token layout — per-query softmax over the
shared text keys makes that identical to a frame-folded form."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="dit-t2v", family="dit",
    num_layers=28, d_model=1152, num_heads=16, num_kv_heads=16,
    d_ff=4608, vocab_size=0,
    is_dit=True, dit_patch_tokens=256, dit_in_dim=16, dit_num_classes=1000,
    dit_num_frames=16, dit_text_len=77,
    source="arXiv:2401.03048 (Latte) + cross-attn text conditioning "
           "(survey T2V scenario)",
)
SMOKE = CONFIG.reduced(num_layers=2, dit_patch_tokens=8, dit_in_dim=8,
                       dit_num_frames=4, dit_text_len=8)
