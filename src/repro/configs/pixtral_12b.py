"""Pixtral-12B — Mistral-Nemo decoder consuming Pixtral-ViT patch embeddings
(vision frontend is a STUB per the brief: input_specs() provides precomputed
patch embeddings) [hf:mistralai/Pixtral-12B-2409]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=160, rope_theta=1e9,
    num_vision_tokens=1024, vision_dim=1024,
    source="hf:mistralai/Pixtral-12B-2409",
)
SMOKE = CONFIG.reduced()
