"""Snowflake Arctic 480B — 128-expert top-2 MoE with a parallel dense
residual MLP [hf:Snowflake/snowflake-arctic-base]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864,                 # per-expert FFN width (assigned spec)
    vocab_size=32000,
    num_experts=128, experts_per_token=2,
    moe_dense_residual=True, dense_ff=7168,   # dense-residual branch
    source="hf:Snowflake/snowflake-arctic-base",
)
SMOKE = CONFIG.reduced()
