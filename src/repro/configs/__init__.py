"""Config registry: `--arch <id>` resolution."""
from .base import ArchConfig, InputShape, INPUT_SHAPES
from . import (arctic_480b, deepseek_v2_236b, dit_audio, dit_t2i, dit_t2v,
               dit_video, dit_xl, falcon_mamba_7b, minitron_8b, pixtral_12b,
               qwen2_7b, qwen2p5_14b, tinyllama_1p1b, whisper_small,
               zamba2_2p7b)

_MODULES = {
    "zamba2-2.7b": zamba2_2p7b,
    "qwen2-7b": qwen2_7b,
    "qwen2.5-14b": qwen2p5_14b,
    "arctic-480b": arctic_480b,
    "minitron-8b": minitron_8b,
    "pixtral-12b": pixtral_12b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "falcon-mamba-7b": falcon_mamba_7b,
    "tinyllama-1.1b": tinyllama_1p1b,
    "whisper-small": whisper_small,
    "dit-xl": dit_xl,
    "dit-video": dit_video,
    "dit-audio": dit_audio,
    "dit-t2i": dit_t2i,
    "dit-t2v": dit_t2v,
}

_DIT_IDS = ("dit-xl", "dit-video", "dit-audio", "dit-t2i", "dit-t2v")
ARCH_IDS = [k for k in _MODULES if k not in _DIT_IDS]  # the 10 assigned
ALL_ARCH_IDS = list(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; available: {ALL_ARCH_IDS}")
    return _MODULES[arch_id].CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; available: {ALL_ARCH_IDS}")
    return _MODULES[arch_id].SMOKE
