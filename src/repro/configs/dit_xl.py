"""DiT-XL/2 — the survey's home architecture (Peebles & Xie), used for the
faithful reproduction of the diffusion-caching claims [arXiv:2212.09748]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="dit-xl", family="dit",
    num_layers=28, d_model=1152, num_heads=16, num_kv_heads=16,
    d_ff=4608, vocab_size=0,
    is_dit=True, dit_patch_tokens=256, dit_in_dim=16, dit_num_classes=1000,
    source="arXiv:2212.09748 (survey ref [5])",
)
SMOKE = CONFIG.reduced(num_layers=2, dit_patch_tokens=16, dit_in_dim=8)
