import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production mesh, print memory/cost analysis, emit roofline terms.

The two lines above MUST stay first: jax locks the device count on first
initialization, and the dry-run needs 512 placeholder host devices so
`jax.make_mesh` can build the 16x16 and 2x16x16 production meshes.  Smoke
tests and benches do NOT import this module and keep seeing 1 device.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape decode_32k
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all          # orchestrates subprocesses
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, ALL_ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.mesh import attn_shards, make_logical_mesh, make_production_mesh
from repro.launch.roofline import analyze, model_flops
from repro.launch.specs import build_case

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results")


def run_case(arch: str, shape_name: str, multi_pod: bool,
             contract_mesh: bool = False) -> dict:
    case = build_case(arch, shape_name)
    cfg = get_config(arch)
    if contract_mesh:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "2x16x16(d,m)" if multi_pod else "16x16(d,m)"
    else:
        mesh = make_logical_mesh(cfg, multi_pod=multi_pod)
        a = attn_shards(cfg)
        mesh_name = (f"2x16x{a}x{16//a}" if multi_pod else f"16x{a}x{16//a}")
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": case.kind, "notes": case.notes}
    if case.skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = case.skip
        return rec

    chips = mesh.devices.size
    t0 = time.perf_counter()
    with mesh:
        in_sh = case.in_shardings(mesh)
        out_sh = case.out_shardings(mesh) if case.out_shardings else None
        fn = case.build_fn(mesh)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*case.inputs.values())
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    rec["memory"] = {
        k: int(getattr(mem, k, 0)) for k in
        ("argument_size_in_bytes", "output_size_in_bytes",
         "temp_size_in_bytes", "generated_code_size_in_bytes")
    }
    per_dev = (rec["memory"]["argument_size_in_bytes"]
               + rec["memory"]["temp_size_in_bytes"])
    rec["bytes_per_device"] = per_dev
    rec["fits_16gb_hbm"] = bool(per_dev < 16e9)

    mf = model_flops(cfg, INPUT_SHAPES[shape_name])
    rl = analyze(compiled, chips, analytic_flops=mf)
    rec["roofline"] = rl.summary()
    rec["model_flops_global"] = mf
    hlo_global = rl.flops * chips
    rec["useful_flops_ratio"] = (mf / hlo_global) if hlo_global else 0.0
    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)
    rec["status"] = "ok"
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--contract-mesh", action="store_true",
                    help="use the flat (data, model) contract mesh instead "
                         "of the per-arch logical (data, attn, ffn) mesh")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) as subprocesses")
    ap.add_argument("--also-multi-pod", action="store_true",
                    help="with --all: additionally run the 2x16x16 mesh")
    ap.add_argument("--out", default=None)
    ap.add_argument("--jobs", type=int, default=2)
    args = ap.parse_args()

    os.makedirs(args.out or os.path.abspath(RESULTS_DIR), exist_ok=True)
    outdir = args.out or os.path.abspath(RESULTS_DIR)

    if args.all:
        combos = [(a, s, False) for a in ALL_ARCH_IDS for s in INPUT_SHAPES]
        if args.also_multi_pod:
            combos += [(a, s, True) for a in ALL_ARCH_IDS for s in INPUT_SHAPES]
        procs = {}
        pending = list(combos)
        failed = []
        while pending or procs:
            while pending and len(procs) < args.jobs:
                a, s, mp = pending.pop(0)
                tag = f"{a}_{s}_{'mp' if mp else 'sp'}"
                path = os.path.join(outdir, f"dryrun_{tag}.json")
                if os.path.exists(path):
                    print(f"[skip existing] {tag}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", a, "--shape", s, "--out", outdir]
                if mp:
                    cmd.append("--multi-pod")
                procs[tag] = (subprocess.Popen(cmd), time.time())
                print(f"[start] {tag}")
            for tag in list(procs):
                p, t0 = procs[tag]
                if p.poll() is not None:
                    dt = time.time() - t0
                    status = "ok" if p.returncode == 0 else f"FAIL({p.returncode})"
                    print(f"[done {status}] {tag} in {dt:.0f}s")
                    if p.returncode != 0:
                        failed.append(tag)
                    del procs[tag]
            time.sleep(2)
        print("FAILED:", failed if failed else "none")
        return

    assert args.arch and args.shape
    tag = (f"{args.arch}_{args.shape}_{'mp' if args.multi_pod else 'sp'}"
           + ("_contract" if args.contract_mesh else ""))
    try:
        rec = run_case(args.arch, args.shape, args.multi_pod,
                       args.contract_mesh)
    except Exception as e:
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    path = os.path.join(outdir, f"dryrun_{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps({k: v for k, v in rec.items() if k != "traceback"},
                     indent=1))
    if rec["status"] == "error":
        sys.exit(1)


if __name__ == "__main__":
    main()
