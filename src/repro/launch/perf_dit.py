import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf experiment for the paper-representative pair: dit-xl x decode_32k.

Lowers three variants of the diffusion serve step on the production mesh:

  uncached      — full denoiser forward every step (the survey's baseline)
  refresh       — TaylorSeer cache-refresh step (full forward + diff update)
  skip (static) — statically-scheduled forecast-only step: the lax.cond is
                  resolved at trace time, so XLA sees ONLY the polynomial
                  forecast — this is how diffusion caching turns into
                  compiled-graph FLOP reduction on TPU (DESIGN §2.1)

and reports per-step and amortized (interval N=4) roofline terms.
Usage: PYTHONPATH=src python -m repro.launch.perf_dit
"""
import json

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, get_config
from repro.core import make_policy
from repro.launch.mesh import make_logical_mesh
from repro.launch.roofline import analyze
from repro.launch.specs import _sds, BF16
from repro.models import dit
from repro import sharding as shd
from repro.launch.specs import _params_specs


def lower_variant(kind: str, interval: int = 4):
    cfg = get_config("dit-xl")
    shape = INPUT_SHAPES["decode_32k"]
    B = shape.global_batch
    policy = make_policy("taylorseer", interval=interval, order=2)
    eps_shape = (B, cfg.dit_patch_tokens, cfg.dit_in_dim)
    pspec = _params_specs(cfg)
    state_spec = jax.eval_shape(lambda: policy.init_state(eps_shape, BF16))
    inputs = {
        "latents": _sds(eps_shape, BF16),
        "t": _sds((B,), jnp.float32),
        "labels": _sds((B,), jnp.int32),
    }
    mesh = make_logical_mesh(cfg)

    def fn(params, state, batch):
        def compute(lat):
            return dit.forward(params, lat, batch["t"], batch["labels"], cfg)

        if kind == "uncached":
            return compute(batch["latents"]), state
        step = 0 if kind == "refresh" else 1   # static python int
        return policy.apply(state, step, batch["latents"], compute)

    with mesh:
        in_sh = (shd.params_sharding(pspec, mesh),
                 shd.cache_sharding(state_spec, mesh),
                 shd.inputs_sharding(inputs, mesh))
        compiled = jax.jit(fn, in_shardings=in_sh).lower(
            pspec, state_spec, inputs).compile()
    rl = analyze(compiled, mesh.devices.size)
    return {"kind": kind,
            "compute_s": rl.flops / 197e12,   # raw HLO term (no analytic floor)
            "memory_s": rl.memory_s, "collective_s": rl.collective_s}


def main():
    rows = [lower_variant(k) for k in ("uncached", "refresh", "skip")]
    by = {r["kind"]: r for r in rows}
    N = 4
    amort = {t: (by["refresh"][t] + (N - 1) * by["skip"][t]) / N
             for t in ("compute_s", "memory_s", "collective_s")}
    out = {"variants": rows, "amortized_N4": amort,
           "speedup_terms": {t: by["uncached"][t] / max(amort[t], 1e-12)
                             for t in ("compute_s", "memory_s",
                                       "collective_s")}}
    print(json.dumps(out, indent=1))
    path = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "benchmarks", "results", "perf_dit_decode.json")
    with open(os.path.abspath(path), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
