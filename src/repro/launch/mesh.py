"""Production mesh builders (functions — importing never touches devices)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256-chip v5e pod mesh, or 2x16x16 = 512-chip two-pod mesh.

    The "pod" axis composes with "data" for batch sharding; its collectives
    cross the DCN boundary in a real deployment."""
    import math
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) > n:           # 512 placeholder devices, single-pod mesh
        devices = devices[:n]
    return jax.make_mesh(shape, axes, devices=devices)


def attn_shards(cfg) -> int:
    """Largest power-of-two <= 16 dividing the KV-head count (and H).

    The production pod has 16 chips on the tensor axis, but e.g. qwen2-7b
    has H=28, KH=4: a flat 16-way shard of the fused (d, H*hd) projection
    splits heads mid-boundary and SPMD falls back to involuntary full
    rematerialization (measured: 6x activation blow-up, EXPERIMENTS §Perf).
    Factoring the tensor axis as (attn=a, ffn=16/a) with a | KH keeps every
    reshape head-aligned."""
    h = cfg.num_heads or 16
    kh = cfg.num_kv_heads or h
    for a in (16, 8, 4, 2, 1):
        if kh % a == 0 and h % a == 0:
            return a
    return 1


def make_logical_mesh(cfg, *, multi_pod: bool = False):
    """Per-arch logical view of the production pod: the 16-chip tensor axis
    factored into ("attn", "ffn") sub-axes sized to the architecture's head
    count.  Same 256/512 physical chips as make_production_mesh.

    Models under 4B params additionally trade tensor parallelism for data
    parallelism (data=32, tp=8): replicated weights fit trivially and the
    per-device activation slice — hence the per-layer all-reduce volume —
    halves (measured -42% collective on tinyllama prefill_32k,
    EXPERIMENTS §Perf iteration t1)."""
    import math
    from repro.models import param_count
    small = param_count(cfg) < 4e9
    # multi-pod batch axes = pod*data: keep the product at 32 so the
    # smallest global batch (prefill_32k's 32) still shards fully
    data = 32 if (small and not multi_pod) else 16
    tp = 256 // data
    a = attn_shards(cfg)
    while a > tp or (cfg.num_kv_heads and cfg.num_kv_heads % a):
        a //= 2
    a = max(a, 1)
    shape = ((2, data, a, tp // a) if multi_pod
             else (data, a, tp // a))
    axes = (("pod", "data", "attn", "ffn") if multi_pod
            else ("data", "attn", "ffn"))
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) > n:
        devices = devices[:n]
    return jax.make_mesh(shape, axes, devices=devices)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the real host devices (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(min(model, n // data), 1)
    return jax.make_mesh((data, model), ("data", "model"))
