"""Serving launcher: batched generation over any zoo architecture.

`python -m repro.launch.serve --arch qwen2-7b --smoke --requests 8`
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ALL_ARCH_IDS, get_config, get_smoke_config
from repro.models import init_params
from repro.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ALL_ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_dit:
        raise SystemExit("dit-xl serves via examples/cached_generation.py")
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServingEngine(params, cfg, slots=args.slots,
                           cache_len=args.cache_len, max_prompt=32,
                           temperature=args.temperature)

    rng = np.random.default_rng(args.seed)
    prompts = [list(rng.integers(1, cfg.vocab_size,
                                 size=rng.integers(4, 16)))
               for _ in range(args.requests)]
    t0 = time.perf_counter()
    results = engine.generate(prompts, max_new_tokens=args.max_new)
    dt = time.perf_counter() - t0
    total = sum(len(r.tokens) for r in results)
    print(f"served {len(results)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")
    for r in results[:4]:
        print(f"  req{r.request_id}: prompt={r.prompt[:6]}... "
              f"-> {r.tokens[:12]}")


if __name__ == "__main__":
    main()
