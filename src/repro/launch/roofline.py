"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds (DESIGN, brief):

  compute    = HLO_FLOPs            / peak_FLOP/s          (per chip)
  memory     = HLO_bytes            / HBM_bw               (per chip)
  collective = collective_bytes     / link_bw              (per chip)

`cost_analysis()` of the SPMD-partitioned executable reports *per-partition*
flops/bytes, so no further division by chip count is needed.  Collective
bytes are not in cost_analysis: we parse the post-optimization HLO and sum
the result-shape bytes of every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute (also per-partition shapes after SPMD).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# `%x = f32[8,128]{1,0} all-reduce(...)` or tuple results
_INSTR = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\](?:\{[^}]*\})?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective kind from post-optimization HLO.

    Two accounting notes (see EXPERIMENTS §Roofline):
      * XLA-CPU's all-reduce-promotion pass upcasts bf16 reductions to f32
        (`to_apply=%add..._promoted`); TPU reduces bf16 natively, so
        promoted ops are counted at their native (half) width.
      * instructions inside `while` bodies are counted once, not times the
        trip count — with scanned layer stacks this is a uniform lower
        bound, consistent across before/after comparisons.
    """
    out: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    for m in _INSTR.finditer(hlo_text):
        tuple_part, dtype, dims, kind = m.groups()
        # async pairs appear as -start/-done; count once (the -start)
        span_txt = hlo_text[m.start():m.start() + 40]
        if "-done(" in span_txt:
            continue
        if tuple_part is not None:
            b = sum(_shape_bytes(d, s) for d, s in _SHAPE.findall(tuple_part))
        else:
            b = _shape_bytes(dtype, dims)
        line_end = hlo_text.find("\n", m.start())
        line = hlo_text[m.start():line_end if line_end > 0 else m.start() + 400]
        if "_promoted" in line and (dtype == "f32" or tuple_part):
            b //= 2          # bf16 on the TPU target
        out[kind] += b
    return out


@dataclass
class Roofline:
    flops: float                 # per-partition HLO flops
    hbm_bytes: float             # per-partition bytes accessed
    coll_bytes: Dict[str, int]   # per kind
    chips: int
    #: analytic MODEL_FLOPS-based fallback (XLA's cost_analysis does not
    #: multiply nested while-loop bodies by their trip counts, so for
    #: grad-accumulation train steps the HLO term is a known undercount —
    #: measured ~30x on qwen2-7b train_4k; see EXPERIMENTS §Roofline notes)
    analytic_flops_per_chip: float = 0.0

    @property
    def compute_s(self) -> float:
        return max(self.flops, self.analytic_flops_per_chip) / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return sum(self.coll_bytes.values()) / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def summary(self) -> dict:
        return {
            "analytic_flops_per_chip": self.analytic_flops_per_chip,
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": dict(self.coll_bytes),
            "coll_bytes_total": float(sum(self.coll_bytes.values())),
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "chips": self.chips,
        }


def analyze(compiled, chips: int, analytic_flops: float = 0.0) -> Roofline:
    """Build the roofline terms from a compiled executable.

    `analytic_flops` is the global MODEL_FLOPS estimate used as the compute
    floor (per chip after division)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):             # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=coll, chips=chips,
                    analytic_flops_per_chip=analytic_flops / max(chips, 1))


def model_flops(cfg, shape) -> float:
    """Survey-style MODEL_FLOPS: 6*N*D (dense) / 6*N_active*D (MoE) for a
    train step; 2*N*D forward-only for prefill; 2*N_active per decode token."""
    from repro.models import active_param_count
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.is_dit:
            tokens = shape.global_batch * cfg.dit_patch_tokens
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        if cfg.is_dit:
            tokens = shape.global_batch * cfg.dit_patch_tokens
        return 2.0 * n * tokens
    # decode: one token per sequence
    tokens = shape.global_batch
    if cfg.is_dit:
        tokens = shape.global_batch * cfg.dit_patch_tokens
    return 2.0 * n * tokens
