"""Step builders + ShapeDtypeStruct input specs for every (arch x shape).

`build_case(cfg, shape)` returns a `Case` with:
  fn            — the pure step function to lower
  inputs        — dict of ShapeDtypeStructs (no allocation)
  in_shardings  — matching NamedSharding pytree builder (mesh -> pytree)
  out_shardings — mesh -> pytree or None (XLA-inferred)
  notes         — human-readable adaptation notes (window, skip reasons)

Shape semantics (brief):
  train_4k     -> train_step (fwd+bwd+AdamW)
  prefill_32k  -> prefill_step (forward, fills KV cache)
  decode_32k   -> serve_step: ONE token vs a seq_len KV cache
  long_500k    -> serve_step at 524288; sub-quadratic attention required:
                  SSM/hybrid run natively, dense/vlm/moe run a sliding-window
                  (8192) variant, whisper-small is skipped (enc-dec ASR has
                  no 512k decoder context) — see DESIGN §4.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.configs import INPUT_SHAPES, get_config
from repro.models import dit, encdec, transformer
from repro.models import init_params
from repro.optim import AdamWState, adamw_update, clip_by_global_norm

PyTree = Any

LONG_WINDOW = 8192          # sliding window used by full-attention archs
BF16 = jnp.bfloat16


@dataclass
class Case:
    arch: str
    shape: str
    kind: str
    fn: Callable                    # step fn, or None when fn_builder set
    inputs: Dict[str, Any]
    in_shardings: Callable          # mesh -> pytree matching inputs
    out_shardings: Callable         # mesh -> pytree or None
    notes: str = ""
    skip: Optional[str] = None      # reason if the combination is skipped
    fn_builder: Optional[Callable] = None   # mesh -> fn (MoE EP needs mesh)

    def build_fn(self, mesh):
        return self.fn_builder(mesh) if self.fn_builder is not None else self.fn


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def _params_specs(cfg):
    """ShapeDtypeStruct pytree of params (+ AdamW moments for training)."""
    return jax.eval_shape(partial(init_params, cfg=cfg), jax.random.PRNGKey(0))


def _moment_dtype(cfg):
    # giant MoEs keep moments in bf16 to fit HBM (DESIGN §5)
    from repro.models import param_count
    return BF16 if param_count(cfg) > 6e10 else jnp.float32


def _ep_kwargs(mesh):
    """moe_forward_ep kwargs for a given mesh (expert-parallel production
    path; see repro.models.moe)."""
    return dict(mesh=mesh, batch_ax=shd.batch_axes(mesh), ep_axis="data",
                inner_axes=("attn", "ffn"))


def _use_ep(cfg, batch: int, mesh_batch: int = 16) -> bool:
    """EP needs the (micro)batch to divide the data axis."""
    return cfg.is_moe and batch % (2 * mesh_batch) in (0, mesh_batch)


def effective_window(cfg, shape_name: str) -> int:
    """Attention window override for long_500k on full-attention archs."""
    if shape_name == "long_500k" and cfg.family in ("dense", "vlm", "moe"):
        return LONG_WINDOW
    return cfg.sliding_window


# ======================================================================
# train_4k
# ======================================================================

def _ce_loss(logits, targets, vocab: int):
    """Cross-entropy with the target logit picked by a one-hot einsum —
    SPMD-friendly under vocab-sharded logits (partial sum + psum instead of
    a cross-shard gather that would all-gather the logits)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(targets, vocab, dtype=jnp.float32)
    tgt = jnp.einsum("bsv,bsv->bs", lf, onehot)
    return (lse - tgt).mean()


def _encdec_loss(params, batch, cfg):
    logits = encdec.forward(params, batch["frames"], batch["tokens"], cfg,
                            remat=True)
    loss = _ce_loss(logits, batch["targets"], cfg.vocab_size)
    return loss, {"loss": loss}


def _dit_loss(params, batch, cfg):
    eps_hat = dit.forward(params, batch["latents"], batch["t"], batch["labels"],
                          cfg, remat=True)
    loss = jnp.mean(jnp.square(eps_hat.astype(jnp.float32) - batch["eps"]))
    return loss, {"loss": loss}


def build_train_case(arch: str, cfg, ishape) -> Case:
    from repro.models import param_count
    B, S = ishape.global_batch, ishape.seq_len
    mdt = _moment_dtype(cfg)
    n_params = param_count(cfg)
    # ZeRO-1: moments sharded over data for >10B (elementwise update, no
    # gather-hoisting risk); full FSDP weights only for the 100B+ MoEs
    # (their expert weights already carry "data"; this catches attention)
    FSDP_W = n_params > 60e9
    FSDP_M = n_params > 10e9

    if cfg.is_dit:
        inputs = {
            "latents": _sds((B, cfg.dit_patch_tokens, cfg.dit_in_dim), BF16),
            "t": _sds((B,), jnp.float32),
            "labels": _sds((B,), jnp.int32),
            "eps": _sds((B, cfg.dit_patch_tokens, cfg.dit_in_dim), jnp.float32),
        }
        loss_fn = partial(_dit_loss, cfg=cfg)
        notes = "DiT trains on latent patches; seq_len means patch tokens"
    elif cfg.is_encoder_decoder:
        inputs = {
            "frames": _sds((B, cfg.encoder_seq, cfg.d_model), BF16),
            "tokens": _sds((B, S), jnp.int32),
            "targets": _sds((B, S), jnp.int32),
        }
        loss_fn = partial(_encdec_loss, cfg=cfg)
        notes = "stub conv frontend: precomputed frame embeddings"
    else:
        inputs = {
            "tokens": _sds((B, S), jnp.int32),
            "targets": _sds((B, S), jnp.int32),
        }
        if cfg.family == "vlm":
            inputs["vision_embeds"] = _sds(
                (B, cfg.num_vision_tokens, cfg.vision_dim), BF16)
        notes = "remat per layer; logits sharded (batch, vocab)"

        def loss_fn(params, batch, _cfg=cfg, ep=None):
            logits, aux = transformer.forward(
                params, batch["tokens"], _cfg,
                vision_embeds=batch.get("vision_embeds"), remat=True, ep=ep)
            if _cfg.family == "vlm":
                logits = logits[:, _cfg.num_vision_tokens:]
            loss = _ce_loss(logits, batch["targets"], _cfg.vocab_size)
            total = (loss + 0.01 * aux["load_balance_loss"]
                     + 1e-3 * aux["router_z_loss"])
            return total, {"loss": loss}

    # gradient accumulation: global batch 256 -> ACCUM microbatches, scanned
    # so activation memory is bounded by one microbatch (DESIGN §5);
    # >10B models halve the microbatch again
    ACCUM_TARGET = 16 if n_params > 10e9 else 8

    def _pick_accum(mesh):
        """Largest accumulation <= target whose microbatch still divides the
        batch shards (multi-pod shards batch 32-way -> microbatch >= 32)."""
        shards = 1
        if mesh is not None:
            import numpy as _np
            shards = int(_np.prod([mesh.shape[a] for a in
                                   shd.batch_axes(mesh)]))
        for a in (ACCUM_TARGET, 8, 4, 2, 1):
            if a <= ACCUM_TARGET and B % a == 0 and (B // a) % shards == 0:
                return a
        return 1

    ACCUM = _pick_accum(None) if B % 16 == 0 else 1

    def make_train_step(mesh=None):
      ACCUM = _pick_accum(mesh) if B % 16 == 0 else 1
      ep = _ep_kwargs(mesh) if (mesh is not None and cfg.is_moe) else None
      lfn = (partial(loss_fn, ep=ep) if (cfg.is_moe and not cfg.is_dit
                                         and not cfg.is_encoder_decoder)
             else loss_fn)

      def train_step(state, batch, loss_fn=lfn):
        params, opt = state
        if ACCUM == 1:
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            # strided microbatch split: microbatch m = rows m::ACCUM, so the
            # per-device row block stays local under the batch sharding (a
            # contiguous split would leave each microbatch on B/ACCUM/16
            # devices and XLA falls back to partial replication — measured
            # 8x activation blow-up, EXPERIMENTS §Perf)
            micro = jax.tree_util.tree_map(
                lambda a: a.reshape((a.shape[0] // ACCUM, ACCUM)
                                    + a.shape[1:]).swapaxes(0, 1),
                batch)
            head = jax.tree_util.tree_map(lambda a: a[0], micro)
            tail = jax.tree_util.tree_map(lambda a: a[1:], micro)

            # init the accumulator from the first microbatch's grads so its
            # sharding is propagated from the backward pass (an explicit
            # zeros tree would default to replicated-on-data and blow HBM)
            (_, m0), g0 = jax.value_and_grad(loss_fn, has_aux=True)(params, head)

            def body(carry, mb):
                g_acc, l_acc = carry
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + m["loss"]), None

            (grads, lsum), _ = jax.lax.scan(body, (g0, m0["loss"]), tail)
            inv = 1.0 / ACCUM
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
            metrics = {"loss": lsum * inv}
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(grads, opt, params, lr=1e-4)
        return (params, opt), dict(metrics, grad_norm=gnorm)

      return train_step

    pspec = _params_specs(cfg)
    mom = jax.tree_util.tree_map(lambda l: _sds(l.shape, mdt), pspec)
    state_spec = (pspec, AdamWState(step=_sds((), jnp.int32), mu=mom, nu=mom))

    def _state_sharding(mesh):
        ps = shd.params_sharding(pspec, mesh, fsdp=FSDP_W)
        mu = shd.params_sharding(mom, mesh, fsdp=FSDP_M)
        return (ps, AdamWState(step=shd.replicated(mesh), mu=mu, nu=mu))

    def in_shardings(mesh):
        return (_state_sharding(mesh), shd.inputs_sharding(inputs, mesh))

    def out_shardings(mesh):
        metr = {"loss": shd.replicated(mesh), "grad_norm": shd.replicated(mesh)}
        return (_state_sharding(mesh), metr)

    return Case(arch=arch, shape=ishape.name, kind="train", fn=None,
                fn_builder=make_train_step,
                inputs={"state": state_spec, "batch": inputs},
                in_shardings=in_shardings, out_shardings=out_shardings,
                notes=notes)


# ======================================================================
# prefill_32k
# ======================================================================

def build_prefill_case(arch: str, cfg, ishape) -> Case:
    B, S = ishape.global_batch, ishape.seq_len
    window = effective_window(cfg, ishape.name)
    cache_len = min(S, window) if window > 0 else S
    notes = ""

    if cfg.is_dit:
        # diffusion "prefill" = one full denoiser forward over the batch
        inputs = {
            "latents": _sds((B, cfg.dit_patch_tokens, cfg.dit_in_dim), BF16),
            "t": _sds((B,), jnp.float32),
            "labels": _sds((B,), jnp.int32),
        }

        def fn(params, batch):
            return dit.forward(params, batch["latents"], batch["t"],
                               batch["labels"], cfg)
        out_sh = None
        notes = "DiT: denoiser forward (one diffusion step over the batch)"
    elif cfg.is_encoder_decoder:
        inputs = {
            "frames": _sds((B, cfg.encoder_seq, cfg.d_model), BF16),
            "tokens": _sds((B, S), jnp.int32),
        }

        def fn(params, batch):
            enc_out = encdec.encode(params, batch["frames"], cfg)
            x = encdec._decoder(params, batch["tokens"], enc_out, cfg)
            logits = (x @ params["lm_head"])[:, -1]
            xk, xv = encdec.cross_kv(params, enc_out, cfg)
            return logits, (xk, xv)
        out_sh = None
        notes = "prefill emits decoder self-KV implicitly + exact cross-KV"
    else:
        inputs = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.family == "vlm":
            inputs["vision_embeds"] = _sds(
                (B, cfg.num_vision_tokens, cfg.vision_dim), BF16)

        def fn(params, batch, ep=None):
            logits, aux, cache = transformer.prefill(
                params, batch["tokens"], cfg, cache_len,
                vision_embeds=batch.get("vision_embeds"), window=window,
                ep=ep)
            return logits[:, -1], cache

        def out_sh(mesh):
            cache_spec = jax.eval_shape(
                partial(transformer.init_cache, cfg, B, cache_len))
            return (shd.logits_sharding(mesh, ndim=2, batch=B,
                                        vocab=cfg.vocab_size),
                    shd.cache_sharding(cache_spec, mesh))
        notes = f"window={window or 'full'}, cache_len={cache_len}"

    pspec = _params_specs(cfg)

    def in_shardings(mesh):
        return (shd.params_sharding(pspec, mesh),
                shd.inputs_sharding(inputs, mesh))

    fn_builder = None
    if cfg.is_moe and B % 16 == 0:
        def fn_builder(mesh, _fn=fn):
            return lambda params, batch: _fn(params, batch,
                                             ep=_ep_kwargs(mesh))
    return Case(arch=arch, shape=ishape.name, kind="prefill", fn=fn,
                fn_builder=fn_builder,
                inputs={"params": pspec, "batch": inputs},
                in_shardings=in_shardings,
                out_shardings=out_sh if callable(out_sh) else (lambda m: None),
                notes=notes)


# ======================================================================
# decode (decode_32k / long_500k)
# ======================================================================

def build_decode_case(arch: str, cfg, ishape) -> Case:
    B, S = ishape.global_batch, ishape.seq_len
    window = effective_window(cfg, ishape.name)

    if cfg.is_dit:
        # diffusion has no token decode; serve_step = one cached denoise step
        # (the survey's own inference loop). Cache = TaylorSeer diff stack.
        from repro.core import make_policy
        policy = make_policy("taylorseer", interval=4, order=2)
        eps_shape = (B, cfg.dit_patch_tokens, cfg.dit_in_dim)
        state_spec = jax.eval_shape(
            lambda: policy.init_state(eps_shape, BF16))
        inputs = {
            "latents": _sds(eps_shape, BF16),
            "t": _sds((B,), jnp.float32),
            "labels": _sds((B,), jnp.int32),
            "step": _sds((), jnp.int32),
        }

        def fn(params, state, batch):
            def compute(lat):
                return dit.forward(params, lat, batch["t"], batch["labels"], cfg)
            y, state = policy.apply(state, batch["step"], batch["latents"],
                                    compute)
            return y, state

        pspec = _params_specs(cfg)

        def in_shardings(mesh):
            # diff stack (order+1, B, T, D): batch on axis 1 (replicated
            # when B=1 does not divide — long_500k)
            st = shd.cache_sharding(state_spec, mesh)
            return (shd.params_sharding(pspec, mesh), st,
                    shd.inputs_sharding(inputs, mesh))

        return Case(arch=arch, shape=ishape.name, kind="decode", fn=fn,
                    inputs={"params": pspec, "state": state_spec,
                            "batch": inputs},
                    in_shardings=in_shardings, out_shardings=lambda m: None,
                    notes="serve_step = cached denoise step (TaylorSeer N=4)")

    if cfg.is_encoder_decoder:
        if ishape.name == "long_500k":
            return Case(arch=arch, shape=ishape.name, kind="decode",
                        fn=None, inputs={}, in_shardings=None,
                        out_shardings=None,
                        skip="enc-dec ASR: 512k decoder context is "
                             "architecturally meaningless (DESIGN §4)")
        cache_len = S
        cache_spec = jax.eval_shape(partial(
            encdec.init_dec_cache, cfg, B, cache_len, cfg.encoder_seq))
        inputs = {"token": _sds((B,), jnp.int32), "pos": _sds((B,), jnp.int32)}

        def fn(params, cache, batch):
            return encdec.decode_step(params, batch["token"], batch["pos"],
                                      cache, cfg)
        notes = f"decoder KV {cache_len} + exact cross-KV ({cfg.encoder_seq})"
    else:
        if ishape.name == "long_500k" and not (
                cfg.mamba_version > 0 or window > 0):
            return Case(arch=arch, shape=ishape.name, kind="decode", fn=None,
                        inputs={}, in_shardings=None, out_shardings=None,
                        skip="full attention at 512k is quadratic-prohibitive")
        cache_len = min(S, window) if window > 0 else S
        if cfg.family in ("ssm",):
            cache_len = 1  # state is O(1); no KV buffer
        cache_spec = jax.eval_shape(partial(
            transformer.init_cache, cfg, B, max(cache_len, 1)))
        inputs = {"token": _sds((B,), jnp.int32), "pos": _sds((B,), jnp.int32)}

        def fn(params, cache, batch, ep=None):
            return transformer.decode_step(params, batch["token"],
                                           batch["pos"], cache, cfg,
                                           window=window, ep=ep)
        notes = (f"window={window or 'full'}, cache_len={cache_len}, "
                 f"pos up to {S}")

    pspec = _params_specs(cfg)

    def in_shardings(mesh):
        return (shd.params_sharding(pspec, mesh),
                shd.cache_sharding(cache_spec, mesh),
                shd.inputs_sharding(inputs, mesh))

    def out_shardings(mesh):
        return (shd.logits_sharding(mesh, ndim=2, batch=B,
                                    vocab=cfg.vocab_size),
                shd.cache_sharding(cache_spec, mesh))

    fn_builder = None
    if cfg.is_moe and not cfg.is_encoder_decoder and B % 16 == 0:
        def fn_builder(mesh, _fn=fn):
            return lambda params, cache, batch: _fn(params, cache, batch,
                                                    ep=_ep_kwargs(mesh))
    return Case(arch=arch, shape=ishape.name, kind="decode", fn=fn,
                fn_builder=fn_builder,
                inputs={"params": pspec, "cache": cache_spec, "batch": inputs},
                in_shardings=in_shardings, out_shardings=out_shardings,
                notes=notes)


# ======================================================================

def build_case(arch: str, shape_name: str) -> Case:
    cfg = get_config(arch)
    ishape = INPUT_SHAPES[shape_name]
    if ishape.kind == "train":
        return build_train_case(arch, cfg, ishape)
    if ishape.kind == "prefill":
        return build_prefill_case(arch, cfg, ishape)
    return build_decode_case(arch, cfg, ishape)
