"""Training launcher: `python -m repro.launch.train --arch tinyllama-1.1b
--smoke --steps 100`.

On this CPU container use --smoke (reduced config, host mesh).  On a real
TPU pod the same launcher runs the full config over the production mesh
(params/opt sharded per repro.sharding).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCH_IDS, get_config, get_smoke_config
from repro.data import lm_batches, latent_batches
from repro.diffusion import linear_schedule
from repro.train import train_loop
from repro.train.steps import (init_train_state, make_diffusion_train_step,
                               make_lm_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ALL_ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"training {cfg.name} ({cfg.family}) for {args.steps} steps")
    state = init_train_state(jax.random.PRNGKey(args.seed), cfg)

    if cfg.is_dit:
        sched = linear_schedule(1000)
        step = make_diffusion_train_step(cfg, sched, peak_lr=args.lr,
                                         total_steps=args.steps,
                                         accum=args.accum)
        lat = latent_batches(args.seed, args.batch, cfg.dit_patch_tokens,
                             cfg.dit_in_dim, cfg.dit_num_classes)

        def batches():
            key = jax.random.PRNGKey(args.seed + 1)
            for x, y in lat:
                key, sub = jax.random.split(key)
                yield {"latents": jnp.asarray(x), "labels": jnp.asarray(y),
                       "key": sub}
        it = batches()
    else:
        step = make_lm_train_step(cfg, peak_lr=args.lr,
                                  total_steps=args.steps, accum=args.accum)
        lm = lm_batches(args.seed, args.batch, args.seq, cfg.vocab_size)
        it = ({"tokens": jnp.asarray(t), "targets": jnp.asarray(y)}
              for t, y in lm)

    state, history = train_loop(step, state, it, args.steps,
                                ckpt_dir=args.ckpt_dir)
    if history:
        print(f"loss {history[0]['loss']:.4f} -> {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
