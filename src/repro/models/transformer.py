"""Decoder LM assembly for the dense / moe / ssm / hybrid / vlm families.

Entry points (all pure functions of (params, inputs, cfg)):

  init_lm(key, cfg)                      -> params
  forward(params, tokens, cfg, ...)      -> (logits, aux)        train / eval
  prefill(params, tokens, cfg, cache_len)-> (logits, cache)      fill KV cache
  decode_step(params, token, pos, cache, cfg) -> (logits, cache) one token

Layer stacks are scanned (`lax.scan` over params stacked on a leading layer
axis) so compile time is ~constant in depth — required for the 40-combo
dry-run matrix.  KV caches are rolling buffers of capacity `cache_len`
(= sliding window when cfg.sliding_window > 0), with absolute positions
stored alongside so masking is exact even after wrap-around.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import (apply_rope, attention_decode, attention_forward,
                     dense_init, embed_init, init_attention, init_mlp,
                     mlp_forward, rms_norm)
from .mla import init_mla, mla_decode, mla_forward
from .moe import init_moe, moe_forward, moe_forward_ep
from .ssm import (init_mamba1, init_mamba2, mamba1_decode, mamba1_forward,
                  mamba2_decode, mamba2_forward)

# ======================================================================
# per-family block init
# ======================================================================

def _init_block(key, cfg, dtype):
    """One layer's params (unstacked)."""
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if cfg.family in ("dense", "vlm"):
        return {
            "ln1": jnp.ones((d,), dtype),
            "attn": init_attention(ks[0], cfg, dtype),
            "ln2": jnp.ones((d,), dtype),
            "mlp": init_mlp(ks[1], d, cfg.d_ff, dtype),
        }
    if cfg.family == "moe":
        attn = init_mla(ks[0], cfg, dtype) if cfg.use_mla else init_attention(ks[0], cfg, dtype)
        return {
            "ln1": jnp.ones((d,), dtype),
            "attn": attn,
            "ln2": jnp.ones((d,), dtype),
            "moe": init_moe(ks[1], cfg, dtype),
        }
    if cfg.family == "ssm":
        init = init_mamba1 if cfg.mamba_version == 1 else init_mamba2
        return {"ln1": jnp.ones((d,), dtype), "mamba": init(ks[0], cfg, dtype)}
    if cfg.family == "hybrid":
        return {"ln1": jnp.ones((d,), dtype),
                "mamba": init_mamba2(ks[0], cfg, dtype)}
    raise ValueError(cfg.family)


def init_lm(key, cfg, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    L = cfg.num_layers
    block_keys = jax.random.split(ks[0], L)
    blocks = jax.vmap(lambda k: _init_block(k, cfg, dtype))(block_keys)
    params = {
        "embed": embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(ks[2], cfg.d_model, cfg.vocab_size, dtype),
    }
    if cfg.family == "hybrid":
        # one *shared* attention+MLP block reused at every application point
        params["shared_attn"] = {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": init_attention(ks[3], cfg, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "mlp": init_mlp(ks[4], cfg.d_model, cfg.d_ff, dtype),
        }
    if cfg.family == "vlm":
        params["vision_proj"] = dense_init(ks[5], cfg.vision_dim, cfg.d_model, dtype)
    return params


def hybrid_points(cfg) -> int:
    return cfg.num_layers // cfg.hybrid_attn_every


# ======================================================================
# full-sequence forward (train / prefill body)
# ======================================================================

def _attn_block_fwd(p, x, cfg, positions, window):
    h, kv = attention_forward(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                              cfg, positions=positions, window=window)
    return x + h, kv


def _embed_inputs(params, tokens, cfg, vision_embeds=None):
    x = params["embed"][tokens]
    if cfg.family == "vlm":
        assert vision_embeds is not None, "pixtral requires stub patch embeddings"
        v = vision_embeds.astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([v, x], axis=1)
    return x


def _moe_layer(p, x, cfg, ep):
    """Dispatch to the dense or expert-parallel MoE path."""
    if ep is not None:
        return moe_forward_ep(p, x, cfg, **ep)
    return moe_forward(p, x, cfg)


def forward(params, tokens, cfg, *, vision_embeds=None, window=None,
            collect_kv=False, remat=False, ep=None):
    """Full-sequence forward. tokens: (B, S) int32.

    Returns (logits, aux) where aux carries MoE losses and (optionally) the
    per-layer KV tensors for prefill.  `remat=True` checkpoints each layer
    (training memory knob; see EXPERIMENTS §Perf).  `ep` (dict of
    moe_forward_ep kwargs) selects the expert-parallel production path."""
    window = cfg.sliding_window if window is None else window
    ckpt = jax.checkpoint if remat else (lambda f: f)
    x = _embed_inputs(params, tokens, cfg, vision_embeds)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    aux = {"load_balance_loss": jnp.zeros((), jnp.float32),
           "router_z_loss": jnp.zeros((), jnp.float32)}

    if cfg.family in ("dense", "vlm"):
        @ckpt
        def body(x, p):
            x, kv = _attn_block_fwd(p, x, cfg, positions, window)
            x = x + mlp_forward(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
            return x, kv if collect_kv else None

        x, kvs = jax.lax.scan(body, x, params["blocks"])
        caches = kvs

    elif cfg.family == "moe":
        @ckpt
        def body(carry, p):
            x, lb, zl = carry
            xi = rms_norm(x, p["ln1"], cfg.norm_eps)
            if cfg.use_mla:
                h, kv = mla_forward(p["attn"], xi, cfg, positions=positions,
                                    window=window)
            else:
                h, kv = attention_forward(p["attn"], xi, cfg,
                                          positions=positions, window=window)
            x = x + h
            mo, a = _moe_layer(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps),
                               cfg, ep)
            x = x + mo
            return ((x, lb + a["load_balance_loss"], zl + a["router_z_loss"]),
                    kv if collect_kv else None)

        (x, lb, zl), kvs = jax.lax.scan(
            body, (x, aux["load_balance_loss"], aux["router_z_loss"]),
            params["blocks"])
        aux["load_balance_loss"], aux["router_z_loss"] = lb, zl
        caches = kvs

    elif cfg.family == "ssm":
        fwd = mamba1_forward if cfg.mamba_version == 1 else mamba2_forward

        @ckpt
        def body(x, p):
            h, c = fwd(p["mamba"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
            return x + h, c if collect_kv else None

        x, states = jax.lax.scan(body, x, params["blocks"])
        caches = states

    elif cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        npts = hybrid_points(cfg)
        sp = params["shared_attn"]
        caches = [] if collect_kv else None

        @ckpt
        def body(x, p):
            h, c = mamba2_forward(p["mamba"],
                                  rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
            return x + h, c if collect_kv else None

        for g in range(npts):
            seg = jax.tree_util.tree_map(lambda a: a[g * k:(g + 1) * k],
                                         params["blocks"])
            x, states = jax.lax.scan(body, x, seg)
            xh, kv = _attn_block_fwd(sp, x, cfg, positions, window)
            x = xh + mlp_forward(sp["mlp"], rms_norm(xh, sp["ln2"], cfg.norm_eps))
            if collect_kv:
                caches.append((states, kv))
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    if collect_kv:
        return logits, aux, caches
    return logits, aux


# ======================================================================
# KV cache containers
# ======================================================================

def init_cache(cfg, batch: int, cache_len: int, dtype=None):
    """Empty decode cache with capacity cache_len (rolling when windowed)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    L, B, W = cfg.num_layers, batch, cache_len
    if cfg.family in ("dense", "vlm"):
        return {
            "k": jnp.zeros((L, B, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((L, B, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "pos": jnp.full((B, W), -1, jnp.int32),
        }
    if cfg.family == "moe":
        if cfg.use_mla:
            return {
                "ckv": jnp.zeros((L, B, W, cfg.kv_lora_rank), dtype),
                "kr": jnp.zeros((L, B, W, cfg.qk_rope_head_dim), dtype),
                "pos": jnp.full((B, W), -1, jnp.int32),
            }
        return {
            "k": jnp.zeros((L, B, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((L, B, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "pos": jnp.full((B, W), -1, jnp.int32),
        }
    if cfg.family == "ssm":
        din = cfg.ssm_expand * cfg.d_model
        if cfg.mamba_version == 1:
            return {
                "conv": jnp.zeros((L, B, cfg.ssm_conv, din), dtype),
                "state": jnp.zeros((L, B, din, cfg.ssm_state), jnp.float32),
            }
        nh = din // cfg.ssm_head_dim
        return {
            "conv": jnp.zeros((L, B, cfg.ssm_conv, din + 2 * cfg.ssm_state), dtype),
            "state": jnp.zeros((L, B, nh, cfg.ssm_head_dim, cfg.ssm_state),
                               jnp.float32),
        }
    if cfg.family == "hybrid":
        din = cfg.ssm_expand * cfg.d_model
        nh = din // cfg.ssm_head_dim
        npts = hybrid_points(cfg)
        return {
            "conv": jnp.zeros((L, B, cfg.ssm_conv, din + 2 * cfg.ssm_state), dtype),
            "state": jnp.zeros((L, B, nh, cfg.ssm_head_dim, cfg.ssm_state),
                               jnp.float32),
            "k": jnp.zeros((npts, B, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((npts, B, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "pos": jnp.full((B, W), -1, jnp.int32),
        }
    raise ValueError(cfg.family)


# ======================================================================
# decode step
# ======================================================================

def decode_step(params, token, pos, cache, cfg, *, window=None, ep=None):
    """token: (B,) int32; pos: (B,) absolute position. Returns (logits, cache)."""
    window = cfg.sliding_window if window is None else window
    x = params["embed"][token][:, None, :]                    # (B,1,d)
    B = x.shape[0]

    if cfg.family in ("dense", "vlm", "moe") and not cfg.use_mla:
        pos_buf = cache["pos"]

        def body(carry, inp):
            x, pos_buf = carry
            p, ck, cv = inp
            xi = rms_norm(x, p["ln1"], cfg.norm_eps)
            h, ck, cv, new_pos = attention_decode(p["attn"], xi, cfg, ck, cv,
                                                  pos_buf, pos, window=window)
            x = x + h
            if cfg.family == "moe":
                mo, _ = _moe_layer(p["moe"],
                                   rms_norm(x, p["ln2"], cfg.norm_eps), cfg, ep)
            else:
                mo = mlp_forward(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
            x = x + mo
            return (x, new_pos), (ck, cv)

        (x, new_pos), (ks, vs) = jax.lax.scan(
            body, (x, pos_buf), (params["blocks"], cache["k"], cache["v"]))
        cache = {"k": ks, "v": vs, "pos": new_pos}

    elif cfg.family == "moe" and cfg.use_mla:
        pos_buf = cache["pos"]

        def body(carry, inp):
            x, pos_buf = carry
            p, ckv, ckr = inp
            xi = rms_norm(x, p["ln1"], cfg.norm_eps)
            h, ckv, ckr, new_pos = mla_decode(p["attn"], xi, cfg, ckv, ckr,
                                              pos_buf, pos, window=window)
            x = x + h
            mo, _ = _moe_layer(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps),
                               cfg, ep)
            x = x + mo
            return (x, new_pos), (ckv, ckr)

        (x, new_pos), (ckvs, ckrs) = jax.lax.scan(
            body, (x, pos_buf), (params["blocks"], cache["ckv"], cache["kr"]))
        cache = {"ckv": ckvs, "kr": ckrs, "pos": new_pos}

    elif cfg.family == "ssm":
        dec = mamba1_decode if cfg.mamba_version == 1 else mamba2_decode

        def body(x, inp):
            p, conv, state = inp
            h, conv, state = dec(p["mamba"], rms_norm(x, p["ln1"], cfg.norm_eps),
                                 cfg, conv, state)
            return x + h, (conv, state)

        x, (convs, states) = jax.lax.scan(
            body, x, (params["blocks"], cache["conv"], cache["state"]))
        cache = {"conv": convs, "state": states}

    elif cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        npts = hybrid_points(cfg)
        sp = params["shared_attn"]
        pos_buf = cache["pos"]
        convs, states, ks, vs = [], [], [], []

        def body(x, inp):
            p, conv, state = inp
            h, conv, state = mamba2_decode(
                p["mamba"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg, conv, state)
            return x + h, (conv, state)

        new_pos = pos_buf
        for g in range(npts):
            sl = slice(g * k, (g + 1) * k)
            seg = jax.tree_util.tree_map(lambda a: a[sl], params["blocks"])
            x, (cv, st) = jax.lax.scan(body, x,
                                       (seg, cache["conv"][sl], cache["state"][sl]))
            convs.append(cv)
            states.append(st)
            xi = rms_norm(x, sp["ln1"], cfg.norm_eps)
            h, ck, cvv, new_pos = attention_decode(sp["attn"], xi, cfg,
                                                   cache["k"][g], cache["v"][g],
                                                   pos_buf, pos, window=window)
            x = x + h
            x = x + mlp_forward(sp["mlp"], rms_norm(x, sp["ln2"], cfg.norm_eps))
            ks.append(ck)
            vs.append(cvv)
        cache = {
            "conv": jnp.concatenate(convs, 0), "state": jnp.concatenate(states, 0),
            "k": jnp.stack(ks, 0), "v": jnp.stack(vs, 0), "pos": new_pos,
        }
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, cache


# ======================================================================
# prefill: full-sequence forward that also fills the decode cache
# ======================================================================

def prefill(params, tokens, cfg, cache_len: int, *, vision_embeds=None,
            window=None, dtype=None, ep=None):
    """Returns (last-token logits, cache ready for decode at pos = S)."""
    window = cfg.sliding_window if window is None else window
    out = forward(params, tokens, cfg, vision_embeds=vision_embeds,
                  window=window, collect_kv=True, ep=ep)
    logits, aux, collected = out
    B = tokens.shape[0]
    S = tokens.shape[1] + (cfg.num_vision_tokens if cfg.family == "vlm" else 0)
    cache = init_cache(cfg, B, cache_len, dtype)
    W = cache_len
    keep = min(S, W)
    src = slice(S - keep, S)
    slots = (jnp.arange(S - keep, S) % W).astype(jnp.int32)

    if cfg.family in ("dense", "vlm") or (cfg.family == "moe" and not cfg.use_mla):
        k, v = collected                                   # (L,B,S,KH,hd)
        cache["k"] = cache["k"].at[:, :, slots].set(k[:, :, src].astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[:, :, slots].set(v[:, :, src].astype(cache["v"].dtype))
        cache["pos"] = cache["pos"].at[:, slots].set(
            jnp.broadcast_to(jnp.arange(S - keep, S)[None], (B, keep)))
    elif cfg.family == "moe" and cfg.use_mla:
        ckv, kr = collected
        cache["ckv"] = cache["ckv"].at[:, :, slots].set(ckv[:, :, src].astype(cache["ckv"].dtype))
        cache["kr"] = cache["kr"].at[:, :, slots].set(kr[:, :, src].astype(cache["kr"].dtype))
        cache["pos"] = cache["pos"].at[:, slots].set(
            jnp.broadcast_to(jnp.arange(S - keep, S)[None], (B, keep)))
    elif cfg.family == "ssm":
        cache["state"] = collected["state"].astype(cache["state"].dtype)
        cache["conv"] = collected["conv"].astype(cache["conv"].dtype)
    elif cfg.family == "hybrid":
        cache["state"] = jnp.concatenate(
            [c[0]["state"] for c in collected], 0).astype(cache["state"].dtype)
        cache["conv"] = jnp.concatenate(
            [c[0]["conv"] for c in collected], 0).astype(cache["conv"].dtype)
        ks = jnp.stack([c[1][0] for c in collected], 0)    # (npts,B,S,KH,hd)
        vs = jnp.stack([c[1][1] for c in collected], 0)
        cache["k"] = cache["k"].at[:, :, slots].set(ks[:, :, src].astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[:, :, slots].set(vs[:, :, src].astype(cache["v"].dtype))
        cache["pos"] = cache["pos"].at[:, slots].set(
            jnp.broadcast_to(jnp.arange(S - keep, S)[None], (B, keep)))
    return logits, aux, cache
