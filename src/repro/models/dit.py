"""Diffusion Transformer (DiT) with AdaLN conditioning (survey Eq. 11-13).

The backbone for the faithful reproduction of the survey's caching claims.
`forward` runs the plain model; `forward_cached` runs the block stack under a
cache policy (per-block granularity) and `signal_fn` exposes the
timestep-modulated input TeaCache thresholds on (Eq. 22).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .encdec import sinusoidal_positions
from .layers import blocked_attention, dense_init, init_mlp, layer_norm, mlp_forward


def timestep_embedding(t, dim):
    """t: (B,) float -> (B, dim)."""
    return sinusoidal_positions(t, dim)


def _init_dit_block(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    H, hd = cfg.num_heads, cfg.head_dim
    return {
        "attn": {"wq": dense_init(ks[0], d, H * hd, dtype),
                 "wk": dense_init(ks[0], d, H * hd, dtype),
                 "wv": dense_init(ks[1], d, H * hd, dtype),
                 "wo": dense_init(ks[1], H * hd, d, dtype)},
        "mlp": init_mlp(ks[2], d, cfg.d_ff, dtype, gated=False),
        # AdaLN-zero: 6 modulation vectors; gate projections init to zero
        "ada_w": jnp.zeros((d, 6 * d), dtype),
        "ada_b": jnp.zeros((6 * d,), dtype),
    }


def init_dit(key, cfg, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    L, d = cfg.num_layers, cfg.d_model
    bkeys = jax.random.split(ks[0], L)
    return {
        "patch_in": dense_init(ks[1], cfg.dit_in_dim, d, dtype),
        "t_mlp1": dense_init(ks[2], d, d, dtype),
        "t_mlp2": dense_init(ks[3], d, d, dtype),
        "class_embed": jax.random.normal(ks[4], (cfg.dit_num_classes + 1, d),
                                         dtype) * 0.02,
        "blocks": jax.vmap(lambda k: _init_dit_block(k, cfg, dtype))(bkeys),
        "final_ada_w": jnp.zeros((d, 2 * d), dtype),
        "final_ada_b": jnp.zeros((2 * d,), dtype),
        "patch_out": dense_init(ks[5], d, cfg.dit_in_dim, dtype, scale=0.0),
    }


def condition(params, t, y, cfg, y_embed=None):
    """(B,) timestep + (B,) class -> (B, d) conditioning vector.

    `y_embed` (B, d) overrides the class-embedding lookup with an arbitrary
    conditioning vector — the negative-prompt path: a guided request's null
    conditioning need not be the model's null-class embedding."""
    te = timestep_embedding(t.astype(jnp.float32), cfg.d_model)
    te = jax.nn.silu(te.astype(params["t_mlp1"].dtype) @ params["t_mlp1"])
    te = te @ params["t_mlp2"]
    ce = params["class_embed"][y] if y_embed is None else y_embed
    return te + ce.astype(te.dtype)


def _modulate(x, shift, scale):
    return x * (1.0 + scale[:, None, :]) + shift[:, None, :]


def dit_block(p, x, c, cfg):
    """One DiT block. x: (B,T,d); c: (B,d) conditioning."""
    B, T, d = x.shape
    mod = jax.nn.silu(c) @ p["ada_w"] + p["ada_b"]
    s1, sc1, g1, s2, sc2, g2 = jnp.split(mod, 6, axis=-1)
    ones = jnp.ones((d,), x.dtype)
    zeros = jnp.zeros((d,), x.dtype)
    h = _modulate(layer_norm(x, ones, zeros), s1, sc1)
    H, hd = cfg.num_heads, cfg.head_dim
    q = (h @ p["attn"]["wq"]).reshape(B, T, H, hd)
    k = (h @ p["attn"]["wk"]).reshape(B, T, H, hd)
    v = (h @ p["attn"]["wv"]).reshape(B, T, H, hd)
    o = blocked_attention(q, k, v, causal=False)
    x = x + g1[:, None, :] * (o.reshape(B, T, H * hd) @ p["attn"]["wo"])
    h = _modulate(layer_norm(x, ones, zeros), s2, sc2)
    x = x + g2[:, None, :] * mlp_forward(p["mlp"], h)
    return x


def modulated_signal(params, x, c, cfg):
    """TeaCache's input-side signal: the first block's AdaLN-modulated input."""
    p0 = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
    mod = jax.nn.silu(c) @ p0["ada_w"] + p0["ada_b"]
    s1, sc1 = jnp.split(mod, 6, axis=-1)[:2]
    d = cfg.d_model
    return _modulate(layer_norm(x, jnp.ones((d,), x.dtype),
                                jnp.zeros((d,), x.dtype)), s1, sc1)


def embed_patches(params, latents, t, y, cfg, y_embed=None):
    x = latents @ params["patch_in"]
    T = x.shape[1]
    x = x + sinusoidal_positions(jnp.arange(T)[None], cfg.d_model).astype(x.dtype)
    c = condition(params, t, y, cfg, y_embed)
    return x, c


def final_layer(params, x, c, cfg):
    mod = jax.nn.silu(c) @ params["final_ada_w"] + params["final_ada_b"]
    s, sc = jnp.split(mod, 2, axis=-1)
    d = cfg.d_model
    h = _modulate(layer_norm(x, jnp.ones((d,), x.dtype),
                             jnp.zeros((d,), x.dtype)), s, sc)
    return h @ params["patch_out"]


def forward(params, latents, t, y, cfg, *, y_embed=None, remat=False):
    """latents: (B, T, in_dim); t: (B,); y: (B,) -> noise prediction."""
    x, c = embed_patches(params, latents, t, y, cfg, y_embed)
    ckpt = jax.checkpoint if remat else (lambda f: f)

    @ckpt
    def body(x, p):
        return dit_block(p, x, c, cfg), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return final_layer(params, x, c, cfg)
