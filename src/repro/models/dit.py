"""Diffusion Transformer (DiT) with AdaLN conditioning (survey Eq. 11-13).

The backbone for the faithful reproduction of the survey's caching claims.
`forward` runs the plain model; `forward_cached` runs the block stack under a
cache policy (per-block granularity) and `signal_fn` exposes the
timestep-modulated input TeaCache thresholds on (Eq. 22).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .encdec import sinusoidal_positions
from .layers import blocked_attention, dense_init, init_mlp, layer_norm, mlp_forward


def timestep_embedding(t, dim):
    """t: (B,) float -> (B, dim)."""
    return sinusoidal_positions(t, dim)


def _init_dit_block(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    H, hd = cfg.num_heads, cfg.head_dim
    block = {
        "attn": {"wq": dense_init(ks[0], d, H * hd, dtype),
                 "wk": dense_init(ks[0], d, H * hd, dtype),
                 "wv": dense_init(ks[1], d, H * hd, dtype),
                 "wo": dense_init(ks[1], H * hd, d, dtype)},
        "mlp": init_mlp(ks[2], d, cfg.d_ff, dtype, gated=False),
        # AdaLN-zero: 6 modulation vectors; gate projections init to zero
        "ada_w": jnp.zeros((d, 6 * d), dtype),
        "ada_b": jnp.zeros((6 * d,), dtype),
    }
    if cfg.dit_text_len > 0:
        # text cross-attention branch (T2I): its own AdaLN-zero triple so
        # no-text configs keep a bit-identical param tree and forward pass
        block["cross"] = {"wq": dense_init(ks[3], d, H * hd, dtype),
                          "wk": dense_init(ks[3], d, H * hd, dtype),
                          "wv": dense_init(ks[0], d, H * hd, dtype),
                          "wo": dense_init(ks[1], H * hd, d, dtype)}
        block["cross_ada_w"] = jnp.zeros((d, 3 * d), dtype)
        block["cross_ada_b"] = jnp.zeros((3 * d,), dtype)
    return block


def init_dit(key, cfg, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    L, d = cfg.num_layers, cfg.d_model
    bkeys = jax.random.split(ks[0], L)
    return {
        "patch_in": dense_init(ks[1], cfg.dit_in_dim, d, dtype),
        "t_mlp1": dense_init(ks[2], d, d, dtype),
        "t_mlp2": dense_init(ks[3], d, d, dtype),
        "class_embed": jax.random.normal(ks[4], (cfg.dit_num_classes + 1, d),
                                         dtype) * 0.02,
        "blocks": jax.vmap(lambda k: _init_dit_block(k, cfg, dtype))(bkeys),
        "final_ada_w": jnp.zeros((d, 2 * d), dtype),
        "final_ada_b": jnp.zeros((2 * d,), dtype),
        "patch_out": dense_init(ks[5], d, cfg.dit_in_dim, dtype, scale=0.0),
    }


def condition(params, t, y, cfg, y_embed=None):
    """(B,) timestep + (B,) class -> (B, d) conditioning vector.

    `y_embed` (B, d) overrides the class-embedding lookup with an arbitrary
    conditioning vector — the negative-prompt path: a guided request's null
    conditioning need not be the model's null-class embedding."""
    te = timestep_embedding(t.astype(jnp.float32), cfg.d_model)
    te = jax.nn.silu(te.astype(params["t_mlp1"].dtype) @ params["t_mlp1"])
    te = te @ params["t_mlp2"]
    ce = params["class_embed"][y] if y_embed is None else y_embed
    return te + ce.astype(te.dtype)


def _modulate(x, shift, scale):
    return x * (1.0 + scale[:, None, :]) + shift[:, None, :]


# ----------------------------------------------------------------------
# text cross-attention (repro.conditioning; survey's T2I/T2V scenario)
# ----------------------------------------------------------------------

def cross_attn_kv(p_cross, te):
    """One layer's text K/V projections.  te: (B, L, d) prompt embeddings
    -> (k, v) each (B, L, H*hd).  Text is step-invariant, so these are the
    cacheable half of the cross-attention branch."""
    return te @ p_cross["wk"], te @ p_cross["wv"]


def text_kv(params, te, cfg):
    """All layers' text K/V at once: (B, L, d) -> (k, v) each
    (B, num_layers, L, H*hd).  Computed ONCE per prompt at admission and
    reused across every denoise step (the per-slot K/V cache's payload)."""
    del cfg
    wk = params["blocks"]["cross"]["wk"]          # (nl, d, H*hd)
    wv = params["blocks"]["cross"]["wv"]
    return (jnp.einsum("bld,ndh->bnlh", te, wk),
            jnp.einsum("bld,ndh->bnlh", te, wv))


def cross_attn_branch(p, x, c, tk, tv, tm, cfg):
    """Gated cross-attention residual: latent queries over text keys.

    tk/tv: (B, L, H*hd) this layer's text K/V; tm: (B, L) bool key mask.
    The branch has its own AdaLN-zero triple (cross_ada_w/b).  Invariant:
    K/V tables are ZEROED at masked positions, so a fully-masked (prompt-
    less) row returns exactly zero — uniform softmax times zero values —
    and the no-text forward is reproduced bit-for-bit."""
    B, T, d = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    mod = jax.nn.silu(c) @ p["cross_ada_w"] + p["cross_ada_b"]
    s, sc, g = jnp.split(mod, 3, axis=-1)
    h = _modulate(layer_norm(x, jnp.ones((d,), x.dtype),
                             jnp.zeros((d,), x.dtype)), s, sc)
    q = (h @ p["cross"]["wq"]).reshape(B, T, H, hd)
    k = tk.reshape(B, -1, H, hd).astype(q.dtype)
    v = tv.reshape(B, -1, H, hd).astype(q.dtype)
    logits = jnp.einsum("bthd,blhd->bhtl", q, k) / math.sqrt(hd)
    logits = jnp.where(tm[:, None, None, :], logits, -1e9)
    o = jnp.einsum("bhtl,blhd->bthd", jax.nn.softmax(logits, axis=-1), v)
    return g[:, None, :] * (o.reshape(B, T, H * hd) @ p["cross"]["wo"])


def cross_attn_embed_branch(p, x, c, te, tm, cfg):
    """cross_attn_branch with K/V projected inline from the prompt
    embeddings — the form block-granularity cache stacks use (their scan
    broadcasts `te` across layers, so per-layer K/V can't ride the args)."""
    tk, tv = cross_attn_kv(p["cross"], te.astype(x.dtype))
    return cross_attn_branch(p, x, c, tk, tv, tm, cfg)


def block_branches(cfg):
    """Module types this backbone's blocks expose as separately cacheable
    branches (PAB's vocabulary; the registry-conformance lint checks
    PABPolicy.RANGES against the union of these over all DiT configs)."""
    return (("spatial_attn", "cross_attn", "mlp") if cfg.dit_text_len > 0
            else ("spatial_attn", "mlp"))


def dit_block(p, x, c, cfg, txt=None):
    """One DiT block. x: (B,T,d); c: (B,d) conditioning; txt: optional
    (tk, tv, tm) per-layer text K/V + mask (see cross_attn_branch)."""
    B, T, d = x.shape
    mod = jax.nn.silu(c) @ p["ada_w"] + p["ada_b"]
    s1, sc1, g1, s2, sc2, g2 = jnp.split(mod, 6, axis=-1)
    ones = jnp.ones((d,), x.dtype)
    zeros = jnp.zeros((d,), x.dtype)
    h = _modulate(layer_norm(x, ones, zeros), s1, sc1)
    H, hd = cfg.num_heads, cfg.head_dim
    q = (h @ p["attn"]["wq"]).reshape(B, T, H, hd)
    k = (h @ p["attn"]["wk"]).reshape(B, T, H, hd)
    v = (h @ p["attn"]["wv"]).reshape(B, T, H, hd)
    o = blocked_attention(q, k, v, causal=False)
    x = x + g1[:, None, :] * (o.reshape(B, T, H * hd) @ p["attn"]["wo"])
    if txt is not None:
        tk, tv, tm = txt
        x = x + cross_attn_branch(p, x, c, tk, tv, tm, cfg)
    h = _modulate(layer_norm(x, ones, zeros), s2, sc2)
    x = x + g2[:, None, :] * mlp_forward(p["mlp"], h)
    return x


def modulated_signal(params, x, c, cfg):
    """TeaCache's input-side signal: the first block's AdaLN-modulated input."""
    p0 = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
    mod = jax.nn.silu(c) @ p0["ada_w"] + p0["ada_b"]
    s1, sc1 = jnp.split(mod, 6, axis=-1)[:2]
    d = cfg.d_model
    return _modulate(layer_norm(x, jnp.ones((d,), x.dtype),
                                jnp.zeros((d,), x.dtype)), s1, sc1)


def embed_patches(params, latents, t, y, cfg, y_embed=None):
    x = latents @ params["patch_in"]
    T = x.shape[1]
    x = x + sinusoidal_positions(jnp.arange(T)[None], cfg.d_model).astype(x.dtype)
    c = condition(params, t, y, cfg, y_embed)
    return x, c


def final_layer(params, x, c, cfg):
    mod = jax.nn.silu(c) @ params["final_ada_w"] + params["final_ada_b"]
    s, sc = jnp.split(mod, 2, axis=-1)
    d = cfg.d_model
    h = _modulate(layer_norm(x, jnp.ones((d,), x.dtype),
                             jnp.zeros((d,), x.dtype)), s, sc)
    return h @ params["patch_out"]


def resolve_txt(params, cfg, batch, text_kv_fn, *, txt_kv=None, txt_mask=None,
                txt_embed=None, dtype=jnp.float32):
    """Normalize a text-conditioning operand set to (tk, tv, tm) with
    tk/tv (B, nl, L, H*hd) and tm (B, L) bool — zero tables + all-False
    mask when no text is supplied, so a text-enabled backbone stays an
    exact no-op for promptless batches (see cross_attn_branch)."""
    if txt_embed is not None and txt_kv is None:
        mask = (jnp.ones((batch, cfg.dit_text_len), bool)
                if txt_mask is None else txt_mask)
        txt_kv = text_kv_fn(params, jnp.where(mask[..., None], txt_embed, 0.0),
                            cfg)
        txt_mask = mask
    if txt_kv is None:
        nl, L = cfg.num_layers, cfg.dit_text_len
        width = cfg.num_heads * cfg.head_dim
        zeros = jnp.zeros((batch, nl, L, width), dtype)
        return zeros, zeros, jnp.zeros((batch, L), bool)
    tk, tv = txt_kv
    tm = (jnp.ones(tk.shape[:1] + tk.shape[2:3], bool)
          if txt_mask is None else txt_mask)
    return tk, tv, tm


def forward(params, latents, t, y, cfg, *, y_embed=None, txt_kv=None,
            txt_mask=None, txt_embed=None, remat=False):
    """latents: (B, T, in_dim); t: (B,); y: (B,) -> noise prediction.

    Text conditioning (cfg.dit_text_len > 0): pass either `txt_kv` (the
    precomputed per-layer K/V pair from text_kv — the serving path) or
    `txt_embed` (B, L, d) prompt embeddings projected inline, plus
    `txt_mask` (B, L).  Omitting both runs the zero-table no-op branch."""
    x, c = embed_patches(params, latents, t, y, cfg, y_embed)
    ckpt = jax.checkpoint if remat else (lambda f: f)

    if cfg.dit_text_len > 0:
        tk, tv, tm = resolve_txt(params, cfg, x.shape[0], text_kv,
                                 txt_kv=txt_kv, txt_mask=txt_mask,
                                 txt_embed=txt_embed, dtype=x.dtype)

        @ckpt
        def body(x, inp):
            p, tk_l, tv_l = inp
            return dit_block(p, x, c, cfg, txt=(tk_l, tv_l, tm)), None

        x, _ = jax.lax.scan(body, x, (params["blocks"],
                                      jnp.moveaxis(tk, 1, 0),
                                      jnp.moveaxis(tv, 1, 0)))
        return final_layer(params, x, c, cfg)

    @ckpt
    def body(x, p):
        return dit_block(p, x, c, cfg), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return final_layer(params, x, c, cfg)
