"""Factorized spatio-temporal DiT (Latte / OpenSora style) — the video
backbone for the survey's multi-modal caching claims.

A latent *clip* carries `F = cfg.dit_num_frames` frames of
`P = cfg.dit_patch_tokens` patches each, flattened to (B, F*P, in_dim) so
the cache/serving stack sees the same (batch, tokens, channels) layout as
the image DiT.  Each block factorizes attention along the two axes:

  spatial attention   — over the P patches of each frame (frames folded
                        into the batch axis),
  temporal attention  — over the F frames at each patch position (patches
                        folded into the batch axis),
  MLP                 — pointwise, axis-agnostic,

each branch AdaLN-zero gated (9 modulation vectors per block).  The three
branch functions are exposed separately (`spatial_branch` /
`temporal_branch` / `mlp_branch`) because Pyramid Attention Broadcast
caches them at *different* intervals — temporal attention output drifts
slowest across denoising steps, so it is broadcast over the longest range
(repro.core.temporal.TemporalPABStack).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .dit import (_modulate, condition, cross_attn_branch,
                  cross_attn_embed_branch, resolve_txt, text_kv)
from .encdec import sinusoidal_positions
from .layers import blocked_attention, dense_init, init_mlp, layer_norm, \
    mlp_forward

#: the three PAB module types of a factorized block, in execution order
#: (text-enabled configs insert cross_attn after spatial_attn — see
#: block_branches)
BRANCHES = ("spatial_attn", "temporal_attn", "mlp")


def _init_attn(key, d, H, hd, dtype):
    k1, k2 = jax.random.split(key)
    return {"wq": dense_init(k1, d, H * hd, dtype),
            "wk": dense_init(k1, d, H * hd, dtype),
            "wv": dense_init(k2, d, H * hd, dtype),
            "wo": dense_init(k2, H * hd, d, dtype)}


def _init_video_block(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    H, hd = cfg.num_heads, cfg.head_dim
    block = {
        "spatial": _init_attn(ks[0], d, H, hd, dtype),
        "temporal": _init_attn(ks[1], d, H, hd, dtype),
        "mlp": init_mlp(ks[2], d, cfg.d_ff, dtype, gated=False),
        # AdaLN-zero: 3 branches x (shift, scale, gate); gates init to zero
        "ada_w": jnp.zeros((d, 9 * d), dtype),
        "ada_b": jnp.zeros((9 * d,), dtype),
    }
    if cfg.dit_text_len > 0:
        # text cross-attention branch (T2V): own AdaLN-zero triple, same
        # param layout as the image DiT's so text_kv works on both
        block["cross"] = _init_attn(ks[3], d, H, hd, dtype)
        block["cross_ada_w"] = jnp.zeros((d, 3 * d), dtype)
        block["cross_ada_b"] = jnp.zeros((3 * d,), dtype)
    return block


def init_video_dit(key, cfg, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    L, d = cfg.num_layers, cfg.d_model
    bkeys = jax.random.split(ks[0], L)
    return {
        "patch_in": dense_init(ks[1], cfg.dit_in_dim, d, dtype),
        "t_mlp1": dense_init(ks[2], d, d, dtype),
        "t_mlp2": dense_init(ks[3], d, d, dtype),
        "class_embed": jax.random.normal(ks[4], (cfg.dit_num_classes + 1, d),
                                         dtype) * 0.02,
        "blocks": jax.vmap(lambda k: _init_video_block(k, cfg, dtype))(bkeys),
        "final_ada_w": jnp.zeros((d, 2 * d), dtype),
        "final_ada_b": jnp.zeros((2 * d,), dtype),
        "patch_out": dense_init(ks[5], d, cfg.dit_in_dim, dtype, scale=0.0),
    }


def _mod9(p, c):
    """The block's 9 modulation vectors, grouped per branch."""
    mod = jax.nn.silu(c) @ p["ada_w"] + p["ada_b"]
    parts = jnp.split(mod, 9, axis=-1)
    return {"spatial_attn": parts[0:3], "temporal_attn": parts[3:6],
            "mlp": parts[6:9]}


def _attend(ap, h, fold, unfold, cfg):
    """One factorized attention: fold an axis into batch, attend, unfold."""
    hf = fold(h)
    B, T, _ = hf.shape
    H, hd = cfg.num_heads, cfg.head_dim
    q = (hf @ ap["wq"]).reshape(B, T, H, hd)
    k = (hf @ ap["wk"]).reshape(B, T, H, hd)
    v = (hf @ ap["wv"]).reshape(B, T, H, hd)
    o = blocked_attention(q, k, v, causal=False)
    return unfold(o.reshape(B, T, H * hd) @ ap["wo"])


def _norm_mod(x, shift, scale, cfg):
    d = cfg.d_model
    return _modulate(layer_norm(x, jnp.ones((d,), x.dtype),
                                jnp.zeros((d,), x.dtype)), shift, scale)


def spatial_branch(p, x, c, cfg):
    """Gated spatial-attention residual: attention over the P patches of each
    frame.  x: (B, F*P, d)."""
    B, T, d = x.shape
    F = cfg.dit_num_frames
    P = T // F
    s, sc, g = _mod9(p, c)["spatial_attn"]
    h = _norm_mod(x, s, sc, cfg)
    o = _attend(p["spatial"], h,
                lambda a: a.reshape(B * F, P, d),
                lambda a: a.reshape(B, F * P, d), cfg)
    return g[:, None, :] * o


def temporal_branch(p, x, c, cfg):
    """Gated temporal-attention residual: attention over the F frames at each
    patch position."""
    B, T, d = x.shape
    F = cfg.dit_num_frames
    P = T // F
    s, sc, g = _mod9(p, c)["temporal_attn"]
    h = _norm_mod(x, s, sc, cfg)
    o = _attend(
        p["temporal"], h,
        lambda a: a.reshape(B, F, P, d).transpose(0, 2, 1, 3).reshape(B * P, F, d),
        lambda a: a.reshape(B, P, F, d).transpose(0, 2, 1, 3).reshape(B, F * P, d),
        cfg)
    return g[:, None, :] * o


def mlp_branch(p, x, c, cfg):
    s, sc, g = _mod9(p, c)["mlp"]
    return g[:, None, :] * mlp_forward(p["mlp"], _norm_mod(x, s, sc, cfg))


BRANCH_FNS = {"spatial_attn": spatial_branch, "temporal_attn": temporal_branch,
              "mlp": mlp_branch}


def block_branches(cfg):
    """Module types this backbone's blocks expose as separately cacheable
    branches, in execution order (the PAB vocabulary; the registry-
    conformance lint checks PABPolicy.RANGES against the union of these
    over all DiT configs).  Cross-attention queries are per-frame patch
    tokens attending over the shared text keys — per-query softmax makes
    the frame-folded and flat-clip forms identical, so the branch runs on
    the flat (B, F*P, d) layout."""
    return (("spatial_attn", "cross_attn", "temporal_attn", "mlp")
            if cfg.dit_text_len > 0 else BRANCHES)


def pab_branch_fns(cfg):
    """The factorized branches bound to `cfg`, keyed by PAB module type —
    the single source for TemporalPABStack construction (pipeline's
    pab_video granularity and DenoiseWorkload.pab_stack both use it).

    Text-enabled configs add the cross_attn branch (broadcast over the
    LONGEST range — text is step-invariant) and every branch takes the
    broadcast stack args (c, te, tm): TemporalPABStack's scan broadcasts
    args across layers, so the cross branch projects its K/V inline from
    the prompt embeddings on refresh steps."""
    if cfg.dit_text_len > 0:
        fns = {name: (lambda p, x, c, te, tm, fn=fn: fn(p, x, c, cfg))
               for name, fn in BRANCH_FNS.items()}
        fns["cross_attn"] = (lambda p, x, c, te, tm:
                             cross_attn_embed_branch(p, x, c, te, tm, cfg))
        return {name: fns[name] for name in block_branches(cfg)}
    return {name: (lambda p, x, c, fn=fn: fn(p, x, c, cfg))
            for name, fn in BRANCH_FNS.items()}


def video_block(p, x, c, cfg, txt=None):
    """One factorized block: the gated residual branches in order; txt
    ((tk, tv, tm) per-layer text K/V + mask) inserts the cross-attention
    branch after spatial attention."""
    for name in BRANCHES:
        x = x + BRANCH_FNS[name](p, x, c, cfg)
        if name == "spatial_attn" and txt is not None:
            tk, tv, tm = txt
            x = x + cross_attn_branch(p, x, c, tk, tv, tm, cfg)
    return x


def embed_patches(params, latents, t, y, cfg, y_embed=None):
    """(B, F*P, in_dim) -> tokens with factorized positions + conditioning."""
    x = latents @ params["patch_in"]
    F = cfg.dit_num_frames
    P = x.shape[1] // F
    d = cfg.d_model
    spat = sinusoidal_positions(jnp.arange(P)[None], d)          # (1, P, d)
    temp = sinusoidal_positions(jnp.arange(F)[None], d)          # (1, F, d)
    pos = (jnp.tile(spat, (1, F, 1)) +
           jnp.repeat(temp, P, axis=1))                          # (1, F*P, d)
    x = x + pos.astype(x.dtype)
    c = condition(params, t, y, cfg, y_embed)
    return x, c


def modulated_signal(params, x, c, cfg):
    """TeaCache's input-side signal for the video backbone: the first block's
    spatial-branch modulated input (the analogue of dit.modulated_signal)."""
    p0 = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
    s, sc, _ = _mod9(p0, c)["spatial_attn"]
    return _norm_mod(x, s, sc, cfg)


def final_layer(params, x, c, cfg):
    mod = jax.nn.silu(c) @ params["final_ada_w"] + params["final_ada_b"]
    s, sc = jnp.split(mod, 2, axis=-1)
    return _norm_mod(x, s, sc, cfg) @ params["patch_out"]


def forward(params, latents, t, y, cfg, *, y_embed=None, txt_kv=None,
            txt_mask=None, txt_embed=None, remat=False):
    """latents: (B, F*P, in_dim); t: (B,); y: (B,) -> noise prediction.
    Text operands as in dit.forward (precomputed txt_kv or inline
    txt_embed, both optional)."""
    x, c = embed_patches(params, latents, t, y, cfg, y_embed)
    ckpt = jax.checkpoint if remat else (lambda f: f)

    if cfg.dit_text_len > 0:
        tk, tv, tm = resolve_txt(params, cfg, x.shape[0], text_kv,
                                 txt_kv=txt_kv, txt_mask=txt_mask,
                                 txt_embed=txt_embed, dtype=x.dtype)

        @ckpt
        def body(x, inp):
            p, tk_l, tv_l = inp
            return video_block(p, x, c, cfg, txt=(tk_l, tv_l, tm)), None

        x, _ = jax.lax.scan(body, x, (params["blocks"],
                                      jnp.moveaxis(tk, 1, 0),
                                      jnp.moveaxis(tv, 1, 0)))
        return final_layer(params, x, c, cfg)

    @ckpt
    def body(x, p):
        return video_block(p, x, c, cfg), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return final_layer(params, x, c, cfg)
