"""Foundational layers shared by the model zoo.

Pure-functional: every layer is `init_*(key, ...) -> params` plus an apply
function.  Attention is implemented as a Q-chunked streaming softmax
(`blocked_attention`) so that `chunk x S_kv` — never `S_q x S_kv` — score
tiles are materialized; the Pallas flash kernel in repro.kernels is the TPU
drop-in for the same contraction and is validated against this function.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

# ----------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------

def dense_init(key, in_dim, out_dim, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), dtype) * scale)


def embed_init(key, vocab, dim, dtype=jnp.float32):
    return jax.random.normal(key, (vocab, dim), dtype) * 0.02


# ----------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------

def rms_norm(x, weight, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * weight + bias
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, D); positions: (..., S) int32."""
    D = x.shape[-1]
    inv = rope_freqs(D, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                 # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, causal: bool, window: int, dtype):
    """(..., Sq, Sk) additive mask from absolute positions.

    Negative k positions mark empty cache slots and are always masked."""
    ok = jnp.broadcast_to(k_pos[..., None, :] >= 0,
                          q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]))
    if causal:
        ok = ok & (k_pos[..., None, :] <= q_pos[..., :, None])
    if window > 0:
        ok = ok & (q_pos[..., :, None] - k_pos[..., None, :] < window)
    return jnp.where(ok, 0.0, -1e30).astype(dtype)


def blocked_attention(q, k, v, *, causal=True, window=0, q_positions=None,
                      k_positions=None, chunk=512, scale=None):
    """Streaming-softmax attention.

    q: (B, Sq, H, D)   k: (B, Sk, KH, Dk)   v: (B, Sk, KH, Dv), KH | H.
    Returns (B, Sq, H, Dv).  Memory per chunk: B*H*chunk*Sk scores.
    """
    B, Sq, H, D = q.shape
    _, Sk, KH, Dv = v.shape
    group = H // KH
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])

    if q_positions is None:
        q_positions = jnp.arange(Sq)[None, :] + (Sk - Sq)
        q_positions = jnp.broadcast_to(q_positions, (B, Sq))
    if k_positions is None:
        k_positions = jnp.broadcast_to(jnp.arange(Sk)[None, :], (B, Sk))

    qg = q.reshape(B, Sq, KH, group, D)

    def attend_chunk(q_c, qpos_c):
        # q_c: (B, C, KH, G, D) -> scores (B, KH, G, C, Sk).  K/V stay in
        # their storage dtype with f32 accumulation via the dot's
        # preferred_element_type — an .astype(f32) on the cache here gets
        # hoisted by XLA into an f32 copy of the whole stacked KV cache
        # (EXPERIMENTS §Perf)
        s = jnp.einsum("bckgd,bskd->bkgcs", q_c.astype(k.dtype), k,
                       preferred_element_type=jnp.float32) * scale
        bias = _mask_bias(qpos_c, k_positions, causal, window, s.dtype)
        s = s + bias[:, None, None, :, :]
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgcs,bskd->bckgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.astype(q.dtype)

    if Sq <= chunk or Sq % chunk != 0:
        out = attend_chunk(qg, q_positions)
    else:
        n = Sq // chunk
        qs = qg.reshape(B, n, chunk, KH, group, D).transpose(1, 0, 2, 3, 4, 5)
        ps = q_positions.reshape(B, n, chunk).transpose(1, 0, 2)

        def body(_, qc_pc):
            qc, pc = qc_pc
            return None, attend_chunk(qc, pc)

        _, outs = jax.lax.scan(body, None, (qs, ps))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KH, group, Dv)
    return out.reshape(B, Sq, H, Dv)


# ----------------------------------------------------------------------
# GQA attention block (params + apply, with optional QKV bias)
# ----------------------------------------------------------------------

def init_attention(key, cfg, dtype=jnp.float32):
    d, H, KH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * hd, dtype),
        "wk": dense_init(ks[1], d, KH * hd, dtype),
        "wv": dense_init(ks[2], d, KH * hd, dtype),
        "wo": dense_init(ks[3], H * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KH * hd,), dtype)
        p["bv"] = jnp.zeros((KH * hd,), dtype)
    return p


def attention_qkv(p, x, cfg):
    B, S, _ = x.shape
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, H, hd), k.reshape(B, S, KH, hd),
            v.reshape(B, S, KH, hd))


def attention_forward(p, x, cfg, *, positions=None, window=None):
    """Full-sequence (train / prefill) self-attention. Returns (out, (k, v))."""
    B, S, _ = x.shape
    q, k, v = attention_qkv(p, x, cfg)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    window = cfg.sliding_window if window is None else window
    o = blocked_attention(q, k, v, causal=True, window=window,
                          q_positions=positions, k_positions=positions)
    o = o.reshape(B, S, -1) @ p["wo"]
    return o, (k, v)


def attention_decode(p, x, cfg, cache_k, cache_v, cache_pos, pos, *,
                     window=None):
    """One-token decode against a (possibly rolling) KV cache.

    x: (B, 1, d).  cache_k/v: (B, W, KH, hd); cache_pos: (B, W) int32 absolute
    positions (-1 = empty).  pos: (B,) int32 current absolute position.
    Returns (out, new_cache_k, new_cache_v, new_cache_pos).
    """
    B = x.shape[0]
    W = cache_k.shape[1]
    q, k, v = attention_qkv(p, x, cfg)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    slot = (pos % W).astype(jnp.int32)
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, slot].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, slot].set(v[:, 0].astype(cache_v.dtype))
    cache_pos = cache_pos.at[bidx, slot].set(pos.astype(jnp.int32))
    window = cfg.sliding_window if window is None else window
    o = blocked_attention(q, cache_k, cache_v, causal=True, window=window,
                          q_positions=pos[:, None], k_positions=cache_pos)
    o = o.reshape(B, 1, -1) @ p["wo"]
    return o, cache_k, cache_v, cache_pos


# ----------------------------------------------------------------------
# MLP (SwiGLU; classic GELU for whisper/DiT)
# ----------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, dtype=jnp.float32, gated=True):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp_forward(p, x):
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]
