"""Mamba1 (selective scan) and Mamba2 (SSD) blocks.

TPU adaptation (DESIGN §2): the CUDA selective-scan kernel becomes
  * mamba1 — chunked *associative* scan: `lax.associative_scan` inside
    fixed-size chunks (parallel depth log L), sequential `lax.scan` across
    chunks carrying the state; working set = chunk * d_inner * state.
  * mamba2 — the SSD matmul formulation (intra-chunk L-matrix einsums feed
    the MXU; inter-chunk recurrence is a cheap scan).  The Pallas kernel in
    repro.kernels.ssd implements the intra-chunk block; this file is the
    reference path and the oracle.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm


# ----------------------------------------------------------------------
# causal depthwise conv (width w, implemented as shifted adds — w is tiny)
# ----------------------------------------------------------------------

def causal_conv(x, w, b):
    """x: (B, S, C); w: (W, C); b: (C,).

    One depthwise lax.conv instead of W shifted-pad-multiply-adds: the
    shifted copies cost (W-1) extra reads+writes of the full activation per
    layer (measured 3x54 padded f32 copies on zamba2 prefill_32k —
    EXPERIMENTS §Perf iteration z1)."""
    W, C = w.shape
    lhs = x.swapaxes(1, 2)                       # (B, C, S)
    rhs = w.T[:, None, :]                        # (C, 1, W)  depthwise
    out = jax.lax.conv_general_dilated(
        lhs, rhs, window_strides=(1,), padding=[(W - 1, 0)],
        feature_group_count=C,
        dimension_numbers=("NCH", "OIH", "NCH"),
        preferred_element_type=x.dtype)
    return out.swapaxes(1, 2) + b


def conv_step(buf, x_t, w, b):
    """Single-token conv against a rolling buffer.

    buf: (B, W, C) holding the last W inputs (oldest first); x_t: (B, C).
    Returns (y_t, new_buf)."""
    buf = jnp.concatenate([buf[:, 1:], x_t[:, None]], axis=1)
    y = jnp.einsum("bwc,wc->bc", buf, w) + b
    return y, buf


# ----------------------------------------------------------------------
# chunked linear recurrence h_t = a_t * h_{t-1} + u_t
# ----------------------------------------------------------------------

def _assoc_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def linear_scan_chunked(a, u, h0, chunk: int):
    """a, u: (B, S, ...) elementwise recurrence tensors; h0: (B, ...).

    Returns (h_all (B,S,...), h_final)."""
    B, S = a.shape[:2]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    a_c = a.reshape(B, nc, chunk, *a.shape[2:]).swapaxes(0, 1)
    u_c = u.reshape(B, nc, chunk, *u.shape[2:]).swapaxes(0, 1)

    def per_chunk(h, au):
        a_ch, u_ch = au  # (B, chunk, ...)
        A_cum, U_cum = jax.lax.associative_scan(_assoc_combine, (a_ch, u_ch),
                                                axis=1)
        h_all = A_cum * h[:, None] + U_cum
        return h_all[:, -1], h_all

    h_final, h_chunks = jax.lax.scan(per_chunk, h0, (a_c, u_c))
    h_all = h_chunks.swapaxes(0, 1).reshape(B, S, *a.shape[2:])
    return h_all, h_final


# ----------------------------------------------------------------------
# Mamba1
# ----------------------------------------------------------------------

def init_mamba1(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(key, 8)
    return {
        "in_proj": dense_init(ks[0], d, 2 * din, dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, din), dtype) * 0.1,
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": dense_init(ks[2], din, dt_rank + 2 * n, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, din, dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (din,),
                                       minval=math.log(1e-3),
                                       maxval=math.log(1e-1))))).astype(dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (din, n))).astype(dtype),
        "D": jnp.ones((din,), dtype),
        "out_proj": dense_init(ks[5], din, d, dtype),
    }


def _conv_tail(raw, width):
    """Last `width` pre-conv inputs, left-padded with zeros (decode buffer)."""
    B, S, C = raw.shape
    if S >= width:
        return raw[:, S - width:]
    return jnp.pad(raw, ((0, 0), (width - S, 0), (0, 0)))


def mamba1_forward(p, u, cfg, chunk: int = 64):
    """Full-sequence mamba1. u: (B, S, d).

    Returns (y, cache) with cache = {"state", "conv"} ready for decode."""
    B, S, d = u.shape
    din = p["D"].shape[0]
    n = cfg.ssm_state
    dt_rank = p["dt_proj"].shape[0]
    xz = u @ p["in_proj"]
    x_raw, z = jnp.split(xz, 2, axis=-1)
    x = jax.nn.silu(causal_conv(x_raw, p["conv_w"], p["conv_b"]))
    dbc = x @ p["x_proj"]
    dt = jax.nn.softplus(dbc[..., :dt_rank] @ p["dt_proj"] + p["dt_bias"])
    B_ = dbc[..., dt_rank:dt_rank + n]
    C_ = dbc[..., dt_rank + n:]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (din, n)
    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A)          # (B,S,din,n)
    dBx = (dt[..., None] * B_[:, :, None, :] * x[..., None]).astype(jnp.float32)
    h0 = jnp.zeros((B, *dA.shape[2:]), jnp.float32)
    if S % chunk != 0:
        chunk = S  # tiny smoke sequences
    h_all, h_fin = linear_scan_chunked(dA, dBx, h0, chunk)
    y = jnp.einsum("bsdn,bsn->bsd", h_all, C_.astype(jnp.float32))
    y = (y + p["D"] * x).astype(u.dtype) * jax.nn.silu(z)
    cache = {"state": h_fin, "conv": _conv_tail(x_raw, cfg.ssm_conv)}
    return y @ p["out_proj"], cache


def mamba1_decode(p, u_t, cfg, conv_buf, h):
    """One-token step. u_t: (B, 1, d); conv_buf: (B, W, din); h: (B, din, n)."""
    B = u_t.shape[0]
    din = p["D"].shape[0]
    n = cfg.ssm_state
    dt_rank = p["dt_proj"].shape[0]
    xz = u_t[:, 0] @ p["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)
    x, conv_buf = conv_step(conv_buf, x, p["conv_w"], p["conv_b"])
    x = jax.nn.silu(x)
    dbc = x @ p["x_proj"]
    dt = jax.nn.softplus(dbc[..., :dt_rank] @ p["dt_proj"] + p["dt_bias"])
    B_ = dbc[..., dt_rank:dt_rank + n]
    C_ = dbc[..., dt_rank + n:]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A)          # (B,din,n)
    h = dA * h + (dt[..., None] * B_[:, None, :] * x[..., None]).astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", h, C_.astype(jnp.float32))
    y = (y + p["D"] * x).astype(u_t.dtype) * jax.nn.silu(z)
    return (y @ p["out_proj"])[:, None], conv_buf, h


# ----------------------------------------------------------------------
# Mamba2 (SSD)
# ----------------------------------------------------------------------

def init_mamba2(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = din // cfg.ssm_head_dim
    ks = jax.random.split(key, 6)
    return {
        # order: [z (din), x (din), B (n), C (n), dt (nh)]
        "in_proj": dense_init(ks[0], d, 2 * din + 2 * n + nh, dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, din + 2 * n), dtype) * 0.1,
        "conv_b": jnp.zeros((din + 2 * n,), dtype),
        "A_log": jnp.log(jax.random.uniform(ks[2], (nh,), minval=1.0,
                                            maxval=16.0)).astype(dtype),
        "D": jnp.ones((nh,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[3], (nh,), minval=math.log(1e-3),
                                       maxval=math.log(1e-1))))).astype(dtype),
        "norm_w": jnp.ones((din,), dtype),
        "out_proj": dense_init(ks[4], din, d, dtype),
    }


def _segsum(dA):
    """dA: (..., L) -> (..., L, L) lower-tri S[i,j] = sum_{k=j+1..i} dA[k]."""
    L = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    S = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, S, -jnp.inf)


def ssd_chunked(x, dt, A, B_, C_, chunk: int, h0=None):
    """Minimal SSD (Mamba2) over chunks.

    x: (b,s,h,p), dt: (b,s,h) (softplus applied), A: (h,) negative,
    B_, C_: (b,s,n) shared across heads (n_groups=1).
    Returns (y (b,s,h,p), h_final (b,h,p,n))."""
    b, s, h, p = x.shape
    n = B_.shape[-1]
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B_.reshape(b, nc, chunk, n)
    Cc = C_.reshape(b, nc, chunk, n)

    cdt = x.dtype if x.dtype == jnp.bfloat16 else jnp.float32

    dA = dtc * A                                             # (b,nc,l,h) <= 0
    dA_cs = jnp.cumsum(dA, axis=2)                           # inclusive

    # intra-chunk — operands in the compute dtype (bf16 on TPU), f32
    # accumulation via preferred_element_type: keeping the L-matrix and
    # score temporaries f32 doubles HBM traffic for no accuracy benefit
    # (decay factors are <= 1; EXPERIMENTS §Perf iteration z2)
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2))).astype(cdt)  # (b,nc,h,l,l)
    xdt = (xc * dtc[..., None]).astype(cdt)
    CB = jnp.einsum("bcln,bcmn->bclm", Cc.astype(cdt), Bc.astype(cdt),
                    preferred_element_type=jnp.float32).astype(cdt)
    Y_diag = jnp.einsum("bclm,bchlm,bcmhp->bclhp", CB, L, xdt,
                        preferred_element_type=jnp.float32)

    # chunk-final states
    decay = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)             # (b,nc,l,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc.astype(cdt),
                        (decay * dtc).astype(cdt), xc.astype(cdt),
                        preferred_element_type=jnp.float32)

    # inter-chunk recurrence with the off-diagonal contribution fused into
    # the scan body: materializing the full (b,nc,h,p,n) h_prevs stack for
    # a post-hoc einsum costs an extra state-stack round trip (§Perf z3)
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))               # (b,nc,h)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    Cd = Cc.astype(cdt).reshape(b, nc, chunk, n)
    eA = jnp.exp(dA_cs).astype(cdt)                          # (b,nc,l,h)

    def step(hprev, inp):
        cd, st, c_t, ea_t = inp      # (b,h), (b,h,p,n), (b,l,n), (b,l,h)
        y_off = jnp.einsum("bln,blh,bhpn->blhp", c_t, ea_t,
                           hprev.astype(cdt),
                           preferred_element_type=jnp.float32)
        hnew = hprev * cd[..., None, None] + st
        return hnew, y_off

    h_fin, y_offs = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (chunk_decay.swapaxes(0, 1), states.swapaxes(0, 1),
         Cd.swapaxes(0, 1), eA.swapaxes(0, 1)))
    Y_off = y_offs.swapaxes(0, 1)                            # (b,nc,l,h,p)

    y = (Y_diag + Y_off).reshape(b, s, h, p)
    return y, h_fin


def _mamba2_inputs(p, u, cfg):
    din = p["norm_w"].shape[0]
    n = cfg.ssm_state
    nh = p["A_log"].shape[0]
    proj = u @ p["in_proj"]
    z = proj[..., :din]
    xBC = proj[..., din:2 * din + 2 * n]
    dt_raw = proj[..., 2 * din + 2 * n:]
    return z, xBC, dt_raw, din, n, nh


def mamba2_forward(p, u, cfg, chunk: int = 64):
    """Full-sequence mamba2 (SSD). u: (B,S,d).

    Returns (y, cache) with cache = {"state", "conv"} ready for decode."""
    B, S, d = u.shape
    z, xBC, dt_raw, din, n, nh = _mamba2_inputs(p, u, cfg)
    xBC_raw = xBC
    xBC = jax.nn.silu(causal_conv(xBC, p["conv_w"], p["conv_b"]))
    x = xBC[..., :din].reshape(B, S, nh, din // nh)
    B_ = xBC[..., din:din + n]
    C_ = xBC[..., din + n:]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_fin = ssd_chunked(x.astype(jnp.float32), dt, A,
                           B_.astype(jnp.float32), C_.astype(jnp.float32),
                           chunk)
    y = y + p["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B, S, din).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    cache = {"state": h_fin, "conv": _conv_tail(xBC_raw, cfg.ssm_conv)}
    return y @ p["out_proj"], cache


def mamba2_decode(p, u_t, cfg, conv_buf, h):
    """One-token step. conv_buf: (B, W, din+2n); h: (B, nh, hd, n)."""
    B = u_t.shape[0]
    z, xBC, dt_raw, din, n, nh = _mamba2_inputs(p, u_t[:, 0:1], cfg)
    z, xBC, dt_raw = z[:, 0], xBC[:, 0], dt_raw[:, 0]
    xBC, conv_buf = conv_step(conv_buf, xBC, p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC)
    x = xBC[..., :din].reshape(B, nh, din // nh)
    B_ = xBC[..., din:din + n]
    C_ = xBC[..., din + n:]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"]).astype(jnp.float32)  # (B,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                     # (B,nh)
    h = h * dA[..., None, None] + (dt[..., None, None] *
                                   x[..., None].astype(jnp.float32) *
                                   B_[:, None, None, :].astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", h, C_.astype(jnp.float32))
    y = y + p["D"][None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B, din).astype(u_t.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return (y @ p["out_proj"])[:, None], conv_buf, h
