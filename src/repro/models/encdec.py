"""Whisper-style encoder-decoder backbone.

Per the brief's carve-out, the mel+conv frontend is a stub: `frames` are
precomputed (B, encoder_seq, d_model) embeddings.  The decoder's
cross-attention K/V are computed ONCE from the encoder output and reused for
every decode step — the survey's motivating example of *exact* cache reuse
under fixed conditioning (§I-C): tested bit-exact in tests/test_models.py.

Deviations noted in DESIGN.md: sinusoidal positions on both sides (instead
of learned decoder positions) so the assigned 32k decoder shapes are
representable; pre-LN layernorm as in the original.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import (blocked_attention, dense_init, embed_init, init_mlp,
                     layer_norm, mlp_forward)


def sinusoidal_positions(positions, d_model):
    """positions: (..., S) int -> (..., S, d_model) float32."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_ln(d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _init_xattn(key, cfg, dtype):
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], d, H * hd, dtype),
            "wk": dense_init(ks[1], d, H * hd, dtype),
            "wv": dense_init(ks[2], d, H * hd, dtype),
            "wo": dense_init(ks[3], H * hd, d, dtype)}


def _init_enc_block(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {"ln1": _init_ln(d, dtype), "attn": _init_xattn(ks[0], cfg, dtype),
            "ln2": _init_ln(d, dtype),
            "mlp": init_mlp(ks[1], d, cfg.d_ff, dtype, gated=False)}


def _init_dec_block(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {"ln1": _init_ln(d, dtype), "self": _init_xattn(ks[0], cfg, dtype),
            "ln2": _init_ln(d, dtype), "cross": _init_xattn(ks[1], cfg, dtype),
            "ln3": _init_ln(d, dtype),
            "mlp": init_mlp(ks[2], d, cfg.d_ff, dtype, gated=False)}


def init_encdec(key, cfg, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.num_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(enc_keys),
        "enc_ln": _init_ln(cfg.d_model, dtype),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(dec_keys),
        "dec_ln": _init_ln(cfg.d_model, dtype),
        "embed": embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype),
        "lm_head": dense_init(ks[3], cfg.d_model, cfg.vocab_size, dtype),
    }


def _attn(p, xq, xkv, cfg, causal, q_positions=None, k_positions=None):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    H, hd = cfg.num_heads, cfg.head_dim
    q = (xq @ p["wq"]).reshape(B, Sq, H, hd)
    k = (xkv @ p["wk"]).reshape(B, Skv, H, hd)
    v = (xkv @ p["wv"]).reshape(B, Skv, H, hd)
    o = blocked_attention(q, k, v, causal=causal, q_positions=q_positions,
                          k_positions=k_positions)
    return o.reshape(B, Sq, H * hd) @ p["wo"]


def encode(params, frames, cfg):
    """frames: (B, S_enc, d_model) stub frontend embeddings."""
    B, S, d = frames.shape
    x = frames + sinusoidal_positions(jnp.arange(S)[None], d).astype(frames.dtype)

    def body(x, p):
        x = x + _attn(p["attn"], layer_norm(x, p["ln1"]["w"], p["ln1"]["b"]),
                      layer_norm(x, p["ln1"]["w"], p["ln1"]["b"]), cfg,
                      causal=False)
        x = x + mlp_forward(p["mlp"], layer_norm(x, p["ln2"]["w"], p["ln2"]["b"]))
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layer_norm(x, params["enc_ln"]["w"], params["enc_ln"]["b"])


def cross_kv(params, enc_out, cfg):
    """Per-layer cross-attention K/V — computed ONCE per request (exact
    cache: the conditioning is fixed across all decode steps)."""
    B, S, _ = enc_out.shape
    H, hd = cfg.num_heads, cfg.head_dim

    def body(_, p):
        k = (enc_out @ p["cross"]["wk"]).reshape(B, S, H, hd)
        v = (enc_out @ p["cross"]["wv"]).reshape(B, S, H, hd)
        return None, (k, v)

    _, kvs = jax.lax.scan(body, None, params["dec_blocks"])
    return kvs  # (L,B,S,H,hd) x2


def _decoder(params, tokens, enc_out, cfg, xkv=None, remat=False):
    B, S = tokens.shape
    x = params["embed"][tokens]
    x = x + sinusoidal_positions(jnp.arange(S)[None], cfg.d_model).astype(x.dtype)
    ckpt = jax.checkpoint if remat else (lambda f: f)

    @ckpt
    def body(x, p):
        x = x + _attn(p["self"], layer_norm(x, p["ln1"]["w"], p["ln1"]["b"]),
                      layer_norm(x, p["ln1"]["w"], p["ln1"]["b"]), cfg,
                      causal=True)
        x = x + _attn(p["cross"], layer_norm(x, p["ln2"]["w"], p["ln2"]["b"]),
                      enc_out, cfg, causal=False)
        x = x + mlp_forward(p["mlp"], layer_norm(x, p["ln3"]["w"], p["ln3"]["b"]))
        return x, None

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"])


def forward(params, frames, tokens, cfg, *, remat=False):
    """Training forward: (B,S_enc,d) frames + (B,S_dec) tokens -> logits."""
    enc_out = encode(params, frames, cfg)
    x = _decoder(params, tokens, enc_out, cfg, remat=remat)
    return x @ params["lm_head"]


def init_dec_cache(cfg, batch, cache_len, enc_seq, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    L, B, W, H, hd = (cfg.num_layers, batch, cache_len, cfg.num_heads,
                      cfg.head_dim)
    return {
        "k": jnp.zeros((L, B, W, H, hd), dtype),
        "v": jnp.zeros((L, B, W, H, hd), dtype),
        "pos": jnp.full((B, W), -1, jnp.int32),
        "xk": jnp.zeros((L, B, enc_seq, H, hd), dtype),
        "xv": jnp.zeros((L, B, enc_seq, H, hd), dtype),
    }


def decode_step(params, token, pos, cache, cfg):
    """One decoder token against self-cache + precomputed cross K/V."""
    B = token.shape[0]
    W = cache["k"].shape[2]
    H, hd = cfg.num_heads, cfg.head_dim
    x = params["embed"][token][:, None, :]
    x = x + sinusoidal_positions(pos[:, None], cfg.d_model).astype(x.dtype)
    pos_buf = cache["pos"]
    slot = (pos % W).astype(jnp.int32)
    bidx = jnp.arange(B)

    def body(carry, inp):
        x, pos_buf = carry
        p, ck, cv, xk, xv = inp
        # self-attention with rolling cache
        xi = layer_norm(x, p["ln1"]["w"], p["ln1"]["b"])
        q = (xi @ p["self"]["wq"]).reshape(B, 1, H, hd)
        k = (xi @ p["self"]["wk"]).reshape(B, 1, H, hd)
        v = (xi @ p["self"]["wv"]).reshape(B, 1, H, hd)
        ck = ck.at[bidx, slot].set(k[:, 0].astype(ck.dtype))
        cv = cv.at[bidx, slot].set(v[:, 0].astype(cv.dtype))
        new_pos = pos_buf.at[bidx, slot].set(pos.astype(jnp.int32))
        o = blocked_attention(q, ck, cv, causal=True,
                              q_positions=pos[:, None], k_positions=new_pos)
        x = x + o.reshape(B, 1, H * hd) @ p["self"]["wo"]
        # cross-attention against the exact cached K/V
        xi = layer_norm(x, p["ln2"]["w"], p["ln2"]["b"])
        q = (xi @ p["cross"]["wq"]).reshape(B, 1, H, hd)
        o = blocked_attention(q, xk, xv, causal=False)
        x = x + o.reshape(B, 1, H * hd) @ p["cross"]["wo"]
        x = x + mlp_forward(p["mlp"], layer_norm(x, p["ln3"]["w"], p["ln3"]["b"]))
        return (x, new_pos), (ck, cv)

    (x, new_pos), (ks, vs) = jax.lax.scan(
        body, (x, pos_buf),
        (params["dec_blocks"], cache["k"], cache["v"], cache["xk"], cache["xv"]))
    cache = dict(cache, k=ks, v=vs, pos=new_pos)
    x = layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"])
    return (x @ params["lm_head"])[:, 0], cache
