"""Model zoo: unified dispatch over the architecture families."""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from . import dit, encdec, layers, mla, moe, ssm, transformer, video_dit
from .transformer import decode_step, forward, init_cache, init_lm, prefill


def init_params(key, cfg, dtype=None):
    """Initialize any architecture in the zoo."""
    if cfg.is_dit:
        if cfg.dit_num_frames > 0:
            return video_dit.init_video_dit(key, cfg, dtype)
        return dit.init_dit(key, cfg, dtype)
    if cfg.is_encoder_decoder:
        return encdec.init_encdec(key, cfg, dtype)
    return transformer.init_lm(key, cfg, dtype)


def params_shape(cfg):
    """ShapeDtypeStruct pytree of the params — no allocation (eval_shape)."""
    return jax.eval_shape(partial(init_params, cfg=cfg), jax.random.PRNGKey(0))


def param_count(cfg) -> int:
    import math
    shapes = params_shape(cfg)
    return sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes))


def active_param_count(cfg) -> int:
    """Parameters touched per token (MoE: only routed-in experts) — the N in
    the survey-style MODEL_FLOPS = 6*N_active*D."""
    total = param_count(cfg)
    if not cfg.is_moe:
        return total
    # subtract the inactive routed experts
    per_expert = 3 * cfg.d_model * cfg.d_ff
    inactive = (cfg.num_experts - cfg.experts_per_token) * per_expert * cfg.num_layers
    return total - inactive


def perturb_zero_init(params, seed: int = 0, scale: float = 0.05):
    """Replace zero-initialized leaves (AdaLN-zero gates, patch_out) with
    small random values.  An untrained DiT with the published AdaLN-zero
    init outputs exactly 0, which makes cache-vs-exact comparisons trivial;
    examples/benchmarks on untrained weights perturb them first."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    key = jax.random.PRNGKey(seed + 1234)
    out = []
    for leaf in leaves:
        key, sub = jax.random.split(key)
        rnd = jax.random.normal(sub, leaf.shape, leaf.dtype) * scale
        out.append(jnp.where(jnp.all(leaf == 0), rnd, leaf))
    return jax.tree_util.tree_unflatten(treedef, out)
