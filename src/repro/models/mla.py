"""Multi-head Latent Attention (DeepSeek-V2).

The KV cache stores only the compressed latent c_kv (rank 512) plus the
shared RoPE key (64) — 576 floats/token instead of 2*H*128 = 32768: the
~57x cache compression that makes deepseek-v2 decode_32k / long_500k
storable (see EXPERIMENTS §Dry-run).

Decode uses the *absorbed* formulation: W_UK is folded into the query and
W_UV into the output so attention runs directly in the compressed space —
per-step FLOPs O(H*(nope+rank)) per cached token, never re-expanding K/V.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import apply_rope, blocked_attention, dense_init


def init_mla(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.num_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, H * (dn + dr), dtype),
        "w_dkv": dense_init(ks[1], d, r, dtype),
        "w_kr": dense_init(ks[2], d, dr, dtype),
        "w_uk": jax.random.normal(ks[3], (H, r, dn), dtype) / math.sqrt(r),
        "w_uv": jax.random.normal(ks[4], (H, r, dv), dtype) / math.sqrt(r),
        "wo": dense_init(ks[5], H * dv, d, dtype),
    }


def _project_q(p, x, cfg, positions):
    B, S, _ = x.shape
    H, dn, dr = cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(p, x, cfg, *, positions=None, window: int = 0):
    """Full-sequence MLA (train / prefill). Returns (out, (c_kv, k_rope))."""
    B, S, _ = x.shape
    H, dn, dr, dv = (cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                     cfg.v_head_dim)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q_nope, q_rope = _project_q(p, x, cfg, positions)
    c_kv = x @ p["w_dkv"]                                   # (B,S,r)
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]          # (B,S,dr)

    # expand keys/values for the full-sequence pass
    k_nope = jnp.einsum("bsr,hrd->bshd", c_kv, p["w_uk"])    # (B,S,H,dn)
    v = jnp.einsum("bsr,hrd->bshd", c_kv, p["w_uv"])         # (B,S,H,dv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    scale = 1.0 / math.sqrt(dn + dr)
    o = blocked_attention(q, k, v, causal=True, window=window,
                          q_positions=positions, k_positions=positions,
                          scale=scale)
    out = o.reshape(B, S, H * dv) @ p["wo"]
    return out, (c_kv, k_rope)


def mla_decode(p, x, cfg, cache_ckv, cache_kr, cache_pos, pos, *, window: int = 0):
    """Absorbed one-token decode.

    cache_ckv: (B, W, r); cache_kr: (B, W, dr); cache_pos: (B, W); pos: (B,).
    """
    B = x.shape[0]
    W = cache_ckv.shape[1]
    H, dn, dr, dv = (cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                     cfg.v_head_dim)
    q_nope, q_rope = _project_q(p, x, cfg, pos[:, None])     # (B,1,H,dn/dr)
    c_kv = x @ p["w_dkv"]                                    # (B,1,r)
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], pos[:, None],
                        cfg.rope_theta)[:, :, 0, :]           # (B,1,dr)

    slot = (pos % W).astype(jnp.int32)
    bidx = jnp.arange(B)
    cache_ckv = cache_ckv.at[bidx, slot].set(c_kv[:, 0].astype(cache_ckv.dtype))
    cache_kr = cache_kr.at[bidx, slot].set(k_rope[:, 0].astype(cache_kr.dtype))
    cache_pos = cache_pos.at[bidx, slot].set(pos.astype(jnp.int32))

    # absorbed scores: q_abs = q_nope @ W_UK^T  -> works on latents directly
    q_abs = jnp.einsum("bohd,hrd->bohr", q_nope, p["w_uk"])   # (B,1,H,r)
    q_abs = q_abs[:, 0].astype(jnp.float32)                   # (B,H,r)
    q_r = q_rope[:, 0].astype(jnp.float32)                    # (B,H,dr)
    scale = 1.0 / math.sqrt(dn + dr)

    # flash-decode style: walk the cache in chunks with an online softmax so
    # the (B,H,W) score tensor is never materialized (with H=128, W=32k,
    # B=128 it would be 2 TB global — see EXPERIMENTS §Dry-run)
    CHUNK = 4096
    nc = max(W // CHUNK, 1)
    Wc = W // nc
    ckv_c = cache_ckv.reshape(B, nc, Wc, -1).swapaxes(0, 1)
    kr_c = cache_kr.reshape(B, nc, Wc, -1).swapaxes(0, 1)
    pos_c = cache_pos.reshape(B, nc, Wc).swapaxes(0, 1)

    r = cache_ckv.shape[-1]
    init = (jnp.full((B, H), -1e30, jnp.float32),      # running max
            jnp.zeros((B, H), jnp.float32),            # running denom
            jnp.zeros((B, H, r), jnp.float32))         # running ctx acc

    def chunk_step(carry, inp):
        m, l, acc = carry
        ckv, kr, kpos = inp                            # (B,Wc,r/dr/·)
        # keep the cache in bf16 and accumulate in f32 via the dot's
        # preferred_element_type: an .astype(f32) here would be hoisted by
        # XLA into an f32 copy of the ENTIRE stacked cache (measured 2 GB/
        # device on deepseek decode_32k — EXPERIMENTS §Perf)
        s = jnp.einsum("bhr,bwr->bhw", q_abs.astype(ckv.dtype), ckv,
                       preferred_element_type=jnp.float32)
        s = s + jnp.einsum("bhd,bwd->bhw", q_r.astype(kr.dtype), kr,
                           preferred_element_type=jnp.float32)
        s = s * scale
        ok = (kpos[:, None, :] <= pos[:, None, None]) & (kpos[:, None, :] >= 0)
        if window > 0:
            ok = ok & (pos[:, None, None] - kpos[:, None, :] < window)
        s = jnp.where(ok, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        pcs = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(pcs, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhw,bwr->bhr", pcs.astype(ckv.dtype), ckv,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(chunk_step, init, (ckv_c, kr_c, pos_c))
    ctx = acc / jnp.maximum(l, 1e-30)[..., None]               # (B,H,r)
    o = jnp.einsum("bhr,hrd->bhd", ctx.astype(p["w_uv"].dtype), p["w_uv"],
                   preferred_element_type=jnp.float32)
    out = o.reshape(B, 1, H * dv).astype(x.dtype) @ p["wo"]
    return out, cache_ckv, cache_kr, cache_pos
