"""Mixture-of-Experts FFN: dense dispatch (small scale) + expert-parallel
scatter dispatch (production scale).

Two dispatch paths with identical routing semantics:

  * moe_forward     — dense one-hot dispatch/combine einsums.  MXU-friendly
    and exactly testable, but materializes a (T, E, capacity) routing tensor
    whose size grows ~T^2: perfect for <=8-expert smoke configs, prohibitive
    at 128-160 experts x 131k tokens (would be >100 TB — EXPERIMENTS §Perf).
  * moe_forward_ep  — production path: `shard_map` over the mesh, tokens
    scatter-added into per-expert capacity buffers with *local* capacity,
    `lax.all_to_all` over the expert(=data) axis to the owning shards,
    expert FFN tensor-sharded over the inner axes, all_to_all back, gather
    combine.  O(T*k*d) memory, no (T,E,cap) tensor.  This is the GShard/
    DeepSpeed-MoE schedule with EP sharing the DP axis.

Both support: top-k routing with renormalized gates + load-balance & z
losses, shared (always-on) experts (DeepSeek-V2), and a parallel dense
residual FFN branch (Arctic).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import dense_init, mlp_forward


def init_moe(key, cfg, dtype=jnp.float32):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 8)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),  # router kept fp32
        "w_gate": jax.random.normal(ks[1], (E, d, ff), dtype) / math.sqrt(d),
        "w_up": jax.random.normal(ks[2], (E, d, ff), dtype) / math.sqrt(d),
        "w_down": jax.random.normal(ks[3], (E, ff, d), dtype) / math.sqrt(ff),
    }
    if cfg.num_shared_experts:
        sf = ff * cfg.num_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks[4], d, sf, dtype),
            "w_up": dense_init(ks[5], d, sf, dtype),
            "w_down": dense_init(ks[6], sf, d, dtype),
        }
    if cfg.moe_dense_residual:
        from .layers import init_mlp
        p["dense_res"] = init_mlp(ks[7], d, cfg.dense_ff, dtype)
    return p


def moe_forward(p, x, cfg) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, d) -> (y, aux) with aux = {load_balance_loss, router_z_loss}."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, d)

    logits = xt.astype(jnp.float32) @ p["router"]            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (T, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # capacity per expert
    cap = max(int(math.ceil(T * k / E * cfg.capacity_factor)), 1)

    # (T, k, E) one-hot of chosen experts
    sel = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)
    # position of each (token, choice) within its expert queue
    # flatten choices in priority order: all k=0 choices first
    sel_f = sel.transpose(1, 0, 2).reshape(k * T, E)          # (kT, E)
    pos_f = jnp.cumsum(sel_f, axis=0) - sel_f                 # (kT, E)
    pos = pos_f.reshape(k, T, E).transpose(1, 0, 2)           # (T, k, E)
    keep = (pos < cap) * sel                                  # dropped past capacity
    pos = jnp.sum(pos * keep, axis=-1).astype(jnp.int32)      # (T, k)

    # dispatch tensor (T, E, cap)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)      # (T, k, cap)
    disp = jnp.einsum("tke,tkc->tec", keep, pos_oh)           # (T, E, cap)
    comb = jnp.einsum("tke,tk,tkc->tec", keep, gate_vals, pos_oh)

    exp_in = jnp.einsum("tec,td->ecd", disp.astype(x.dtype), xt)   # (E, cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", exp_in, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", exp_in, p["w_up"])
    exp_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])           # (E, cap, d)
    y = jnp.einsum("tec,ecd->td", comb.astype(x.dtype), exp_out)   # (T, d)

    if "shared" in p:
        y = y + mlp_forward(p["shared"], xt)
    if "dense_res" in p:
        y = y + mlp_forward(p["dense_res"], xt)

    # aux losses (Switch-style)
    frac_tokens = jnp.mean(sel.sum(1), axis=0)                # (E,) f_i
    frac_probs = jnp.mean(probs, axis=0)                      # (E,) p_i
    lb_loss = E * jnp.sum(frac_tokens * frac_probs)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"load_balance_loss": lb_loss, "router_z_loss": z_loss}
    return y.reshape(B, S, d), aux


# ======================================================================
# expert-parallel production path
# ======================================================================

def _route(logits, k):
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)
    return probs, gate_vals, gate_idx


def _queue_positions(gate_idx, E):
    """Position of each (token, choice) within its expert's queue — cumsum
    over a (T*k, E) one-hot, priority order = all first choices first."""
    T, k = gate_idx.shape
    sel = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)       # (T, k, E)
    sel_f = sel.transpose(1, 0, 2).reshape(k * T, E)
    pos_f = jnp.cumsum(sel_f, axis=0) - sel_f
    pos = pos_f.reshape(k, T, E).transpose(1, 0, 2)
    pos = jnp.sum(pos * sel, axis=-1).astype(jnp.int32)        # (T, k)
    return pos, sel


def _ep_body(x, router, w_gate, w_up, w_down, *, cfg, ep_axis, inner_axes,
             batch_ax):
    """Per-shard body under shard_map.

    x: (B_loc, S, d) local tokens (replicated over the inner axes);
    w_*: (E_loc, d, ff_loc) local expert shards.  Returns (y_loc, lb, z)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    ep = (jax.lax.axis_size(ep_axis) if hasattr(jax.lax, "axis_size")
          else jax.lax.psum(1, ep_axis))
    T = B * S
    xt = x.reshape(T, d)

    logits = xt.astype(jnp.float32) @ router                    # (T, E)
    probs, gate_vals, gate_idx = _route(logits, k)
    cap = max(int(math.ceil(T * k / E * cfg.capacity_factor)), 1)

    pos, sel = _queue_positions(gate_idx, E)
    keep = pos < cap                                            # (T, k) bool
    flat_idx = jnp.where(keep, gate_idx * cap + pos, E * cap)   # drop slot

    # scatter dispatch into (E*cap + 1, d); the +1 row swallows drops
    buf = jnp.zeros((E * cap + 1, d), x.dtype)
    for i in range(k):
        buf = buf.at[flat_idx[:, i]].add(xt)
    buf = buf[:E * cap].reshape(E, cap, d)

    # all-to-all: send each expert's queue to its owning shard
    # (E, cap, d) -> (E/ep, ep*cap, d)
    buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=1,
                             tiled=True)

    # expert FFN, ff sharded over the inner axes -> psum completes d
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * \
        jnp.einsum("ecd,edf->ecf", buf, w_up)
    out = jnp.einsum("ecf,efd->ecd", h, w_down)
    if inner_axes:
        out = jax.lax.psum(out, inner_axes)

    # return the computed queues to the token shards
    out = jax.lax.all_to_all(out, ep_axis, split_axis=1, concat_axis=0,
                             tiled=True)                        # (E, cap, d)
    out = jnp.concatenate(
        [out.reshape(E * cap, d), jnp.zeros((1, d), out.dtype)], axis=0)

    y = jnp.zeros((T, d), x.dtype)
    for i in range(k):
        contrib = out[flat_idx[:, i]] * gate_vals[:, i, None].astype(out.dtype)
        y = y + jnp.where(keep[:, i, None], contrib, 0.0).astype(x.dtype)

    # aux losses need GLOBAL token fractions: pmean f_i and p_i over the
    # batch shards BEFORE the (nonlinear) product — local-then-average
    # differs whenever shards are imbalanced
    frac_tokens = jax.lax.pmean(jnp.mean(sel.sum(1), axis=0), batch_ax)
    frac_probs = jax.lax.pmean(jnp.mean(probs, axis=0), batch_ax)
    lb = E * jnp.sum(frac_tokens * frac_probs)
    z = jax.lax.pmean(jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
                      batch_ax)
    return y.reshape(B, S, d), lb, z


def moe_forward_ep(p, x, cfg, *, mesh, batch_ax=("data",), ep_axis="data",
                   inner_axes=("attn", "ffn")) -> Tuple[jnp.ndarray, dict]:
    """Expert-parallel MoE layer (see module docstring).

    Shared experts / the dense residual run at pjit level (plain
    tensor-parallel MLPs over all tokens); only routed experts enter the
    shard_map."""
    inner_axes = tuple(a for a in inner_axes if a in mesh.axis_names
                       and mesh.shape[a] > 1)
    rep_axes = tuple(a for a in mesh.axis_names
                     if a not in (ep_axis,) + tuple(batch_ax))

    body = partial(_ep_body, cfg=cfg, ep_axis=ep_axis,
                   inner_axes=inner_axes, batch_ax=batch_ax)

    ff_spec = P(ep_axis, None, inner_axes or None)
    down_spec = P(ep_axis, inner_axes or None, None)
    x_spec = P(batch_ax, None, None)

    if hasattr(jax, "shard_map"):               # jax >= 0.6
        smap, relax = jax.shard_map, {"check_vma": False}
    else:                                       # jax 0.4.x
        from jax.experimental.shard_map import shard_map as smap
        relax = {"check_rep": False}
    y, lb, z = smap(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), ff_spec, ff_spec, down_spec),
        out_specs=(x_spec, P(), P()),
        **relax,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if "shared" in p:
        y = y + mlp_forward(p["shared"], x)
    if "dense_res" in p:
        y = y + mlp_forward(p["dense_res"], x)
    aux = {"load_balance_loss": lb, "router_z_loss": z}
    return y, aux
