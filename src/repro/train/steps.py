"""Loss functions and jit-able train steps for both workload kinds.

`make_*_train_step` returns a pure (state, batch) -> (state, metrics)
function suitable for `jax.jit` / `pjit` with shardings; gradient
accumulation splits the batch into microbatches inside one step via
`lax.scan` (constant memory in accumulation factor).
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import init_params
from repro.models import transformer, dit
from repro.optim import (AdamWState, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_warmup_schedule)

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: AdamWState


def init_train_state(key, cfg, dtype=None) -> TrainState:
    params = init_params(key, cfg, dtype)
    return TrainState(params=params, opt=adamw_init(params))


# ----------------------------------------------------------------------
# losses
# ----------------------------------------------------------------------

def lm_loss(params, tokens, targets, cfg, *, vision_embeds=None,
            aux_weight: float = 0.01, z_weight: float = 1e-3):
    """Causal-LM cross-entropy (+ MoE aux losses when applicable)."""
    logits, aux = transformer.forward(params, tokens, cfg,
                                      vision_embeds=vision_embeds)
    if cfg.family == "vlm":           # vision tokens carry no LM targets
        logits = logits[:, cfg.num_vision_tokens:]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = nll.mean()
    total = (loss + aux_weight * aux["load_balance_loss"]
             + z_weight * aux["router_z_loss"])
    return total, {"loss": loss, "lb_loss": aux["load_balance_loss"],
                   "z_loss": aux["router_z_loss"]}


def diffusion_loss(params, latents, labels, cfg, sched, key):
    """DDPM eps-prediction MSE (survey Eq. 8)."""
    B = latents.shape[0]
    kt, ke, kd = jax.random.split(key, 3)
    t = jax.random.randint(kt, (B,), 0, sched.T)
    eps = jax.random.normal(ke, latents.shape, latents.dtype)
    x_t = sched.q_sample(latents, t, eps)
    # classifier-free guidance training: drop the label 10% of the time
    drop = jax.random.bernoulli(kd, 0.1, (B,))
    y = jnp.where(drop, cfg.dit_num_classes, labels)
    eps_hat = dit.forward(params, x_t.astype(jnp.dtype(cfg.dtype)),
                          t.astype(jnp.float32), y, cfg)
    loss = jnp.mean(jnp.square(eps_hat.astype(jnp.float32) - eps))
    return loss, {"loss": loss}


# ----------------------------------------------------------------------
# train steps (with optional gradient accumulation)
# ----------------------------------------------------------------------

def _accumulated_grads(loss_fn, params, batch, accum: int):
    """Mean grads/metrics over `accum` microbatches via lax.scan."""
    if accum <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return grads, metrics

    micro = jax.tree_util.tree_map(
        lambda a: a.reshape((accum, a.shape[0] // accum) + a.shape[1:]), batch)

    def body(carry, mb):
        g_acc, m_acc = carry
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
        m_acc = jax.tree_util.tree_map(jnp.add, m_acc, metrics)
        return (g_acc, m_acc), None

    zeros_g = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    zeros_m = None
    # one dry eval_shape to build the metric zeros
    metric_shape = jax.eval_shape(
        lambda p, b: loss_fn(p, b)[1], params,
        jax.tree_util.tree_map(lambda a: a[0], micro))
    zeros_m = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), metric_shape)
    (g, m), _ = jax.lax.scan(body, (zeros_g, zeros_m), micro)
    inv = 1.0 / accum
    return (jax.tree_util.tree_map(lambda a: a * inv, g),
            jax.tree_util.tree_map(lambda a: a * inv, m))


def make_lm_train_step(cfg, *, peak_lr=3e-4, warmup=100, total_steps=10_000,
                       accum: int = 1, max_grad_norm: float = 1.0,
                       weight_decay: float = 0.1):
    def loss_fn(params, batch):
        tokens, targets = batch["tokens"], batch["targets"]
        return lm_loss(params, tokens, targets, cfg,
                       vision_embeds=batch.get("vision_embeds"))

    def step(state: TrainState, batch) -> tuple:
        grads, metrics = _accumulated_grads(loss_fn, state.params, batch, accum)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = cosine_warmup_schedule(state.opt.step, peak_lr=peak_lr,
                                    warmup_steps=warmup, total_steps=total_steps)
        params, opt = adamw_update(grads, state.opt, state.params, lr=lr,
                                   weight_decay=weight_decay)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return TrainState(params, opt), metrics

    return step


def make_diffusion_train_step(cfg, sched, *, peak_lr=1e-4, warmup=100,
                              total_steps=10_000, accum: int = 1,
                              max_grad_norm: float = 1.0):
    def step(state: TrainState, batch):
        def loss_fn(params, b):
            return diffusion_loss(params, b["latents"], b["labels"], cfg,
                                  sched, b["key"])

        grads, metrics = _accumulated_grads(loss_fn, state.params, batch, accum)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = cosine_warmup_schedule(state.opt.step, peak_lr=peak_lr,
                                    warmup_steps=warmup, total_steps=total_steps)
        params, opt = adamw_update(grads, state.opt, state.params, lr=lr,
                                   weight_decay=0.0)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return TrainState(params, opt), metrics

    return step
