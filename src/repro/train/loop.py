"""Generic training loop: jit, periodic logging, periodic checkpointing."""
from __future__ import annotations

import time
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from repro import checkpoint as ckpt_lib


def train_loop(step_fn: Callable, state, batches: Iterator, num_steps: int, *,
               log_every: int = 10, ckpt_dir: Optional[str] = None,
               ckpt_every: int = 500, log_fn=print, jit: bool = True,
               donate: bool = True, verify_donation: bool = False):
    """Run `num_steps` of `step_fn(state, batch) -> (state, metrics)`.

    `verify_donation=True` checks, on the first batch, that every leaf of
    the donated state actually aliases an output in the lowered program
    (repro.analysis.ir) — donate_argnums that fails to alias silently
    no-ops and doubles peak memory.  Raises ValueError when it does.

    Returns (final state, list of metric dicts)."""
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0,) if donate else ())
    history = []
    t0 = time.perf_counter()
    for i, batch in enumerate(batches):
        if i >= num_steps:
            break
        if i == 0 and jit and donate and verify_donation:
            from repro.analysis.ir import check_donation
            issue = check_donation(
                step_fn.lower(state, batch).as_text(),
                len(jax.tree_util.tree_leaves(state)),
                "train_loop step_fn donate_argnums=(0,)")
            if issue is not None:
                raise ValueError(issue.message)
        state, metrics = step_fn(state, batch)
        if (i + 1) % log_every == 0 or i == 0:
            metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            metrics["steps_per_s"] = (i + 1) / dt
            history.append({"step": i + 1, **metrics})
            log_fn(f"step {i+1:5d}  " + "  ".join(
                f"{k}={v:.4g}" for k, v in metrics.items()))
        if ckpt_dir and (i + 1) % ckpt_every == 0:
            ckpt_lib.save(ckpt_dir, i + 1, state)
    return state, history
