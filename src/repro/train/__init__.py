"""Training substrate: losses, step functions, the loop."""
from .steps import (diffusion_loss, lm_loss, make_diffusion_train_step,
                    make_lm_train_step, TrainState)
from .loop import train_loop

__all__ = ["lm_loss", "diffusion_loss", "make_lm_train_step",
           "make_diffusion_train_step", "TrainState", "train_loop"]
