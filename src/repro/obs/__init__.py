"""repro.obs — unified tracing, metrics, and program profiling.

The serving stack grew three half-observability mechanisms — aggregate
ServingTelemetry counters, ServeSession TickEvent hooks, and the control
plane's TelemetryWindow.  This package unifies them behind one
instrumentation surface and adds what none of them provided:

  clock      — the one monotonic clock helper (`monotonic()`); every wall
               time measured under serving/ and modalities/ goes through
               it (repro.analysis' clock-discipline rule lints this in CI)
  trace      — TraceRecorder: TickEvents -> Chrome/Perfetto trace (per
               sub-pool tracks, plan/backbone phases, per-slot cache
               lifecycle spans annotated with signal vs threshold) + a
               cache-event JSONL that rebuilds a SignalTraceLog from disk
  metrics    — MetricsRegistry: labelled counters / gauges / histograms,
               Prometheus text exposition + JSON snapshots, an event ring
               for discrete occurrences (policy swaps, retunes)
  profiling  — per-program compile time + XLA cost analysis captured by
               engine.warmup(), the measured redundancy ratio
               (FLOPs avoided / dense FLOPs), opt-in jax.profiler traces

Metric naming convention
------------------------
All metric names follow  `repro_<subsystem>_<metric>_<unit>`:

  * `<subsystem>`: `engine` (tick paths), `scheduler` (admission),
    `serving` (telemetry views), `window` (sliding-window views),
    `control` (tuner/plane), `autotune` (pricing).
  * `<metric>`: snake_case noun phrase (`ticks`, `rows_computed`,
    `plan_seconds`, `queue_depth`).
  * `<unit>` suffix where the value has one: `_seconds`, `_ms`, `_bytes`,
    `_rows`; monotonic counters additionally end in `_total`
    (Prometheus convention), e.g. `repro_engine_rows_computed_total`.
  * Labels carry dimensions, never name suffixes: `{modality="video",
    kind="full"}`, not `repro_engine_ticks_video_full`.

Instrumentation is strictly opt-in: no registry is consulted unless one
is passed (`ServeSession(..., metrics=...)`, `OnlineTuner(registry=...)`),
so hooks-off serving pays nothing.
"""
from .clock import monotonic, monotonic_ns, wall
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      default_registry)
from .profiling import (ProgramIR, ProgramProfile, capture_ir,
                        compile_program, flops_per_row, profiler_trace,
                        program_cost, redundancy_ratio)
from .trace import (TraceRecorder, load_cache_events, load_probes,
                    policy_signature, signal_trace_from_files,
                    validate_chrome_trace)

__all__ = [
    "monotonic", "monotonic_ns", "wall",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "default_registry",
    "ProgramIR", "ProgramProfile", "capture_ir", "compile_program",
    "flops_per_row", "profiler_trace", "program_cost", "redundancy_ratio",
    "TraceRecorder", "load_cache_events", "load_probes", "policy_signature",
    "signal_trace_from_files", "validate_chrome_trace",
]
