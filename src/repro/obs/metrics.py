"""Counter / gauge / histogram registry with label support.

One process-wide registry (or as many private ones as tests want) that the
serving stack's instrumentation points publish into:

  * engine tick paths (ticks, backbone rows, plan/device seconds — via
    `ServeSession(..., metrics=registry)`),
  * scheduler admission (admitted requests, queue depth),
  * the control plane (retune pricings, blue/green swaps as events),
  * one-shot views: `ServingTelemetry.publish()` and
    `TelemetryWindow.publish()` export their aggregates as gauges so the
    pre-existing bookkeeping surfaces through the same exporters instead
    of growing a third format.

Exporters: `prometheus_text()` (text exposition format, scrapeable) and
`snapshot()` (JSON-able dict, for benchmark payloads and tests).  Discrete
occurrences that don't aggregate well (a policy swap, a retune decision)
go through `event()` into a bounded ring included in the snapshot.

Metric names follow the repo convention `repro_<subsystem>_<metric>_<unit>`
(see repro.obs.__doc__).  All instruments are host-side dicts — O(1) per
update, safe to leave enabled in hot paths (the bench_serving smoke run
bounds recorder+metrics overhead at <= 5% req/s).
"""
from __future__ import annotations

import json
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Iterable, List, Optional, Tuple

from .clock import wall

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "default_registry"]

#: label sets are stored as sorted (key, value) tuples — hashable, ordered
LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(key: LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


@dataclass
class Counter:
    """Monotonically increasing value per label set."""
    name: str
    help: str = ""
    values: Dict[LabelKey, float] = field(default_factory=dict)

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        k = _label_key(labels)
        self.values[k] = self.values.get(k, 0.0) + amount

    def value(self, **labels: str) -> float:
        return self.values.get(_label_key(labels), 0.0)


@dataclass
class Gauge:
    """Point-in-time value per label set (set/add, may go down)."""
    name: str
    help: str = ""
    values: Dict[LabelKey, float] = field(default_factory=dict)

    def set(self, value: float, **labels: str) -> None:
        self.values[_label_key(labels)] = float(value)

    def add(self, amount: float, **labels: str) -> None:
        k = _label_key(labels)
        self.values[k] = self.values.get(k, 0.0) + amount

    def value(self, **labels: str) -> float:
        return self.values.get(_label_key(labels), 0.0)


#: default histogram buckets: tick/plan latencies in seconds, 100us..10s
DEFAULT_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0,
                   3.0, 10.0)


@dataclass
class Histogram:
    """Cumulative-bucket histogram per label set (Prometheus semantics:
    bucket counts are cumulative, +Inf bucket == total count)."""
    name: str
    help: str = ""
    buckets: Tuple[float, ...] = DEFAULT_BUCKETS
    # per label set: (bucket counts incl +Inf, sum, count)
    values: Dict[LabelKey, List] = field(default_factory=dict)

    def observe(self, value: float, **labels: str) -> None:
        k = _label_key(labels)
        slot = self.values.get(k)
        if slot is None:
            slot = self.values[k] = [[0] * (len(self.buckets) + 1), 0.0, 0]
        counts, _, _ = slot
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                counts[i] += 1
        counts[-1] += 1
        slot[1] += float(value)
        slot[2] += 1

    def count(self, **labels: str) -> int:
        slot = self.values.get(_label_key(labels))
        return slot[2] if slot else 0

    def sum(self, **labels: str) -> float:
        slot = self.values.get(_label_key(labels))
        return slot[1] if slot else 0.0

    def mean(self, **labels: str) -> float:
        slot = self.values.get(_label_key(labels))
        return slot[1] / slot[2] if slot and slot[2] else math.nan


class MetricsRegistry:
    """Get-or-create instrument registry + exporters + event ring."""

    def __init__(self, max_events: int = 256):
        self._instruments: Dict[str, object] = {}
        self.events: Deque[Dict] = deque(maxlen=max_events)
        self.events_seen = 0

    # -- instruments ---------------------------------------------------
    def _get(self, cls, name: str, help: str, **kw):
        inst = self._instruments.get(name)
        if inst is None:
            inst = self._instruments[name] = cls(name, help, **kw)
        elif not isinstance(inst, cls):
            raise TypeError(f"metric '{name}' already registered as "
                            f"{type(inst).__name__}, not {cls.__name__}")
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Iterable[float]] = None) -> Histogram:
        kw = {"buckets": tuple(buckets)} if buckets is not None else {}
        return self._get(Histogram, name, help, **kw)

    # -- events --------------------------------------------------------
    def event(self, name: str, **fields) -> None:
        """Record one discrete occurrence (a policy swap, a retune) in the
        bounded event ring — snapshot-visible, not Prometheus-exported."""
        self.events.append({"time": wall(), "event": name, **fields})
        self.events_seen += 1

    # -- exporters -----------------------------------------------------
    def prometheus_text(self) -> str:
        """Prometheus text exposition format (one scrape body)."""
        lines: List[str] = []
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            if inst.help:
                lines.append(f"# HELP {name} {inst.help}")
            if isinstance(inst, Counter):
                lines.append(f"# TYPE {name} counter")
                for k in sorted(inst.values):
                    lines.append(f"{name}{_fmt_labels(k)} {inst.values[k]:g}")
            elif isinstance(inst, Gauge):
                lines.append(f"# TYPE {name} gauge")
                for k in sorted(inst.values):
                    lines.append(f"{name}{_fmt_labels(k)} {inst.values[k]:g}")
            elif isinstance(inst, Histogram):
                lines.append(f"# TYPE {name} histogram")
                for k in sorted(inst.values):
                    counts, total, n = inst.values[k]
                    for ub, c in zip(inst.buckets, counts):
                        lk = _fmt_labels(k + (("le", f"{ub:g}"),))
                        lines.append(f"{name}_bucket{lk} {c}")
                    lk = _fmt_labels(k + (("le", "+Inf"),))
                    lines.append(f"{name}_bucket{lk} {counts[-1]}")
                    lines.append(f"{name}_sum{_fmt_labels(k)} {total:g}")
                    lines.append(f"{name}_count{_fmt_labels(k)} {n}")
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> Dict:
        """JSON-able dump of every instrument + the event ring."""
        out: Dict = {"metrics": {}, "events": list(self.events),
                     "events_seen": self.events_seen}
        for name, inst in sorted(self._instruments.items()):
            if isinstance(inst, (Counter, Gauge)):
                out["metrics"][name] = {
                    "type": type(inst).__name__.lower(), "help": inst.help,
                    "values": [{"labels": dict(k), "value": v}
                               for k, v in sorted(inst.values.items())]}
            else:
                out["metrics"][name] = {
                    "type": "histogram", "help": inst.help,
                    "buckets": list(inst.buckets),
                    "values": [{"labels": dict(k), "bucket_counts": v[0],
                                "sum": v[1], "count": v[2]}
                               for k, v in sorted(inst.values.items())]}
        return out

    def write_snapshot(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, default=float)

    def write_prometheus(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.prometheus_text())


_DEFAULT: Optional[MetricsRegistry] = None


def default_registry() -> MetricsRegistry:
    """The process-wide registry (lazily created).  Instrumentation points
    never publish here implicitly — callers opt in by passing it around —
    so hooks-off serving stays zero-overhead."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = MetricsRegistry()
    return _DEFAULT
