"""The one host-side clock for the serving stack.

Every wall-time measurement in `repro.serving` and `repro.modalities` —
engine tick device seconds, TickEvent plan_seconds, TelemetryWindow
statistics, benchmark harness timings — must come from this module, not
from ad-hoc `time.time()` / `time.perf_counter()` calls (the CI lint's
clock-discipline rule, repro.analysis, enforces this for serving/ and
modalities/).

Why one helper instead of "everyone calls perf_counter":

  * mixing `time.time()` (wall, NTP-steppable, ~ms resolution on some
    hosts) with `time.perf_counter()` (monotonic, ns resolution) in one
    subtraction silently produces garbage durations;
  * trace tooling needs every span on ONE monotonic axis — the Chrome
    trace exporter (repro.obs.trace) timestamps events with this clock,
    so engine timings and recorder spans line up without conversion;
  * tests can monkeypatch a single symbol to make timing deterministic.

`monotonic()` is the measurement clock (seconds, arbitrary epoch, never
steps backwards).  `wall()` is for human-facing timestamps only (log
lines, file names) and must never be subtracted from `monotonic()`.
"""
from __future__ import annotations

import time

__all__ = ["monotonic", "monotonic_ns", "wall"]


def monotonic() -> float:
    """Monotonic seconds (arbitrary epoch) — use for ALL duration math."""
    return time.perf_counter()


def monotonic_ns() -> int:
    """Monotonic nanoseconds — for exporters that want integer ticks."""
    return time.perf_counter_ns()


def wall() -> float:
    """Wall-clock epoch seconds — human-facing timestamps ONLY (subject to
    NTP steps; never mix with `monotonic()` in a subtraction)."""
    return time.time()
