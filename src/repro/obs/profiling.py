"""Program profiling: compile-time capture, XLA cost analysis, profiler
trace contexts.

The survey's redundancy claim — caching works because consecutive steps
recompute nearly identical activations — is usually reported in *rows* or
*steps* saved.  This module turns it into FLOPs: `engine.warmup()` AOT-
compiles each bucket-size tick program through `compile_program`, keeping
per-program compile seconds and the XLA cost model's FLOPs / bytes, and
`redundancy_ratio` combines those with telemetry row counters into the
measured ratio  (theoretical FLOPs avoided) / (dense FLOPs) — what the
cache ACTUALLY saved of the compute a dense pool would have run.

`profiler_trace` is the opt-in `jax.profiler` context for benchmark runs
(`bench_serving --profile-dir ...`): a no-op unless a directory is given,
so nothing ships a profiler dependency into the hot path.
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Dict, Optional

from .clock import monotonic

__all__ = ["ProgramProfile", "compile_program", "program_cost",
           "flops_per_row", "redundancy_ratio", "profiler_trace"]


@dataclass(frozen=True)
class ProgramProfile:
    """One compiled program's cost card (engine.warmup fills one per
    bucket size / dense tick kind)."""
    key: object                 # bucket size (int) or tick kind (str)
    compile_seconds: float
    flops: float                # XLA cost model; nan when unavailable
    bytes_accessed: float       # XLA cost model; nan when unavailable

    def as_dict(self) -> Dict:
        return {"key": self.key, "compile_seconds": self.compile_seconds,
                "flops": self.flops, "bytes_accessed": self.bytes_accessed}


def program_cost(compiled) -> Dict[str, float]:
    """FLOPs / bytes from a compiled executable's XLA cost analysis.

    `cost_analysis()` returns a per-device list on some backends and a
    bare dict on others, and may be unimplemented entirely (some Pallas
    lowerings) — normalize to {"flops", "bytes_accessed"} with nan for
    anything the backend would not report."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {"flops": math.nan, "bytes_accessed": math.nan}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return {"flops": math.nan, "bytes_accessed": math.nan}
    return {"flops": float(ca.get("flops", math.nan)),
            "bytes_accessed": float(ca.get("bytes accessed", math.nan))}


def compile_program(jitted, *args, key=None, **kwargs):
    """AOT-compile a jit'd function on example args.

    Returns (compiled, ProgramProfile).  The compiled executable is
    directly callable with matching-shape args — the engine swaps it into
    its tick-program cache so warmup's compile is never paid twice — and
    its cost analysis prices the program in FLOPs/bytes."""
    t0 = monotonic()
    compiled = jitted.lower(*args, **kwargs).compile()
    dt = monotonic() - t0
    cost = program_cost(compiled)
    return compiled, ProgramProfile(key=key, compile_seconds=dt,
                                    flops=cost["flops"],
                                    bytes_accessed=cost["bytes_accessed"])


def flops_per_row(profiles: Dict) -> float:
    """Marginal backbone FLOPs per gathered row, from the per-bucket
    program profiles: (flops[largest bucket] - flops[skip]) / bucket.
    Subtracting the bucket-0 (skip) program removes the per-slot policy /
    DDIM arithmetic every tick pays regardless of rows; nan when the
    profiles are missing or costless (backend without a cost model)."""
    buckets = sorted(k for k in profiles if isinstance(k, int) and k > 0)
    if not buckets:
        return math.nan
    largest = buckets[-1]
    base = profiles.get(0)
    f_base = base.flops if base is not None and not math.isnan(
        base.flops) else 0.0
    f_top = profiles[largest].flops
    if math.isnan(f_top):
        return math.nan
    return max(f_top - f_base, 0.0) / largest


def redundancy_ratio(profiles: Dict, rows_computed: int, rows_padding: int,
                     rows_saved: int) -> Dict[str, float]:
    """The survey's redundancy ratio, measured: theoretical FLOPs avoided
    over the FLOPs a dense (no-cache, whole-pool) serving run would have
    dispatched for the same traffic.

    rows_* come straight from ServingTelemetry (backbone_rows_computed /
    _padding / _saved).  Padding rows DO run through the backbone, so they
    count against the saving — the ratio prices the pow-2 bucket waste
    honestly."""
    fpr = flops_per_row(profiles)
    dispatched = rows_computed + rows_padding
    dense = dispatched + rows_saved
    avoided = rows_saved - rows_padding  # padding burns part of the saving
    if math.isnan(fpr) or dense <= 0:
        return {"flops_per_row": fpr, "dense_flops": math.nan,
                "flops_avoided": math.nan, "redundancy_ratio": math.nan}
    return {"flops_per_row": fpr,
            "dense_flops": fpr * (rows_computed + rows_saved),
            "flops_avoided": fpr * avoided,
            "redundancy_ratio": avoided / (rows_computed + rows_saved)}


@contextmanager
def profiler_trace(log_dir: Optional[str] = None):
    """Opt-in `jax.profiler.trace` context: profiles the enclosed block
    into `log_dir` (TensorBoard / Perfetto-loadable) when a directory is
    given, and is a strict no-op otherwise — benchmarks wrap their timed
    sections in this unconditionally."""
    if not log_dir:
        yield
        return
    import jax
    with jax.profiler.trace(log_dir):
        yield
