"""Program profiling: compile-time capture, XLA cost analysis, profiler
trace contexts.

The survey's redundancy claim — caching works because consecutive steps
recompute nearly identical activations — is usually reported in *rows* or
*steps* saved.  This module turns it into FLOPs: `engine.warmup()` AOT-
compiles each bucket-size tick program through `compile_program`, keeping
per-program compile seconds and the XLA cost model's FLOPs / bytes, and
`redundancy_ratio` combines those with telemetry row counters into the
measured ratio  (theoretical FLOPs avoided) / (dense FLOPs) — what the
cache ACTUALLY saved of the compute a dense pool would have run.

`profiler_trace` is the opt-in `jax.profiler` context for benchmark runs
(`bench_serving --profile-dir ...`): a no-op unless a directory is given,
so nothing ships a profiler dependency into the hot path.
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from .clock import monotonic

__all__ = ["ProgramIR", "ProgramProfile", "capture_ir", "compile_program",
           "program_cost", "flops_per_row", "redundancy_ratio",
           "profiler_trace"]


@dataclass(frozen=True)
class ProgramProfile:
    """One compiled program's cost card (engine.warmup fills one per
    bucket size / dense tick kind)."""
    key: object                 # bucket size (int) or tick kind (str)
    compile_seconds: float
    flops: float                # XLA cost model; nan when unavailable
    bytes_accessed: float       # XLA cost model; nan when unavailable
    #: repro.analysis.ir findings attached by engine.warmup(verify=True);
    #: empty means verified-clean OR not verified — check engine.ir_findings
    #: (None = never verified) to tell the two apart
    ir_findings: Tuple = ()

    def as_dict(self) -> Dict:
        d = {"key": self.key, "compile_seconds": self.compile_seconds,
             "flops": self.flops, "bytes_accessed": self.bytes_accessed}
        if self.ir_findings:
            d["ir_findings"] = [
                f.to_dict() if hasattr(f, "to_dict") else str(f)
                for f in self.ir_findings]
        return d


@dataclass(frozen=True)
class ProgramIR:
    """The inspectable intermediate representations of one jit program,
    captured at trace/lower time (a `Compiled` executable no longer
    carries its jaxpr, so engines capture this during warmup).

    `jaxpr` is the ClosedJaxpr — closed-over arrays (model params, any
    accidentally baked table) appear as `.consts`.  `lowered_text` is the
    StableHLO module as text; donated-and-actually-aliased arguments carry
    a `tf.aliasing_output` attribute there, which is what the ir-donation
    check keys on.  `declared_const_specs` is the (shape, dtype-name)
    multiset of consts the owner *intends* to close over (an engine's
    model param leaves); anything else above the bloat threshold is a
    closure-capture leak."""
    key: object
    jaxpr: object                              # jax ClosedJaxpr
    lowered_text: str                          # StableHLO module text
    fn_file: str = ""                          # def-site of the python fn
    fn_line: int = 0
    declared_const_specs: Tuple = ()           # ((shape, dtype_name), ...)


def _fn_def_site(jitted) -> Tuple[str, int]:
    """Best-effort (file, line) of the python function under a jit wrapper,
    for anchoring findings that have no per-eqn source info."""
    fn = getattr(jitted, "__wrapped__", jitted)
    code = getattr(fn, "__code__", None)
    if code is None:
        return "", 0
    return code.co_filename, code.co_firstlineno


def capture_ir(jitted, *args, key=None, declared_const_specs=(),
               **kwargs) -> ProgramIR:
    """Trace + lower a jit'd function on example args and keep the IRs
    (without compiling).  Engines use this to re-capture IR for programs
    whose compiled executables were already swapped in by a prior warmup."""
    traced = jitted.trace(*args, **kwargs)
    fn_file, fn_line = _fn_def_site(jitted)
    return ProgramIR(key=key, jaxpr=traced.jaxpr,
                     lowered_text=traced.lower().as_text(),
                     fn_file=fn_file, fn_line=fn_line,
                     declared_const_specs=tuple(declared_const_specs))


def program_cost(compiled) -> Dict[str, float]:
    """FLOPs / bytes from a compiled executable's XLA cost analysis.

    `cost_analysis()` returns a per-device list on some backends and a
    bare dict on others, and may be unimplemented entirely (some Pallas
    lowerings) — normalize to {"flops", "bytes_accessed"} with nan for
    anything the backend would not report."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {"flops": math.nan, "bytes_accessed": math.nan}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return {"flops": math.nan, "bytes_accessed": math.nan}
    return {"flops": float(ca.get("flops", math.nan)),
            "bytes_accessed": float(ca.get("bytes accessed", math.nan))}


def compile_program(jitted, *args, key=None, want_ir=False,
                    declared_const_specs=(), **kwargs):
    """AOT-compile a jit'd function on example args.

    Returns (compiled, ProgramProfile) — or (compiled, profile, ProgramIR)
    with `want_ir=True`, sharing one trace/lower pipeline so IR capture
    costs no extra trace.  The compiled executable is directly callable
    with matching-shape args — the engine swaps it into its tick-program
    cache so warmup's compile is never paid twice — and its cost analysis
    prices the program in FLOPs/bytes."""
    t0 = monotonic()
    ir = None
    if want_ir:
        traced = jitted.trace(*args, **kwargs)
        lowered = traced.lower()
        fn_file, fn_line = _fn_def_site(jitted)
        ir = ProgramIR(key=key, jaxpr=traced.jaxpr,
                       lowered_text=lowered.as_text(),
                       fn_file=fn_file, fn_line=fn_line,
                       declared_const_specs=tuple(declared_const_specs))
    else:
        lowered = jitted.lower(*args, **kwargs)
    compiled = lowered.compile()
    dt = monotonic() - t0
    cost = program_cost(compiled)
    profile = ProgramProfile(key=key, compile_seconds=dt,
                             flops=cost["flops"],
                             bytes_accessed=cost["bytes_accessed"])
    return (compiled, profile, ir) if want_ir else (compiled, profile)


def flops_per_row(profiles: Dict) -> float:
    """Marginal backbone FLOPs per gathered row, from the per-bucket
    program profiles: (flops[largest bucket] - flops[skip]) / bucket.
    Subtracting the bucket-0 (skip) program removes the per-slot policy /
    DDIM arithmetic every tick pays regardless of rows; nan when the
    profiles are missing or costless (backend without a cost model)."""
    buckets = sorted(k for k in profiles if isinstance(k, int) and k > 0)
    if not buckets:
        return math.nan
    largest = buckets[-1]
    base = profiles.get(0)
    f_base = base.flops if base is not None and not math.isnan(
        base.flops) else 0.0
    f_top = profiles[largest].flops
    if math.isnan(f_top):
        return math.nan
    return max(f_top - f_base, 0.0) / largest


def redundancy_ratio(profiles: Dict, rows_computed: int, rows_padding: int,
                     rows_saved: int) -> Dict[str, float]:
    """The survey's redundancy ratio, measured: theoretical FLOPs avoided
    over the FLOPs a dense (no-cache, whole-pool) serving run would have
    dispatched for the same traffic.

    rows_* come straight from ServingTelemetry (backbone_rows_computed /
    _padding / _saved).  Padding rows DO run through the backbone, so they
    count against the saving — the ratio prices the pow-2 bucket waste
    honestly."""
    fpr = flops_per_row(profiles)
    dispatched = rows_computed + rows_padding
    dense = dispatched + rows_saved
    avoided = rows_saved - rows_padding  # padding burns part of the saving
    if math.isnan(fpr) or dense <= 0:
        return {"flops_per_row": fpr, "dense_flops": math.nan,
                "flops_avoided": math.nan, "redundancy_ratio": math.nan}
    return {"flops_per_row": fpr,
            "dense_flops": fpr * (rows_computed + rows_saved),
            "flops_avoided": fpr * avoided,
            "redundancy_ratio": avoided / (rows_computed + rows_saved)}


@contextmanager
def profiler_trace(log_dir: Optional[str] = None):
    """Opt-in `jax.profiler.trace` context: profiles the enclosed block
    into `log_dir` (TensorBoard / Perfetto-loadable) when a directory is
    given, and is a strict no-op otherwise — benchmarks wrap their timed
    sections in this unconditionally."""
    if not log_dir:
        yield
        return
    import jax
    with jax.profiler.trace(log_dir):
        yield
