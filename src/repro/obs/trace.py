"""TraceRecorder — structured tracing for the serving engines.

A TickHook (`ServeSession(..., hooks=[recorder.observe])`, or one entry per
modality for MixedModalityEngine) that turns the engine's TickEvent stream
into two durable artifacts:

  * A Chrome/Perfetto `trace_event` JSON file (`write_chrome_trace`): one
    process (pid) per modality sub-pool, with a "plan" track (host time
    deciding each tick: the fused want pass + its device sync), a
    "backbone" track (device time of the dispatched tick program,
    annotated with kind / bucket / rows; the gather and scatter of the
    row-compacted program are XLA-fused into that one program, so they
    appear as instant markers on its span rather than separately-timed
    phases), and one track per slot carrying cache-lifecycle spans:
    admit -> per-tick compute / reuse / cond-only events annotated with
    the policy's signal value and threshold -> finish or preempt.
    Open with https://ui.perfetto.dev or chrome://tracing.

  * A cache-event JSONL log (`write_cache_events`): one line per active
    slot per tick — slot, request id, step, t, policy, want_compute,
    want_uncond, signal distance, rows in bucket.  This is the durable
    counterpart of the control plane's in-memory SignalTraceLog ring:
    `signal_trace_from_files` rebuilds a SignalTraceLog from it (plus the
    optional probe-latents sidecar from `write_probes`), so
    `probe_training_set` / `fit_want_gate` can train from files long
    after the serving process exited.

The recorder is engine-agnostic (it duck-types TickEvent and never touches
the engine), host-side, and O(slots) per tick; bench_serving's smoke run
bounds hooks-on overhead at <= 5% req/s.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence

import numpy as np

from .clock import monotonic

__all__ = ["TraceRecorder", "policy_signature", "load_cache_events",
           "load_probes", "signal_trace_from_files", "validate_chrome_trace"]


def policy_signature(policy) -> Dict[str, Optional[float]]:
    """(name, threshold) metadata for annotating trace events.

    `threshold` is the scalar the policy's refresh decision compares its
    signal against, taken from the first of the conventional attribute
    names; None for policies without one (interval schedules)."""
    if policy is None:
        return {"policy": "none", "threshold": None}
    if isinstance(policy, str):
        return {"policy": policy, "threshold": None}
    name = getattr(policy, "name", type(policy).__name__)
    threshold = None
    for attr in ("delta", "threshold"):
        v = getattr(policy, attr, None)
        if isinstance(v, (int, float)):
            threshold = float(v)
            break
    return {"policy": str(name), "threshold": threshold}


class TraceRecorder:
    """Record TickEvents into a Chrome trace + cache-event JSONL.

    Parameters
    ----------
    policy: the pool's main CachePolicy (or its name) — stamped on every
        cache event together with its threshold, so the log answers "why
        did this slot skip" without joining against config files.
    probe_every: like SignalTraceLog — every Nth admitted request also
        records its pre-tick latent trajectory (requires the session to
        run with capture_latents=True); `write_probes` persists them.
    """

    def __init__(self, policy=None, *, probe_every: int = 0,
                 max_probes: int = 8, max_probe_steps: int = 64):
        sig = policy_signature(policy)
        self.policy_name: str = sig["policy"]
        self.threshold: Optional[float] = sig["threshold"]
        #: chrome trace_event dicts (the "traceEvents" array)
        self.events: List[Dict] = []
        #: cache-event dicts, one per active slot per tick
        self.cache_events: List[Dict] = []
        self.probe_every = int(probe_every)
        self.max_probes = int(max_probes)
        self.max_probe_steps = int(max_probe_steps)
        #: request_id -> {"label", "steps", "tvals", "xs"}
        self.probes: Dict[int, Dict] = {}
        self._admitted = 0
        self._t0 = monotonic()
        self._pids: Dict[str, int] = {}          # modality -> pid
        self._named_tids: Dict[tuple, bool] = {}  # (pid, tid) named yet?
        #: (modality, slot) -> request_id with an open lifecycle span
        self._open: Dict[tuple, Dict] = {}
        self.ticks_seen = 0

    @property
    def wants_latents(self) -> bool:
        """Should sessions feeding this recorder run capture_latents?"""
        return self.probe_every > 0

    # -- chrome plumbing ----------------------------------------------
    def _us(self, t: float) -> float:
        return (t - self._t0) * 1e6

    def _pid(self, modality: str) -> int:
        pid = self._pids.get(modality)
        if pid is None:
            pid = self._pids[modality] = len(self._pids) + 1
            self.events.append({"ph": "M", "name": "process_name",
                                "pid": pid, "tid": 0,
                                "args": {"name": f"pool:{modality}"}})
        return pid

    def _tid(self, pid: int, tid: int, name: str) -> int:
        if not self._named_tids.get((pid, tid)):
            self._named_tids[(pid, tid)] = True
            self.events.append({"ph": "M", "name": "thread_name",
                                "pid": pid, "tid": tid,
                                "args": {"name": name}})
        return tid

    # slot tracks start at tid 2 (0 = plan, 1 = backbone)
    _TID_PLAN, _TID_BACKBONE, _TID_SLOT0 = 0, 1, 2

    # -- the hook ------------------------------------------------------
    def observe(self, event) -> None:
        """TickHook entry point: fold one TickEvent into both artifacts."""
        t_now = monotonic()
        pid = self._pid(event.modality)
        seconds = float(event.seconds)
        plan_s = float(event.plan_seconds)
        t_start = t_now - seconds - plan_s       # tick began planning here
        t_dev = t_now - seconds                  # device program began here
        bucket = int(event.rows_computed) + int(event.rows_padding)

        if plan_s > 0.0:
            self.events.append({
                "ph": "X", "name": "plan", "cat": "plan", "pid": pid,
                "tid": self._tid(pid, self._TID_PLAN, "plan"),
                "ts": self._us(t_start), "dur": plan_s * 1e6,
                "args": {"tick": event.tick,
                         "on_device": event.metric is not None}})
        tid_bb = self._tid(pid, self._TID_BACKBONE, "backbone")
        self.events.append({
            "ph": "X", "name": f"tick:{event.kind}", "cat": "backbone",
            "pid": pid, "tid": tid_bb,
            "ts": self._us(t_dev), "dur": seconds * 1e6,
            "args": {"tick": event.tick, "kind": event.kind,
                     "rows_computed": int(event.rows_computed),
                     "rows_padding": int(event.rows_padding),
                     "bucket": bucket}})
        if event.kind != "skip":
            # gather/scatter are fused INTO the tick program by XLA — no
            # separate device timing exists, so they are instant markers
            # bracketing the span, not separately-timed phases
            self.events.append({
                "ph": "i", "name": "gather", "cat": "backbone", "pid": pid,
                "tid": tid_bb, "ts": self._us(t_dev), "s": "t",
                "args": {"rows": int(event.rows_computed)}})
            self.events.append({
                "ph": "i", "name": "scatter", "cat": "backbone", "pid": pid,
                "tid": tid_bb, "ts": self._us(t_now), "s": "t",
                "args": {"rows": int(event.rows_computed)}})

        rids = np.asarray(event.request_ids)
        active = np.asarray(event.active, bool)
        metric = (np.asarray(event.metric, np.float32)
                  if event.metric is not None else None)

        # -- slot lifecycle: admit opens a span on the slot's track -----
        for req in event.admitted:
            self._admitted += 1
            if (self.probe_every > 0
                    and (self._admitted - 1) % self.probe_every == 0
                    and len(self.probes) < self.max_probes):
                self.probes.setdefault(req.request_id, {
                    "label": int(getattr(req, "class_label", 0)),
                    "steps": [], "tvals": [], "xs": []})
            slots = np.nonzero(rids == req.request_id)[0]
            if len(slots) == 0:
                continue
            s = int(slots[0])
            tid = self._tid(pid, self._TID_SLOT0 + s, f"slot {s}")
            self.events.append({
                "ph": "B", "name": f"req {req.request_id}", "cat": "slot",
                "pid": pid, "tid": tid, "ts": self._us(t_start),
                "args": {"request_id": int(req.request_id),
                         "num_steps": int(req.num_steps),
                         "guided": bool(getattr(req, "guided", False))}})
            self._open[(event.modality, s)] = {
                "request_id": int(req.request_id)}

        # -- per-slot, per-tick cache decisions -------------------------
        for s in np.nonzero(active)[0]:
            s = int(s)
            rid = int(rids[s])
            wc = bool(event.want_cond[s])
            wu = bool(event.want_uncond[s])
            sig = float(metric[s]) if metric is not None else None
            if wc and wu:
                name = "compute+cfg"
            elif wc:
                name = "compute"
            elif wu:
                name = "cond-only"   # uncond-branch refresh rides alone
            else:
                name = "reuse"
            tid = self._tid(pid, self._TID_SLOT0 + s, f"slot {s}")
            self.events.append({
                "ph": "X", "name": name, "cat": "cache", "pid": pid,
                "tid": tid, "ts": self._us(t_dev), "dur": seconds * 1e6,
                "args": {"step": int(event.steps[s]),
                         "t": float(event.tvals[s]),
                         "signal": sig, "threshold": self.threshold}})
            self.cache_events.append({
                "tick": int(event.tick), "modality": event.modality,
                "slot": s, "request_id": rid,
                "step": int(event.steps[s]), "t": float(event.tvals[s]),
                "policy": self.policy_name, "want_compute": wc,
                "want_uncond": wu, "guided": bool(event.guided[s]),
                "signal": sig, "threshold": self.threshold,
                "rows_in_bucket": bucket, "kind": event.kind})
            probe = self.probes.get(rid)
            if (probe is not None and event.latents is not None
                    and len(probe["steps"]) < self.max_probe_steps):
                probe["steps"].append(int(event.steps[s]))
                probe["tvals"].append(float(event.tvals[s]))
                probe["xs"].append(np.asarray(event.latents[s]))

        # -- finishes close their slot spans ----------------------------
        for rec in event.finished:
            self._close(event.modality, pid, t_now, rec.request_id,
                        preempted=False,
                        computed_steps=int(rec.computed_steps))
        self.ticks_seen += 1

    #: the recorder IS a TickHook: hooks=[recorder] and hooks=[recorder.observe]
    #: are equivalent
    __call__ = observe

    def _close(self, modality: str, pid: int, t: float, rid: int,
               preempted: bool, computed_steps: Optional[int] = None) -> None:
        for key, info in list(self._open.items()):
            if key[0] == modality and info["request_id"] == rid:
                tid = self._TID_SLOT0 + key[1]
                args = {"request_id": rid, "preempted": preempted}
                if computed_steps is not None:
                    args["computed_steps"] = computed_steps
                self.events.append({"ph": "E", "name": f"req {rid}",
                                    "cat": "slot", "pid": pid, "tid": tid,
                                    "ts": self._us(t), "args": args})
                del self._open[key]
                return

    def finish(self) -> None:
        """Close lifecycle spans still open (preempted / cut-off requests)
        so the trace has no dangling "B" events.  Idempotent."""
        t = monotonic()
        for (modality, s), info in list(self._open.items()):
            pid = self._pid(modality)
            self._close(modality, pid, t, info["request_id"],
                        preempted=True)

    # -- artifacts -----------------------------------------------------
    def chrome_trace(self) -> Dict:
        """The Chrome `trace_event` JSON object (displayTimeUnit ms)."""
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms",
                "otherData": {"policy": self.policy_name,
                              "threshold": self.threshold}}

    def write_chrome_trace(self, path: str) -> None:
        self.finish()
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, default=float)

    def write_cache_events(self, path: str) -> None:
        with open(path, "w") as f:
            for ev in self.cache_events:
                f.write(json.dumps(ev, default=float) + "\n")

    def write_probes(self, path: str) -> None:
        """Persist probed latent trajectories as an .npz sidecar keyed by
        request id (xs_<rid>, tvals_<rid>, steps_<rid>, label_<rid>)."""
        arrays = {}
        for rid, p in self.probes.items():
            if not p["xs"]:
                continue
            arrays[f"xs_{rid}"] = np.stack(p["xs"])
            arrays[f"tvals_{rid}"] = np.asarray(p["tvals"], np.float32)
            arrays[f"steps_{rid}"] = np.asarray(p["steps"], np.int32)
            arrays[f"label_{rid}"] = np.asarray(p["label"], np.int32)
        np.savez(path, **arrays)

    # -- views ---------------------------------------------------------
    def computed_steps_by_request(self) -> Dict[int, int]:
        """want_compute tick count per request id, from the cache-event
        log — must reconcile exactly with RequestRecord.computed_steps
        (tests/test_observability.py asserts so)."""
        out: Dict[int, int] = {}
        for ev in self.cache_events:
            out.setdefault(ev["request_id"], 0)
            if ev["want_compute"]:
                out[ev["request_id"]] += 1
        return out

    def uncond_steps_by_request(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for ev in self.cache_events:
            out.setdefault(ev["request_id"], 0)
            if ev["want_uncond"]:
                out[ev["request_id"]] += 1
        return out


# ----------------------------------------------------------------------
# file consumers: JSONL / probes -> SignalTraceLog (durable ring)
# ----------------------------------------------------------------------

def load_cache_events(path: str) -> List[Dict]:
    """Parse a cache-event JSONL file back into dicts."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def load_probes(path: str) -> Dict[int, Dict]:
    """Parse a `write_probes` .npz back into {request_id: probe dict}."""
    probes: Dict[int, Dict] = {}
    with np.load(path) as z:
        for key in z.files:
            kind, rid = key.rsplit("_", 1)
            p = probes.setdefault(int(rid), {})
            p[kind] = z[key]
    return {rid: {"label": int(p.get("label", 0)),
                  "steps": [int(s) for s in p.get("steps", [])],
                  "tvals": [float(t) for t in p.get("tvals", [])],
                  "xs": list(p["xs"])}
            for rid, p in probes.items() if "xs" in p}


def signal_trace_from_files(cache_events_path: str,
                            probes_path: Optional[str] = None):
    """Rebuild a SignalTraceLog from a cache-event JSONL (+ optional probe
    sidecar): the durable alternative to keeping the in-memory ring alive.
    The result feeds `probe_training_set` / `fit_want_gate` unchanged."""
    # lazy import: repro.obs must stay importable without the serving stack
    from repro.serving.control.trace import SignalTraceLog, TraceEntry
    events = load_cache_events(cache_events_path)
    log = SignalTraceLog(max_entries=max(len(events), 1))
    for ev in events:
        log.entries.append(TraceEntry(
            tick=int(ev["tick"]), modality=ev.get("modality", "image"),
            request_id=int(ev["request_id"]), step=int(ev["step"]),
            want_cond=bool(ev["want_compute"]),
            want_uncond=bool(ev["want_uncond"]),
            metric=float(ev["signal"]) if ev.get("signal") is not None
            else 0.0,
            guided=bool(ev.get("guided", False))))
        log.entries_seen += 1
    if probes_path is not None:
        log.probes.update(load_probes(probes_path))
    return log


# ----------------------------------------------------------------------
# schema validation (the golden-file test's checker, usable standalone)
# ----------------------------------------------------------------------

_REQUIRED = {"ph", "name", "pid", "tid"}


def validate_chrome_trace(trace: Dict) -> List[str]:
    """Structural validation of a Chrome trace object.  Returns a list of
    problems (empty == valid): required keys per event, non-negative
    timestamps, per-track monotonic event starts, and B/E span nesting
    (every begin closed by a matching end, never crossed)."""
    problems: List[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    last_ts: Dict[tuple, float] = {}
    open_spans: Dict[tuple, List[str]] = {}
    for i, ev in enumerate(events):
        missing = _REQUIRED - set(ev)
        if missing:
            problems.append(f"event {i}: missing keys {sorted(missing)}")
            continue
        if ev["ph"] == "M":
            continue
        ts = ev.get("ts")
        if ts is None:
            problems.append(f"event {i}: non-metadata event without ts")
            continue
        if ts < 0:
            problems.append(f"event {i}: negative ts {ts}")
        track = (ev["pid"], ev["tid"])
        if ev["ph"] in ("X", "B", "i") and ts + 1e-6 < last_ts.get(
                track, 0.0):
            problems.append(f"event {i}: ts {ts} went backwards on track "
                            f"{track} (last {last_ts[track]})")
        if ev["ph"] in ("X", "B", "i"):
            last_ts[track] = max(last_ts.get(track, 0.0), ts)
        if ev["ph"] == "B":
            open_spans.setdefault(track, []).append(ev["name"])
        elif ev["ph"] == "E":
            stack = open_spans.get(track, [])
            if not stack:
                problems.append(f"event {i}: E without open B on {track}")
            elif stack[-1] != ev["name"]:
                problems.append(f"event {i}: E '{ev['name']}' crosses open "
                                f"span '{stack[-1]}' on {track}")
            else:
                stack.pop()
    for track, stack in open_spans.items():
        if stack:
            problems.append(f"track {track}: unclosed spans {stack}")
    return problems
