"""repro.conditioning — text conditioning for T2I/T2V serving.

The survey's headline scenario is text-to-image/video generation; this
package supplies the text side and its caches, exploiting the one
invariance every other cache in the repo has to *estimate* but text gets
for free: prompts do not change across denoise steps.

  encoder — ClipCap-style prefix text encoder: byte-level tokens -> a
            (L_text, d_model) prompt-embedding table, padded to exactly
            cfg.dit_text_len so serving keeps its fixed-shape discipline
  cache   — PromptCache: content-hashed LRU over prompt embeddings; the
            encoder runs once per UNIQUE prompt (obs metrics:
            repro_conditioning_prompt_cache_*)

Downstream, the serving engine holds per-slot cross-attn K/V tables next
to null_vecs: K/V projections are computed once at admission
(models.dit.text_kv over all layers at once) and reused by every tick —
zero text FLOPs inside the denoise loop.  CFG negative prompts reuse the
null-vec path with the pooled embedding, plus their own K/V tables for
the uncond rows.
"""
from .cache import PromptCache, PromptEmbedding
from .encoder import (TextEncoderConfig, encode_tokens, init_text_encoder,
                      pooled_embedding, text_encoder_config, tokenize)

__all__ = [
    "PromptCache", "PromptEmbedding",
    "TextEncoderConfig", "encode_tokens", "init_text_encoder",
    "pooled_embedding", "text_encoder_config", "tokenize",
]
