"""ClipCap-style prefix text encoder: tokens -> (L_text, d_model) prompt
embeddings the DiT cross-attention branches attend over.

The survey's central serving scenario is text-to-image/video; this module
is the text side of it.  Deliberately small — a byte-level tokenizer, a
few bidirectional pre-LN transformer blocks, and a projection into the
backbone's d_model — because the caching claims it supports do not depend
on encoder quality: prompt embeddings are DETERMINISTIC per prompt and
step-invariant across the whole denoise trajectory, which makes them the
cheapest cache in the system (repro.conditioning.cache.PromptCache pays
the encoder once per unique prompt, the engine pays the cross-attn K/V
projection once per admission).

Every prompt is padded to exactly `max_len` (= cfg.dit_text_len) tokens:
the serving engine's bucket programs keep their padded-shape discipline
and the retrace sentinel stays at zero.  Padding positions are masked out
of the encoder's self-attention (negative k_positions are always masked
by blocked_attention) and the output rows at padding positions are zeroed
— the invariant the cross-attention no-op branch relies on.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.encdec import sinusoidal_positions
from repro.models.layers import (blocked_attention, dense_init, embed_init,
                                 init_mlp, layer_norm, mlp_forward)

__all__ = ["TextEncoderConfig", "text_encoder_config", "init_text_encoder",
           "tokenize", "encode_tokens", "pooled_embedding"]

TokensLike = Union[str, Sequence[int]]


@dataclass(frozen=True)
class TextEncoderConfig:
    """Shape contract between encoder, PromptCache, and serving engine."""
    d_model: int                 # output width == backbone d_model
    max_len: int                 # padded prompt length == cfg.dit_text_len
    vocab: int = 256             # byte-level tokens
    num_layers: int = 2
    num_heads: int = 4
    d_ff: int = 0                # 0 -> 4 * d_model

    def __post_init__(self):
        if self.max_len < 1:
            raise ValueError("text encoder needs max_len >= 1 "
                             "(cfg.dit_text_len > 0)")
        if self.d_ff == 0:
            object.__setattr__(self, "d_ff", 4 * self.d_model)
        if self.d_model % self.num_heads:
            raise ValueError(f"d_model {self.d_model} not divisible by "
                             f"num_heads {self.num_heads}")

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads


def text_encoder_config(cfg, **overrides) -> TextEncoderConfig:
    """Derive the encoder shape contract from a text-enabled ArchConfig."""
    kw = dict(d_model=cfg.d_model, max_len=cfg.dit_text_len)
    kw.update(overrides)
    return TextEncoderConfig(**kw)


def _init_block(key, tc, dtype):
    d, H, hd = tc.d_model, tc.num_heads, tc.head_dim
    ks = jax.random.split(key, 4)
    return {
        "attn": {"wq": dense_init(ks[0], d, H * hd, dtype),
                 "wk": dense_init(ks[1], d, H * hd, dtype),
                 "wv": dense_init(ks[2], d, H * hd, dtype),
                 "wo": dense_init(ks[3], H * hd, d, dtype)},
        "mlp": init_mlp(ks[3], d, tc.d_ff, dtype, gated=False),
    }


def init_text_encoder(key, tc: TextEncoderConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    bkeys = jax.random.split(ks[0], tc.num_layers)
    return {
        "tok_embed": embed_init(ks[1], tc.vocab, tc.d_model, dtype),
        "blocks": jax.vmap(lambda k: _init_block(k, tc, dtype))(bkeys),
        "proj": dense_init(ks[2], tc.d_model, tc.d_model, dtype),
    }


def tokenize(prompt: TokensLike, tc: TextEncoderConfig):
    """prompt (str or explicit int token sequence) -> (ids, mask):
    ids (max_len,) int32, mask (max_len,) bool.

    Strings tokenize byte-level (UTF-8) and truncate silently at max_len;
    an EXPLICIT overlong token sequence is a caller error and raises."""
    if isinstance(prompt, str):
        ids = list(prompt.encode("utf-8"))[:tc.max_len]
    else:
        ids = [int(t) for t in prompt]
        if len(ids) > tc.max_len:
            raise ValueError(f"prompt token sequence of length {len(ids)} "
                             f"exceeds max_len {tc.max_len}")
        bad = [t for t in ids if not 0 <= t < tc.vocab]
        if bad:
            raise ValueError(f"prompt tokens out of vocab range "
                             f"[0, {tc.vocab}): {bad[:4]}")
    n = len(ids)
    out = np.zeros((tc.max_len,), np.int32)
    out[:n] = ids
    mask = np.zeros((tc.max_len,), bool)
    mask[:n] = True
    return out, mask


def encode_tokens(params, ids, mask, tc: TextEncoderConfig):
    """(B, L) int32 ids + (B, L) bool mask -> (B, L, d_model) f32 prompt
    embeddings, zeroed at padding positions."""
    L = tc.max_len
    x = params["tok_embed"][ids]
    x = x + sinusoidal_positions(jnp.arange(L)[None], tc.d_model).astype(
        x.dtype)
    qpos = jnp.broadcast_to(jnp.arange(L)[None], ids.shape)
    kpos = jnp.where(mask, qpos, -1)          # negative -> always masked
    d = tc.d_model
    ones, zeros = jnp.ones((d,), x.dtype), jnp.zeros((d,), x.dtype)
    H, hd = tc.num_heads, tc.head_dim

    def body(x, p):
        B, T, _ = x.shape
        h = layer_norm(x, ones, zeros)
        q = (h @ p["attn"]["wq"]).reshape(B, T, H, hd)
        k = (h @ p["attn"]["wk"]).reshape(B, T, H, hd)
        v = (h @ p["attn"]["wv"]).reshape(B, T, H, hd)
        o = blocked_attention(q, k, v, causal=False,
                              q_positions=qpos, k_positions=kpos)
        x = x + o.reshape(B, T, H * hd) @ p["attn"]["wo"]
        x = x + mlp_forward(p["mlp"], layer_norm(x, ones, zeros))
        return x, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    out = layer_norm(x, ones, zeros) @ params["proj"]
    return jnp.where(mask[..., None], out, 0.0)


def pooled_embedding(embed, mask):
    """Masked mean over the token axis: (..., L, d) -> (..., d).  Embeds
    are already zeroed at padding, so a sum over L only needs the count.
    This is the ClipCap-style pooled vector the CFG negative-prompt path
    feeds through the engine's null-vec tables."""
    n = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1)
    return jnp.sum(embed, axis=-2) / n
