"""Prompt-level embedding cache: content-hashed, LRU-bounded.

Prompt embeddings are deterministic per prompt and step-invariant across
the whole denoise trajectory — the static-reuse end of the survey's
static->dynamic spectrum.  PromptCache therefore pays the text encoder
exactly once per UNIQUE prompt; every re-submission (the common serving
case: popular prompts, CFG pairs, retries) is a host-side dict hit.  The
per-slot cross-attn K/V tables downstream (engine._build_text_tables)
extend the same invariance: K/V projections happen once at admission,
never per step.

Entries are keyed by a content hash of the PADDED token buffer, so a
string prompt and its explicit token-sequence spelling share one entry.
Hit/miss/eviction counts publish through repro.obs metrics
(`repro_conditioning_prompt_cache_*`).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp
import numpy as np

import jax

from repro.obs import compile_program
from repro.obs.profiling import capture_ir

from .encoder import (TextEncoderConfig, TokensLike, encode_tokens,
                      pooled_embedding, tokenize)

__all__ = ["PromptEmbedding", "PromptCache"]


@dataclass(frozen=True)
class PromptEmbedding:
    """One cached prompt: padded tokens + the two embedding views."""
    key: str                     # content hash of the padded token buffer
    tokens: np.ndarray           # (L,) int32
    mask: np.ndarray             # (L,) bool
    embed: np.ndarray            # (L, d) f32, zeroed at padding
    pooled: np.ndarray           # (d,) f32 masked mean (neg-prompt vector)


class PromptCache:
    """prompt -> PromptEmbedding with LRU bounds and obs metrics.

    Host-side by design: admission-time code (SlotScheduler refill), not
    tick-path code — the one device->host transfer per unique prompt is
    the price of keeping every tick program free of text-encoder FLOPs.
    `warmup()` AOT-compiles the encoder program so a prompt-bearing
    admission after `engine.warmup()` compiles nothing (the retrace
    sentinel's zero-recompile claim extends over text serving)."""

    def __init__(self, params, tc: TextEncoderConfig, capacity: int = 128,
                 metrics=None, name: str = "default"):
        if capacity < 1:
            raise ValueError(f"PromptCache capacity must be >= 1, "
                             f"got {capacity}")
        self.params = params
        self.tc = tc
        self.capacity = int(capacity)
        self.name = name
        self._entries: "OrderedDict[str, PromptEmbedding]" = OrderedDict()
        self._metrics = metrics
        self.hits = 0
        self.misses = 0
        self.evictions = 0

        def _encode(ids, mask):
            # the batch squeeze lives INSIDE the program: an eager [0] on
            # the result would compile tiny slice/squeeze programs at the
            # first in-session miss, tripping the retrace sentinel
            emb = encode_tokens(params, ids, mask, tc)
            return emb[0], pooled_embedding(emb, mask)[0]

        self._encode_src = _encode          # kept for IR re-capture
        self._encode = jax.jit(_encode)
        self._compiled = None               # warmup() swaps in the AOT exe

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def content_key(self, prompt: TokensLike) -> str:
        ids, mask = tokenize(prompt, self.tc)
        return self._hash(ids, mask)

    @staticmethod
    def _hash(ids: np.ndarray, mask: np.ndarray) -> str:
        return hashlib.sha1(ids.tobytes() + mask.tobytes()).hexdigest()

    def _count(self, what: str, amount: int = 1) -> None:
        if what in ("hits", "misses", "evictions"):
            setattr(self, what, getattr(self, what) + amount)
        if self._metrics is not None:
            self._metrics.counter(
                f"repro_conditioning_prompt_cache_{what}_total",
                "PromptCache LRU events").inc(amount, cache=self.name)
            self._metrics.gauge(
                "repro_conditioning_prompt_cache_size",
                "live PromptCache entries").set(len(self._entries),
                                                cache=self.name)

    # ------------------------------------------------------------------
    def get(self, prompt: TokensLike) -> PromptEmbedding:
        """Embedding table for `prompt` — encoder runs only on a miss."""
        ids, mask = tokenize(prompt, self.tc)
        key = self._hash(ids, mask)
        hit = self._entries.get(key)
        if hit is not None:
            self._entries.move_to_end(key)
            self._count("hits")
            return hit
        fn = self._compiled if self._compiled is not None else self._encode
        emb_dev, pool_dev = fn(jnp.asarray(ids[None]), jnp.asarray(mask[None]))
        # repro-lint: disable-next-line=host-sync-in-hot-path -- admission-
        # time transfer, paid once per UNIQUE prompt (never per tick/step)
        emb = np.asarray(emb_dev, np.float32)
        pool = np.asarray(pool_dev, np.float32)
        entry = PromptEmbedding(key=key, tokens=ids, mask=mask,
                                embed=emb, pooled=pool)
        self._entries[key] = entry
        self._count("misses")
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self._count("evictions")
        return entry

    # ------------------------------------------------------------------
    def param_leaf_specs(self):
        """(shape, dtype-name) multiset of the encoder's param leaves —
        what the engine declares to the ir-const-bloat check so warmup
        verification stays clean over the text-encoder program."""
        return tuple((tuple(leaf.shape), leaf.dtype.name)
                     for leaf in jax.tree_util.tree_leaves(self.params))

    def _example_args(self):
        L = self.tc.max_len
        return (jnp.zeros((1, L), jnp.int32), jnp.zeros((1, L), bool))

    def warmup(self, verify: bool = False, declared_const_specs=None):
        """AOT-compile the encoder program; returns its ProgramProfile
        (plus the ProgramIR under `verify=True`).  The compiled executable
        replaces the lazy jit so post-warmup misses never trigger a
        compile."""
        specs = (self.param_leaf_specs() if declared_const_specs is None
                 else declared_const_specs)
        out = compile_program(self._encode, *self._example_args(),
                              key="text_encoder", want_ir=verify,
                              declared_const_specs=specs)
        self._compiled = out[0]
        return out[1:] if verify else out[1]

    def capture_ir(self, declared_const_specs=None):
        """Re-capture the encoder program's IR (engine._capture_program_ir
        hook — a Compiled executable no longer carries its jaxpr)."""
        specs = (self.param_leaf_specs() if declared_const_specs is None
                 else declared_const_specs)
        return capture_ir(jax.jit(self._encode_src), *self._example_args(),
                          key="text_encoder", declared_const_specs=specs)

    # ------------------------------------------------------------------
    @property
    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._entries),
                "capacity": self.capacity,
                "hit_rate": self.hits / max(self.hits + self.misses, 1)}
