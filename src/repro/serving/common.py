"""Abstractions shared by the LLM and diffusion serving engines.

Both engines follow the same continuous-batching shape: a FIFO admission
queue feeds a fixed pool of slots, every slot advances through one compiled
device program per tick, and finished slots are refilled mid-flight.  The
request/queue machinery is host-side and backend-agnostic, so it lives here
rather than in either engine.
"""
from __future__ import annotations

from collections import deque
from typing import Generic, Iterable, List, Optional, TypeVar

R = TypeVar("R")


class RequestQueue(Generic[R]):
    """FIFO admission queue with batch pops.

    Tracks `submitted` so telemetry can report queueing depth over time.
    """

    def __init__(self, requests: Iterable[R] = ()):
        self._q: deque = deque(requests)
        self.submitted = len(self._q)

    def push(self, request: R) -> None:
        self._q.append(request)
        self.submitted += 1

    def pop(self) -> Optional[R]:
        return self._q.popleft() if self._q else None

    def pop_many(self, n: int) -> List[R]:
        out = []
        while self._q and len(out) < n:
            out.append(self._q.popleft())
        return out

    def peek(self) -> Optional[R]:
        return self._q[0] if self._q else None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
