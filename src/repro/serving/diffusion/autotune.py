"""SLA-driven cache-policy autotuning for the diffusion serving engine.

The policy zoo (repro.core.POLICY_REGISTRY) trades quality for compute along
method-specific hyperparameters; which point is right depends on the traffic
class being served ("interactive" preview traffic tolerates lower PSNR for
latency; "quality" traffic does not).  The autotuner sweeps candidate
(policy, hyperparams) pairs on a small calibration batch against the exact
(uncached) trajectory and picks, per traffic class, the cheapest candidate
that still meets the SLA:

    minimize   compute_fraction                 (~ 1/speedup, survey §III-B)
    subject to PSNR(x0_policy, x0_exact) >= sla.min_psnr
               est_latency <= sla.max_latency_ms     (when step times given)

Falling back to the highest-PSNR candidate when nothing is feasible keeps
the server serving rather than erroring on an over-tight SLA.

CFG-aware tuning: with `cfg_scale > 0` the calibration reference is the
exact two-branch guided trajectory and each candidate is additionally swept
over `cfg_intervals` — unconditional-branch reuse intervals (None = naive
two-branch; N = FasterCacheCFG(interval=N)).  The minimized cost becomes the
*row-weighted* compute fraction (cond computes + uncond computes) / (2 T),
i.e. the fraction of backbone rows a guided request actually dispatches.

Latency is priced in actual backbone rows: the row-compacted engine
dispatches exactly the rows the pool's schedules want, so the per-request
estimate is  T * (occupancy * rows_per_step * ms_per_row + tick_overhead_ms)
with rows_per_step = cond fraction + uncond fraction and `occupancy` the
busy-slot count sharing each tick (phase alignment puts a homogeneous pool's
refreshes on the same ticks).  Feed it
`row_time_ms=ServingTelemetry.row_time_ms()` from a prior serving run; the
older `step_time_ms` tick-kind pricing (which charged a whole-pool tick even
for a 1-row refresh) is kept as a fallback for dense-engine measurements.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import CachePolicy, FasterCacheCFG, make_policy
from repro.core.metrics import psnr
from repro.diffusion import ddim_step, linear_schedule, sample
from repro.diffusion.pipeline import CachedDenoiser, cfg_denoise_fn


@dataclass(frozen=True)
class SLA:
    """Per-traffic-class serving objective."""
    name: str = "default"
    min_psnr: float = 20.0           # quality floor vs the exact trajectory
    max_latency_ms: Optional[float] = None  # per-request budget (optional)


@dataclass
class TunedPolicy:
    """Autotuner output: a constructible policy choice + its measurements."""
    policy_name: str
    kwargs: Dict = field(default_factory=dict)
    psnr: float = 0.0
    #: minimized cost: cond compute fraction for unguided tuning, the
    #: row-weighted (cond + uncond) / 2 fraction for guided tuning
    compute_fraction: float = 1.0
    est_latency_ms: Optional[float] = None
    feasible: bool = True
    #: guided tuning only: FasterCacheCFG reuse interval (None = naive
    #: two-branch) and the resulting uncond-branch compute fraction
    cfg_interval: Optional[int] = None
    uncond_compute_fraction: float = 0.0
    #: cond-branch compute fraction alone (== compute_fraction for unguided
    #: tuning); kept separately so `price_and_pick` can re-price the rows a
    #: candidate gathers per step without re-running the quality sweep
    cond_compute_fraction: float = 1.0
    #: True when the serving engine can plan every tick on the host (both
    #: branches' want_compute are step-only): no fused want pass, no device
    #: sync per tick.  State-dependent policies (TeaCache & co) pay that
    #: sync, which `price_and_pick` charges via its `plan_ms` surcharge.
    static_plan: bool = True

    def make(self) -> CachePolicy:
        return make_policy(self.policy_name, **self.kwargs)

    def make_cfg_policy(self, num_steps: int) -> Optional[CachePolicy]:
        """The tuned uncond-branch gate for DiffusionServingEngine /
        CachedDenoiser, or None for naive two-branch guidance."""
        if self.cfg_interval is None:
            return None
        return FasterCacheCFG(self.cfg_interval, num_steps)

    @property
    def align(self) -> int:
        """Phase-alignment interval for the serving scheduler: the lcm of
        the two branch intervals so their refreshes land on shared ticks."""
        a = max(int(self.kwargs.get("interval", 1)), 1)
        b = max(int(self.cfg_interval or 1), 1)
        return a * b // math.gcd(a, b)


#: default sweep: one representative per taxonomy branch, two operating
#: points for the interval-scheduled families
DEFAULT_CANDIDATES: List[Tuple[str, Dict]] = [
    ("none", {}),
    ("fora", {"interval": 2}),
    ("fora", {"interval": 4}),
    ("taylorseer", {"interval": 2, "order": 1}),
    ("taylorseer", {"interval": 4, "order": 2}),
    ("teacache", {"delta": 0.1}),
    ("teacache", {"delta": 0.3}),
    ("magcache", {"delta": 0.1}),
    ("freqca", {"interval": 4}),
]


def _plans_on_host(policy: CachePolicy, num_steps: int) -> bool:
    """Mirror of the serving engine's static-plan probe: True when
    want_compute is a pure function of the step index, i.e. the engine
    will plan ticks host-side with no per-tick device sync."""
    try:
        for s in range(num_steps):
            bool(policy.want_compute(None, s, None))
        return True
    except Exception:
        return False


def _measured_compute_fraction(policy: CachePolicy, state, num_steps: int) -> float:
    """Computes issued / steps, from whichever counter the policy keeps."""
    pol = state.get("policy", {}) if isinstance(state, dict) else {}
    if isinstance(pol, dict):
        for key in ("n_compute", "n_valid"):
            if key in pol:
                return float(np.asarray(pol[key])) / max(num_steps, 1)
    sched = policy.static_schedule(num_steps)
    if sched is not None:
        return sum(map(bool, sched)) / max(num_steps, 1)
    return 1.0


def calibration_reference(params, cfg, num_steps: int, batch: int = 1,
                          seed: int = 0, noise_schedule=None,
                          cfg_scale: float = 0.0, class_label: int = 0):
    """Exact (uncached) calibration trajectory shared by all candidates.

    With cfg_scale > 0 the reference is the exact two-branch guided
    trajectory, so candidate PSNR measures guided-output fidelity."""
    sched = noise_schedule or linear_schedule(1000)
    ts = sched.spaced(num_steps)
    xT = jax.random.normal(jax.random.PRNGKey(seed),
                           (batch, cfg.dit_tokens, cfg.dit_in_dim))
    exact, _ = sample(cfg_denoise_fn(params, cfg, cfg_scale, class_label),
                      xT, ts, sched, step_fn=ddim_step)
    return sched, ts, xT, np.asarray(exact)


def evaluate_candidate(name: str, kwargs: Dict, params, cfg, sched, ts, xT,
                       exact: np.ndarray, cfg_scale: float = 0.0,
                       cfg_interval: Optional[int] = None,
                       class_label: int = 0) -> Tuple[float, float, float]:
    """Run one candidate on the calibration trajectory.

    Returns (psnr_db, cond_compute_fraction, uncond_compute_fraction)."""
    policy = make_policy(name, **kwargs)
    cfg_pol = (FasterCacheCFG(cfg_interval, len(ts))
               if (cfg_scale > 0.0 and cfg_interval is not None) else None)
    den = CachedDenoiser(params, cfg, policy, cfg_scale=cfg_scale,
                         cfg_policy=cfg_pol, class_label=class_label)
    x0, state = sample(den, xT, ts, sched, step_fn=ddim_step,
                       denoiser_state=den.init_state(xT.shape[0]))
    q = float(psnr(np.asarray(x0), exact))
    cf = _measured_compute_fraction(policy, state, len(ts))
    if cfg_scale <= 0.0:
        cf_u = 0.0
    elif cfg_pol is None:
        cf_u = 1.0                      # naive: uncond recomputes every step
    else:
        sched_u = cfg_pol.static_schedule(len(ts))
        cf_u = sum(map(bool, sched_u)) / max(len(ts), 1)
    return q, cf, cf_u


def sweep_candidates(params, cfg,
                     candidates: Optional[Sequence[Tuple[str, Dict]]] = None,
                     num_steps: int = 16, batch: int = 1, seed: int = 0,
                     noise_schedule=None, cfg_scale: float = 0.0,
                     cfg_intervals: Sequence[Optional[int]] = (None,),
                     verbose: bool = False) -> List[TunedPolicy]:
    """Quality sweep: run every candidate on the calibration trajectory.

    Measures ONLY traffic-independent quantities — PSNR vs the exact
    trajectory and per-branch compute fractions.  No SLA judgement, no
    latency: those depend on live row pricing and pool occupancy, which is
    `price_and_pick`'s job.  This split is what makes online retuning cheap:
    the control plane sweeps once at startup and re-prices the cached list
    (host-side arithmetic over ~10 entries) on every retune window instead
    of re-running trajectories."""
    candidates = list(candidates if candidates is not None
                      else DEFAULT_CANDIDATES)
    cfg_ivs = list(cfg_intervals) if cfg_scale > 0.0 else [None]
    sched, ts, xT, exact = calibration_reference(
        params, cfg, num_steps, batch, seed, noise_schedule,
        cfg_scale=cfg_scale)

    evaluated: List[TunedPolicy] = []
    for name, kwargs in candidates:
        # resolve the full hyperparameters here so TunedPolicy.make()
        # reconstructs exactly what was calibrated (magcache sizes its
        # gamma curve from num_steps)
        kwargs = dict(kwargs)
        kwargs.setdefault("num_steps", num_steps)
        host_plan = _plans_on_host(make_policy(name, **kwargs), num_steps)
        for ci in cfg_ivs:
            q, cf, cf_u = evaluate_candidate(
                name, kwargs, params, cfg, sched, ts, xT, exact,
                cfg_scale=cfg_scale, cfg_interval=ci)
            # guided cost = fraction of backbone rows dispatched per step
            cost = (cf + cf_u) / 2.0 if cfg_scale > 0.0 else cf
            # the engine plans on the host only when BOTH branches admit a
            # step-only schedule (ci=None means an all-True host plan)
            static = host_plan and (
                ci is None
                or _plans_on_host(FasterCacheCFG(ci, num_steps), num_steps))
            evaluated.append(TunedPolicy(name, dict(kwargs), psnr=q,
                                         compute_fraction=cost,
                                         cfg_interval=ci,
                                         uncond_compute_fraction=cf_u,
                                         cond_compute_fraction=cf,
                                         static_plan=static))
            if verbose:
                tag = f" cfg_iv={ci}" if cfg_scale > 0.0 else ""
                print(f"  {name:12s} {kwargs}{tag} "
                      f"psnr={q:6.2f}dB cf={cost:.3f}")
    return evaluated


def price_and_pick(evaluated: Sequence[TunedPolicy], sla: SLA,
                   num_steps: int = 16,
                   step_time_ms: Optional[Tuple[float, float]] = None,
                   row_time_ms: Optional[Tuple[float, float]] = None,
                   occupancy: int = 1,
                   plan_ms: float = 0.0,
                   verbose: bool = False,
                   registry=None) -> TunedPolicy:
    """Price swept candidates against live timings and pick for the SLA.

    registry: optional repro.obs MetricsRegistry — each pricing run lands
    in its event ring (winner, feasible count, timing inputs) so retune
    decisions are auditable alongside the serving metrics.

    Pure host-side arithmetic over the `sweep_candidates` output — cheap
    enough to run on every control-plane retune window with fresh
    `row_time_ms` / `occupancy` from the sliding telemetry window.  With
    row pricing the pick minimizes estimated latency (quality breaks
    ties); without timings it falls back to compute fraction.  Falls back
    to the highest-PSNR candidate (marked infeasible) when nothing meets
    the SLA, so the server keeps serving on an over-tight objective.

    plan_ms: measured host cost per tick of the fused want pass + its
    device sync (`TelemetryWindow.plan_time_ms()`), charged per step to
    candidates without a host-side static plan.  Row counts alone misprice
    state-dependent policies — a TeaCache tick that skips every row still
    pays a device round trip to find that out — and this surcharge is what
    lets the online tuner prefer a calibrated static schedule over a
    dynamic policy with fewer rows but slower wall-clock ticks."""
    priced: List[TunedPolicy] = []
    for t in evaluated:
        # rows this candidate gathers per step in the compacted engine
        rows_per_step = t.cond_compute_fraction + t.uncond_compute_fraction
        lat = None
        if row_time_ms is not None:
            t_row, t_tick = row_time_ms
            lat = num_steps * (max(occupancy, 1) * rows_per_step * t_row
                               + t_tick)
            if not t.static_plan:
                lat += num_steps * max(plan_ms, 0.0)
        elif step_time_ms is not None:
            t_full, t_skip = step_time_ms
            cost = t.compute_fraction
            lat = num_steps * (cost * t_full + (1.0 - cost) * t_skip)
        ok = t.psnr >= sla.min_psnr and (
            lat is None or sla.max_latency_ms is None
            or lat <= sla.max_latency_ms)
        priced.append(replace(t, est_latency_ms=lat, feasible=ok))
        if verbose:
            tag = (f" cfg_iv={t.cfg_interval}"
                   if t.cfg_interval is not None else "")
            lat_s = f" lat={lat:.1f}ms" if lat is not None else ""
            print(f"  [{sla.name}] {t.policy_name:12s} {t.kwargs}{tag} "
                  f"psnr={t.psnr:6.2f}dB cf={t.compute_fraction:.3f}"
                  f"{lat_s} {'ok' if ok else 'infeasible'}")

    feasible = [t for t in priced if t.feasible]
    if feasible:
        if row_time_ms is not None:
            # cheapest feasible by estimated wall-clock (rows + plan
            # surcharge); quality breaks ties.  Without the surcharge this
            # ordering coincides with compute_fraction, so the objective
            # only *diverges* when a candidate needs device-planned ticks.
            pick = min(feasible,
                       key=lambda t: (t.est_latency_ms, -t.psnr))
        else:
            # no timings: cheapest feasible by rows; quality breaks ties
            pick = min(feasible, key=lambda t: (t.compute_fraction, -t.psnr))
    else:
        # nothing meets the SLA: serve the closest-to-exact candidate
        best = max(priced, key=lambda t: t.psnr)
        pick = replace(best, feasible=False)
    if registry is not None:
        registry.event(
            "autotune.price_and_pick", sla=sla.name,
            picked=pick.policy_name, feasible=pick.feasible,
            n_candidates=len(priced), n_feasible=len(feasible),
            est_latency_ms=pick.est_latency_ms,
            row_time_ms=row_time_ms, occupancy=occupancy, plan_ms=plan_ms)
    return pick


def autotune(params, cfg, sla: SLA,
             candidates: Optional[Sequence[Tuple[str, Dict]]] = None,
             num_steps: int = 16, batch: int = 1, seed: int = 0,
             noise_schedule=None,
             step_time_ms: Optional[Tuple[float, float]] = None,
             row_time_ms: Optional[Tuple[float, float]] = None,
             occupancy: int = 1,
             cfg_scale: float = 0.0,
             cfg_intervals: Sequence[Optional[int]] = (None,),
             verbose: bool = False) -> TunedPolicy:
    """Sweep candidates against `sla` on a calibration batch.

    row_time_ms: measured (ms_per_backbone_row, skip_tick_ms) from a prior
    serving run — `ServingTelemetry.row_time_ms()` — prices a candidate's
    latency by backbone rows: a request waits for its whole tick, and with
    phase-aligned admission a homogeneous pool's co-resident slots gather
    rows on the same ticks, so the per-step estimate is
    `occupancy * rows_per_step * ms_per_row + skip_tick_ms` with
    rows_per_step = cond fraction + uncond fraction.  Pass
    `occupancy=slots` (or the typical busy-slot count) for a loaded pool;
    the default 1 prices an otherwise-idle engine and UNDER-estimates
    per-request latency under load by roughly the occupancy factor.

    step_time_ms: legacy tick-kind pricing, (backbone_tick_ms, skip_tick_ms)
    from `ServingTelemetry.step_time_ms()` — used only when row_time_ms is
    not given (it charges a whole-pool backbone tick even for a 1-row
    refresh, over-estimating compacted serving).  Without either, only the
    PSNR floor is enforced.

    cfg_scale > 0 tunes for *guided* traffic: every (policy, hyperparams)
    candidate is crossed with `cfg_intervals` (uncond-branch reuse intervals;
    None = naive two-branch) and the minimized compute fraction weights both
    branches' backbone rows.

    Composition of `sweep_candidates` (trajectory quality measurement) and
    `price_and_pick` (SLA pricing) — call those directly to amortize the
    sweep across repeated re-pricings (the online control plane does).
    """
    evaluated = sweep_candidates(
        params, cfg, candidates=candidates, num_steps=num_steps, batch=batch,
        seed=seed, noise_schedule=noise_schedule, cfg_scale=cfg_scale,
        cfg_intervals=cfg_intervals)
    return price_and_pick(evaluated, sla, num_steps=num_steps,
                          step_time_ms=step_time_ms, row_time_ms=row_time_ms,
                          occupancy=occupancy, verbose=verbose)


def autotune_traffic_classes(params, cfg, slas: Mapping[str, SLA],
                             **kw) -> Dict[str, TunedPolicy]:
    """One tuned policy per traffic class (e.g. interactive vs quality)."""
    return {name: autotune(params, cfg, sla, **kw)
            for name, sla in slas.items()}
