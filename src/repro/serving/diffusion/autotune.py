"""SLA-driven cache-policy autotuning for the diffusion serving engine.

The policy zoo (repro.core.POLICY_REGISTRY) trades quality for compute along
method-specific hyperparameters; which point is right depends on the traffic
class being served ("interactive" preview traffic tolerates lower PSNR for
latency; "quality" traffic does not).  The autotuner sweeps candidate
(policy, hyperparams) pairs on a small calibration batch against the exact
(uncached) trajectory and picks, per traffic class, the cheapest candidate
that still meets the SLA:

    minimize   compute_fraction                 (~ 1/speedup, survey §III-B)
    subject to PSNR(x0_policy, x0_exact) >= sla.min_psnr
               est_latency <= sla.max_latency_ms     (when step times given)

Falling back to the highest-PSNR candidate when nothing is feasible keeps
the server serving rather than erroring on an over-tight SLA.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import CachePolicy, make_policy
from repro.core.metrics import psnr
from repro.diffusion import ddim_step, linear_schedule, sample
from repro.diffusion.pipeline import CachedDenoiser, cfg_denoise_fn


@dataclass(frozen=True)
class SLA:
    """Per-traffic-class serving objective."""
    name: str = "default"
    min_psnr: float = 20.0           # quality floor vs the exact trajectory
    max_latency_ms: Optional[float] = None  # per-request budget (optional)


@dataclass
class TunedPolicy:
    """Autotuner output: a constructible policy choice + its measurements."""
    policy_name: str
    kwargs: Dict = field(default_factory=dict)
    psnr: float = 0.0
    compute_fraction: float = 1.0
    est_latency_ms: Optional[float] = None
    feasible: bool = True

    def make(self) -> CachePolicy:
        return make_policy(self.policy_name, **self.kwargs)

    @property
    def align(self) -> int:
        """Phase-alignment interval for the serving scheduler."""
        return max(int(self.kwargs.get("interval", 1)), 1)


#: default sweep: one representative per taxonomy branch, two operating
#: points for the interval-scheduled families
DEFAULT_CANDIDATES: List[Tuple[str, Dict]] = [
    ("none", {}),
    ("fora", {"interval": 2}),
    ("fora", {"interval": 4}),
    ("taylorseer", {"interval": 2, "order": 1}),
    ("taylorseer", {"interval": 4, "order": 2}),
    ("teacache", {"delta": 0.1}),
    ("teacache", {"delta": 0.3}),
    ("magcache", {"delta": 0.1}),
    ("freqca", {"interval": 4}),
]


def _measured_compute_fraction(policy: CachePolicy, state, num_steps: int) -> float:
    """Computes issued / steps, from whichever counter the policy keeps."""
    pol = state.get("policy", {}) if isinstance(state, dict) else {}
    if isinstance(pol, dict):
        for key in ("n_compute", "n_valid"):
            if key in pol:
                return float(np.asarray(pol[key])) / max(num_steps, 1)
    sched = policy.static_schedule(num_steps)
    if sched is not None:
        return sum(map(bool, sched)) / max(num_steps, 1)
    return 1.0


def calibration_reference(params, cfg, num_steps: int, batch: int = 1,
                          seed: int = 0, noise_schedule=None):
    """Exact (uncached) calibration trajectory shared by all candidates."""
    sched = noise_schedule or linear_schedule(1000)
    ts = sched.spaced(num_steps)
    xT = jax.random.normal(jax.random.PRNGKey(seed),
                           (batch, cfg.dit_patch_tokens, cfg.dit_in_dim))
    exact, _ = sample(cfg_denoise_fn(params, cfg, 0.0), xT, ts, sched,
                      step_fn=ddim_step)
    return sched, ts, xT, np.asarray(exact)


def evaluate_candidate(name: str, kwargs: Dict, params, cfg, sched, ts, xT,
                       exact: np.ndarray) -> Tuple[float, float]:
    """Run one candidate on the calibration trajectory.

    Returns (psnr_db, compute_fraction)."""
    policy = make_policy(name, **kwargs)
    den = CachedDenoiser(params, cfg, policy)
    x0, state = sample(den, xT, ts, sched, step_fn=ddim_step,
                       denoiser_state=den.init_state(xT.shape[0]))
    q = float(psnr(np.asarray(x0), exact))
    cf = _measured_compute_fraction(policy, state, len(ts))
    return q, cf


def autotune(params, cfg, sla: SLA,
             candidates: Optional[Sequence[Tuple[str, Dict]]] = None,
             num_steps: int = 16, batch: int = 1, seed: int = 0,
             noise_schedule=None,
             step_time_ms: Optional[Tuple[float, float]] = None,
             verbose: bool = False) -> TunedPolicy:
    """Sweep candidates against `sla` on a calibration batch.

    step_time_ms: measured (full_tick_ms, skip_tick_ms) from a prior serving
    run (ServingTelemetry summary) — enables the latency constraint; without
    it only the PSNR floor is enforced.
    """
    candidates = list(candidates if candidates is not None
                      else DEFAULT_CANDIDATES)
    sched, ts, xT, exact = calibration_reference(
        params, cfg, num_steps, batch, seed, noise_schedule)

    evaluated: List[TunedPolicy] = []
    for name, kwargs in candidates:
        # resolve the full hyperparameters here so TunedPolicy.make()
        # reconstructs exactly what was calibrated (magcache sizes its
        # gamma curve from num_steps)
        kwargs = dict(kwargs)
        kwargs.setdefault("num_steps", num_steps)
        q, cf = evaluate_candidate(name, kwargs, params, cfg, sched, ts, xT,
                                   exact)
        lat = None
        if step_time_ms is not None:
            t_full, t_skip = step_time_ms
            lat = num_steps * (cf * t_full + (1.0 - cf) * t_skip)
        ok = q >= sla.min_psnr and (
            lat is None or sla.max_latency_ms is None
            or lat <= sla.max_latency_ms)
        evaluated.append(TunedPolicy(name, dict(kwargs), psnr=q,
                                     compute_fraction=cf, est_latency_ms=lat,
                                     feasible=ok))
        if verbose:
            print(f"  [{sla.name}] {name:12s} {kwargs} "
                  f"psnr={q:6.2f}dB cf={cf:.3f} "
                  f"{'ok' if ok else 'infeasible'}")

    feasible = [t for t in evaluated if t.feasible]
    if feasible:
        # cheapest feasible; quality breaks ties
        return min(feasible, key=lambda t: (t.compute_fraction, -t.psnr))
    # nothing meets the SLA: serve the closest-to-exact candidate
    best = max(evaluated, key=lambda t: t.psnr)
    best.feasible = False
    return best


def autotune_traffic_classes(params, cfg, slas: Mapping[str, SLA],
                             **kw) -> Dict[str, TunedPolicy]:
    """One tuned policy per traffic class (e.g. interactive vs quality)."""
    return {name: autotune(params, cfg, sla, **kw)
            for name, sla in slas.items()}
