"""DiffusionServingEngine — step-interleaved continuous batching for
latent generation with per-slot cache states, including classifier-free
guidance with per-slot CFG-branch reuse (FasterCacheCFG, survey §III-C).

Device side, every tick is one of exactly three jit'd programs over the
whole slot pool (no per-request compilation, arbitrary request mixes):

  * tick_full — both-branch backbone: cond and uncond rows stacked into one
    2S-row batch (slot axis == batch axis, backbone outside vmap), then the
    vmapped per-slot policy step: each slot's main policy takes its own
    COMPUTE / REUSE / FORECAST branch and its FasterCacheCFG state gates the
    uncond row the same way (lax.cond vmaps to a select).  Dispatched only
    when some active guided slot's CFG policy wants a fresh uncond compute.
  * tick_cond_only — backbone over the S cond rows only; every active slot
    reuses (blend-extrapolates) its cached uncond branch, so the uncond rows
    are dropped from the backbone batch entirely.  For unguided pools this
    is the only backbone program — it is PR 2's tick_full.
  * tick_skip — no backbone at all; dispatched when no slot wants any
    compute.  These ticks cost only forecast/reuse arithmetic.

CFG doubles backbone cost; FasterCacheCFG(interval=N) makes (N-1)/N of
backbone ticks cond-only, recovering most of the doubled cost — serving
throughput lands between 1x and 2x of naive two-branch serving
(benchmarks/bench_serving.py --cfg).

Host side, the SlotScheduler refills finished slots from the admission
queue mid-flight.  Refill resets the slot's combined cache state — main
policy AND CFG branch — to a fresh `init_state` (reset-on-refill): slot
reuse must never leak either cache between requests.  Guided and unguided
requests share one pool; an unguided slot's uncond output is discarded by a
select (never blended), and its `want_uncond` is masked off so pure-unguided
pools never pay for the 2S-row program.

The DDIM update is re-derived here in traced per-slot form (gathered
alpha-bar tables instead of Python-float arithmetic) because slots sit at
different timesteps of *different* step-budget grids within one program.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CachePolicy, SlotBatchedPolicy, cache_state_bytes,
                        make_policy)
from repro.diffusion import NoiseSchedule, linear_schedule
from repro.diffusion.pipeline import slot_cfg_denoise_fns

from .scheduler import DiffusionRequest, SlotScheduler
from .telemetry import RequestRecord, ServingTelemetry


def request_noise_key(req: DiffusionRequest):
    """Per-request PRNG key for the initial latent noise.

    Folds the request id into the user seed: requests left at the default
    `seed=0` must still draw *distinct* initial noise (identical seeds once
    made every default request produce the identical sample)."""
    return jax.random.fold_in(jax.random.PRNGKey(req.seed), req.request_id)


@dataclass
class DiffusionResult:
    """One served request: final latent sample + its telemetry record."""
    request_id: int
    x0: np.ndarray
    record: RequestRecord


class DiffusionServingEngine:
    """Fixed-slot continuous-batching server over one DiT backbone."""

    def __init__(self, params, cfg, policy: Union[CachePolicy, str, None] = None,
                 *, slots: int = 8, max_steps: int = 64,
                 noise_schedule: Optional[NoiseSchedule] = None,
                 align: Optional[int] = None,
                 cfg_policy: Union[CachePolicy, str, None] = None):
        self.params, self.cfg = params, cfg
        self.slots = slots
        self.max_steps = max_steps
        self.sched = noise_schedule or linear_schedule(1000)
        if isinstance(policy, str):
            policy = make_policy(policy)
        self.policy = policy if policy is not None else make_policy("none")
        # uncond-branch gate for guided requests; None = naive two-branch
        # serving (every guided slot recomputes its uncond row each step)
        if isinstance(cfg_policy, str):
            cfg_policy = make_policy(cfg_policy, num_steps=max_steps)
        self.cfg_policy = cfg_policy
        # phase-aligned admission: default to the lcm of the two compute
        # intervals so both branches' refreshes land on shared ticks
        if align is not None:
            self.align = align
        else:
            a = max(int(getattr(self.policy, "interval", 1)), 1)
            b = max(int(getattr(cfg_policy, "interval", 1)), 1) \
                if cfg_policy is not None else 1
            self.align = a * b // math.gcd(a, b)

        T, D = cfg.dit_patch_tokens, cfg.dit_in_dim
        self._feat = (1, T, D)                      # per-slot policy feature
        self._sig_shape = (1, T, cfg.d_model)       # TeaCache signal shape
        self.batched = SlotBatchedPolicy(self.policy, slots)
        (backbone2_fn, backbone_fn, apply_fn, want_cond_fn,
         want_uncond_fn) = slot_cfg_denoise_fns(params, cfg, self.policy,
                                                cfg_policy)
        # combined per-slot state: main policy branch + uncond CFG branch
        # (an empty dict when cfg_policy is None — NoCachePolicy is stateless)
        uncond_pol = self.cfg_policy
        self._fresh = {
            "policy": self.batched.init_slot_state(
                self._feat, signal_shape=self._sig_shape),
            "cfg": (uncond_pol.init_state(self._feat)
                    if uncond_pol is not None else {}),
        }

        def make_tick(mode: str):
            def tick(states, steps, xs, tvals, labels, nulls, scales, cfg_ws,
                     ab_t, ab_n):
                # the backbone runs OUTSIDE vmap: slot axis == batch axis
                if mode == "full":
                    y_c, y_u = backbone2_fn(xs, tvals, labels, nulls)
                elif mode == "cond":
                    y_c, y_u = backbone_fn(xs, tvals, labels), jnp.zeros_like(xs)
                else:
                    y_c = y_u = jnp.zeros_like(xs)
                eps, states = jax.vmap(apply_fn)(states, steps, xs, tvals,
                                                 labels, scales, cfg_ws,
                                                 y_c, y_u)
                a_t = ab_t[:, None, None]
                a_n = ab_n[:, None, None]
                x0_hat = (xs - jnp.sqrt(1.0 - a_t) * eps) / jnp.sqrt(a_t)
                x_next = jnp.sqrt(a_n) * x0_hat + jnp.sqrt(1.0 - a_n) * eps
                return x_next, states
            return jax.jit(tick)

        self._ticks = {kind: make_tick(kind)
                       for kind in ("full", "cond", "skip")}
        self._want_cond = jax.jit(
            lambda states, steps, xs, tvals, labels:
            jax.vmap(want_cond_fn)(states, steps, xs, tvals, labels))
        self._want_uncond = jax.jit(
            lambda states, steps, xs, guided:
            jax.vmap(want_uncond_fn)(states, steps, xs, guided))

        def refill(xs, states, slot, noise, fresh):
            return (xs.at[slot].set(noise),
                    SlotBatchedPolicy.reset_slot(states, slot, fresh))

        self._refill = jax.jit(refill)

        # Policies whose want_compute depends only on the step (interval
        # schedules, or the conservative always-True default) admit a
        # host-side compute plan with no device round trip.  Deriving it
        # from want_compute itself — NOT static_schedule — keeps the plan
        # sound for policies like ToCa whose off-schedule branch still
        # calls compute_fn: their base want_compute is True everywhere, so
        # they simply never get skip ticks.  State-dependent predicates
        # (TeaCache & co) raise on the None state and take the device path.
        self._static_plan = self._probe_static_plan(self.policy)
        # the uncond mirror: all-True when cfg_policy is None (naive mode)
        self._static_cfg_plan = (
            self._probe_static_plan(uncond_pol) if uncond_pol is not None
            else np.ones((max_steps,), bool))

        # host-side per-slot timestep tables, padded to max_steps (+1 for the
        # terminal alpha-bar = 1.0 that closes the DDIM update)
        self._ab = np.ones((slots, max_steps + 1), np.float32)
        self._tv = np.zeros((slots, max_steps), np.float32)
        self._labels = np.zeros((slots,), np.int32)
        self._nulls = np.full((slots,), cfg.dit_num_classes, np.int32)
        self._scales = np.zeros((slots,), np.float32)
        self._nsteps = np.ones((slots,), np.int32)
        self._guided = np.zeros((slots,), bool)
        #: ServingTelemetry of the most recent serve() call
        self.telemetry: Optional[ServingTelemetry] = None

    def _probe_static_plan(self, policy: CachePolicy) -> Optional[np.ndarray]:
        try:
            return np.asarray(
                [bool(policy.want_compute(None, s, None))
                 for s in range(self.max_steps)], bool)
        except Exception:
            return None

    # ------------------------------------------------------------------
    def _install_request(self, slot: int, req: DiffusionRequest) -> None:
        ts = self.sched.spaced(req.num_steps)
        abar = self.sched.alpha_bars[ts].astype(np.float32)
        self._ab[slot, :] = 1.0
        self._ab[slot, :req.num_steps] = abar
        self._tv[slot, :] = 0.0
        self._tv[slot, :req.num_steps] = ts.astype(np.float32)
        self._labels[slot] = req.class_label
        self._nulls[slot] = (req.null_label if req.null_label is not None
                             else self.cfg.dit_num_classes)
        self._scales[slot] = req.cfg_scale
        self._nsteps[slot] = req.num_steps
        self._guided[slot] = req.guided

    def _plan(self, states, steps, xs, tvals) -> np.ndarray:
        """Per-slot cond-branch compute decision (before masking)."""
        if self._static_plan is not None:
            return self._static_plan[steps]
        labels = jnp.asarray(self._labels)
        return np.asarray(self._want_cond(states, jnp.asarray(steps), xs,
                                          jnp.asarray(tvals), labels))

    def _plan_uncond(self, states, steps, xs) -> np.ndarray:
        """Per-slot uncond-branch compute decision (before active masking).

        Already masked by the per-slot guided flag — unguided slots never
        request an uncond compute."""
        if self._static_cfg_plan is not None:
            return self._static_cfg_plan[steps] & self._guided
        return np.asarray(self._want_uncond(states, jnp.asarray(steps), xs,
                                            jnp.asarray(self._guided)))

    # ------------------------------------------------------------------
    def serve(self, requests: Sequence[DiffusionRequest],
              telemetry: Optional[ServingTelemetry] = None,
              max_ticks: Optional[int] = None) -> List[DiffusionResult]:
        """Run every request through the slot pool; returns results in
        request order.  With max_ticks, unfinished requests are recorded as
        preempted in telemetry (never silently dropped)."""
        for r in requests:
            if r.num_steps > self.max_steps:
                raise ValueError(f"request {r.request_id}: num_steps="
                                 f"{r.num_steps} > max_steps={self.max_steps}")
        tele = telemetry if telemetry is not None else ServingTelemetry()
        tele.cache_state_bytes_per_slot = cache_state_bytes(self._fresh)
        tele.start()

        sched = SlotScheduler(self.slots, self.align)
        now = time.perf_counter
        recs: Dict[int, RequestRecord] = {
            r.request_id: RequestRecord(r.request_id, r.num_steps,
                                        r.traffic_class,
                                        cfg_scale=r.cfg_scale,
                                        enqueue_time=now())
            for r in requests}
        sched.submit_all(requests)

        T, D = self.cfg.dit_patch_tokens, self.cfg.dit_in_dim
        xs = jnp.zeros((self.slots, T, D), jnp.float32)
        states = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (self.slots,) + a.shape).copy(),
            self._fresh)

        results: Dict[int, DiffusionResult] = {}
        tick = 0
        while not sched.idle():
            # -- refill free slots from the queue (phase-aligned) -------
            for slot, req in sched.admit(tick):
                noise = jax.random.normal(request_noise_key(req), (T, D))
                xs, states = self._refill(xs, states, slot.index, noise,
                                          self._fresh)
                self._install_request(slot.index, req)
                rec = recs[req.request_id]
                rec.admit_time = now()
                rec.admit_tick = tick
                rec.slot = slot.index

            active = np.asarray(sched.active_mask())
            steps = np.asarray(sched.steps(), np.int32)
            idx = np.minimum(steps, self.max_steps - 1)
            rows = np.arange(self.slots)
            tvals = self._tv[rows, idx]
            ab_t = self._ab[rows, idx]
            ab_n = self._ab[rows, idx + 1]
            # per-slot trajectory-progress weight for FasterCacheCFG's blend
            cfg_ws = idx.astype(np.float32) / np.maximum(self._nsteps - 1, 1)

            want_c = self._plan(states, idx, xs, tvals) & active
            want_u = self._plan_uncond(states, idx, xs) & active
            if want_u.any():
                kind = "full"          # some slot refreshes its uncond cache
            elif want_c.any():
                kind = "cond"          # uncond rows dropped from the batch
            else:
                kind = "skip"
            t0 = now()
            xs, states = self._ticks[kind](
                states, jnp.asarray(idx), xs, jnp.asarray(tvals),
                jnp.asarray(self._labels), jnp.asarray(self._nulls),
                jnp.asarray(self._scales), jnp.asarray(cfg_ws),
                jnp.asarray(ab_t), jnp.asarray(ab_n))
            xs.block_until_ready()
            tele.record_tick(kind, now() - t0)
            if kind == "full":
                tele.uncond_rows_computed += self.slots
            else:
                tele.uncond_rows_saved += int((active & self._guided).sum())

            for slot in sched.slots:
                if slot.busy and want_c[slot.index]:
                    recs[slot.request.request_id].computed_steps += 1
                if slot.busy and want_u[slot.index]:
                    recs[slot.request.request_id].uncond_computed_steps += 1

            # -- advance + harvest finished slots -----------------------
            sched.advance()
            for slot, req in sched.harvest():
                rec = recs[req.request_id]
                rec.finish_time = now()
                rec.finish_tick = tick + 1
                tele.finish_request(rec)
                results[req.request_id] = DiffusionResult(
                    req.request_id, np.asarray(xs[slot.index]), rec)

            tick += 1
            if max_ticks is not None and tick >= max_ticks:
                break

        # requests cut off by max_ticks (mid-flight or still queued) are
        # reported as preempted, never silently dropped with half-filled
        # records poisoning the latency aggregates
        for r in requests:
            if r.request_id not in results:
                tele.preempt_request(recs[r.request_id])

        tele.stop()
        self.telemetry = tele
        return [results[r.request_id] for r in requests
                if r.request_id in results]
