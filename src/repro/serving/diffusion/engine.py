"""DiffusionServingEngine — step-interleaved continuous batching for
latent generation with per-slot cache states, including classifier-free
guidance with per-slot CFG-branch reuse (FasterCacheCFG, survey §III-C).

Device side, every tick gathers EXACTLY the backbone rows the per-slot
policies want computed this tick (row compaction, the default):

  * Each active slot contributes a cond row iff its main policy wants a
    compute and an uncond row iff it is guided and its CFG policy wants an
    uncond refresh.  The wanted rows are gathered into one compacted batch,
    padded to the next power-of-two bucket, run through the backbone (slot
    axis == batch axis, backbone outside vmap), and scattered back to the
    S-row y_c / y_u layout before the vmapped per-slot policy step — each
    slot still takes its own COMPUTE / REUSE / FORECAST branch (lax.cond
    vmaps to a select), rows that were not gathered arrive as zeros and may
    only reach discarded branches.  One jit program per bucket size (all
    gather/scatter indices are traced), so the program count is bounded by
    log2(2S) + 2 regardless of request mix.
  * A tick with zero wanted rows dispatches the skip program — no backbone
    at all, only forecast/reuse arithmetic.

This is the batch dimension's version of block-level partial computing
(DeepCache / Cache-Me-if-You-Can): a TeaCache pool where one slot fires
dispatches a 1-row bucket, not a whole-pool batch, and a mixed
guided/unguided pool pays per uncond row instead of doubling the batch
whenever any slot refreshes its CFG branch.

`row_compaction=False` restores the dense engine — one of exactly three
whole-pool programs per tick (tick_full over 2S rows, tick_cond_only over S
rows, tick_skip) — kept as the equivalence/benchmark baseline; the
compacted engine must reproduce its per-request outputs exactly
(tests/test_serving_compaction.py).  The tick *kinds* full/cond/skip are
still reported either way; under compaction they classify which branches
the gathered rows came from while the row counters carry the real cost.

Modalities: the engine serves whatever backbone the config selects —
image/audio DiT or the factorized video DiT (`cfg.dit_num_frames > 0`);
latent rows are (cfg.dit_tokens, cfg.dit_in_dim) either way.  One engine
instance hosts ONE modality (token shapes must agree across slots);
repro.modalities.MixedModalityEngine runs several engines as per-modality
sub-pools under one scheduler/telemetry umbrella by driving the
tick-granular `ServeSession` API below instead of the blocking `serve()`.

CFG doubles backbone cost; FasterCacheCFG(interval=N) drops each slot's
uncond row from (N-1)/N of its backbone ticks — serving throughput lands
between 1x and 2x of naive two-branch serving
(benchmarks/bench_serving.py --cfg).  A request's `null_label` may be an
arbitrary conditioning VECTOR (negative prompt) instead of a class id; the
engine threads it through the uncond rows as a per-slot embedding override.

Host side, the SlotScheduler refills finished slots from the admission
queue mid-flight.  Refill resets the slot's combined cache state — main
policy AND CFG branch — to a fresh `init_state` (reset-on-refill): slot
reuse must never leak either cache between requests.  Guided and unguided
requests share one pool; an unguided slot's uncond output is discarded by a
select (never blended), and its `want_uncond` is masked off so pure-unguided
pools never pay for the 2S-row program.

The DDIM update is re-derived here in traced per-slot form (gathered
alpha-bar tables instead of Python-float arithmetic) because slots sit at
different timesteps of *different* step-budget grids within one program.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CachePolicy, SlotBatchedPolicy, cache_state_bytes,
                        make_policy)
from repro.diffusion import NoiseSchedule, linear_schedule
from repro.diffusion.pipeline import slot_compact_denoise_fns, slot_want_fns
from repro.models import dit
from repro.obs.clock import monotonic
from repro.obs.profiling import ProgramIR, ProgramProfile, compile_program

from .scheduler import DiffusionRequest, SlotScheduler
from .telemetry import RequestRecord, ServingTelemetry


def request_noise_key(req: DiffusionRequest):
    """Per-request PRNG key for the initial latent noise.

    Folds the request id into the user seed: requests left at the default
    `seed=0` must still draw *distinct* initial noise (identical seeds once
    made every default request produce the identical sample)."""
    return jax.random.fold_in(jax.random.PRNGKey(req.seed), req.request_id)


def compact_rows(want_c: np.ndarray, want_u: np.ndarray, slots: int):
    """Plan one row-compacted tick from the per-slot want masks.

    Returns (bucket, row_slot, row_uncond, row_dest): the wanted cond rows
    first, then the wanted uncond rows, padded to the next power-of-two
    bucket (capped at the tick's dense batch — `slots` for cond-only ticks,
    `2*slots` otherwise) so the engine compiles at most one tick program per
    bucket size.
    `row_slot[b]` is the source slot of compacted row b, `row_uncond[b]`
    selects the null label, and `row_dest[b]` is the scatter target in the
    (2*slots + 1)-row buffer: cond row of slot i -> i, uncond row -> slots+i,
    padding -> the 2*slots dump row (discarded).  bucket == 0 means a pure
    skip tick (no backbone program at all)."""
    c_rows = np.nonzero(want_c)[0].astype(np.int32)
    u_rows = np.nonzero(want_u)[0].astype(np.int32)
    n = len(c_rows) + len(u_rows)
    if n == 0:
        z = np.zeros((0,), np.int32)
        return 0, z, np.zeros((0,), bool), z
    # capped at this tick's dense batch (S for cond-only ticks, 2S when any
    # uncond row is gathered): for non-power-of-two slot counts the next
    # power of two can overshoot the whole-pool batch, which would make a
    # busy compacted tick dispatch MORE rows than the dense engine
    cap = 2 * slots if len(u_rows) else slots
    bucket = min(1 << (int(n) - 1).bit_length(), cap)
    row_slot = np.zeros((bucket,), np.int32)
    row_uncond = np.zeros((bucket,), bool)
    row_dest = np.full((bucket,), 2 * slots, np.int32)
    row_slot[:len(c_rows)] = c_rows
    row_dest[:len(c_rows)] = c_rows
    row_slot[len(c_rows):n] = u_rows
    row_uncond[len(c_rows):n] = True
    row_dest[len(c_rows):n] = u_rows + slots
    return bucket, row_slot, row_uncond, row_dest


@dataclass
class DiffusionResult:
    """One served request: final latent sample + its telemetry record."""
    request_id: int
    x0: np.ndarray
    record: RequestRecord


@dataclass
class TickEvent:
    """Everything one engine tick decided and produced, for observer hooks.

    ServeSession calls each hook with one TickEvent per tick (after
    harvest), which is how the control plane (repro.serving.control)
    watches a live engine: TelemetryWindow derives sliding-window row
    pricing and occupancy from it, SignalTraceLog records per-slot
    want/metric traces.  All arrays are host-side copies indexed by slot;
    slots not active this tick carry request_id -1.

    `metric` is the per-slot `CachePolicy.want_metric` scalar (the value
    the refresh decision thresholded on); None when the engine planned the
    tick from a host-side static schedule (no device metric exists).
    `plan_seconds` is the host time spent DECIDING the tick (the fused
    want pass + its device_get sync for state-dependent policies; ~0 for
    static schedules planned on the host) — the overhead the online
    tuner's cost model charges non-static candidates per step.
    `latents` is the pre-tick (slots, tokens, in_dim) latent batch — only
    populated when the session was started with `capture_latents=True`
    (it costs a device transfer per tick)."""
    tick: int
    modality: str
    kind: str                       # "full" | "cond" | "skip"
    seconds: float                  # device time of this tick's program
    rows_computed: int
    rows_padding: int
    active: np.ndarray              # (S,) bool
    request_ids: np.ndarray         # (S,) int64, -1 = free slot
    steps: np.ndarray               # (S,) int32 per-slot step index
    tvals: np.ndarray               # (S,) float32 model-facing timesteps
    labels: np.ndarray              # (S,) int32 class conditioning
    guided: np.ndarray              # (S,) bool
    want_cond: np.ndarray           # (S,) bool, after active masking
    want_uncond: np.ndarray         # (S,) bool, after active masking
    plan_seconds: float = 0.0       # host time of the want/plan decision
    metric: Optional[np.ndarray] = None     # (S,) float32 or None
    latents: Optional[np.ndarray] = None    # (S, T, D) pre-tick, opt-in
    admitted: List[DiffusionRequest] = field(default_factory=list)
    finished: List[RequestRecord] = field(default_factory=list)


#: observer hook signature: called once per tick, must not mutate the engine
TickHook = Callable[[TickEvent], None]


class ServeSession:
    """One in-flight batch of requests, advanced one tick at a time.

    `DiffusionServingEngine.serve()` drives a session to completion; the
    mixed-modality engine (repro.modalities) interleaves the sessions of
    several per-modality sub-pools under one umbrella by calling `tick()`
    round-robin and `finish()` once every session reports `done`."""

    def __init__(self, engine: "DiffusionServingEngine",
                 requests: Sequence[DiffusionRequest],
                 telemetry: Optional[ServingTelemetry] = None,
                 hooks: Optional[Sequence[TickHook]] = None,
                 capture_latents: bool = False,
                 modality: Optional[str] = None,
                 metrics=None):
        for r in requests:
            self._validate(engine, r)
        # per-slot timestep/conditioning tables live on the engine, so two
        # interleaved sessions of one engine would corrupt each other
        if engine._session_active:
            raise RuntimeError(
                "engine already has a session in flight; finish() it first "
                "(use one engine per modality sub-pool, never shared)")
        engine._session_active = True
        self.engine = engine
        self.requests = list(requests)
        #: observer hooks, called once per tick with a TickEvent
        self.hooks: List[TickHook] = list(hooks or ())
        #: copy the pre-tick latent batch into each TickEvent (opt-in:
        #: costs one device transfer per tick; the control plane's probe
        #: logging needs it to replay the backbone offline)
        self.capture_latents = bool(capture_latents)
        #: modality label stamped on TickEvents (an engine hosts ONE
        #: modality); inferred from the first request when not given
        self.modality = (modality if modality is not None
                         else (requests[0].modality if requests else "image"))
        self.tele = telemetry if telemetry is not None else ServingTelemetry()
        self.tele.cache_state_bytes_per_slot = cache_state_bytes(engine._fresh)
        self.tele.start()
        #: opt-in repro.obs.MetricsRegistry — tick paths, scheduler
        #: admission, and request lifecycle publish into it; None costs
        #: nothing (naming: repro_<subsystem>_<metric>_<unit>)
        self.metrics = metrics

        self.sched = SlotScheduler(engine.slots, engine.align)
        if metrics is not None:
            self.sched.bind_metrics(metrics, modality=self.modality)
        now = monotonic
        self.recs: Dict[int, RequestRecord] = {
            r.request_id: RequestRecord(r.request_id, r.num_steps,
                                        r.traffic_class,
                                        cfg_scale=r.cfg_scale,
                                        modality=r.modality,
                                        enqueue_time=now())
            for r in requests}
        self.sched.submit_all(requests)

        T, D = engine.tokens, engine.in_dim
        self.xs = jnp.zeros((engine.slots, T, D), jnp.float32)
        self.states = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None],
                                       (engine.slots,) + a.shape).copy(),
            engine._fresh)
        # device-resident negative-prompt tables: (slots, d_model) is the
        # one per-slot operand that grows with the model, so it is uploaded
        # only when admission changes it, not on every tick
        self._null_vecs = jnp.asarray(engine._null_vecs)
        self._null_mask = jnp.asarray(engine._null_mask)
        # device-resident per-slot cross-attn text tables (K/V + masks for
        # prompt and negative prompt), rebuilt only when admission changes
        # a slot's prompt — text is step-invariant, so every tick reuses
        # them verbatim ({} on text-free engines: zero operand leaves)
        self._txt = engine._build_text_tables()
        self.results: Dict[int, DiffusionResult] = {}
        self.ticks = 0
        self._finished = False

    @staticmethod
    def _validate(engine: "DiffusionServingEngine",
                  r: DiffusionRequest) -> None:
        """Reject malformed requests before any work runs, not at admission
        deep inside a tick — same contract as admission itself
        (engine._check_request is the single source of truth)."""
        engine._check_request(r)

    @property
    def done(self) -> bool:
        return self.sched.idle()

    # ------------------------------------------------------------------
    def submit(self, request: DiffusionRequest) -> None:
        """Mid-session admission: enqueue one more request on a live
        session.  It is admitted at the next phase-aligned tick with a free
        slot (reset-on-refill applies exactly as for initial requests).
        This is what lets the control plane keep one session serving an
        open-ended stream instead of batching requests up front."""
        if self._finished:
            raise RuntimeError("session already finished; submit to a new "
                               "session instead")
        if request.request_id in self.recs:
            raise ValueError(f"request id {request.request_id} already "
                             f"submitted to this session")
        self._validate(self.engine, request)
        self.requests.append(request)
        self.recs[request.request_id] = RequestRecord(
            request.request_id, request.num_steps, request.traffic_class,
            cfg_scale=request.cfg_scale, modality=request.modality,
            enqueue_time=monotonic())
        self.sched.submit(request)

    def transfer_queued(self) -> List[DiffusionRequest]:
        """Pop every request still waiting in the admission queue (never
        admitted to a slot) and drop its bookkeeping here, so the caller
        can resubmit it to another session.  The control plane's blue/green
        rollover uses this: in-flight slots drain on this session under the
        policy that admitted them, while the un-admitted backlog follows
        the session that will actually admit it — otherwise a rollover
        would strand the backlog on the outgoing policy."""
        moved = self.sched.queue.pop_many(len(self.sched.queue))
        for r in moved:
            del self.recs[r.request_id]
            self.requests.remove(r)
        return moved

    # ------------------------------------------------------------------
    def tick(self) -> None:
        """One engine tick: refill free slots, plan the wanted rows,
        dispatch the matching program, advance and harvest."""
        if self._finished:
            raise RuntimeError("session already finished; the engine's "
                               "per-slot tables may belong to a new session")
        eng, sched, tele = self.engine, self.sched, self.tele
        now = monotonic
        T, D = eng.tokens, eng.in_dim

        # -- refill free slots from the queue (phase-aligned) -------
        admitted = sched.admit(self.ticks)
        for slot, req in admitted:
            noise = jax.random.normal(request_noise_key(req), (T, D))
            self.xs, self.states = eng._refill(self.xs, self.states,
                                               slot.index, noise, eng._fresh)
            eng._install_request(slot.index, req)
            rec = self.recs[req.request_id]
            rec.admit_time = now()
            rec.admit_tick = self.ticks
            rec.slot = slot.index
        if admitted:
            self._null_vecs = jnp.asarray(eng._null_vecs)
            self._null_mask = jnp.asarray(eng._null_mask)
            # one text_kv pass per admission wave (not per tick): project
            # the newly installed prompt embeddings to per-slot K/V tables
            self._txt = eng._build_text_tables()

        active = np.asarray(sched.active_mask())
        steps = np.asarray(sched.steps(), np.int32)
        idx = np.minimum(steps, eng.max_steps - 1)
        rows = np.arange(eng.slots)
        tvals = eng._tv[rows, idx]
        ab_t = eng._ab[rows, idx]
        ab_n = eng._ab[rows, idx + 1]
        # per-slot trajectory-progress weight for FasterCacheCFG's blend
        cfg_ws = idx.astype(np.float32) / np.maximum(eng._nsteps - 1, 1)

        # per-slot request ids + optional pre-tick latents, captured BEFORE
        # the device tick / harvest mutate them (for the TickEvent)
        rids = np.asarray([s.request.request_id if s.busy else -1
                           for s in sched.slots], np.int64)
        latents = np.asarray(self.xs) if self.capture_latents else None

        t_plan = now()
        want_c, want_u, metric = eng._plan_all(self.states, idx, self.xs,
                                               tvals)
        plan_s = now() - t_plan
        want_c = want_c & active
        want_u = want_u & active
        n_c, n_u = int(want_c.sum()), int(want_u.sum())
        if n_u:
            kind = "full"          # some slot refreshes its uncond cache
        elif n_c:
            kind = "cond"          # cond-branch rows only
        else:
            kind = "skip"
        # rows a dense whole-pool tick of this kind dispatches (the dense
        # engine's actual batch; also what row compaction saves against)
        dense_rows = {"full": 2 * eng.slots, "cond": eng.slots,
                      "skip": 0}[kind]
        args = (self.states, jnp.asarray(idx), self.xs, jnp.asarray(tvals),
                jnp.asarray(eng._labels), jnp.asarray(eng._nulls),
                self._null_vecs, self._null_mask, self._txt,
                jnp.asarray(eng._scales), jnp.asarray(cfg_ws),
                jnp.asarray(ab_t), jnp.asarray(ab_n))
        if eng.row_compaction:
            bucket, row_slot, row_uncond, row_dest = compact_rows(
                want_c, want_u, eng.slots)
            t0 = now()
            self.xs, self.states = eng._compact_tick(bucket)(
                *args, jnp.asarray(row_slot), jnp.asarray(row_uncond),
                jnp.asarray(row_dest))
            self.xs.block_until_ready()
            tick_s = now() - t0
            rows_done = n_c + n_u
            rows_pad = bucket - rows_done
            tele.record_tick(kind, tick_s,
                             rows_computed=rows_done,
                             rows_padding=rows_pad,
                             rows_saved=dense_rows - rows_done)
        else:
            t0 = now()
            self.xs, self.states = eng._ticks[kind](*args)
            self.xs.block_until_ready()
            tick_s = now() - t0
            rows_done, rows_pad = dense_rows, 0
            tele.record_tick(kind, tick_s, rows_computed=dense_rows)
        # uncond accounting in rows actually refreshing a CFG cache: a
        # dense full tick used to add `slots`, over-counting inactive and
        # unguided slots into the autotuner's row cost
        tele.uncond_rows_computed += n_u
        tele.uncond_rows_saved += int(
            (active & eng._guided & ~want_u).sum())

        for slot in sched.slots:
            if slot.busy and want_c[slot.index]:
                self.recs[slot.request.request_id].computed_steps += 1
            if slot.busy and want_u[slot.index]:
                self.recs[slot.request.request_id].uncond_computed_steps += 1

        # -- advance + harvest finished slots -----------------------
        sched.advance()
        finished: List[RequestRecord] = []
        for slot, req in sched.harvest():
            rec = self.recs[req.request_id]
            rec.finish_time = now()
            rec.finish_tick = self.ticks + 1
            tele.finish_request(rec)
            finished.append(rec)
            self.results[req.request_id] = DiffusionResult(
                req.request_id, np.asarray(self.xs[slot.index]), rec)

        if self.metrics is not None:
            self._publish_tick(kind, tick_s, plan_s, rows_done, rows_pad,
                               dense_rows - rows_done
                               if eng.row_compaction else 0,
                               n_u, int(active.sum()), len(finished))

        if self.hooks:
            event = TickEvent(
                tick=self.ticks, modality=self.modality, kind=kind,
                seconds=tick_s, plan_seconds=plan_s,
                rows_computed=rows_done,
                rows_padding=rows_pad, active=active, request_ids=rids,
                steps=steps, tvals=np.asarray(tvals, np.float32),
                labels=eng._labels.copy(), guided=eng._guided.copy(),
                want_cond=want_c, want_uncond=want_u,
                metric=metric, latents=latents,
                admitted=[req for _, req in admitted], finished=finished)
            for hook in self.hooks:
                hook(event)

        self.ticks += 1

    def _publish_tick(self, kind: str, tick_s: float, plan_s: float,
                      rows_done: int, rows_pad: int, rows_saved: int,
                      n_u: int, occupancy: int, finished: int) -> None:
        """One tick's worth of registry updates (metric names follow
        repro_<subsystem>_<metric>_<unit>, labels carry dimensions)."""
        m, mod = self.metrics, self.modality
        m.counter("repro_engine_ticks_total",
                  "engine ticks by program kind").inc(
            kind=kind, modality=mod)
        m.counter("repro_engine_tick_seconds_total",
                  "device seconds of dispatched tick programs").inc(
            tick_s, kind=kind, modality=mod)
        m.counter("repro_engine_plan_seconds_total",
                  "host seconds spent deciding ticks (want pass)").inc(
            plan_s, modality=mod)
        m.counter("repro_engine_rows_computed_total",
                  "backbone rows carrying real per-slot work").inc(
            rows_done, modality=mod)
        m.counter("repro_engine_rows_padding_total",
                  "pow-2 bucket padding rows dispatched").inc(
            rows_pad, modality=mod)
        m.counter("repro_engine_rows_saved_total",
                  "rows a dense whole-pool tick would have added").inc(
            rows_saved, modality=mod)
        m.counter("repro_engine_uncond_rows_computed_total",
                  "uncond rows refreshing a CFG cache").inc(
            n_u, modality=mod)
        m.counter("repro_engine_requests_finished_total",
                  "requests completed").inc(finished, modality=mod)
        m.gauge("repro_engine_occupancy_slots",
                "busy slots at the latest tick").set(occupancy, modality=mod)
        m.histogram("repro_engine_tick_seconds",
                    "device tick time distribution").observe(
            tick_s, modality=mod)

    # ------------------------------------------------------------------
    def finish(self) -> List[DiffusionResult]:
        """Close the session: preempted accounting, telemetry stop, results
        in request order.  Idempotent."""
        if not self._finished:
            # requests cut off before completion (mid-flight or still
            # queued) are reported as preempted, never silently dropped with
            # half-filled records poisoning the latency aggregates
            for r in self.requests:
                if r.request_id not in self.results:
                    self.tele.preempt_request(self.recs[r.request_id])
                    if self.metrics is not None:
                        self.metrics.counter(
                            "repro_engine_requests_preempted_total",
                            "requests cut off before completion").inc(
                            modality=self.modality)
            self.tele.stop()
            self.engine.telemetry = self.tele
            self.engine._session_active = False
            self._finished = True
        return [self.results[r.request_id] for r in self.requests
                if r.request_id in self.results]


class DiffusionServingEngine:
    """Fixed-slot continuous-batching server over one DiT backbone."""

    def __init__(self, params, cfg, policy: Union[CachePolicy, str, None] = None,
                 *, slots: int = 8, max_steps: int = 64,
                 noise_schedule: Optional[NoiseSchedule] = None,
                 align: Optional[int] = None,
                 cfg_policy: Union[CachePolicy, str, None] = None,
                 row_compaction: bool = True,
                 conditioner=None):
        self.params, self.cfg = params, cfg
        self.slots = slots
        self.max_steps = max_steps
        self.row_compaction = bool(row_compaction)
        # text conditioning (T2I/T2V): a repro.conditioning.PromptCache that
        # resolves DiffusionRequest.prompt_tokens at admission; requires a
        # text-enabled config (per-block cross-attention branches)
        self.text_enabled = cfg.dit_text_len > 0
        if conditioner is not None and not self.text_enabled:
            raise ValueError(f"conditioner given but config '{cfg.name}' is "
                             f"not text-enabled (dit_text_len == 0)")
        self.conditioner = conditioner
        self.sched = noise_schedule or linear_schedule(1000)
        # string-built policies get the engine's actual geometry: num_steps
        # for step-indexed curves (magcache), frames for the temporal
        # policies (teacache_video's per-frame reduction must group by the
        # CONFIG's frame count, not the registry default)
        policy_kw = {"num_steps": max_steps}
        if cfg.dit_num_frames > 0:
            policy_kw["frames"] = cfg.dit_num_frames
        if isinstance(policy, str):
            policy = make_policy(policy, **policy_kw)
        self.policy = policy if policy is not None else make_policy("none")
        # uncond-branch gate for guided requests; None = naive two-branch
        # serving (every guided slot recomputes its uncond row each step)
        if isinstance(cfg_policy, str):
            cfg_policy = make_policy(cfg_policy, **policy_kw)
        self.cfg_policy = cfg_policy
        # phase-aligned admission: default to the lcm of the two compute
        # intervals so both branches' refreshes land on shared ticks
        if align is not None:
            self.align = align
        else:
            a = max(int(getattr(self.policy, "interval", 1)), 1)
            b = max(int(getattr(cfg_policy, "interval", 1)), 1) \
                if cfg_policy is not None else 1
            self.align = a * b // math.gcd(a, b)

        # latent row shape for this engine's modality (video folds the frame
        # axis into the token axis: dit_tokens = frames * per-frame patches)
        self.tokens, self.in_dim = cfg.dit_tokens, cfg.dit_in_dim
        T, D = self.tokens, self.in_dim
        self._feat = (1, T, D)                      # per-slot policy feature
        self._sig_shape = (1, T, cfg.d_model)       # TeaCache signal shape
        self.batched = SlotBatchedPolicy(self.policy, slots)
        (compact_backbone_fn, backbone2_fn, backbone_fn, apply_fn,
         want_cond_fn, want_uncond_fn) = slot_compact_denoise_fns(
            params, cfg, self.policy, cfg_policy)
        # combined per-slot state: main policy branch + uncond CFG branch
        # (an empty dict when cfg_policy is None — NoCachePolicy is stateless)
        uncond_pol = self.cfg_policy
        self._fresh = {
            "policy": self.batched.init_slot_state(
                self._feat, signal_shape=self._sig_shape),
            "cfg": (uncond_pol.init_state(self._feat)
                    if uncond_pol is not None else {}),
        }

        def slot_step(states, steps, xs, tvals, labels, scales, cfg_ws,
                      ab_t, ab_n, y_c, y_u):
            """Shared tail of every tick program: vmapped per-slot policy
            step + traced per-slot DDIM update."""
            eps, states = jax.vmap(apply_fn)(states, steps, xs, tvals,
                                             labels, scales, cfg_ws,
                                             y_c, y_u)
            a_t = ab_t[:, None, None]
            a_n = ab_n[:, None, None]
            x0_hat = (xs - jnp.sqrt(1.0 - a_t) * eps) / jnp.sqrt(a_t)
            x_next = jnp.sqrt(a_n) * x0_hat + jnp.sqrt(1.0 - a_n) * eps
            return x_next, states

        def make_tick(mode: str):
            """Dense whole-pool programs (row_compaction=False baseline):
            the backbone runs OUTSIDE vmap over S or 2S rows.  `txt` is the
            per-slot text-table dict — an EMPTY dict on text-free engines,
            which contributes zero jit operand leaves, so their program
            signature is exactly the pre-text one."""
            def tick(states, steps, xs, tvals, labels, nulls, null_vecs,
                     null_mask, txt, scales, cfg_ws, ab_t, ab_n):
                if mode == "full":
                    y_c, y_u = backbone2_fn(xs, tvals, labels, nulls,
                                            null_vecs, null_mask, txt=txt)
                elif mode == "cond":
                    y_c = backbone_fn(xs, tvals, labels, txt=txt)
                    y_u = jnp.zeros_like(xs)
                else:
                    y_c = y_u = jnp.zeros_like(xs)
                return slot_step(states, steps, xs, tvals, labels, scales,
                                 cfg_ws, ab_t, ab_n, y_c, y_u)
            return jax.jit(tick)

        def make_compact_tick(bucket: int):
            """One parameterized row-compacted program per bucket size: the
            backbone runs over the gathered `bucket`-row batch only; the
            scatter restores the S-row y_c / y_u layout (missing rows zero —
            they only reach branches the per-slot select discards).  All
            index operands are traced, so this compiles once per bucket."""
            def tick(states, steps, xs, tvals, labels, nulls, null_vecs,
                     null_mask, txt, scales, cfg_ws, ab_t, ab_n,
                     row_slot, row_uncond, row_dest):
                if bucket == 0:
                    y_c = y_u = jnp.zeros_like(xs)
                else:
                    y_c, y_u = compact_backbone_fn(xs, tvals, labels, nulls,
                                                   null_vecs, null_mask, txt,
                                                   row_slot, row_uncond,
                                                   row_dest)
                return slot_step(states, steps, xs, tvals, labels, scales,
                                 cfg_ws, ab_t, ab_n, y_c, y_u)
            return jax.jit(tick)

        # program builders are kept either way: repro.analysis.ir re-traces
        # programs through them to capture jaxprs AFTER warmup swapped the
        # tick caches to bare Compiled executables (which carry no jaxpr)
        self._make_compact_tick = make_compact_tick
        self._make_tick = make_tick
        if self.row_compaction:
            self._compact_ticks = {}   # bucket size -> jit'd program (lazy)
            self._ticks = None
        else:
            self._ticks = {kind: make_tick(kind)
                           for kind in ("full", "cond", "skip")}
        # fused plan pass: cond want + uncond want + trace metric in ONE
        # jitted call — the TeaCache signal is computed over the whole slot
        # batch outside vmap (repro.diffusion.pipeline.slot_want_fns), so a
        # signal-policy pool pays one batched embed and one device sync per
        # tick instead of per-slot singleton embeds and two syncs
        self._want_all = jax.jit(
            slot_want_fns(params, cfg, self.policy, cfg_policy))
        # the pre-compile jit wrapper, kept for IR re-capture (warmup swaps
        # self._want_all for its Compiled executable)
        self._want_src = self._want_all

        def build_text_tables(te, tm, ne, nm):
            """Per-slot cross-attn K/V over ALL layers at once, from the
            admission-time prompt / negative-prompt embedding tables.  Runs
            once per admission wave — text K/V is step-invariant, so no
            tick program carries a single text-projection FLOP.  Embeddings
            are re-zeroed under their masks (defense in depth: the zero-
            K/V + all-masked no-op branch must hold bit-exactly)."""
            te = jnp.where(tm[..., None], te, 0.0)
            ne = jnp.where(nm[..., None], ne, 0.0)
            tk, tv = dit.text_kv(params, te, cfg)
            nk, nv = dit.text_kv(params, ne, cfg)
            return {"k": tk, "v": tv, "mask": tm,
                    "nk": nk, "nv": nv, "nmask": nm}

        self._text_tables_src = build_text_tables
        self._text_tables = (jax.jit(build_text_tables)
                             if self.text_enabled else None)

        def refill(xs, states, slot, noise, fresh):
            return (xs.at[slot].set(noise),
                    SlotBatchedPolicy.reset_slot(states, slot, fresh))

        self._refill = jax.jit(refill)

        # Policies whose want_compute depends only on the step (interval
        # schedules, or the conservative always-True default) admit a
        # host-side compute plan with no device round trip.  Deriving it
        # from want_compute itself — NOT static_schedule — keeps the plan
        # sound for policies like ToCa whose off-schedule branch still
        # calls compute_fn: their base want_compute is True everywhere, so
        # they simply never get skip ticks.  State-dependent predicates
        # (TeaCache & co) raise on the None state and take the device path.
        self._static_plan = self._probe_static_plan(self.policy)
        # the uncond mirror: all-True when cfg_policy is None (naive mode)
        self._static_cfg_plan = (
            self._probe_static_plan(uncond_pol) if uncond_pol is not None
            else np.ones((max_steps,), bool))

        # host-side per-slot timestep tables, padded to max_steps (+1 for the
        # terminal alpha-bar = 1.0 that closes the DDIM update)
        self._ab = np.ones((slots, max_steps + 1), np.float32)
        self._tv = np.zeros((slots, max_steps), np.float32)
        self._labels = np.zeros((slots,), np.int32)
        self._nulls = np.full((slots,), cfg.dit_num_classes, np.int32)
        # negative-prompt conditioning vectors (per slot) + their mask
        self._null_vecs = np.zeros((slots, cfg.d_model), np.float32)
        self._null_mask = np.zeros((slots,), bool)
        # per-slot prompt / negative-prompt embedding tables (host side;
        # zero-size when the config is not text-enabled) — the admission-
        # time inputs of build_text_tables, padded to cfg.dit_text_len
        Lt = cfg.dit_text_len
        self._txt_embed = np.zeros((slots, Lt, cfg.d_model), np.float32)
        self._txt_mask = np.zeros((slots, Lt), bool)
        self._neg_embed = np.zeros((slots, Lt, cfg.d_model), np.float32)
        self._neg_mask = np.zeros((slots, Lt), bool)
        self._scales = np.zeros((slots,), np.float32)
        self._nsteps = np.ones((slots,), np.int32)
        self._guided = np.zeros((slots,), bool)
        #: ServingTelemetry of the most recent serve() call
        self.telemetry: Optional[ServingTelemetry] = None
        # guards the one-live-session invariant (see ServeSession)
        self._session_active = False
        #: per-program cost cards filled by warmup() — keyed by bucket size
        #: (row-compacted), tick kind (dense), plus "want" for the plan pass
        self.program_profile: Dict[object, ProgramProfile] = {}
        #: captured jaxpr/StableHLO per program (same keys), filled by
        #: warmup(verify=True) or lazily by _capture_program_ir()
        self.program_ir: Dict[object, ProgramIR] = {}
        #: repro.analysis.ir findings from the last warmup(verify=True);
        #: None = never verified, [] = verified clean
        self.ir_findings: Optional[List] = None
        self._warmed = False

    def _compact_tick(self, bucket: int):
        """The jit'd row-compacted program for one bucket size (lazy; at most
        log2(2*slots) + 2 programs ever exist)."""
        fn = self._compact_ticks.get(bucket)
        if fn is None:
            fn = self._compact_ticks[bucket] = self._make_compact_tick(bucket)
        return fn

    # -- text conditioning ---------------------------------------------
    def _text_table_operands(self):
        """Dummy (te, tm, ne, nm) operands shaped like one admission wave's
        inputs to build_text_tables (text-enabled engines only)."""
        S, Lt = self.slots, self.cfg.dit_text_len
        te = jnp.zeros((S, Lt, self.cfg.d_model), jnp.float32)
        tm = jnp.zeros((S, Lt), bool)
        return te, tm, te, tm

    def _empty_txt(self):
        """An all-masked per-slot text-table dict (zero K/V, zero masks) —
        the exact no-op under the cross-attn masking invariant.  {} on
        text-free engines: an empty dict contributes zero jit operand
        leaves, keeping their tick signature byte-identical to pre-text."""
        if not self.text_enabled:
            return {}
        S, Lt = self.slots, self.cfg.dit_text_len
        kd = self.params["blocks"]["cross"]["wk"].shape[-1]
        z = jnp.zeros((S, self.cfg.num_layers, Lt, kd), jnp.float32)
        m = jnp.zeros((S, Lt), bool)
        return {"k": z, "v": z, "mask": m, "nk": z, "nv": z, "nmask": m}

    def _build_text_tables(self):
        """The live per-slot text-table dict from the host embedding
        tables: one jitted text_kv pass over every slot, re-run only when
        admission changed a slot's prompt (never per tick)."""
        if not self.text_enabled:
            return {}
        return self._text_tables(
            jnp.asarray(self._txt_embed), jnp.asarray(self._txt_mask),
            jnp.asarray(self._neg_embed), jnp.asarray(self._neg_mask))

    # ------------------------------------------------------------------
    def _warmup_operands(self):
        """Dummy device operands shaped exactly like a live tick's: the
        13-tuple every tick program takes (the text-table dict is empty on
        text-free engines — zero operand leaves), and the fused want
        pass's 6-tuple (shared prefixes, so warmup and IR capture trace
        the same shapes a session dispatches)."""
        S = self.slots
        T, D = self.tokens, self.in_dim
        xs = jnp.zeros((S, T, D), jnp.float32)
        states = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (S,) + a.shape).copy(),
            self._fresh)
        zi = jnp.zeros((S,), jnp.int32)
        zf = jnp.zeros((S,), jnp.float32)
        nv = jnp.zeros((S, self.cfg.d_model), jnp.float32)
        nm = jnp.zeros((S,), bool)
        ab = jnp.full((S,), 0.5, jnp.float32)
        tick_args = (states, zi, xs, zf, zi, zi, nv, nm, self._empty_txt(),
                     zf, zf, ab, ab)
        want_args = (states, zi, xs, zf, zi, nm)
        return tick_args, want_args

    def _warmup_buckets(self) -> List[int]:
        """Every bucket a tick can request, mirroring compact_rows exactly:
        cond-only ticks pad n in 1..S capped at S, ticks with uncond rows
        pad n in 1..2S capped at 2S."""
        S = self.slots
        return sorted(
            {0}
            | {min(1 << (n - 1).bit_length(), S) for n in range(1, S + 1)}
            | {min(1 << (n - 1).bit_length(), 2 * S)
               for n in range(1, 2 * S + 1)})

    def _param_leaf_specs(self):
        """(shape, dtype-name) multiset of the model param leaves — the
        consts a tick program is DECLARED to close over; anything else
        big is closure-capture bloat (repro.analysis.ir const check).
        Includes the conditioner's text-encoder leaves, so the
        "text_encoder" program verifies under the same declaration."""
        specs = tuple(
            (tuple(getattr(l, "shape", ())), str(getattr(l, "dtype", "")))
            for l in jax.tree_util.tree_leaves(self.params))
        if self.conditioner is not None:
            specs += tuple(self.conditioner.param_leaf_specs())
        return specs

    def warmup(self, verify: bool = False) -> Dict[object, ProgramProfile]:
        """Compile every tick program on dummy inputs before serving, and
        profile each one while at it.

        Row compaction spreads the engine across one program per bucket size;
        without warmup each first-seen bucket pays its XLA compile inside a
        live tick (state-dependent policies like TeaCache surface new bucket
        sizes mid-run, long after admission warmed the common ones).  The
        mixed-modality engine calls this on every sub-pool so the first
        mixed tick doesn't pay several modality-shaped compiles at once.

        Each program is AOT-compiled (repro.obs.profiling.compile_program)
        so the per-program compile time and the XLA cost model's FLOPs /
        bytes are captured into `self.program_profile` — keyed by bucket
        size (compacted), tick kind (dense), plus "want" for the fused
        plan pass — and the compiled executable is swapped into the tick
        cache so serving never re-pays the compile.  Returns the profile
        dict; `repro.obs.profiling.redundancy_ratio` combines it with
        telemetry row counters into measured-FLOPs-saved.

        `verify=True` additionally captures each program's jaxpr/StableHLO
        during the same trace pipeline and runs the repro.analysis.ir
        contract checks (host callbacks, f64/weak-type leaks, donation
        aliasing, const bloat) over the whole program set: findings land
        in `self.ir_findings` and on each returned profile's
        `ir_findings`.  Warmup also pre-runs the small host-utility
        programs a live session dispatches outside the tick programs
        (admission noise, the jit'd refill, the harvest row gather), so
        steady-state serving after warmup compiles NOTHING — the
        ir-retrace sentinel enforces exactly that."""
        if self._warmed:
            if verify and self.ir_findings is None:
                self._run_ir_verification()
            return self.program_profile
        args, want_args = self._warmup_operands()
        specs = self._param_leaf_specs() if verify else ()
        # the fused want pass also compiles on first use; without this a
        # state-dependent policy pays that compile inside its first live tick
        if self._static_plan is None or self._static_cfg_plan is None:
            if verify:
                self._want_all, prof, ir = compile_program(
                    self._want_src, *want_args, key="want", want_ir=True,
                    declared_const_specs=specs)
                self.program_ir["want"] = ir
            else:
                self._want_all, prof = compile_program(
                    self._want_all, *want_args, key="want")
            self.program_profile["want"] = prof
        # text-serving programs: the admission-time K/V table build, and
        # the conditioner's text encoder — both outside the tick loop, but
        # a live session dispatches them, so the zero-recompile-after-
        # warmup claim must cover them too
        if self.text_enabled:
            targs = self._text_table_operands()
            if verify:
                self._text_tables, prof, ir = compile_program(
                    self._text_tables, *targs, key="text_kv", want_ir=True,
                    declared_const_specs=specs)
                self.program_ir["text_kv"] = ir
            else:
                self._text_tables, prof = compile_program(
                    self._text_tables, *targs, key="text_kv")
            self.program_profile["text_kv"] = prof
            if self.conditioner is not None:
                if verify:
                    prof, ir = self.conditioner.warmup(verify=True)
                    self.program_ir["text_encoder"] = ir
                else:
                    prof = self.conditioner.warmup()
                self.program_profile["text_encoder"] = prof
        if self.row_compaction:
            S = self.slots
            for bucket in self._warmup_buckets():
                row_slot = jnp.zeros((bucket,), jnp.int32)
                row_uncond = jnp.zeros((bucket,), bool)
                row_dest = jnp.full((bucket,), 2 * S, jnp.int32)
                fn = self._make_compact_tick(bucket)
                if verify:
                    compiled, prof, ir = compile_program(
                        fn, *args, row_slot, row_uncond, row_dest,
                        key=bucket, want_ir=True,
                        declared_const_specs=specs)
                    self.program_ir[bucket] = ir
                else:
                    compiled, prof = compile_program(
                        fn, *args, row_slot, row_uncond, row_dest,
                        key=bucket)
                self._compact_ticks[bucket] = compiled
                self.program_profile[bucket] = prof
                # run once: validates the compiled avals against real-shaped
                # operands now instead of inside the first live tick
                compiled(*args, row_slot, row_uncond, row_dest)[0] \
                    .block_until_ready()
        else:
            for kind in ("full", "cond", "skip"):
                if verify:
                    compiled, prof, ir = compile_program(
                        self._ticks[kind], *args, key=kind, want_ir=True,
                        declared_const_specs=specs)
                    self.program_ir[kind] = ir
                else:
                    compiled, prof = compile_program(
                        self._ticks[kind], *args, key=kind)
                self._ticks[kind] = compiled
                self.program_profile[kind] = prof
                compiled(*args)[0].block_until_ready()
        # pre-warm the host-utility programs a live session dispatches
        # outside the tick programs: admission noise (PRNGKey / fold_in /
        # normal), the jit'd refill, and the harvest row gather+transfer.
        # Without this the first admission/harvest pays their compiles
        # mid-session — which the retrace sentinel rightly counts
        xs, states = args[2], args[0]
        key = jax.random.fold_in(jax.random.PRNGKey(0), 0)
        noise = jax.random.normal(key, (self.tokens, self.in_dim))
        warm_xs, _ = self._refill(xs, states, 0, noise, self._fresh)
        np.asarray(warm_xs[0])
        if self.text_enabled:
            # validates the compiled text_kv avals against the real host
            # tables (and warms their host->device transfers)
            jax.tree_util.tree_map(lambda a: a.block_until_ready(),
                                   self._build_text_tables())
        self._warmed = True
        if verify:
            self._run_ir_verification()
        return self.program_profile

    def _capture_program_ir(self) -> Dict[object, ProgramIR]:
        """ProgramIR per warmup program key, capturing lazily when warmup
        ran without verify: programs are re-traced through their stored
        builders (fresh jit wrappers — the warmed caches hold bare
        Compiled executables, which carry no jaxpr)."""
        if not self._warmed:
            self.warmup()
        if self.program_ir:
            return self.program_ir
        from repro.obs.profiling import capture_ir
        specs = self._param_leaf_specs()
        args, want_args = self._warmup_operands()
        if self._static_plan is None or self._static_cfg_plan is None:
            self.program_ir["want"] = capture_ir(
                self._want_src, *want_args, key="want",
                declared_const_specs=specs)
        if self.text_enabled:
            self.program_ir["text_kv"] = capture_ir(
                jax.jit(self._text_tables_src), *self._text_table_operands(),
                key="text_kv", declared_const_specs=specs)
            if self.conditioner is not None:
                self.program_ir["text_encoder"] = \
                    self.conditioner.capture_ir()
        if self.row_compaction:
            S = self.slots
            for bucket in self._warmup_buckets():
                row_slot = jnp.zeros((bucket,), jnp.int32)
                row_uncond = jnp.zeros((bucket,), bool)
                row_dest = jnp.full((bucket,), 2 * S, jnp.int32)
                self.program_ir[bucket] = capture_ir(
                    self._make_compact_tick(bucket), *args, row_slot,
                    row_uncond, row_dest, key=bucket,
                    declared_const_specs=specs)
        else:
            for kind in ("full", "cond", "skip"):
                self.program_ir[kind] = capture_ir(
                    self._make_tick(kind), *args, key=kind,
                    declared_const_specs=specs)
        return self.program_ir

    def _run_ir_verification(self) -> None:
        """verify_programs over the captured IR set; findings land on
        self.ir_findings and on the matching program profiles.  The
        analysis layer is imported lazily — engines serving in production
        never pay for it unless verify was requested."""
        import dataclasses
        from repro.analysis.ir import verify_programs_by_key
        by_key = verify_programs_by_key(self)
        self.ir_findings = [
            f for _, fs in sorted(by_key.items(), key=lambda kv: str(kv[0]))
            for f in fs]
        for k, prof in list(self.program_profile.items()):
            attached = tuple(by_key.get(k, ()))
            if attached:
                self.program_profile[k] = dataclasses.replace(
                    prof, ir_findings=attached)

    def _probe_static_plan(self, policy: CachePolicy) -> Optional[np.ndarray]:
        try:
            return np.asarray(
                [bool(policy.want_compute(None, s, None))
                 for s in range(self.max_steps)], bool)
        except Exception:
            return None

    # ------------------------------------------------------------------
    def _check_request(self, req: DiffusionRequest) -> None:
        """The one request-shape contract, shared by session submission
        (ServeSession._validate) and slot admission (_install_request) —
        previously duplicated at both sites and free to drift."""
        if req.num_steps > self.max_steps:
            raise ValueError(f"request {req.request_id}: num_steps="
                             f"{req.num_steps} > max_steps={self.max_steps}")
        if req.null_label is not None and np.ndim(req.null_label) > 0:
            shape = np.shape(req.null_label)
            if shape != (self.cfg.d_model,):
                raise ValueError(
                    f"request {req.request_id}: null_label vector shape "
                    f"{shape} != (d_model={self.cfg.d_model},)")
        if req.prompt_tokens is not None or req.neg_prompt_tokens is not None:
            if not self.text_enabled:
                raise ValueError(
                    f"request {req.request_id}: prompt on non-text config "
                    f"'{self.cfg.name}' (dit_text_len == 0)")
            if self.conditioner is None:
                raise ValueError(
                    f"request {req.request_id}: prompt given but the engine "
                    f"has no conditioner (pass conditioner=PromptCache(...))")
        if (req.neg_prompt_tokens is not None and req.null_label is not None
                and np.ndim(req.null_label) > 0):
            raise ValueError(
                f"request {req.request_id}: neg_prompt_tokens conflicts "
                f"with a vector-valued null_label — both claim the uncond "
                f"conditioning vector")

    def _install_request(self, slot: int, req: DiffusionRequest) -> None:
        self._check_request(req)
        ts = self.sched.spaced(req.num_steps)
        abar = self.sched.alpha_bars[ts].astype(np.float32)
        self._ab[slot, :] = 1.0
        self._ab[slot, :req.num_steps] = abar
        self._tv[slot, :] = 0.0
        self._tv[slot, :req.num_steps] = ts.astype(np.float32)
        self._labels[slot] = req.class_label
        null = req.null_label
        self._null_vecs[slot, :] = 0.0
        self._null_mask[slot] = False
        if null is None:
            self._nulls[slot] = self.cfg.dit_num_classes
        elif np.ndim(null) == 0:
            self._nulls[slot] = int(null)
        else:
            # negative prompt: an arbitrary conditioning vector overrides the
            # class-embedding lookup on this slot's uncond rows (shape was
            # checked by _check_request)
            self._nulls[slot] = self.cfg.dit_num_classes
            self._null_vecs[slot, :] = np.asarray(null, np.float32)
            self._null_mask[slot] = True
        if self.text_enabled:
            # reset-on-refill extends to the text tables: slot reuse must
            # never leak a previous request's prompt into this one
            self._txt_embed[slot] = 0.0
            self._txt_mask[slot] = False
            self._neg_embed[slot] = 0.0
            self._neg_mask[slot] = False
            if req.prompt_tokens is not None:
                pe = self.conditioner.get(req.prompt_tokens)
                self._txt_embed[slot] = pe.embed
                self._txt_mask[slot] = pe.mask
            if req.neg_prompt_tokens is not None:
                ne = self.conditioner.get(req.neg_prompt_tokens)
                self._neg_embed[slot] = ne.embed
                self._neg_mask[slot] = ne.mask
                # the pooled negative-prompt embedding rides the null-vec
                # path: uncond rows condition on it instead of the
                # null-class embedding, AND cross-attend its K/V above
                self._nulls[slot] = self.cfg.dit_num_classes
                self._null_vecs[slot, :] = ne.pooled
                self._null_mask[slot] = True
        self._scales[slot] = req.cfg_scale
        self._nsteps[slot] = req.num_steps
        self._guided[slot] = req.guided

    def _plan_all(self, states, steps, xs, tvals):
        """Per-slot (want_cond, want_uncond, metric) plan — before active
        masking; want_uncond is already masked by the per-slot guided flag.

        When BOTH branches admit a host-side static schedule the plan costs
        no device round trip at all (and metric is None — nothing dynamic
        was measured).  Otherwise one fused jit call produces both want
        vectors and the per-slot trace metric in a single device sync; a
        branch that is static anyway is then overridden from its host plan
        (the device predicate for it is mirrored, so this is equivalence-
        preserving, not a behavior switch)."""
        if self._static_plan is not None and self._static_cfg_plan is not None:
            return (self._static_plan[steps],
                    self._static_cfg_plan[steps] & self._guided, None)
        # repro-lint: disable-next-line=host-sync-in-hot-path -- THE one priced per-tick sync: fused want-pass, surcharged in plan cost
        wc, wu, metric = jax.device_get(self._want_all(
            states, jnp.asarray(steps), xs, jnp.asarray(tvals),
            jnp.asarray(self._labels), jnp.asarray(self._guided)))
        wc, wu = np.asarray(wc, bool), np.asarray(wu, bool)
        if self._static_plan is not None:
            wc = self._static_plan[steps]
        if self._static_cfg_plan is not None:
            wu = self._static_cfg_plan[steps] & self._guided
        return wc, wu, np.asarray(metric, np.float32)

    # ------------------------------------------------------------------
    def start_session(self, requests: Sequence[DiffusionRequest],
                      telemetry: Optional[ServingTelemetry] = None,
                      hooks: Optional[Sequence[TickHook]] = None,
                      capture_latents: bool = False,
                      modality: Optional[str] = None,
                      metrics=None) -> ServeSession:
        """Begin a tick-granular serving session (see ServeSession).

        At most ONE session per engine may be in flight (enforced): the
        per-slot timestep/conditioning tables live on the engine.
        Interleaving across engines (the mixed-modality pool) is fine.
        `hooks` observe each tick (TickEvent); `capture_latents` copies the
        pre-tick latent batch into each event (device transfer per tick);
        `metrics` (a repro.obs MetricsRegistry) opts the session into
        publishing the repro_engine_* / repro_scheduler_* instrument set."""
        return ServeSession(self, requests, telemetry, hooks=hooks,
                            capture_latents=capture_latents,
                            modality=modality, metrics=metrics)

    def serve(self, requests: Sequence[DiffusionRequest],
              telemetry: Optional[ServingTelemetry] = None,
              max_ticks: Optional[int] = None,
              hooks: Optional[Sequence[TickHook]] = None,
              capture_latents: bool = False,
              metrics=None) -> List[DiffusionResult]:
        """Run every request through the slot pool; returns results in
        request order.  With max_ticks, unfinished requests are recorded as
        preempted in telemetry (never silently dropped)."""
        session = self.start_session(requests, telemetry, hooks=hooks,
                                     capture_latents=capture_latents,
                                     metrics=metrics)
        try:
            while not session.done:
                session.tick()
                if max_ticks is not None and session.ticks >= max_ticks:
                    break
        finally:
            # also on a failed tick: release the engine's session latch and
            # record unfinished requests as preempted, so the engine stays
            # retryable after an error (finish() is idempotent)
            session.finish()
        return session.finish()
