"""DiffusionServingEngine — step-interleaved continuous batching for
latent generation with per-slot cache states.

Device side, every tick is one of exactly two jit'd programs over the whole
slot pool (no per-request compilation, arbitrary request mixes):

  * tick_full — vmapped CachedDenoiser step: each slot's policy takes its
    own COMPUTE / REUSE / FORECAST branch (lax.cond vmaps to a select); the
    backbone runs batched over all slots.
  * tick_skip — identical shape but the compute branch is a cheap dummy;
    dispatched only on ticks where *no* slot's `want_compute` is true, so
    the dummy branch's outputs are never selected.  These ticks cost only
    the forecast/reuse arithmetic — this is where serving-level speedup
    comes from.

Host side, the SlotScheduler refills finished slots from the admission
queue mid-flight.  Refill resets the slot's cache state to a fresh
`init_state` (reset-on-refill) — slot reuse must never leak cache state
between requests.  With phase-aligned admission (scheduler docstring),
interval policies make (N-1)/N of all ticks skip ticks.

The DDIM update is re-derived here in traced per-slot form (gathered
alpha-bar tables instead of Python-float arithmetic) because slots sit at
different timesteps of *different* step-budget grids within one program.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CachePolicy, SlotBatchedPolicy, cache_state_bytes,
                        make_policy)
from repro.diffusion import NoiseSchedule, linear_schedule
from repro.diffusion.pipeline import slot_denoise_fns

from .scheduler import DiffusionRequest, SlotScheduler
from .telemetry import RequestRecord, ServingTelemetry


@dataclass
class DiffusionResult:
    """One served request: final latent sample + its telemetry record."""
    request_id: int
    x0: np.ndarray
    record: RequestRecord


class DiffusionServingEngine:
    """Fixed-slot continuous-batching server over one DiT backbone."""

    def __init__(self, params, cfg, policy: Union[CachePolicy, str, None] = None,
                 *, slots: int = 8, max_steps: int = 64,
                 noise_schedule: Optional[NoiseSchedule] = None,
                 align: Optional[int] = None):
        self.params, self.cfg = params, cfg
        self.slots = slots
        self.max_steps = max_steps
        self.sched = noise_schedule or linear_schedule(1000)
        if isinstance(policy, str):
            policy = make_policy(policy)
        self.policy = policy if policy is not None else make_policy("none")
        # phase-aligned admission: default to the policy's compute interval
        self.align = align if align is not None else \
            max(int(getattr(self.policy, "interval", 1)), 1)

        T, D = cfg.dit_patch_tokens, cfg.dit_in_dim
        self._feat = (1, T, D)                      # per-slot policy feature
        self._sig_shape = (1, T, cfg.d_model)       # TeaCache signal shape
        self.batched = SlotBatchedPolicy(self.policy, slots)
        self._fresh = self.batched.init_slot_state(
            self._feat, signal_shape=self._sig_shape)

        backbone_fn, apply_fn, want_fn = slot_denoise_fns(params, cfg,
                                                          self.policy)

        def make_tick(full: bool):
            def tick(states, steps, xs, tvals, labels, ab_t, ab_n):
                # the backbone runs OUTSIDE vmap: slot axis == batch axis
                y_full = (backbone_fn(xs, tvals, labels) if full
                          else jnp.zeros_like(xs))
                eps, states = jax.vmap(apply_fn)(states, steps, xs, tvals,
                                                 labels, y_full)
                a_t = ab_t[:, None, None]
                a_n = ab_n[:, None, None]
                x0_hat = (xs - jnp.sqrt(1.0 - a_t) * eps) / jnp.sqrt(a_t)
                x_next = jnp.sqrt(a_n) * x0_hat + jnp.sqrt(1.0 - a_n) * eps
                return x_next, states
            return jax.jit(tick)

        self._tick_full = make_tick(full=True)
        self._tick_skip = make_tick(full=False)
        self._want = jax.jit(lambda states, steps, xs, tvals, labels:
                             jax.vmap(want_fn)(states, steps, xs, tvals,
                                               labels))

        def refill(xs, states, slot, noise, fresh):
            return (xs.at[slot].set(noise),
                    SlotBatchedPolicy.reset_slot(states, slot, fresh))

        self._refill = jax.jit(refill)

        # Policies whose want_compute depends only on the step (interval
        # schedules, or the conservative always-True default) admit a
        # host-side compute plan with no device round trip.  Deriving it
        # from want_compute itself — NOT static_schedule — keeps the plan
        # sound for policies like ToCa whose off-schedule branch still
        # calls compute_fn: their base want_compute is True everywhere, so
        # they simply never get skip ticks.  State-dependent predicates
        # (TeaCache & co) raise on the None state and take the device path.
        try:
            self._static_plan = np.asarray(
                [bool(self.policy.want_compute(None, s, None))
                 for s in range(max_steps)], bool)
        except Exception:
            self._static_plan = None

        # host-side per-slot timestep tables, padded to max_steps (+1 for the
        # terminal alpha-bar = 1.0 that closes the DDIM update)
        self._ab = np.ones((slots, max_steps + 1), np.float32)
        self._tv = np.zeros((slots, max_steps), np.float32)
        self._labels = np.zeros((slots,), np.int32)
        #: ServingTelemetry of the most recent serve() call
        self.telemetry: Optional[ServingTelemetry] = None

    # ------------------------------------------------------------------
    def _install_request(self, slot: int, req: DiffusionRequest) -> None:
        ts = self.sched.spaced(req.num_steps)
        abar = self.sched.alpha_bars[ts].astype(np.float32)
        self._ab[slot, :] = 1.0
        self._ab[slot, :req.num_steps] = abar
        self._tv[slot, :] = 0.0
        self._tv[slot, :req.num_steps] = ts.astype(np.float32)
        self._labels[slot] = req.class_label

    def _plan(self, states, steps, xs, tvals) -> np.ndarray:
        """Per-slot compute decision for this tick (before masking)."""
        if self._static_plan is not None:
            return self._static_plan[steps]
        labels = jnp.asarray(self._labels)
        return np.asarray(self._want(states, jnp.asarray(steps), xs,
                                     jnp.asarray(tvals), labels))

    # ------------------------------------------------------------------
    def serve(self, requests: Sequence[DiffusionRequest],
              telemetry: Optional[ServingTelemetry] = None,
              max_ticks: Optional[int] = None) -> List[DiffusionResult]:
        """Run every request through the slot pool; returns results in
        request order."""
        for r in requests:
            if r.num_steps > self.max_steps:
                raise ValueError(f"request {r.request_id}: num_steps="
                                 f"{r.num_steps} > max_steps={self.max_steps}")
        tele = telemetry if telemetry is not None else ServingTelemetry()
        tele.cache_state_bytes_per_slot = cache_state_bytes(self._fresh)
        tele.start()

        sched = SlotScheduler(self.slots, self.align)
        now = time.perf_counter
        recs: Dict[int, RequestRecord] = {
            r.request_id: RequestRecord(r.request_id, r.num_steps,
                                        r.traffic_class, enqueue_time=now())
            for r in requests}
        sched.submit_all(requests)

        T, D = self.cfg.dit_patch_tokens, self.cfg.dit_in_dim
        xs = jnp.zeros((self.slots, T, D), jnp.float32)
        states = self.batched.init_state(self._feat,
                                         signal_shape=self._sig_shape)

        results: Dict[int, DiffusionResult] = {}
        tick = 0
        while not sched.idle():
            # -- refill free slots from the queue (phase-aligned) -------
            for slot, req in sched.admit(tick):
                noise = jax.random.normal(jax.random.PRNGKey(req.seed), (T, D))
                xs, states = self._refill(xs, states, slot.index, noise,
                                          self._fresh)
                self._install_request(slot.index, req)
                rec = recs[req.request_id]
                rec.admit_time = now()
                rec.admit_tick = tick
                rec.slot = slot.index

            active = np.asarray(sched.active_mask())
            steps = np.asarray(sched.steps(), np.int32)
            idx = np.minimum(steps, self.max_steps - 1)
            rows = np.arange(self.slots)
            tvals = self._tv[rows, idx]
            ab_t = self._ab[rows, idx]
            ab_n = self._ab[rows, idx + 1]

            want = self._plan(states, idx, xs, tvals) & active
            full = bool(want.any())
            program = self._tick_full if full else self._tick_skip
            t0 = now()
            xs, states = program(states, jnp.asarray(idx), xs,
                                 jnp.asarray(tvals), jnp.asarray(self._labels),
                                 jnp.asarray(ab_t), jnp.asarray(ab_n))
            xs.block_until_ready()
            tele.record_tick(full, now() - t0)

            for slot in sched.slots:
                if slot.busy and want[slot.index]:
                    recs[slot.request.request_id].computed_steps += 1

            # -- advance + harvest finished slots -----------------------
            sched.advance()
            for slot, req in sched.harvest():
                rec = recs[req.request_id]
                rec.finish_time = now()
                rec.finish_tick = tick + 1
                tele.finish_request(rec)
                results[req.request_id] = DiffusionResult(
                    req.request_id, np.asarray(xs[slot.index]), rec)

            tick += 1
            if max_ticks is not None and tick >= max_ticks:
                break

        tele.stop()
        self.telemetry = tele
        return [results[r.request_id] for r in requests
                if r.request_id in results]
