"""Step-interleaved continuous-batching scheduler (host side).

A fixed pool of slots; each slot holds one request at its own denoising
step.  All slots advance together by one vmapped device program per tick;
slots whose request has exhausted its step budget are harvested and refilled
from the admission queue *mid-flight* — the other slots never stall.

Phase-aligned admission: interval-scheduled policies (FORA, TaylorSeer,
FreqCa, ...) compute at per-request steps {0, N, 2N, ...}.  If requests are
admitted only at global ticks that are multiples of N, every slot's compute
steps land on the same ticks, so (N-1)/N of all ticks need no backbone at
all and the engine dispatches the cheap forecast/reuse program.  Admission
of a freed slot waits at most N-1 ticks; with the batch still advancing this
costs far less than it saves (see benchmarks/bench_serving.py).

This module is pure host-side bookkeeping — no jax — so the lifecycle is
unit-testable in microseconds (tests/test_serving_diffusion.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

from repro.serving.common import RequestQueue


@dataclass(frozen=True, eq=False)
class DiffusionRequest:
    """One latent-generation request.

    num_steps is the request's denoising step budget — requests with
    different budgets share slots (mixed-budget continuous batching).

    cfg_scale > 0 makes the request *guided*: the engine runs a second,
    unconditional backbone branch and blends eps = e_u + s (e_c - e_u).
    `null_label` selects that branch's conditioning: None (the model's
    null-class embedding), an int class id, or an arbitrary (d_model,)
    conditioning VECTOR — the negative-prompt path, which bypasses the
    class-embedding table entirely.  Guided and unguided requests share one
    slot pool.

    `modality` routes the request to the matching per-modality sub-pool in
    a mixed pool (repro.modalities.MixedModalityEngine); a single-modality
    DiffusionServingEngine ignores it.

    `prompt_tokens` carries text conditioning (T2I/T2V): a prompt string or
    an explicit token-id sequence, resolved through the engine's PromptCache
    at admission (text-enabled configs only).  `neg_prompt_tokens` is the
    CFG negative prompt — its K/V tables feed the slot's uncond rows and
    its pooled embedding rides the null-vec path (so it conflicts with a
    vector-valued `null_label`; the engine rejects that combination)."""
    request_id: int
    num_steps: int
    seed: int = 0
    class_label: int = 0
    traffic_class: str = "default"
    cfg_scale: float = 0.0
    null_label: Optional[Any] = None
    modality: str = "image"
    prompt_tokens: Optional[Any] = None
    neg_prompt_tokens: Optional[Any] = None

    @property
    def guided(self) -> bool:
        return self.cfg_scale > 0.0


@dataclass
class Slot:
    """One slot's lifecycle state."""
    index: int
    request: Optional[DiffusionRequest] = None
    step: int = 0
    admit_tick: int = -1

    @property
    def busy(self) -> bool:
        return self.request is not None

    @property
    def done(self) -> bool:
        return self.busy and self.step >= self.request.num_steps


class SlotScheduler:
    """Admission queue + slot pool + per-request step budgets.

    The engine drives it as:
        admitted = sched.admit(tick)        # refill free slots (aligned)
        ...run one device tick...
        sched.advance()                     # step += 1 on busy slots
        for slot, req in sched.harvest():   # budget exhausted -> free slot
    """

    def __init__(self, num_slots: int, align: int = 1):
        assert num_slots >= 1 and align >= 1
        self.slots: List[Slot] = [Slot(i) for i in range(num_slots)]
        self.align = align
        self.queue: RequestQueue = RequestQueue()
        self._metrics = None
        self._metric_labels = {}

    def bind_metrics(self, registry, **labels) -> None:
        """Opt this scheduler into publishing repro_scheduler_* metrics
        (admissions by traffic class, queue depth) into a repro.obs
        MetricsRegistry.  `labels` (e.g. modality=...) tag every sample."""
        self._metrics = registry
        self._metric_labels = {k: str(v) for k, v in labels.items()
                               if v is not None}

    # -- queue ----------------------------------------------------------
    def submit(self, request: DiffusionRequest) -> None:
        self.queue.push(request)

    def submit_all(self, requests) -> None:
        for r in requests:
            self.submit(r)

    # -- lifecycle ------------------------------------------------------
    def admit(self, tick: int) -> List[Tuple[Slot, DiffusionRequest]]:
        """Fill free slots from the queue; respects phase alignment."""
        if tick % self.align != 0:
            return []
        admitted = []
        for slot in self.slots:
            if slot.busy or not self.queue:
                continue
            req = self.queue.pop()
            slot.request = req
            slot.step = 0
            slot.admit_tick = tick
            admitted.append((slot, req))
        if self._metrics is not None:
            reg, lbl = self._metrics, self._metric_labels
            if admitted:
                adm = reg.counter(
                    "repro_scheduler_admitted_total",
                    "Requests admitted into a slot, by traffic class.")
                for _, req in admitted:
                    adm.inc(traffic_class=req.traffic_class,
                            guided=str(req.guided).lower(), **lbl)
            reg.gauge(
                "repro_scheduler_queue_depth",
                "Requests waiting in the admission queue."
            ).set(len(self.queue), **lbl)
        return admitted

    def advance(self) -> None:
        for slot in self.slots:
            if slot.busy:
                slot.step += 1

    def harvest(self) -> List[Tuple[Slot, DiffusionRequest]]:
        """Pop (slot, request) pairs whose budget is exhausted; frees slots."""
        out = []
        for slot in self.slots:
            if slot.done:
                out.append((slot, slot.request))
                slot.request = None
                slot.step = 0
                slot.admit_tick = -1
        return out

    # -- views ----------------------------------------------------------
    @property
    def num_slots(self) -> int:
        return len(self.slots)

    def active_mask(self) -> List[bool]:
        return [s.busy for s in self.slots]

    def steps(self) -> List[int]:
        return [s.step for s in self.slots]

    def any_busy(self) -> bool:
        return any(s.busy for s in self.slots)

    def idle(self) -> bool:
        return not self.any_busy() and not self.queue
