"""repro.serving.diffusion — cache-aware continuous-batching diffusion serving.

The survey frames diffusion caching as a training-free path to real-time
multimodal serving; this package is that serving layer.  Many concurrent
generation requests, each at its own denoising step with its own step
budget, advance together through two shared jit'd programs while each slot
carries its own cache state (repro.core.SlotBatchedPolicy):

  engine     — DiffusionServingEngine: row-compacted denoise ticks (gather
               exactly the cond/uncond rows whose per-slot policies want a
               compute into one power-of-two bucket, scatter back; one jit
               program per bucket size), classifier-free guidance with
               per-slot FasterCacheCFG uncond-branch reuse, mid-flight slot
               refill, reset-on-refill; `row_compaction=False` restores the
               dense whole-pool full/cond/skip program triple as the
               equivalence baseline
  scheduler  — SlotScheduler: admission queue, slot lifecycle, per-request
               step budgets (+ cfg_scale / null_label), phase-aligned
               admission
  autotune   — SLA-driven sweep of POLICY_REGISTRY (optionally × CFG reuse
               intervals): pick policy + hyperparams per traffic class
               against latency/quality budgets, latency priced in actual
               backbone rows (row_time_ms)
  telemetry  — per-request latency / compute_fraction / cache hit rates +
               uncond computes saved, fleet throughput, backbone rows
               computed / padded / saved, full/cond/skip tick mix,
               preempted-request accounting, cache bytes per slot
"""
from .autotune import (SLA, TunedPolicy, autotune, autotune_traffic_classes,
                       price_and_pick, sweep_candidates)
from .engine import (DiffusionResult, DiffusionServingEngine, ServeSession,
                     TickEvent, compact_rows, request_noise_key)
from .scheduler import DiffusionRequest, Slot, SlotScheduler
from .telemetry import RequestRecord, ServingTelemetry

__all__ = [
    "SLA", "TunedPolicy", "autotune", "autotune_traffic_classes",
    "price_and_pick", "sweep_candidates",
    "DiffusionResult", "DiffusionServingEngine", "ServeSession", "TickEvent",
    "compact_rows", "request_noise_key",
    "DiffusionRequest", "Slot", "SlotScheduler",
    "RequestRecord", "ServingTelemetry",
]
