"""repro.serving.diffusion — cache-aware continuous-batching diffusion serving.

The survey frames diffusion caching as a training-free path to real-time
multimodal serving; this package is that serving layer.  Many concurrent
generation requests, each at its own denoising step with its own step
budget, advance together through two shared jit'd programs while each slot
carries its own cache state (repro.core.SlotBatchedPolicy):

  engine     — DiffusionServingEngine: vmapped denoise tick (full/skip
               program pair), mid-flight slot refill, reset-on-refill
  scheduler  — SlotScheduler: admission queue, slot lifecycle, per-request
               step budgets, phase-aligned admission
  autotune   — SLA-driven sweep of POLICY_REGISTRY: pick policy +
               hyperparams per traffic class against latency/quality budgets
  telemetry  — per-request latency / compute_fraction / cache hit rates,
               fleet throughput, full-vs-skip tick mix, cache bytes per slot
"""
from .autotune import SLA, TunedPolicy, autotune, autotune_traffic_classes
from .engine import DiffusionResult, DiffusionServingEngine
from .scheduler import DiffusionRequest, Slot, SlotScheduler
from .telemetry import RequestRecord, ServingTelemetry

__all__ = [
    "SLA", "TunedPolicy", "autotune", "autotune_traffic_classes",
    "DiffusionResult", "DiffusionServingEngine",
    "DiffusionRequest", "Slot", "SlotScheduler",
    "RequestRecord", "ServingTelemetry",
]
