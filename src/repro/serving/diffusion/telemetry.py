"""Serving telemetry: per-request and fleet-level metrics.

The survey's acceleration claims are single-trajectory (compute_fraction,
PSNR); a serving system additionally cares about queue wait, end-to-end
latency, request throughput, and how often the batch-level scheduler managed
to dispatch a cheap program instead of the full backbone.  This module
collects both views:

  * RequestRecord — one request's lifecycle timestamps + cache counters,
    including CFG accounting (how many unconditional-branch computes the
    per-slot FasterCacheCFG state saved) and an explicit `preempted` flag
    for requests cut off by `serve(max_ticks=...)`.
  * ServingTelemetry — fleet aggregation: throughput, latency percentiles,
    the full / cond-only / skip tick mix, backbone rows computed / padded /
    saved by row compaction, uncond rows dispatched vs saved, cache hit +
    forecast rates, cache_state_bytes/slot.

Tick kinds (kept for compatibility with the PR-3 dense engine; under row
compaction they classify WHICH branches the tick's gathered rows came from,
no longer the batch size):
  "full" — some gathered row is an uncond-branch refresh
  "cond" — cond-branch rows only (also the only backbone tick kind for
           unguided pools)
  "skip" — no backbone at all (forecast/reuse arithmetic only)
The true per-tick cost now lives in the row counters:
`backbone_rows_computed` (rows carrying real per-slot work), `_padding`
(power-of-two bucket waste), `_saved` (rows a dense whole-pool tick would
have dispatched on top).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.obs.clock import monotonic

TICK_KINDS = ("full", "cond", "skip")


def _pct(xs: List[float], q: float) -> float:
    """Linear-interpolated percentile (numpy's default method).

    Nearest-rank via int(q * (len-1)) truncates DOWN, so p95 over a small
    fleet (10 requests -> index int(8.55) = 8) silently reported the ~p89
    sample; interpolating between the bracketing order statistics matches
    np.percentile exactly (tests/test_serving_compaction.py asserts so).
    An empty window has no percentile: nan, never a fake 0.0 an SLA check
    could mistake for "infinitely fast"."""
    if not xs:
        return math.nan
    xs = sorted(xs)
    pos = q * (len(xs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


@dataclass
class RequestRecord:
    """Lifecycle + cache telemetry for one request."""
    request_id: int
    num_steps: int
    traffic_class: str = "default"
    cfg_scale: float = 0.0
    modality: str = "image"
    enqueue_time: float = 0.0
    admit_time: float = 0.0
    finish_time: float = 0.0
    admit_tick: int = -1
    finish_tick: int = -1
    slot: int = -1
    computed_steps: int = 0          # ticks where this slot ran a full compute
    uncond_computed_steps: int = 0   # ticks where the uncond branch refreshed
    #: True when serve(max_ticks=...) ended before this request completed
    #: (either mid-flight or still queued); its latency fields are partial
    #: and it is excluded from latency/throughput aggregation.
    preempted: bool = False

    @property
    def guided(self) -> bool:
        return self.cfg_scale > 0.0

    @property
    def latency(self) -> float:
        """End-to-end seconds from enqueue to completion."""
        return self.finish_time - self.enqueue_time

    @property
    def queue_wait(self) -> float:
        return self.admit_time - self.enqueue_time

    @property
    def compute_fraction(self) -> float:
        """Fraction of denoise steps that ran the backbone for this request;
        the survey's acceleration factor is ~ 1/compute_fraction (§III-B)."""
        return self.computed_steps / max(self.num_steps, 1)

    @property
    def cache_hit_rate(self) -> float:
        """Steps served from cache (verbatim reuse or forecast)."""
        return 1.0 - self.compute_fraction

    @property
    def uncond_saved_steps(self) -> int:
        """Unconditional-branch computes avoided by CFG-branch reuse
        (FasterCacheCFG); 0 for unguided requests."""
        if not self.guided:
            return 0
        return max(self.num_steps - self.uncond_computed_steps, 0)


@dataclass
class ServingTelemetry:
    """Aggregates RequestRecords plus per-tick engine counters.

    `max_records` bounds the retained RequestRecord lists (a ring buffer:
    oldest records are dropped once the cap is reached) so long-lived serve
    sessions don't grow without limit.  Aggregate counters (request counts,
    latency/compute-fraction/queue-wait sums, uncond savings) are kept
    monotonically regardless of the cap, so `summary()` means and totals
    stay exact over ALL traffic; only the percentile and per-traffic-class
    views narrow to the retained window — which is precisely what the
    control plane's sliding-window retuner wants.  The default (None) keeps
    every record, matching pre-cap behavior exactly."""
    cache_state_bytes_per_slot: int = 0
    max_records: Optional[int] = None
    records: List[RequestRecord] = field(default_factory=list)
    preempted_records: List[RequestRecord] = field(default_factory=list)
    # monotonic aggregates: survive ring-buffer eviction
    requests_finished: int = 0
    requests_preempted: int = 0
    latency_sum_s: float = 0.0
    queue_wait_sum_s: float = 0.0
    compute_fraction_sum: float = 0.0
    guided_finished: int = 0
    uncond_saved_steps_sum: int = 0
    ticks_full: int = 0          # both-branch backbone (2S rows)
    ticks_cond: int = 0          # cond-only backbone (S rows)
    ticks_skip: int = 0
    tick_seconds_full: float = 0.0
    tick_seconds_cond: float = 0.0
    tick_seconds_skip: float = 0.0
    #: uncond backbone rows that refreshed an active guided slot's CFG cache
    #: (rows a dense engine additionally dispatches but whose output the
    #: per-slot select discards are NOT counted here — they show up in
    #: backbone_rows_computed instead)
    uncond_rows_computed: int = 0
    #: uncond rows a naive two-branch server would have dispatched but this
    #: engine did not (active guided slots whose CFG cache was reused)
    uncond_rows_saved: int = 0
    #: backbone rows carrying real per-slot work (cond + uncond), summed over
    #: ticks.  For the dense whole-pool engine this is the full batch (S or
    #: 2S per backbone tick — slot-count inflation included, because those
    #: rows really run); for the row-compacted engine it is exactly the rows
    #: whose policies wanted a compute.
    backbone_rows_computed: int = 0
    #: pad rows added to reach the power-of-two bucket size (compacted engine
    #: only; these also run through the backbone, so actual dispatched batch
    #: rows = backbone_rows_computed + backbone_rows_padding)
    backbone_rows_padding: int = 0
    #: rows a dense whole-pool tick of the same kind would have dispatched
    #: minus the rows this engine actually needed
    backbone_rows_saved: int = 0
    _t0: Optional[float] = None
    _t1: Optional[float] = None

    # ------------------------------------------------------------------
    def start(self) -> None:
        self._t0 = monotonic()

    def stop(self) -> None:
        self._t1 = monotonic()

    def record_tick(self, kind: str, seconds: float, *,
                    rows_computed: int = 0, rows_padding: int = 0,
                    rows_saved: int = 0) -> None:
        assert kind in TICK_KINDS, kind
        if kind == "full":
            self.ticks_full += 1
            self.tick_seconds_full += seconds
        elif kind == "cond":
            self.ticks_cond += 1
            self.tick_seconds_cond += seconds
        else:
            self.ticks_skip += 1
            self.tick_seconds_skip += seconds
        self.backbone_rows_computed += int(rows_computed)
        self.backbone_rows_padding += int(rows_padding)
        self.backbone_rows_saved += int(rows_saved)

    def _trim(self, lst: List[RequestRecord]) -> None:
        if self.max_records is not None and len(lst) > self.max_records:
            del lst[:len(lst) - self.max_records]

    def finish_request(self, rec: RequestRecord) -> None:
        self.requests_finished += 1
        self.latency_sum_s += rec.latency
        self.queue_wait_sum_s += rec.queue_wait
        self.compute_fraction_sum += rec.compute_fraction
        if rec.guided:
            self.guided_finished += 1
            self.uncond_saved_steps_sum += rec.uncond_saved_steps
        self.records.append(rec)
        self._trim(self.records)

    def preempt_request(self, rec: RequestRecord) -> None:
        """Record a request cut off by max_ticks instead of dropping it."""
        rec.preempted = True
        self.requests_preempted += 1
        self.preempted_records.append(rec)
        self._trim(self.preempted_records)

    # ------------------------------------------------------------------
    @property
    def elapsed(self) -> float:
        t1 = self._t1 if self._t1 is not None else monotonic()
        return (t1 - self._t0) if self._t0 is not None else 0.0

    @property
    def ticks_backbone(self) -> int:
        return self.ticks_full + self.ticks_cond

    def step_time_ms(self):
        """(backbone_tick_ms, skip_tick_ms) — the pair autotune's latency
        constraint consumes.  Backbone time averages over full AND cond-only
        ticks (unguided pools only ever record the latter)."""
        nb = self.ticks_backbone
        t_back = (1e3 * (self.tick_seconds_full + self.tick_seconds_cond) / nb
                  if nb else 0.0)
        t_skip = (1e3 * self.tick_seconds_skip / self.ticks_skip
                  if self.ticks_skip else 0.0)
        return t_back, t_skip

    def row_time_ms(self):
        """(ms_per_backbone_row, skip_tick_ms) — autotune's row-priced
        latency model.  Backbone tick time divided by the rows those ticks
        actually dispatched (real + padding), so the estimate prices a
        candidate by the rows it gathers instead of by tick kind."""
        rows = self.backbone_rows_computed + self.backbone_rows_padding
        t_row = (1e3 * (self.tick_seconds_full + self.tick_seconds_cond) /
                 rows if rows else 0.0)
        t_skip = (1e3 * self.tick_seconds_skip / self.ticks_skip
                  if self.ticks_skip else 0.0)
        return t_row, t_skip

    def summary(self) -> Dict[str, float]:
        """Fleet summary.  Counts, means and totals come from the monotonic
        aggregate counters (exact over all traffic, ring buffer or not);
        latency percentiles come from the retained record window."""
        lat = [r.latency for r in self.records]
        ticks = self.ticks_full + self.ticks_cond + self.ticks_skip
        n = self.requests_finished
        cf_mean = self.compute_fraction_sum / n if n else 1.0
        return {
            "requests": n,
            "requests_preempted": self.requests_preempted,
            "elapsed_s": self.elapsed,
            "throughput_rps": n / self.elapsed if self.elapsed > 0 else 0.0,
            "latency_p50_s": _pct(lat, 0.50),
            "latency_p95_s": _pct(lat, 0.95),
            "queue_wait_mean_s": self.queue_wait_sum_s / n if n else 0.0,
            "compute_fraction_mean": cf_mean,
            "cache_hit_rate_mean": 1.0 - cf_mean,
            "ticks": ticks,
            # fraction of ticks that ran the backbone at all (full or cond)
            "full_tick_fraction": self.ticks_backbone / ticks if ticks else 0.0,
            # fraction that needed the 2S-row both-branch program
            "cfg_full_tick_fraction": self.ticks_full / ticks if ticks else 0.0,
            "tick_ms_backbone_mean": self.step_time_ms()[0],
            "tick_ms_full_mean": (1e3 * self.tick_seconds_full /
                                  self.ticks_full if self.ticks_full else 0.0),
            "tick_ms_cond_mean": (1e3 * self.tick_seconds_cond /
                                  self.ticks_cond if self.ticks_cond else 0.0),
            "tick_ms_skip_mean": (1e3 * self.tick_seconds_skip /
                                  self.ticks_skip if self.ticks_skip else 0.0),
            "guided_requests": self.guided_finished,
            "backbone_rows_computed": self.backbone_rows_computed,
            "backbone_rows_padding": self.backbone_rows_padding,
            "backbone_rows_saved": self.backbone_rows_saved,
            "backbone_rows_per_tick_mean":
                (self.backbone_rows_computed / self.ticks_backbone
                 if self.ticks_backbone else 0.0),
            "uncond_rows_computed": self.uncond_rows_computed,
            "uncond_rows_saved": self.uncond_rows_saved,
            "uncond_saved_steps_total": self.uncond_saved_steps_sum,
            "cache_state_bytes_per_slot": self.cache_state_bytes_per_slot,
        }

    def publish(self, registry, modality: Optional[str] = None) -> None:
        """Export this telemetry's aggregates as `repro_serving_*` gauges
        into a repro.obs MetricsRegistry — the telemetry becomes a VIEW
        over the unified metrics surface instead of a fourth export format.
        Gauges, not counters: `summary()` values are level readings of this
        object (re-publishing overwrites, never double-counts)."""
        labels = {"modality": modality} if modality is not None else {}
        for key, value in self.summary().items():
            registry.gauge(
                f"repro_serving_{key}",
                f"ServingTelemetry.summary()['{key}'] (published view)."
            ).set(float(value), **labels)

    def by_traffic_class(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for tc in sorted({r.traffic_class for r in self.records}):
            recs = [r for r in self.records if r.traffic_class == tc]
            lat = [r.latency for r in recs]
            out[tc] = {
                "requests": len(recs),
                "latency_p50_s": _pct(lat, 0.50),
                "latency_p95_s": _pct(lat, 0.95),
                "compute_fraction_mean":
                    sum(r.compute_fraction for r in recs) / len(recs),
            }
        return out
