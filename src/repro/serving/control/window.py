"""TelemetryWindow — sliding-window serving statistics for the control plane.

The offline autotuner prices candidates with whatever `row_time_ms` /
`occupancy` the caller measured once; a live server's costs drift (traffic
mix, co-tenant load, pool occupancy).  TelemetryWindow is the control
plane's eye on the running engine: a TickHook (`observe`) fed one TickEvent
per engine tick, keeping bounded deques of recent ticks and finished
requests, from which it derives exactly the inputs the row-priced cost
model consumes —

    row_time_ms()  — (ms_per_backbone_row, skip_tick_ms) over the window,
                     the same shape ServingTelemetry.row_time_ms() reports
                     for a whole run
    occupancy()    — mean busy slots on backbone ticks (rounded >= 1), the
                     row-term multiplier under load

plus quality-side signals (compute fraction, mean want_metric, externally
attached PSNR proxies) the tuner can floor on.  Everything is host-side and
O(window) — safe to call between ticks.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, Optional

import numpy as np

from repro.serving.diffusion.engine import TickEvent
from repro.serving.diffusion.telemetry import RequestRecord


@dataclass(frozen=True)
class TickStat:
    """One tick's window-relevant numbers (a compressed TickEvent)."""
    tick: int
    modality: str
    kind: str                 # "full" | "cond" | "skip"
    seconds: float
    plan_seconds: float       # host time spent deciding the tick
    planned_on_device: bool   # True when the want pass synced the device
    rows_computed: int
    rows_padding: int
    occupancy: int            # busy slots this tick
    mean_metric: float        # mean want_metric over active slots (0 if n/a)


class TelemetryWindow:
    """Sliding window over TickEvents; feeds the online tuner's cost model."""

    def __init__(self, max_ticks: int = 256, max_requests: int = 64):
        self.ticks: Deque[TickStat] = deque(maxlen=max_ticks)
        self.finished: Deque[RequestRecord] = deque(maxlen=max_requests)
        #: monotonic totals (survive window eviction)
        self.ticks_seen = 0
        self.requests_seen = 0
        #: externally attached quality proxies: request_id -> PSNR dB
        #: (the window cannot measure quality itself — it never sees a
        #: reference trajectory; benchmarks/calibrators attach it)
        self.psnr_proxies: Dict[int, float] = {}
        self._psnr_window: Deque[float] = deque(maxlen=max_requests)

    # ------------------------------------------------------------------
    def observe(self, event: TickEvent) -> None:
        """TickHook entry point: fold one engine tick into the window."""
        active = np.asarray(event.active, bool)
        occ = int(active.sum())
        if event.metric is not None and occ:
            mean_metric = float(np.asarray(event.metric)[active].mean())
        else:
            mean_metric = 0.0
        self.ticks.append(TickStat(
            tick=event.tick, modality=event.modality, kind=event.kind,
            seconds=float(event.seconds),
            plan_seconds=float(event.plan_seconds),
            planned_on_device=event.metric is not None,
            rows_computed=int(event.rows_computed),
            rows_padding=int(event.rows_padding),
            occupancy=occ, mean_metric=mean_metric))
        self.ticks_seen += 1
        for rec in event.finished:
            self.finished.append(rec)
            self.requests_seen += 1

    def note_psnr(self, request_id: int, psnr_db: float) -> None:
        """Attach an externally measured quality proxy for one request."""
        self.psnr_proxies[request_id] = float(psnr_db)
        self._psnr_window.append(float(psnr_db))

    # ------------------------------------------------------------------
    def _backbone(self):
        return [t for t in self.ticks if t.kind != "skip"]

    def row_time_ms(self) -> Optional[tuple]:
        """(ms_per_backbone_row, skip_tick_ms) over the window — the
        autotune-shaped pricing pair — or None while the window has no
        backbone ticks yet (nothing sound to price with)."""
        back = self._backbone()
        rows = sum(t.rows_computed + t.rows_padding for t in back)
        if rows == 0:
            return None
        t_row = 1e3 * sum(t.seconds for t in back) / rows
        skips = [t for t in self.ticks if t.kind == "skip"]
        t_skip = (1e3 * sum(t.seconds for t in skips) / len(skips)
                  if skips else 0.0)
        return t_row, t_skip

    def occupancy(self) -> int:
        """Mean busy slots on backbone ticks, rounded, floored at 1 — the
        multiplier on the row term of the latency estimate."""
        back = self._backbone()
        if not back:
            return 1
        return max(int(round(sum(t.occupancy for t in back) / len(back))), 1)

    def plan_time_ms(self) -> float:
        """Mean host ms per tick spent on the fused want pass, over ticks
        the engine had to plan ON DEVICE (metric present).  Static-schedule
        policies plan on the host for ~free, so those ticks are excluded —
        and 0.0 is returned while the window holds no device-planned ticks.
        That makes the tuner OPTIMISTIC about unmeasured dynamic candidates
        (it may swap onto one), after which the next window measures the
        real sync cost and the loop re-prices — self-correcting rather than
        pre-emptively pessimistic."""
        planned = [t for t in self.ticks if t.planned_on_device]
        if not planned:
            return 0.0
        return 1e3 * sum(t.plan_seconds for t in planned) / len(planned)

    def compute_fraction(self) -> float:
        """Mean per-request compute fraction over the finished window."""
        if not self.finished:
            return 1.0
        return sum(r.compute_fraction for r in self.finished) / \
            len(self.finished)

    def mean_metric(self) -> float:
        """Mean want_metric over the window's active slots (TeaCache-style
        accumulated distances; 0.0 under schedule-only policies)."""
        vals = [t.mean_metric for t in self.ticks if t.occupancy]
        return sum(vals) / len(vals) if vals else 0.0

    def psnr_mean(self) -> Optional[float]:
        """Mean attached PSNR proxy over the request window, if any."""
        if not self._psnr_window:
            return None
        return sum(self._psnr_window) / len(self._psnr_window)

    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        rt = self.row_time_ms()
        back = self._backbone()
        return {
            "window_ticks": len(self.ticks),
            "ticks_seen": self.ticks_seen,
            "requests_seen": self.requests_seen,
            "backbone_ticks": len(back),
            "row_time_ms": rt[0] if rt else 0.0,
            "skip_tick_ms": rt[1] if rt else 0.0,
            "occupancy": self.occupancy(),
            "plan_time_ms": self.plan_time_ms(),
            "compute_fraction": self.compute_fraction(),
            "mean_metric": self.mean_metric(),
            "psnr_proxy_mean": self.psnr_mean() or 0.0,
        }

    def publish(self, registry, modality: Optional[str] = None) -> None:
        """Export the window's summary as `repro_window_*` gauges into a
        repro.obs MetricsRegistry — the sliding-window view joins the same
        scrape surface as the engine counters (gauges because the window
        slides: each publish is a level reading, not an increment)."""
        labels = {"modality": modality} if modality is not None else {}
        for key, value in self.summary().items():
            registry.gauge(
                f"repro_window_{key}",
                f"TelemetryWindow.summary()['{key}'] (published view)."
            ).set(float(value), **labels)
