"""SignalTraceLog — per-slot signal traces from a live engine, and the
learned want_compute predictor trained on them.

The survey's arc is static reuse -> dynamic prediction -> learned
prediction.  The serving engine already *computes* the dynamic signals
every tick (TeaCache accumulated distances, FasterCacheCFG refresh
decisions) — the fused want pass returns them as the per-slot `metric` at
zero extra device syncs.  This module keeps them:

  * SignalTraceLog.observe — a TickHook recording one TraceEntry per active
    slot per tick (ring-bounded): (tick, request id, step, want_cond,
    want_uncond, metric).  This is the serving-side dataset the survey's
    learned methods assume exists.
  * Probe capture — every `probe_every`-th admitted request additionally
    logs its pre-tick latent trajectory (needs the session started with
    `capture_latents=True`; the tuner does this automatically when given a
    probing trace log).
  * probe_training_set — replays the backbone over each probe's logged
    latents in ONE batched forward (the trajectory axis is the batch axis)
    to produce (inputs, exact outputs) teacher pairs.
  * fit_want_gate — trains the LazyDiT gate (repro.core.learned) on those
    pairs with the HarmoniCa-style full-trajectory soft-skip loss.  The
    result serves through `make_policy("lazydit", gate=...)` — a learned
    want_compute flowing through the row-compacted bucket path, where a
    misprediction costs one gathered row, not a pool tick.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.learned import init_gate, lazy_trajectory_loss
from repro.diffusion.pipeline import backbone_fns
from repro.serving.diffusion.engine import TickEvent


@dataclass(frozen=True)
class TraceEntry:
    """One (slot, tick) observation of the serving-time cache decisions."""
    tick: int
    modality: str
    request_id: int
    step: int
    want_cond: bool
    want_uncond: bool
    #: the scalar the refresh decision thresholded on (CachePolicy
    #: .want_metric — TeaCache's corrected accumulated distance, LazyDiT's
    #: gate score, 0.0 under host-side static plans)
    metric: float
    guided: bool


class SignalTraceLog:
    """Ring-bounded log of per-slot serving signals + probe trajectories."""

    def __init__(self, max_entries: int = 4096, probe_every: int = 0,
                 max_probes: int = 8, max_probe_steps: int = 64):
        self.entries: Deque[TraceEntry] = deque(maxlen=max_entries)
        self.entries_seen = 0
        #: probe capture: every probe_every-th admitted request logs its
        #: latent trajectory (0 disables probing)
        self.probe_every = int(probe_every)
        self.max_probes = int(max_probes)
        self.max_probe_steps = int(max_probe_steps)
        #: request_id -> {"label", "steps", "tvals", "xs"}
        self.probes: Dict[int, Dict] = {}
        self._admitted = 0

    @property
    def wants_latents(self) -> bool:
        """Should sessions feeding this log run with capture_latents?"""
        return self.probe_every > 0

    # ------------------------------------------------------------------
    def observe(self, event: TickEvent) -> None:
        """TickHook entry point."""
        for req in event.admitted:
            self._admitted += 1
            if (self.probe_every > 0
                    and (self._admitted - 1) % self.probe_every == 0
                    and len(self.probes) < self.max_probes):
                self.probes.setdefault(req.request_id, {
                    "label": int(req.class_label), "steps": [],
                    "tvals": [], "xs": []})

        active = np.asarray(event.active, bool)
        metric = (np.asarray(event.metric)
                  if event.metric is not None else None)
        for s in np.nonzero(active)[0]:
            rid = int(event.request_ids[s])
            self.entries.append(TraceEntry(
                tick=event.tick, modality=event.modality, request_id=rid,
                step=int(event.steps[s]),
                want_cond=bool(event.want_cond[s]),
                want_uncond=bool(event.want_uncond[s]),
                metric=float(metric[s]) if metric is not None else 0.0,
                guided=bool(event.guided[s])))
            self.entries_seen += 1
            probe = self.probes.get(rid)
            if (probe is not None and event.latents is not None
                    and len(probe["steps"]) < self.max_probe_steps):
                probe["steps"].append(int(event.steps[s]))
                probe["tvals"].append(float(event.tvals[s]))
                probe["xs"].append(np.asarray(event.latents[s]))

    # ------------------------------------------------------------------
    def by_request(self, request_id: int) -> List[TraceEntry]:
        return [e for e in self.entries if e.request_id == request_id]

    def summary(self) -> Dict[str, float]:
        n = len(self.entries)
        return {
            "entries": n,
            "entries_seen": self.entries_seen,
            "probes": len(self.probes),
            "probe_steps": sum(len(p["steps"]) for p in self.probes.values()),
            "want_cond_rate": (sum(e.want_cond for e in self.entries) / n
                               if n else 0.0),
            "want_uncond_rate": (sum(e.want_uncond for e in self.entries) / n
                                 if n else 0.0),
            "metric_mean": (sum(e.metric for e in self.entries) / n
                            if n else 0.0),
        }


# ----------------------------------------------------------------------
# learned want_compute: probe trajectories -> teacher pairs -> gate
# ----------------------------------------------------------------------

def probe_training_set(params, cfg, trace: SignalTraceLog,
                       min_steps: int = 3) -> List[Tuple]:
    """Teacher pairs from the log's probe trajectories.

    For each probed request, replays the backbone over the logged pre-tick
    latents in ONE batched forward (trajectory axis == batch axis — the
    same layout trick the serving engine uses for slots) and returns
    [(inputs (T, tokens, D), exact outputs (T, tokens, D)), ...].  Probes
    shorter than `min_steps` carry no skippable structure and are dropped."""
    forward_fn, _ = backbone_fns(params, cfg)
    sets = []
    for rid in sorted(trace.probes):
        p = trace.probes[rid]
        if len(p["xs"]) < min_steps:
            continue
        xs = jnp.asarray(np.stack(p["xs"]))
        tv = jnp.asarray(np.asarray(p["tvals"], np.float32))
        labels = jnp.full((xs.shape[0],), p["label"], jnp.int32)
        eps = forward_fn(xs, tv, labels)
        sets.append((xs, eps))
    return sets


def fit_want_gate(key, trajectories, *, steps: int = 150, lr: float = 0.05,
                  rho: float = 0.1):
    """Train a LazyDiT gate on (inputs, outputs) trajectory pairs.

    Mean of the HarmoniCa-style full-trajectory soft-skip loss over all
    trajectories (each rolled out with its own carried cache, so no
    cross-request boundary artifacts).  Returns (gate, loss_history);
    serve the gate via make_policy("lazydit", gate=gate, threshold=...)."""
    if not trajectories:
        raise ValueError("fit_want_gate needs at least one probe "
                         "trajectory (is SignalTraceLog.probe_every set, "
                         "and the session capturing latents?)")
    gate = init_gate(key, trajectories[0][0].shape[-1])

    def loss_fn(g):
        losses = [lazy_trajectory_loss(g, i, o, rho=rho)
                  for i, o in trajectories]
        return sum(losses) / len(losses)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    hist = []
    for _ in range(steps):
        loss, grads = grad_fn(gate)
        gate = jax.tree_util.tree_map(lambda p, g: p - lr * g, gate, grads)
        # repro-lint: disable-next-line=host-sync-in-hot-path -- offline gate training, not a tick path
        hist.append(float(loss))
    return gate, hist
