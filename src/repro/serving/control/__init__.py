"""repro.serving.control — online control plane for cache-aware serving.

The serving engine executes per-slot cache policies; this package decides
WHICH policy, continuously, from the running system itself:

  window      — TelemetryWindow: a TickHook keeping sliding-window serving
                stats (backbone row times, occupancy, compute fraction,
                want-metric means, attached PSNR proxies) shaped exactly
                like the autotuner's pricing inputs
  tuner       — OnlineTuner: quality-sweep once, re-price per window,
                blue/green session rollover at refill boundaries (in-flight
                slots finish under the policy that admitted them);
                ControlPlane: one tuner per modality sub-pool behind a
                single submit/tick/drain surface
  trace       — SignalTraceLog: ring-bounded per-slot signal traces
                (want_cond / want_uncond / want_metric per tick) + probe
                latent trajectories; probe_training_set / fit_want_gate
                turn them into a learned want_compute predictor served via
                make_policy("lazydit", gate=...)
  smoothcache — SmoothCacheSchedule: calibrate-once static per-modality
                schedule (profile rel-L1 drift, greedy threshold), the
                static baseline the online tuner is benchmarked against
"""
from .smoothcache import (SmoothCacheSchedule, calibration_profile,
                          smoothcache_for_modality)
from .trace import SignalTraceLog, TraceEntry, fit_want_gate, probe_training_set
from .tuner import ControlPlane, OnlineTuner
from .window import TelemetryWindow, TickStat

__all__ = [
    "TelemetryWindow", "TickStat",
    "OnlineTuner", "ControlPlane",
    "SignalTraceLog", "TraceEntry", "probe_training_set", "fit_want_gate",
    "SmoothCacheSchedule", "calibration_profile", "smoothcache_for_modality",
]
