"""OnlineTuner / ControlPlane — live policy retuning at refill boundaries.

The offline autotuner picks one policy per traffic class before serving
starts, priced with whatever timings the operator measured once.  The
online tuner closes the loop while the engine serves:

  1. quality sweep ONCE at startup (`sweep_candidates` — PSNR and compute
     fractions are traffic-independent, so they never need re-measuring);
  2. a TelemetryWindow hook watches the live engine (row_time_ms,
     occupancy);
  3. every `retune_every` ticks, `price_and_pick` re-prices the cached
     sweep against the window (host-side arithmetic over ~10 candidates —
     cheap enough for every window) and, if a different candidate wins,
     ROLLS OVER to it.

Rollover is blue/green at the session level, which is what makes the
"never mutate in-flight slots" invariant structural rather than policed:
policy hyperparameters are baked into an engine's jit'd tick programs and
per-slot cache states, so the tuner never touches a live engine.  Instead
the active session stops receiving new submissions and keeps ticking until
its in-flight requests drain under the policy they were admitted with
(reset-on-refill untouched), while a fresh session — on a cached engine for
the new candidate, or a newly built one — becomes the admission target and
inherits the old session's un-admitted backlog.
Policy swaps therefore apply exactly at refill boundaries: a request's
whole trajectory runs under one policy, the one that admitted it.

ControlPlane bundles one OnlineTuner per modality behind a single
submit/tick/drain surface — the mixed-modality umbrella with a control
loop per sub-pool.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

from repro.obs.clock import monotonic
from repro.serving.diffusion import (SLA, DiffusionRequest, DiffusionResult,
                                     DiffusionServingEngine, ServeSession,
                                     TunedPolicy, price_and_pick,
                                     sweep_candidates)

from .trace import SignalTraceLog
from .window import TelemetryWindow


def _policy_key(t: TunedPolicy) -> Tuple:
    """Identity of a tuned operating point (kwargs may hold unhashable
    values like gate pytrees — repr them)."""
    return (t.policy_name, repr(sorted(t.kwargs.items(), key=lambda kv:
                                       kv[0])), t.cfg_interval)


class OnlineTuner:
    """One modality sub-pool's control loop: sweep once, watch the window,
    re-pick at refill boundaries via blue/green session rollover."""

    def __init__(self, params, cfg, sla: SLA, *,
                 slots: int = 4, max_steps: int = 16,
                 modality: str = "image",
                 candidates: Optional[Sequence[Tuple[str, Dict]]] = None,
                 cfg_scale: float = 0.0,
                 cfg_intervals: Sequence[Optional[int]] = (None,),
                 calib_batch: int = 1, seed: int = 0,
                 retune_every: int = 64, min_window_ticks: int = 8,
                 window: Optional[TelemetryWindow] = None,
                 trace: Optional[SignalTraceLog] = None,
                 initial: Union[TunedPolicy, Tuple[str, Dict], None] = None,
                 engine_kw: Optional[Dict] = None,
                 warmup: bool = False, verbose: bool = False,
                 registry=None):
        self.params, self.cfg, self.sla = params, cfg, sla
        self.slots, self.max_steps = slots, max_steps
        self.modality = modality
        self.retune_every = int(retune_every)
        self.min_window_ticks = int(min_window_ticks)
        self.window = window if window is not None else TelemetryWindow()
        self.trace = trace
        self.engine_kw = dict(engine_kw or {})
        self._warmup = bool(warmup)
        self.verbose = bool(verbose)
        #: optional repro.obs MetricsRegistry: retune decisions become
        #: repro_control_* counters and blue/green swaps land in the event
        #: ring; sessions opened by this tuner publish repro_engine_* too
        self.registry = registry

        # 1. quality sweep once: PSNR / compute fractions are
        # traffic-independent, so retunes only ever re-PRICE this list
        self.swept: List[TunedPolicy] = sweep_candidates(
            params, cfg, candidates=candidates, num_steps=max_steps,
            batch=calib_batch, seed=seed, cfg_scale=cfg_scale,
            cfg_intervals=cfg_intervals, verbose=verbose)

        if initial is None:
            # no live timings yet: pick on quality/compute alone
            self.current = price_and_pick(self.swept, sla,
                                          num_steps=max_steps,
                                          registry=self.registry)
        elif isinstance(initial, TunedPolicy):
            self.current = initial
        else:                              # ("name", {kwargs}) shorthand
            name, kwargs = initial
            match = [t for t in self.swept if t.policy_name == name
                     and all(t.kwargs.get(k) == v for k, v in kwargs.items())]
            self.current = (match[0] if match
                            else TunedPolicy(name, dict(kwargs)))

        #: engines cached per tuned operating point (hyperparameters are
        #: baked into jit programs — an engine can be REUSED for a policy
        #: it was built for, once its previous session finished, but never
        #: retuned in place)
        self._engines: Dict[Tuple, List[DiffusionServingEngine]] = {}
        #: audit log of applied swaps
        self.swaps: List[Dict] = []
        self.results: Dict[int, DiffusionResult] = {}
        self._order: List[int] = []
        self.ticks = 0

        self.active: ServeSession = self._new_session(self.current)
        #: sessions rolled over but still draining in-flight requests
        #: under the policy that admitted them
        self.draining: List[ServeSession] = []

    # ------------------------------------------------------------------
    def _engine_for(self, tuned: TunedPolicy) -> DiffusionServingEngine:
        key = _policy_key(tuned)
        for eng in self._engines.get(key, []):
            if not eng._session_active:
                return eng
        eng = DiffusionServingEngine(
            self.params, self.cfg, tuned.make(),
            slots=self.slots, max_steps=self.max_steps,
            cfg_policy=tuned.make_cfg_policy(self.max_steps),
            **self.engine_kw)
        if self._warmup:
            eng.warmup()
        self._engines.setdefault(key, []).append(eng)
        return eng

    def prewarm(self) -> None:
        """Build + compile an engine for every swept candidate so a later
        rollover swaps onto warm jit programs instead of paying an XLA
        compile mid-traffic.  Optional: engines are otherwise built lazily
        at the first swap onto their candidate."""
        for t in self.swept:
            self._engine_for(t).warmup()

    def _new_session(self, tuned: TunedPolicy) -> ServeSession:
        hooks = [self.window.observe]
        capture = False
        if self.trace is not None:
            hooks.append(self.trace.observe)
            capture = self.trace.wants_latents
        return self._engine_for(tuned).start_session(
            [], hooks=hooks, capture_latents=capture,
            modality=self.modality, metrics=self.registry)

    # ------------------------------------------------------------------
    def submit(self, request: DiffusionRequest) -> None:
        """Enqueue on the ACTIVE session — new admissions always see the
        current policy; draining sessions take no new work.  After a drain/
        finish the tuner stays live: the next submit opens a fresh session
        on the current policy (bursty traffic, serve-measure-serve loops)."""
        if self.active._finished:
            self.active = self._new_session(self.current)
        self._order.append(request.request_id)
        self.active.submit(request)

    def submit_all(self, requests: Sequence[DiffusionRequest]) -> None:
        for r in requests:
            self.submit(r)

    @property
    def done(self) -> bool:
        return self.active.done and not self.draining

    def _collect(self, session: ServeSession) -> None:
        for rid, res in session.results.items():
            self.results.setdefault(rid, res)

    def tick(self) -> None:
        """Advance the active session and every draining session one tick;
        retire drained sessions; retune on the cadence."""
        if not self.active.done:
            self.active.tick()
        for s in self.draining:
            if not s.done:
                s.tick()
        for s in list(self.draining):
            if s.done:
                s.finish()          # releases the engine for reuse
                self._collect(s)
                self.draining.remove(s)
        self._collect(self.active)
        self.ticks += 1
        if self.retune_every > 0 and self.ticks % self.retune_every == 0:
            self.maybe_retune()

    def drain(self, max_ticks: int = 100_000) -> List[DiffusionResult]:
        """Tick until every session (active + draining) is done; results in
        submission order."""
        ticks = 0
        while not self.done and ticks < max_ticks:
            self.tick()
            ticks += 1
        return self.finish()

    def finish(self) -> List[DiffusionResult]:
        """Close every session (idempotent) and return completed results
        in submission order."""
        for s in [self.active] + self.draining:
            s.finish()
            self._collect(s)
        return [self.results[rid] for rid in self._order
                if rid in self.results]

    # ------------------------------------------------------------------
    def maybe_retune(self,
                     force_to: Optional[TunedPolicy] = None
                     ) -> Optional[TunedPolicy]:
        """Re-price the sweep against the live window and roll over if a
        different candidate wins.  Returns the new TunedPolicy when a swap
        happened, else None.  `force_to` bypasses the pricing (tests and
        operator overrides)."""
        row_time = self.window.row_time_ms()
        occ = self.window.occupancy()
        if force_to is not None:
            pick = force_to
        else:
            if (row_time is None
                    or len(self.window.ticks) < self.min_window_ticks):
                return None                 # window not informative yet
            pick = price_and_pick(self.swept, self.sla,
                                  num_steps=self.max_steps,
                                  row_time_ms=row_time, occupancy=occ,
                                  plan_ms=self.window.plan_time_ms(),
                                  verbose=self.verbose,
                                  registry=self.registry)
            if self.registry is not None:
                self.registry.counter(
                    "repro_control_retunes_total",
                    "Window re-pricings of the candidate sweep."
                ).inc(modality=self.modality,
                      swapped=str(_policy_key(pick)
                                  != _policy_key(self.current)).lower())
        if _policy_key(pick) == _policy_key(self.current):
            return None
        self._swap(pick, row_time, occ)
        return pick

    def _swap(self, pick: TunedPolicy, row_time, occ: int) -> None:
        """Blue/green rollover at the refill boundary: the old session
        drains its in-flight requests under the policy that admitted them
        (per-slot cache state and jit programs untouched); only NEW
        submissions land on the new policy's session."""
        old = self.active
        self.draining.append(old)
        self.active = self._new_session(pick)
        # in-flight slots stay on `old` until they drain, but the
        # un-admitted backlog follows the admission target — otherwise a
        # rollover would leave queued requests serving under the policy
        # the tuner just decided against
        for r in old.transfer_queued():
            self.active.submit(r)
        self.swaps.append({
            "tick": self.ticks, "time": monotonic(),
            "from": (self.current.policy_name, dict(self.current.kwargs),
                     self.current.cfg_interval),
            "to": (pick.policy_name, dict(pick.kwargs), pick.cfg_interval),
            "row_time_ms": row_time, "occupancy": occ,
            "plan_time_ms": self.window.plan_time_ms(),
            "est_latency_ms": pick.est_latency_ms,
        })
        if self.registry is not None:
            self.registry.counter(
                "repro_control_swaps_total",
                "Blue/green session rollovers applied by the online tuner."
            ).inc(modality=self.modality, to=pick.policy_name)
            self.registry.event(
                "control.swap", modality=self.modality, tick=self.ticks,
                policy_from=self.current.policy_name,
                policy_to=pick.policy_name,
                row_time_ms=row_time, occupancy=occ,
                est_latency_ms=pick.est_latency_ms)
        self.current = pick
        if self.verbose:
            print(f"[control:{self.modality}] tick {self.ticks}: "
                  f"{self.swaps[-1]['from']} -> {self.swaps[-1]['to']} "
                  f"(row_time={row_time}, occupancy={occ})")

    # ------------------------------------------------------------------
    def summary(self) -> Dict:
        return {
            "modality": self.modality,
            "policy": self.current.policy_name,
            "policy_kwargs": {k: v for k, v in self.current.kwargs.items()
                              if not hasattr(v, "keys")},
            "cfg_interval": self.current.cfg_interval,
            "swaps": len(self.swaps),
            "ticks": self.ticks,
            "draining_sessions": len(self.draining),
            "requests_completed": len(self.results),
            "window": self.window.summary(),
            **({"trace": self.trace.summary()}
               if self.trace is not None else {}),
        }


class ControlPlane:
    """Per-modality OnlineTuners behind one submit/tick/drain surface."""

    def __init__(self, tuners: Mapping[str, OnlineTuner],
                 registry=None):
        if not tuners:
            raise ValueError("ControlPlane needs at least one tuner")
        self.tuners: Dict[str, OnlineTuner] = dict(tuners)
        self._order: List[int] = []
        #: optional repro.obs MetricsRegistry; also handed to tuners that
        #: don't already publish somewhere
        self.registry = registry
        if registry is not None:
            for t in self.tuners.values():
                if t.registry is None:
                    t.registry = registry

    def submit(self, request: DiffusionRequest) -> None:
        if request.modality not in self.tuners:
            raise KeyError(f"request {request.request_id}: no tuner for "
                           f"modality '{request.modality}' "
                           f"(tuners: {sorted(self.tuners)})")
        self._order.append(request.request_id)
        if self.registry is not None:
            self.registry.counter(
                "repro_control_submitted_total",
                "Requests submitted through the control plane."
            ).inc(modality=request.modality,
                  traffic_class=request.traffic_class)
        self.tuners[request.modality].submit(request)

    def submit_all(self, requests: Sequence[DiffusionRequest]) -> None:
        for r in requests:
            self.submit(r)

    @property
    def done(self) -> bool:
        return all(t.done for t in self.tuners.values())

    def tick(self) -> None:
        """Round-robin: advance each non-idle modality loop one tick."""
        for t in self.tuners.values():
            if not t.done:
                t.tick()

    def drain(self, max_ticks: int = 100_000) -> List[DiffusionResult]:
        ticks = 0
        while not self.done and ticks < max_ticks:
            self.tick()
            ticks += 1
        results: Dict[int, DiffusionResult] = {}
        for t in self.tuners.values():
            for res in t.finish():
                results[res.request_id] = res
        return [results[rid] for rid in self._order if rid in results]

    def summary(self) -> Dict[str, Dict]:
        return {m: t.summary() for m, t in sorted(self.tuners.items())}
