"""SmoothCacheSchedule — calibrate-once static per-modality schedule.

SmoothCache (PAPERS.md) is the strongest *static* point on the survey's
static->dynamic axis: profile the model ONCE per modality (the rel-L1
change of consecutive exact outputs along a calibration trajectory), derive
a layer-agnostic compute/reuse schedule by greedy error accumulation, then
serve that fixed schedule forever.  No runtime signals, no per-tick
decisions — which makes it both the cheapest possible planner (the serving
engine hosts it entirely on the host-side static-plan fast path: zero
device syncs for planning) and the baseline any *online* control loop must
beat: wherever live telemetry buys nothing, the calibrated static schedule
is already optimal.

Mechanically this is repro.core.adaptive.BlockCachePolicy (the
"Cache Me if You Can" greedy scheduler, Eq. 34-35) applied at MODEL
granularity with a calibration recorder attached — the survey's point that
SmoothCache and layer-adaptive calibration share one algorithm."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np

from repro.core.adaptive import BlockCachePolicy
from repro.core.metrics import rel_l1
from repro.diffusion import ddim_step, linear_schedule, sample
from repro.diffusion.pipeline import cfg_denoise_fn


def calibration_profile(params, cfg, num_steps: int, batch: int = 1,
                        seed: int = 0, class_label: int = 0,
                        cfg_scale: float = 0.0,
                        noise_schedule=None) -> Sequence[float]:
    """Per-step rel-L1 change of the exact model output along one
    calibration trajectory: profile[t] = relL1(eps_t, eps_{t-1}),
    profile[0] = 0 (the first step always computes)."""
    sched = noise_schedule or linear_schedule(1000)
    ts = sched.spaced(num_steps)
    xT = jax.random.normal(jax.random.PRNGKey(seed),
                           (batch, cfg.dit_tokens, cfg.dit_in_dim))
    base = cfg_denoise_fn(params, cfg, cfg_scale, class_label)
    outs = []

    def recorder(state, i, x, t_vec):
        eps, state = base(state, i, x, t_vec)
        outs.append(np.asarray(eps))
        return eps, state

    sample(recorder, xT, ts, sched, step_fn=ddim_step)
    profile = [0.0]
    for i in range(1, len(outs)):
        profile.append(float(rel_l1(outs[i], outs[i - 1])))
    return profile


class SmoothCacheSchedule(BlockCachePolicy):
    """Static calibrated schedule at model granularity.

    `alpha` is the accumulated-change threshold: larger alpha -> longer
    reuse runs -> cheaper serving at lower fidelity.  Int-step
    `want_compute` needs no state, so the serving engine derives a
    host-side static plan and never pays a planning device sync."""

    name = "smoothcache"

    def __init__(self, profile: Sequence[float], alpha: float = 0.1):
        super().__init__(profile, alpha)
        self.alpha = float(alpha)

    @classmethod
    def calibrate(cls, params, cfg, num_steps: int, alpha: float = 0.1,
                  batch: int = 1, seed: int = 0, class_label: int = 0,
                  cfg_scale: float = 0.0,
                  noise_schedule=None) -> "SmoothCacheSchedule":
        """Profile one exact trajectory on this modality's backbone and
        build the static schedule (the profile-once serve-forever flow)."""
        profile = calibration_profile(
            params, cfg, num_steps, batch=batch, seed=seed,
            class_label=class_label, cfg_scale=cfg_scale,
            noise_schedule=noise_schedule)
        return cls(profile, alpha)

    @property
    def compute_fraction(self) -> float:
        """Scheduled computes / calibrated steps."""
        return sum(map(bool, self._schedule)) / max(len(self._schedule), 1)

    def __repr__(self):  # pragma: no cover - debug aid
        return (f"SmoothCacheSchedule(steps={len(self._schedule)}, "
                f"alpha={self.alpha}, cf={self.compute_fraction:.2f})")


def smoothcache_for_modality(workload, num_steps: int, alpha: float = 0.1,
                             cfg_scale: float = 0.0,
                             seed: int = 0) -> SmoothCacheSchedule:
    """Calibrate a SmoothCacheSchedule for one repro.modalities workload
    (profile on that modality's backbone; serve statically)."""
    return SmoothCacheSchedule.calibrate(
        workload.params, workload.cfg, num_steps, alpha=alpha,
        cfg_scale=cfg_scale, seed=seed)
