"""Serving engines: batched LLM decode and cache-aware diffusion.

  engine    — ServingEngine: LLM prefill + rolling-KV continuous decode
  diffusion — DiffusionServingEngine: step-interleaved continuous batching
              of denoising trajectories with per-slot cache states
  control   — online control plane over the diffusion engine: telemetry
              windows, live policy retuning at refill boundaries, signal
              trace logging + learned want_compute, SmoothCache baseline
  common    — request-queue machinery shared by both engines
"""
from .common import RequestQueue
from .engine import ServingEngine, GenerationResult, greedy_generate

__all__ = ["RequestQueue", "ServingEngine", "GenerationResult",
           "greedy_generate"]
