"""Batched serving engine with KV caches and decode-side caching."""
from .engine import ServingEngine, GenerationResult, greedy_generate

__all__ = ["ServingEngine", "GenerationResult", "greedy_generate"]
