"""Batched serving engine.

Continuous-batching-lite: a fixed batch of `slots`, each slot running one
request (prompt prefill + greedy/temperature decode against the rolling KV
cache from repro.models).  Finished slots are refilled from a queue.  All
device work is two jit'd programs (prefill, decode_step) shared across
requests — no per-request recompilation as long as prompt lengths are
bucketed.

Beyond-paper integration of the survey's idea: `layer_skip_policy` applies
LazyDiT-style cross-step layer-output reuse during decode (the survey's
Eq. 14-15 applied to the token axis instead of the denoising axis).  It is
exact-KV plus approximate-hidden reuse; bench_decode_cache.py quantifies the
error/speed trade-off.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill
from repro.serving.common import RequestQueue

PyTree = Any


@dataclass
class GenerationResult:
    request_id: int
    prompt: List[int]
    tokens: List[int] = field(default_factory=list)


class ServingEngine:
    """Fixed-slot batched generation over one architecture."""

    def __init__(self, params, cfg, *, slots: int = 8, cache_len: int = 1024,
                 max_prompt: int = 256, temperature: float = 0.0,
                 eos_id: Optional[int] = None, sync_every: int = 8):
        self.params, self.cfg = params, cfg
        self.slots, self.cache_len = slots, cache_len
        self.max_prompt = max_prompt
        self.temperature = temperature
        self.eos_id = eos_id
        #: decode steps between early-exit probes; each probe is a scalar
        #: host sync, so probing every step would serialize the decode loop
        self.sync_every = max(1, sync_every)

        def _pf(p, toks):
            logits, _, cache = prefill(p, toks, cfg, cache_len)
            return logits[:, -1, :], cache     # next-token logits only

        self._prefill = jax.jit(_pf)

        def _step(p, tok, pos, cache, key):
            logits, cache = decode_step(p, tok, pos, cache, cfg)
            if temperature > 0.0:
                nxt = jax.random.categorical(key, logits / temperature, -1)
            else:
                nxt = jnp.argmax(logits, -1)
            return nxt.astype(jnp.int32), cache

        self._decode = jax.jit(_step)

    # ------------------------------------------------------------------
    def generate(self, prompts: List[List[int]], max_new_tokens: int = 32,
                 seed: int = 0) -> List[GenerationResult]:
        """Generate for every prompt; batches of `slots` at a time.

        Prompts are right-aligned into a common max_prompt window so one
        compiled prefill serves every request."""
        results = [GenerationResult(i, p) for i, p in enumerate(prompts)]
        key = jax.random.PRNGKey(seed)
        queue = RequestQueue(range(len(prompts)))
        while queue:
            chunk = queue.pop_many(self.slots)
            toks = np.zeros((self.slots, self.max_prompt), np.int32)
            for row, ridx in enumerate(chunk):
                p = prompts[ridx][-self.max_prompt:]
                toks[row, -len(p):] = p       # right-aligned
            logits, cache = self._prefill(self.params, jnp.asarray(toks))
            pos = jnp.full((self.slots,), self.max_prompt, jnp.int32)
            if self.temperature > 0.0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / self.temperature, -1)
            else:
                tok = jnp.argmax(logits, -1)
            tok = tok.astype(jnp.int32)
            # The decode loop stays sync-free: tokens accumulate as device
            # arrays and the done mask lives on-device, so back-to-back
            # decode steps pipeline instead of round-tripping every token.
            done = jnp.asarray(np.arange(self.slots) >= len(chunk))
            emitted = []
            since_probe = 0
            for step in range(max_new_tokens):
                emitted.append(tok)
                if self.eos_id is not None:
                    done = done | (tok == self.eos_id)
                    since_probe += 1
                    if (since_probe >= self.sync_every
                            and step + 1 < max_new_tokens):
                        since_probe = 0
                        # repro-lint: disable-next-line=host-sync-in-hot-path -- strided early-exit probe: one scalar sync per sync_every steps
                        if bool(done.all()):
                            break
                if step + 1 < max_new_tokens:
                    key, sub = jax.random.split(key)
                    tok, cache = self._decode(self.params, tok, pos, cache,
                                              sub)
                    pos = pos + 1
            if emitted:
                # repro-lint: disable-next-line=host-sync-in-hot-path -- one bulk transfer per chunk, outside the per-token loop
                toks_host = np.asarray(jnp.stack(emitted, axis=1))
                for row, ridx in enumerate(chunk):
                    row_toks = toks_host[row]
                    if self.eos_id is not None:
                        hits = np.nonzero(row_toks == self.eos_id)[0]
                        if hits.size:          # keep through the first EOS
                            row_toks = row_toks[:hits[0] + 1]
                    results[ridx].tokens.extend(int(t) for t in row_toks)
            del cache
        return results


def greedy_generate(params, cfg, prompt_tokens, max_new_tokens: int = 16,
                    cache_len: int = 256):
    """Single-sequence convenience wrapper used by tests/examples."""
    eng = ServingEngine(params, cfg, slots=1, cache_len=cache_len,
                        max_prompt=len(prompt_tokens))
    out = eng.generate([list(prompt_tokens)], max_new_tokens)
    return out[0].tokens
