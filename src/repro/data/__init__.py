"""Deterministic synthetic data pipelines (offline container — no downloads).

Three generators matching the three workload kinds:

  lm_batches      — token streams with a planted bigram structure so that a
                    trained model measurably reduces loss (used by the
                    end-to-end training example and integration tests)
  latent_batches  — DiT latent patches + class labels (diffusion training)
  frame_batches   — precomputed "encoder frames" for the enc-dec / VLM stub
                    frontends (the brief's one allowed stub)

Each is an infinite iterator of host numpy arrays keyed by a seed; every
batch is reproducible from (seed, step) alone so multi-host sharded loading
needs no coordination — each host slices its shard by process index.
"""
from .synthetic import (LMBatchIterator, frame_embeddings, latent_batches,
                        lm_batches, patch_embeddings)

__all__ = ["lm_batches", "latent_batches", "frame_embeddings",
           "patch_embeddings", "LMBatchIterator"]
