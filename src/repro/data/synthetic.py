"""Seeded synthetic data: reproducible from (seed, step) with no state."""
from __future__ import annotations

import numpy as np


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


# ----------------------------------------------------------------------
# language modelling: planted bigram chain
# ----------------------------------------------------------------------

def _bigram_table(seed: int, vocab: int, branching: int = 8) -> np.ndarray:
    """Each token transitions to one of `branching` successors — a structure
    a model can learn, giving a measurable loss floor below log(vocab)."""
    g = np.random.default_rng(seed)
    return g.integers(0, vocab, size=(vocab, branching), dtype=np.int64)


def lm_batches(seed: int, batch: int, seq_len: int, vocab: int,
               start_step: int = 0):
    """Infinite iterator of (tokens, targets) int32 arrays (B, S)."""
    table = _bigram_table(seed, vocab)
    branching = table.shape[1]
    step = start_step
    while True:
        g = _rng(seed, step)
        toks = np.empty((batch, seq_len + 1), np.int64)
        toks[:, 0] = g.integers(0, vocab, size=batch)
        choices = g.integers(0, branching, size=(batch, seq_len))
        for s in range(seq_len):
            toks[:, s + 1] = table[toks[:, s], choices[:, s]]
        yield toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)
        step += 1


class LMBatchIterator:
    """Checkpointable wrapper: state is just the step counter."""

    def __init__(self, seed: int, batch: int, seq_len: int, vocab: int,
                 step: int = 0):
        self.seed, self.batch, self.seq_len, self.vocab = seed, batch, seq_len, vocab
        self.step = step
        self._it = lm_batches(seed, batch, seq_len, vocab, start_step=step)

    def __next__(self):
        self.step += 1
        return next(self._it)

    def state_dict(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_state(cls, state, batch, seq_len, vocab):
        return cls(state["seed"], batch, seq_len, vocab, step=state["step"])


# ----------------------------------------------------------------------
# diffusion: structured latents (class-dependent mean + low-rank texture)
# ----------------------------------------------------------------------

def latent_batches(seed: int, batch: int, tokens: int, dim: int,
                   num_classes: int, start_step: int = 0):
    """Infinite iterator of (latents (B,T,D) f32, labels (B,) i32).

    Latents are class-conditional Gaussians with a shared low-rank texture —
    enough structure that a trained denoiser beats an untrained one."""
    g0 = np.random.default_rng(seed)
    class_means = g0.normal(0, 1.0, size=(num_classes, dim)).astype(np.float32)
    texture = g0.normal(0, 1.0, size=(8, tokens, dim)).astype(np.float32)
    step = start_step
    while True:
        g = _rng(seed, step)
        labels = g.integers(0, num_classes, size=batch)
        coef = g.normal(0, 0.3, size=(batch, 8, 1, 1)).astype(np.float32)
        x = class_means[labels][:, None, :] + (coef * texture[None]).sum(1)
        x += g.normal(0, 0.1, size=x.shape).astype(np.float32)
        yield x.astype(np.float32), labels.astype(np.int32)
        step += 1


# ----------------------------------------------------------------------
# stub modality frontends (the brief's carve-out)
# ----------------------------------------------------------------------

def frame_embeddings(seed: int, batch: int, frames: int, dim: int) -> np.ndarray:
    """Whisper stub: precomputed conv-frontend frame embeddings (B, F, D)."""
    g = np.random.default_rng(seed)
    t = np.linspace(0, 8 * np.pi, frames, dtype=np.float32)
    base = np.stack([np.sin(t * (i % 7 + 1)) for i in range(dim)], -1)
    noise = g.normal(0, 0.1, size=(batch, frames, dim)).astype(np.float32)
    return base[None] * 0.5 + noise


def patch_embeddings(seed: int, batch: int, patches: int, dim: int) -> np.ndarray:
    """Pixtral stub: precomputed ViT patch embeddings (B, P, D)."""
    g = np.random.default_rng(seed)
    return g.normal(0, 1.0, size=(batch, patches, dim)).astype(np.float32)
