"""Similarity / change metrics used by cache gating policies.

These are the signals the surveyed methods threshold on:
  * rel_l1     — TeaCache Eq. 22, BlockCache Eq. 34
  * mag_ratio  — MagCache Eq. 29
  * transform_rate — EasyCache Eq. 31
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-8


def rel_l1(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Symmetric relative L1 difference (TeaCache Eq. 22)."""
    num = jnp.sum(jnp.abs(a - b))
    den = jnp.sum(jnp.abs(a)) + jnp.sum(jnp.abs(b)) + _EPS
    return num / den


def rel_l1_block(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """One-sided relative L1 (BlockCache Eq. 34)."""
    return jnp.sum(jnp.abs(a - b)) / (jnp.sum(jnp.abs(a)) + _EPS)


def rel_l2(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Relative L2 error ||a-b|| / ||b|| (SpeCa verifier, Eq. 56)."""
    return jnp.linalg.norm((a - b).ravel()) / (jnp.linalg.norm(b.ravel()) + _EPS)


def mag_ratio(r_t: jnp.ndarray, r_prev: jnp.ndarray) -> jnp.ndarray:
    """Magnitude ratio of adjacent residuals (MagCache Eq. 29)."""
    return jnp.linalg.norm(r_t.ravel()) / (jnp.linalg.norm(r_prev.ravel()) + _EPS)


def transform_rate(v_t, v_prev, x_t, x_prev) -> jnp.ndarray:
    """Relative transformation rate k_t (EasyCache Eq. 31)."""
    num = jnp.linalg.norm((v_t - v_prev).ravel())
    den = jnp.linalg.norm((x_t - x_prev).ravel()) + _EPS
    return num / den


def cosine_sim(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    a = a.ravel()
    b = b.ravel()
    return jnp.dot(a, b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b) + _EPS)


def psnr(a: jnp.ndarray, b: jnp.ndarray, data_range: float = 2.0) -> jnp.ndarray:
    """Peak signal-to-noise ratio, used by the quality benchmarks."""
    mse = jnp.mean((a - b) ** 2)
    return 10.0 * jnp.log10(data_range**2 / (mse + _EPS))
