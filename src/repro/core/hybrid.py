"""Hybrid caching policies (survey §III-D4): multi-dimensional coordination.

  * ClusCaPolicy — spatial token clustering: on refresh steps all tokens are
    computed and K-means clustered; on cached steps only one representative
    token per cluster is computed and its fresh value is propagated to its
    cluster through the gamma-blend of Eq. 53-54.  Propagation uses gathers /
    one-hot style dense ops, never scatters with dynamic shapes — TPU layout
    friendly (DESIGN §2.2).
  * SpeCaPolicy  — speculative Forecast-Then-Verify: a TaylorSeer draft
    forecast (Eq. 55) is checked by a lightweight verifier that computes the
    true module output on a small token probe and measures relative error
    (Eq. 56); rejected drafts roll back to a full computation.  Theoretical
    speedup S ~= 1/((1-alpha)+gamma_v) (Eq. 57) is measured in
    benchmarks/bench_speca.py.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .metrics import rel_l2
from .policy import CachePolicy, cond_or_static, interval_pred
from .predictive import forecast_from_diffs, update_diff_stack


def kmeans(tokens: jnp.ndarray, k: int, iters: int = 5):
    """Deterministic fixed-iteration K-means over (T, D) tokens.

    Returns (assign (T,), centroids (k, D), reps (k,)) where reps[i] is the
    token index closest to centroid i.  `k` is clamped to the token count —
    there can be no more clusters than tokens, and an unclamped `k > T`
    would stride the init by zero (every centroid seeded from token 0).
    """
    T = tokens.shape[0]
    k = min(k, T)
    # deterministic init: evenly strided tokens
    idx0 = (jnp.arange(k) * max(T // k, 1)) % T
    cent = tokens[idx0]

    def step(cent, _):
        d2 = jnp.sum((tokens[:, None, :] - cent[None, :, :]) ** 2, -1)  # (T,k)
        assign = jnp.argmin(d2, axis=1)
        onehot = jax.nn.one_hot(assign, k, dtype=tokens.dtype)  # (T,k)
        counts = jnp.maximum(onehot.sum(0), 1.0)  # (k,)
        cent = (onehot.T @ tokens) / counts[:, None]
        return cent, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    d2 = jnp.sum((tokens[:, None, :] - cent[None, :, :]) ** 2, -1)
    assign = jnp.argmin(d2, axis=1)
    # representative = closest token to each centroid
    reps = jnp.argmin(d2, axis=0)  # (k,)
    return assign, cent, reps


class ClusCaPolicy(CachePolicy):
    """Cluster-driven feature caching over (T, D) token features.

    `signals["subset_fn"]` must map a (k, D) token subset through the module
    (the engine provides it for token-wise modules such as MLPs; attention
    modules fall back to full compute on refresh steps only).
    """

    name = "clusca"
    is_predictive = True

    def __init__(self, interval: int, k: int = 16, gamma: float = 0.7,
                 kmeans_iters: int = 5):
        self.interval = interval
        self.k = k
        self.gamma = float(gamma)
        self.kmeans_iters = kmeans_iters

    def _k(self, T: int) -> int:
        """Effective cluster count: never more clusters than tokens."""
        return min(self.k, T)

    def init_state(self, shape, dtype=jnp.float32):
        T = shape[-2]
        return {
            "cache": jnp.zeros(shape, dtype),
            "assign": jnp.zeros(shape[:-2] + (T,), jnp.int32),
            "reps": jnp.zeros(shape[:-2] + (self._k(T),), jnp.int32),
        }

    def apply(self, state, step, x, compute_fn, subset_fn: Optional[Callable] = None,
              **signals):
        def compute(state):
            y = compute_fn(x)

            def cluster_2d(y2):
                assign, _, reps = kmeans(y2.astype(jnp.float32),
                                         self._k(y2.shape[0]),
                                         self.kmeans_iters)
                return assign, reps

            if y.ndim == 2:
                assign, reps = cluster_2d(y)
            else:  # leading batch dims -> vmap over them (flattened)
                lead = y.shape[:-2]
                flat = y.reshape((-1,) + y.shape[-2:])
                assign, reps = jax.vmap(cluster_2d)(flat)
                assign = assign.reshape(lead + assign.shape[-1:])
                reps = reps.reshape(lead + reps.shape[-1:])
            return y, {"cache": y.astype(state["cache"].dtype),
                       "assign": assign, "reps": reps}

        def partial(state):
            if subset_fn is None:
                # no token-subset path available: plain reuse
                return state["cache"].astype(x.dtype), state

            def one(x2, cache2, assign, reps):
                x_reps = jnp.take(x2, reps, axis=0)           # (k, D)
                y_reps = subset_fn(x_reps)                    # (k, D)
                mu = jnp.take(y_reps, assign, axis=0)         # (T, D) gather
                y = self.gamma * mu + (1.0 - self.gamma) * cache2.astype(mu.dtype)
                # freshly computed representatives are exact (one-hot blend)
                onehot = jax.nn.one_hot(reps, x2.shape[0], dtype=y.dtype)  # (k,T)
                is_rep = jnp.clip(onehot.sum(0), 0.0, 1.0)[:, None]        # (T,1)
                y = y * (1.0 - is_rep) + (onehot.T @ y_reps) * is_rep
                return y

            if x.ndim == 2:
                y = one(x, state["cache"], state["assign"], state["reps"])
            else:
                lead = x.shape[:-2]
                y = jax.vmap(one)(
                    x.reshape((-1,) + x.shape[-2:]),
                    state["cache"].reshape((-1,) + x.shape[-2:]),
                    state["assign"].reshape((-1, x.shape[-2])),
                    state["reps"].reshape((-1, state["reps"].shape[-1])),
                )
                y = y.reshape(lead + y.shape[-2:])
            new = dict(state)
            new["cache"] = y.astype(state["cache"].dtype)
            return y.astype(x.dtype), new

        pred = interval_pred(step, self.interval)
        return cond_or_static(pred, compute, partial, state)

    def want_compute(self, state, step, x, **signals):
        # the partial branch never calls compute_fn (it uses subset_fn when
        # available), so the interval predicate is exact for serving
        return jnp.asarray(interval_pred(step, self.interval))

    def static_schedule(self, num_steps: int):
        return [s % self.interval == 0 for s in range(num_steps)]


class SpeCaPolicy(CachePolicy):
    """Speculative feature caching: TaylorSeer draft + probe verification.

    `signals["subset_fn"]` maps a (P, D) probe-token subset through the
    module; the probe is a fixed stride subset of tokens.  If unavailable,
    verification degrades to accept-always (pure TaylorSeer).
    """

    name = "speca"
    is_predictive = True

    def __init__(self, interval: int, order: int = 2, tau: float = 0.1,
                 probe: int = 16):
        self.interval = interval
        self.order = order
        self.tau = float(tau)
        self.probe = probe

    def init_state(self, shape, dtype=jnp.float32):
        return {
            "diffs": jnp.zeros((self.order + 1, *shape), jnp.float32),
            "n_valid": jnp.zeros((), jnp.int32),
            "last_step": jnp.zeros((), jnp.int32),
            "accepts": jnp.zeros((), jnp.int32),
            "rejects": jnp.zeros((), jnp.int32),
        }

    def _probe_idx(self, T):
        stride = max(T // self.probe, 1)
        return jnp.arange(self.probe) * stride % T

    def apply(self, state, step, x, compute_fn, subset_fn: Optional[Callable] = None,
              **signals):
        step_val = jnp.asarray(step, jnp.int32)

        def full(state):
            y = compute_fn(x)
            return y, {**state,
                       "diffs": update_diff_stack(state["diffs"], y),
                       "n_valid": state["n_valid"] + 1,
                       "last_step": step_val}

        def speculate(state):
            k = (step_val - state["last_step"]).astype(jnp.float32)
            u = k / float(self.interval)
            y_hat = forecast_from_diffs(state["diffs"], u, state["n_valid"],
                                        "taylor")
            verify_fn = signals.get("verify_fn")
            if subset_fn is None and verify_fn is None:
                return y_hat.astype(x.dtype), state

            def accept_(state):
                return y_hat.astype(x.dtype), {**state,
                                               "accepts": state["accepts"] + 1}

            def reject_(state):
                y, new = full(state)
                return y, {**new, "rejects": state["rejects"] + 1}

            if verify_fn is not None:
                # external verifier (benchmarks use the full model as an
                # oracle; production uses a cheap probe)
                err = verify_fn(x, y_hat.astype(x.dtype))
                return jax.lax.cond(err <= self.tau, accept_, reject_, state)

            idx = self._probe_idx(x.shape[-2])

            def probe_one(x2, yh2):
                xt = jnp.take(x2, idx, axis=0)
                yt = subset_fn(xt)
                return rel_l2(jnp.take(yh2, idx, axis=0), yt)

            if x.ndim == 2:
                err = probe_one(x, y_hat)
            else:
                errs = jax.vmap(probe_one)(
                    x.reshape((-1,) + x.shape[-2:]),
                    y_hat.reshape((-1,) + x.shape[-2:]))
                err = jnp.max(errs)

            def accept(state):
                return y_hat.astype(x.dtype), {**state,
                                               "accepts": state["accepts"] + 1}

            def reject(state):
                y, new = full(state)
                new = {**new, "rejects": state["rejects"] + 1}
                return y, new

            return jax.lax.cond(err <= self.tau, accept, reject, state)

        pred = interval_pred(step, self.interval)
        return cond_or_static(pred, full, speculate, state)

    def want_compute(self, state, step, x, subset_fn=None, **signals):
        if subset_fn is None and signals.get("verify_fn") is None:
            # degraded accept-always mode: speculate never calls compute_fn
            return jnp.asarray(interval_pred(step, self.interval))
        # a rejected draft rolls back to a full compute at any step, so the
        # serving engine must always dispatch the full program
        return jnp.asarray(True)

    def static_schedule(self, num_steps: int):
        return [s % self.interval == 0 for s in range(num_steps)]
