"""Timestep-adaptive and layer-adaptive caching policies (survey §III-D1/D2).

These introduce the survey's "error checking mechanism": a cheap online
signal decides, per step, whether to refresh the cache.  All predicates are
traced (`lax.cond`), so a single compiled program serves every trajectory.

  * TeaCachePolicy   — rel-L1 of the timestep-modulated input, polynomial
    corrected, accumulated until threshold delta (Eq. 22-24).
  * MagCachePolicy   — accumulated magnitude-decay error 1 - prod(gamma_i)
    against a calibrated / analytic gamma curve (Eq. 29-30).
  * EasyCachePolicy  — online transformation-rate gate (Eq. 31-33), fully
    self-contained (no calibration).
  * BlockCachePolicy — "Cache Me if You Can" layer-adaptive scheduling from a
    calibration profile of per-block rel-L1 changes (Eq. 34-35); produces a
    *static* per-block schedule, which is also what the roofline dry-runs
    consume.
  * ForesightPolicy  — warm-up-estimated per-layer threshold, then online
    input-change gating (Eq. 40-41).
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .metrics import rel_l1, rel_l1_block
from .policy import CachePolicy


class TeaCachePolicy(CachePolicy):
    """TeaCache: accumulate corrected input-side change until it crosses delta.

    `signals["signal"]` must carry the timestep-embedding-modulated input
    (for DiT: AdaLN(x, t, c) of the first block); we fall back to x itself.
    `poly` are the correction-polynomial coefficients (Eq. 23), lowest order
    first; TeaCache fits these offline per model family — identity by
    default.
    """

    name = "teacache"
    uses_signal = True

    def __init__(self, delta: float, poly: Sequence[float] = (0.0, 1.0)):
        self.delta = float(delta)
        self.poly = tuple(float(p) for p in poly)

    def init_state(self, shape, dtype=jnp.float32, signal_shape=None):
        return {
            "cache": jnp.zeros(shape, dtype),
            "prev_signal": jnp.zeros(signal_shape or shape, jnp.float32),
            "acc": jnp.zeros((), jnp.float32),
            "n": jnp.zeros((), jnp.int32),
            "n_compute": jnp.zeros((), jnp.int32),
        }

    def _correct(self, d):
        out = jnp.zeros((), jnp.float32)
        for i, a in enumerate(self.poly):
            out = out + a * d**i
        return out

    def _signal_distance(self, sig, prev):
        """Scalar change metric between consecutive signals (Eq. 22).

        Subclass hook: TemporalTeaCachePolicy (repro.core.temporal) reduces a
        per-frame distance over the clip's frame axis instead."""
        return rel_l1(sig, prev)

    def apply(self, state, step, x, compute_fn, **signals):
        sig = signals.get("signal", x).astype(jnp.float32)
        d = self._correct(self._signal_distance(sig, state["prev_signal"]))
        acc = state["acc"] + d
        first = state["n"] == 0
        refresh = jnp.logical_or(first, acc >= self.delta)

        def compute(state):
            y = compute_fn(x)
            return y, {
                "cache": y.astype(state["cache"].dtype),
                "prev_signal": sig,
                "acc": jnp.zeros((), jnp.float32),
                "n": state["n"] + 1,
                "n_compute": state["n_compute"] + 1,
            }

        def reuse(state):
            new = dict(state)
            new["acc"] = acc
            new["prev_signal"] = sig
            new["n"] = state["n"] + 1
            return state["cache"].astype(x.dtype), new

        return jax.lax.cond(refresh, compute, reuse, state)

    def want_compute(self, state, step, x, **signals):
        sig = signals.get("signal", x).astype(jnp.float32)
        d = self._correct(self._signal_distance(sig, state["prev_signal"]))
        return jnp.logical_or(state["n"] == 0, state["acc"] + d >= self.delta)

    def want_metric(self, state, step, x, **signals):
        """The corrected accumulated distance the delta threshold sees."""
        sig = signals.get("signal", x).astype(jnp.float32)
        d = self._correct(self._signal_distance(sig, state["prev_signal"]))
        return (state["acc"] + d).astype(jnp.float32)


class MagCachePolicy(CachePolicy):
    """MagCache: accumulated error eps(t) = 1 - prod(gamma_i) since the last
    refresh (Eq. 30); gamma is the per-step residual-magnitude ratio curve,
    either calibrated from one profiling run or the analytic default."""

    name = "magcache"

    def __init__(self, delta: float, gammas: Sequence[float] | None = None,
                 num_steps: int = 50):
        self.delta = float(delta)
        if gammas is None:
            # analytic default: magnitude ratio decays towards 1 late in
            # sampling (unified amplitude decay law, survey Eq. 29-30)
            t = np.arange(num_steps)
            gammas = 1.0 - 0.05 * np.exp(-3.0 * t / max(num_steps - 1, 1))
        self.gammas = jnp.asarray(np.asarray(gammas, np.float32))

    def init_state(self, shape, dtype=jnp.float32):
        return {
            "cache": jnp.zeros(shape, dtype),
            "prod": jnp.ones((), jnp.float32),
            "n": jnp.zeros((), jnp.int32),
            "n_compute": jnp.zeros((), jnp.int32),
        }

    def apply(self, state, step, x, compute_fn, **signals):
        step_val = jnp.asarray(step, jnp.int32)
        g = self.gammas[jnp.clip(step_val, 0, self.gammas.shape[0] - 1)]
        prod = state["prod"] * g
        err = 1.0 - prod
        refresh = jnp.logical_or(state["n"] == 0, err >= self.delta)

        def compute(state):
            y = compute_fn(x)
            return y, {"cache": y.astype(state["cache"].dtype),
                       "prod": jnp.ones((), jnp.float32), "n": state["n"] + 1,
                       "n_compute": state["n_compute"] + 1}

        def reuse(state):
            return state["cache"].astype(x.dtype), {
                "cache": state["cache"], "prod": prod, "n": state["n"] + 1,
                "n_compute": state["n_compute"]}

        return jax.lax.cond(refresh, compute, reuse, state)

    def want_compute(self, state, step, x, **signals):
        step_val = jnp.asarray(step, jnp.int32)
        g = self.gammas[jnp.clip(step_val, 0, self.gammas.shape[0] - 1)]
        err = 1.0 - state["prod"] * g
        return jnp.logical_or(state["n"] == 0, err >= self.delta)

    def want_metric(self, state, step, x, **signals):
        """The accumulated magnitude-decay error the delta threshold sees."""
        step_val = jnp.asarray(step, jnp.int32)
        g = self.gammas[jnp.clip(step_val, 0, self.gammas.shape[0] - 1)]
        return (1.0 - state["prod"] * g).astype(jnp.float32)


class EasyCachePolicy(CachePolicy):
    """EasyCache: local-linearity gate.  On refresh, store the transformation
    vector Delta = v - x (Eq. 32) and rate k (Eq. 31); on skipped steps
    approximate v = x + Delta and accumulate the deviation estimate
    eps_n = k * ||x_n - x_{n-1}|| / ||v_{n-1}|| (Eq. 33) until tau."""

    name = "easycache"

    def __init__(self, tau: float, warmup: int = 2):
        self.tau = float(tau)
        self.warmup = warmup

    def init_state(self, shape, dtype=jnp.float32):
        return {
            "delta": jnp.zeros(shape, jnp.float32),
            "k": jnp.zeros((), jnp.float32),
            "prev_x": jnp.zeros(shape, jnp.float32),
            "prev_v": jnp.zeros(shape, jnp.float32),
            "acc": jnp.zeros((), jnp.float32),
            "n": jnp.zeros((), jnp.int32),
            "n_compute": jnp.zeros((), jnp.int32),
        }

    def apply(self, state, step, x, compute_fn, **signals):
        xf = x.astype(jnp.float32)
        dx = jnp.linalg.norm((xf - state["prev_x"]).ravel())
        v_norm = jnp.linalg.norm(state["prev_v"].ravel()) + 1e-8
        eps = state["k"] * dx / v_norm * 100.0
        acc = state["acc"] + eps
        refresh = jnp.logical_or(state["n"] < self.warmup, acc >= self.tau)

        def compute(state):
            y = compute_fn(x)
            yf = y.astype(jnp.float32)
            dv = jnp.linalg.norm((yf - state["prev_v"]).ravel())
            k = dv / (dx + 1e-8)
            return y, {
                "delta": yf - xf, "k": k, "prev_x": xf, "prev_v": yf,
                "acc": jnp.zeros((), jnp.float32), "n": state["n"] + 1,
                "n_compute": state["n_compute"] + 1,
            }

        def reuse(state):
            v_hat = xf + state["delta"]
            new = dict(state)
            new["prev_x"] = xf
            new["prev_v"] = v_hat
            new["acc"] = acc
            new["n"] = state["n"] + 1
            return v_hat.astype(x.dtype), new

        return jax.lax.cond(refresh, compute, reuse, state)

    def want_compute(self, state, step, x, **signals):
        xf = x.astype(jnp.float32)
        dx = jnp.linalg.norm((xf - state["prev_x"]).ravel())
        v_norm = jnp.linalg.norm(state["prev_v"].ravel()) + 1e-8
        eps = state["k"] * dx / v_norm * 100.0
        return jnp.logical_or(state["n"] < self.warmup,
                              state["acc"] + eps >= self.tau)


class BlockCachePolicy(CachePolicy):
    """Layer-adaptive static scheduling from a calibration profile.

    `profile[t]` is the measured rel-L1 change of this block's output between
    steps t-1 and t (Eq. 34) from one calibration trajectory.  The schedule
    recomputes whenever the cumulative change since the last refresh would
    exceed delta (Eq. 35).  The result is a static per-block compute plan —
    cheap, robust, and exactly what the compiled roofline graphs consume.

    Steps beyond the calibration profile recompute (recompute-on-overflow):
    a trajectory longer than the profile has no measured change data, and
    silently clamping to the last scheduled decision (what an out-of-range
    gather would do) can extend a reuse run indefinitely.
    """

    name = "blockcache"

    def __init__(self, profile: Sequence[float], delta: float):
        self.profile = [float(p) for p in profile]
        self.delta = float(delta)
        self._schedule = self._build_schedule()

    def _build_schedule(self) -> List[bool]:
        sched, acc = [], 0.0
        for t, change in enumerate(self.profile):
            if t == 0:
                sched.append(True)
                acc = 0.0
                continue
            acc += change
            if acc > self.delta:
                sched.append(True)
                acc = 0.0
            else:
                sched.append(False)
        return sched

    def _sched_at(self, step: int) -> bool:
        """Concrete-step lookup with recompute-on-overflow."""
        return self._schedule[step] if step < len(self._schedule) else True

    def init_state(self, shape, dtype=jnp.float32):
        return {"cache": jnp.zeros(shape, dtype),
                "sched": jnp.asarray(self._schedule, jnp.bool_)}

    def apply(self, state, step, x, compute_fn, **signals):
        if isinstance(step, int):
            if self._sched_at(step):
                y = compute_fn(x)
                return y, {**state, "cache": y.astype(state["cache"].dtype)}
            return state["cache"].astype(x.dtype), state

        pred = self.want_compute(state, step, x)

        def compute(state):
            y = compute_fn(x)
            return y, {**state, "cache": y.astype(state["cache"].dtype)}

        def reuse(state):
            return state["cache"].astype(x.dtype), state

        return jax.lax.cond(pred, compute, reuse, state)

    def want_compute(self, state, step, x=None, **signals):
        if isinstance(step, int):
            return jnp.asarray(self._sched_at(step))
        step = jnp.asarray(step, jnp.int32)
        n = state["sched"].shape[0]
        in_range = step < n
        return jnp.where(in_range, state["sched"][jnp.clip(step, 0, n - 1)],
                         True)

    def static_schedule(self, num_steps: int):
        if num_steps <= len(self._schedule):
            return self._schedule[:num_steps]
        return self._schedule + [True] * (num_steps - len(self._schedule))


class ForesightPolicy(CachePolicy):
    """Foresight: during the first `warmup` steps always compute and estimate
    the per-layer variation scale lambda_l (Eq. 40); afterwards reuse while
    the online input-change metric delta_l(t) stays below gamma*lambda_l
    (Eq. 41)."""

    name = "foresight"

    def __init__(self, gamma: float = 1.0, warmup: int = 3):
        self.gamma = float(gamma)
        self.warmup = warmup

    def init_state(self, shape, dtype=jnp.float32):
        return {
            "cache": jnp.zeros(shape, dtype),
            "prev_in": jnp.zeros(shape, jnp.float32),
            "lam": jnp.zeros((), jnp.float32),
            "n": jnp.zeros((), jnp.int32),
            "n_compute": jnp.zeros((), jnp.int32),
        }

    def apply(self, state, step, x, compute_fn, **signals):
        xf = x.astype(jnp.float32)
        delta = rel_l1_block(xf, state["prev_in"])
        in_warmup = state["n"] < self.warmup
        refresh = jnp.logical_or(in_warmup, delta > self.gamma * state["lam"])

        def compute(state):
            y = compute_fn(x)
            # exponentially-weighted lambda estimate (Eq. 40's decaying sum)
            lam = jnp.where(state["n"] == 0, delta,
                            0.9 * state["lam"] + 0.1 * delta)
            return y, {"cache": y.astype(state["cache"].dtype),
                       "prev_in": xf, "lam": lam, "n": state["n"] + 1,
                       "n_compute": state["n_compute"] + 1}

        def reuse(state):
            new = dict(state)
            new["prev_in"] = xf
            new["n"] = state["n"] + 1
            return state["cache"].astype(x.dtype), new

        return jax.lax.cond(refresh, compute, reuse, state)

    def want_compute(self, state, step, x, **signals):
        delta = rel_l1_block(x.astype(jnp.float32), state["prev_in"])
        return jnp.logical_or(state["n"] < self.warmup,
                              delta > self.gamma * state["lam"])
