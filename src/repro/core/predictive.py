"""Predictive ("Cache-Then-Forecast") policies — survey §III-D3.

All of TaylorSeer / HiCache / AB-Cache / FoCa share one state layout: a
finite-difference stack over the features computed at the last few *full*
steps, maintained exactly as TaylorSeer does:

    d[0] <- F                    (freshly computed feature)
    d[i] <- d[i-1] - d_old[i-1]  (Newton forward differences)

plus `n_valid` (how many computes have happened — early forecasts must not
use unwarmed high orders) and `interval` (spacing, in steps, between the two
most recent computes, used to normalise the elapsed offset u = k/interval).

The forecast bases:

  * taylor  (TaylorSeer, Eq. 42):      y ~= sum_i d[i] * u^i / i!
  * newton  (beyond-paper):            y ~= sum_i d[i] * binom(u, i)
      -- exact for degree-<=m polynomial trajectories sampled on the grid;
         strictly dominates the Taylor form (see tests/test_predictive.py).
  * hermite (HiCache, Eq. 47):         y ~= d[0] + sum_{i>=1} d[i]/i! * Ht_i(u),
      Ht_i(x) = sigma^i * H_i(sigma * x)   (physicists' Hermite, contracted)
  * ab      (AB-Cache, Eq. 45, 2nd order Adams-Bashforth):
      y ~= d[0] + u * (d[1] + d[2]/2)
  * foca    (FoCa, Eq. 48): BDF2 predictor + Heun trapezoidal corrector,
      iterated k times on the feature ODE.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from .policy import CachePolicy, cond_or_static, interval_pred

BASES = ("taylor", "newton", "hermite", "ab", "foca")


def _hermite_poly(i: int, x):
    """Physicists' Hermite H_i(x), small fixed order — unrolled recurrence."""
    h_prev, h = jnp.ones_like(x), 2.0 * x
    if i == 0:
        return h_prev
    for _ in range(i - 1):
        h_prev, h = h, 2.0 * x * h - 2.0 * (_ + 1) * h_prev
    return h


def update_diff_stack(diffs: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Shift a (order+1, ...) finite-difference stack with a new sample."""
    order = diffs.shape[0] - 1
    new = [y.astype(diffs.dtype)]
    for i in range(1, order + 1):
        new.append(new[i - 1] - diffs[i - 1])
    return jnp.stack(new, axis=0)


def forecast_from_diffs(diffs, u, n_valid, basis: str = "taylor", sigma: float = 0.5):
    """Evaluate the chosen basis at normalised elapsed offset u (scalar)."""
    order = diffs.shape[0] - 1
    u = jnp.asarray(u, jnp.float32)

    if basis == "foca":
        return _foca_forecast(diffs, u, n_valid)

    coeffs = []
    for i in range(order + 1):
        if basis == "taylor":
            c = u**i / math.factorial(i)
        elif basis == "newton":
            # backward-difference Newton: the stack holds nabla^i F at the
            # newest grid point, so F(t0 + u*N) = sum_i nabla^i F * binom(u+i-1, i)
            c = jnp.ones(())
            for j in range(i):
                c = c * (u + j)
            c = c / math.factorial(i)
        elif basis == "hermite":
            if i == 0:
                c = jnp.ones(())
            else:
                c = (sigma**i) * _hermite_poly(i, sigma * u) / math.factorial(i)
        elif basis == "ab":
            c = {0: jnp.ones(()), 1: u, 2: 0.5 * u}.get(i, jnp.zeros(()))
        else:  # pragma: no cover
            raise ValueError(f"unknown basis {basis}")
        # orders beyond the number of observed computes are invalid -> mask
        valid = (jnp.asarray(n_valid) > i).astype(jnp.float32)
        coeffs.append(c * valid)
    coeffs = jnp.stack(coeffs)  # (order+1,)
    flat = diffs.reshape(order + 1, -1).astype(jnp.float32)
    out = jnp.tensordot(coeffs, flat, axes=1)
    return out.reshape(diffs.shape[1:])


def _foca_forecast(diffs, u, n_valid):
    """FoCa: BDF2 predict + Heun correct, iterated ceil(u) unit steps.

    f_k   = d[0], f_{k-1} = d[0] - d[1]; derivative estimate f'_k = d[1]
    (unit grid).  Each unit step:
        pred  = 4/3 f_k - 1/3 f_{k-1} + 2/3 f'_k          (BDF2, Eq. 48)
        f'_pred = pred - f_k                               (local slope)
        next  = f_k + (f'_k + f'_pred)/2                   (Heun corrector)
    Falls back to reuse until two computes have been seen.
    """
    f_k = diffs[0].astype(jnp.float32)
    f_km1 = (diffs[0] - diffs[1]).astype(jnp.float32)
    n_steps = jnp.maximum(jnp.ceil(u).astype(jnp.int32), 0)

    def body(_, carry):
        f_k, f_km1 = carry
        fp = f_k - f_km1
        pred = 4.0 / 3.0 * f_k - 1.0 / 3.0 * f_km1 + 2.0 / 3.0 * fp
        fp_pred = pred - f_k
        nxt = f_k + 0.5 * (fp + fp_pred)
        return nxt, f_k

    # u is a traced scalar: bound the loop by a static max and mask.
    MAX_STEPS = 64
    def masked_body(i, carry):
        new = body(i, carry)
        take = i < n_steps
        return (jnp.where(take, new[0], carry[0]), jnp.where(take, new[1], carry[1]))

    out, _ = jax.lax.fori_loop(0, MAX_STEPS, masked_body, (f_k, f_km1))
    # without >=2 computes, fall back to plain reuse
    return jnp.where(jnp.asarray(n_valid) >= 2, out, f_k)


class PredictivePolicy(CachePolicy):
    """TaylorSeer / HiCache / AB-Cache / FoCa under one roof."""

    is_predictive = True

    def __init__(self, interval: int, order: int = 2, basis: str = "taylor",
                 sigma: float = 0.5):
        assert basis in BASES, basis
        assert order >= 1
        self.interval = interval
        self.order = order
        self.basis = basis
        self.sigma = sigma
        self.name = {"taylor": "taylorseer", "newton": "newtonseer",
                     "hermite": "hicache", "ab": "abcache", "foca": "foca"}[basis]

    def init_state(self, shape, dtype=jnp.float32):
        return {
            "diffs": jnp.zeros((self.order + 1, *shape), dtype),
            "n_valid": jnp.zeros((), jnp.int32),
            "last_step": jnp.zeros((), jnp.int32),
        }

    def apply(self, state, step, x, compute_fn, **signals):
        step_val = jnp.asarray(step, jnp.int32)

        def compute(state):
            y = compute_fn(x)
            return y, {
                "diffs": update_diff_stack(state["diffs"], y),
                "n_valid": state["n_valid"] + 1,
                "last_step": step_val,
            }

        def forecast(state):
            k = (step_val - state["last_step"]).astype(jnp.float32)
            u = k / float(self.interval)
            y = forecast_from_diffs(state["diffs"], u, state["n_valid"],
                                    self.basis, self.sigma)
            return y.astype(x.dtype), state

        return cond_or_static(interval_pred(step, self.interval),
                              compute, forecast, state)

    def want_compute(self, state, step, x, **signals):
        return jnp.asarray(interval_pred(step, self.interval))

    def static_schedule(self, num_steps: int):
        return [s % self.interval == 0 for s in range(num_steps)]


class FreqCaPolicy(CachePolicy):
    """FreqCa (Eq. 49-51): split the feature along the token axis into low and
    high frequency bands; the low band is reused verbatim (high cross-step
    similarity), the high band is forecast with a 2nd-order Hermite step
    (smooth temporal evolution)."""

    is_predictive = True
    name = "freqca"

    def __init__(self, interval: int, cutoff: float = 0.25, sigma: float = 0.5,
                 axis: int = -2):
        self.interval = interval
        self.cutoff = cutoff
        self.sigma = sigma
        self.axis = axis

    def _split(self, y):
        n = y.shape[self.axis]
        f = jnp.fft.rfft(y.astype(jnp.float32), axis=self.axis)
        k = jnp.arange(f.shape[self.axis])
        keep = (k <= max(int(self.cutoff * n // 2), 1)).astype(f.dtype)
        shape = [1] * y.ndim
        shape[self.axis] = f.shape[self.axis]
        keep = keep.reshape(shape)
        low = jnp.fft.irfft(f * keep, n=n, axis=self.axis)
        return low, y.astype(jnp.float32) - low

    def init_state(self, shape, dtype=jnp.float32):
        return {
            "low": jnp.zeros(shape, jnp.float32),
            "high_diffs": jnp.zeros((3, *shape), jnp.float32),
            "n_valid": jnp.zeros((), jnp.int32),
            "last_step": jnp.zeros((), jnp.int32),
        }

    def apply(self, state, step, x, compute_fn, **signals):
        step_val = jnp.asarray(step, jnp.int32)

        def compute(state):
            y = compute_fn(x)
            low, high = self._split(y)
            return y, {
                "low": low,
                "high_diffs": update_diff_stack(state["high_diffs"], high),
                "n_valid": state["n_valid"] + 1,
                "last_step": step_val,
            }

        def forecast(state):
            k = (step_val - state["last_step"]).astype(jnp.float32)
            u = k / float(self.interval)
            high = forecast_from_diffs(state["high_diffs"], u, state["n_valid"],
                                       "hermite", self.sigma)
            return (state["low"] + high).astype(x.dtype), state

        return cond_or_static(interval_pred(step, self.interval),
                              compute, forecast, state)

    def want_compute(self, state, step, x, **signals):
        return jnp.asarray(interval_pred(step, self.interval))

    def static_schedule(self, num_steps: int):
        return [s % self.interval == 0 for s in range(num_steps)]
