"""Cache engine: binds policies to modules / layer stacks.

Granularities (survey Fig. 2 "reuse granularity" axis):

  * MODEL  — one policy gates the whole backbone forward (TeaCache,
    MagCache, EasyCache operate here).  This is also FreqCa's CRF trick
    (Eq. 52): caching the *cumulative residual* (= final hidden state)
    costs O(1) memory instead of O(L) per-layer caches.
  * BLOCK  — one policy instance per transformer block, states stacked on a
    leading layer axis and threaded through the `lax.scan` over layers
    (BlockCache, Foresight, FORA-per-block, TaylorSeer-per-block).
  * MODULE — separate policies for attention vs MLP (PAB's per-type ranges).

`DeepCache` from the survey is a *structural composition* at this level:
wrap only the deep sub-network (U-Net up-path, DiT mid-blocks) in a
CachedModule while the shallow path always recomputes — see
repro/diffusion/pipeline.py and DBCacheStack below.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from .metrics import rel_l1_block
from .policy import CachePolicy, NoCachePolicy

PyTree = Any


class CachedModule:
    """A module fn wrapped with a cache policy.

    fn: (x, *args) -> y with y.shape == policy feature shape.
    """

    def __init__(self, fn: Callable, policy: CachePolicy):
        self.fn = fn
        self.policy = policy

    def init(self, shape, dtype=jnp.float32):
        return self.policy.init_state(shape, dtype)

    def __call__(self, state, step, x, *args, **signals):
        return self.policy.apply(state, step, x,
                                 lambda xx: self.fn(xx, *args), **signals)


class CachedStack:
    """`lax.scan` over L blocks, each block's output gated by `policy`.

    block_fn: (layer_params, x, *args) -> y        (same shape as x)
    params are stacked on a leading layer axis.
    """

    def __init__(self, block_fn: Callable, policy: CachePolicy, num_layers: int):
        self.block_fn = block_fn
        self.policy = policy
        self.num_layers = num_layers

    def init(self, shape, dtype=jnp.float32):
        one = self.policy.init_state(shape, dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (self.num_layers,) + a.shape).copy(),
            one)

    def __call__(self, states, step, x, stacked_params, *args):
        def body(carry, inp):
            x = carry
            params_l, state_l = inp
            y, state_l = self.policy.apply(
                state_l, step, x, lambda xx: self.block_fn(params_l, xx, *args))
            return y, state_l

        y, new_states = jax.lax.scan(body, x, (stacked_params, states))
        return y, new_states


class DBCacheStack:
    """DBCache (survey §III-D2): probe -> decide -> correct.

    The first `front_n` blocks always compute and act as the probe: the
    rel-L1 between the probe output and the previous step's probe output
    decides whether the middle section reuses its cached output.  The last
    `back_n` blocks always compute (the corrector)."""

    def __init__(self, block_fn: Callable, num_layers: int, front_n: int = 2,
                 back_n: int = 2, threshold: float = 0.05):
        assert front_n + back_n < num_layers
        self.block_fn = block_fn
        self.num_layers = num_layers
        self.front_n = front_n
        self.back_n = back_n
        self.threshold = float(threshold)

    def init(self, shape, dtype=jnp.float32):
        return {
            "mid_cache": jnp.zeros(shape, dtype),
            "prev_probe": jnp.zeros(shape, jnp.float32),
            "n": jnp.zeros((), jnp.int32),
        }

    def _run_range(self, x, stacked_params, lo, hi, *args):
        section = jax.tree_util.tree_map(lambda p: p[lo:hi], stacked_params)

        def body(carry, params_l):
            return self.block_fn(params_l, carry, *args), None

        y, _ = jax.lax.scan(body, x, section)
        return y

    def __call__(self, state, step, x, stacked_params, *args):
        L, F, B = self.num_layers, self.front_n, self.back_n
        probe = self._run_range(x, stacked_params, 0, F, *args)
        change = rel_l1_block(probe.astype(jnp.float32), state["prev_probe"])
        refresh = jnp.logical_or(state["n"] == 0, change > self.threshold)

        def compute_mid(_):
            return self._run_range(probe, stacked_params, F, L - B, *args)

        def reuse_mid(_):
            return state["mid_cache"].astype(probe.dtype)

        mid = jax.lax.cond(refresh, compute_mid, reuse_mid, None)
        y = self._run_range(mid, stacked_params, L - B, L, *args)
        new_state = {
            "mid_cache": jnp.where(refresh, mid, state["mid_cache"]).astype(
                state["mid_cache"].dtype),
            "prev_probe": probe.astype(jnp.float32),
            "n": state["n"] + 1,
        }
        return y, new_state


class SlotBatchedPolicy:
    """A cache policy whose state carries a leading *slot* axis.

    The diffusion serving engine (repro.serving.diffusion) runs many
    concurrent requests, each at its own denoising step, through one vmapped
    program.  Each slot therefore needs its own cache state and its own step
    counter; this wrapper

      * builds the batched state by broadcasting one freshly-initialised
        per-slot state to `(slots, ...)` leaves,
      * vmaps `apply` / `want_compute` over (state, step, x, signals),
      * resets a single slot's state in place when the scheduler refills it
        with a new request (reset-on-refill — slot reuse must never leak
        cache state between requests).

    `apply`'s compute_fn runs per slot under vmap; pass per-slot context
    (e.g. the slot's timestep) via `extra`, a tuple of arrays with a leading
    slot axis that is forwarded as `compute_fn(x, *extra_slot)`.
    """

    def __init__(self, policy: CachePolicy, slots: int):
        self.policy = policy
        self.slots = slots

    # -- state ----------------------------------------------------------
    def init_slot_state(self, shape, dtype=jnp.float32, **kw) -> PyTree:
        """One slot's fresh state (also the reset target)."""
        try:
            return self.policy.init_state(shape, dtype, **kw)
        except TypeError:  # policy without extra kwargs (e.g. signal_shape)
            return self.policy.init_state(shape, dtype)

    def init_state(self, shape, dtype=jnp.float32, **kw) -> PyTree:
        one = self.init_slot_state(shape, dtype, **kw)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (self.slots,) + a.shape).copy(),
            one)

    @staticmethod
    def reset_slot(states: PyTree, slot, fresh: PyTree) -> PyTree:
        """Overwrite slot `slot`'s state with `fresh` (jit-friendly)."""
        return jax.tree_util.tree_map(lambda b, o: b.at[slot].set(o),
                                      states, fresh)

    # -- vmapped policy ops ---------------------------------------------
    def apply(self, states, steps, xs, compute_fn, extra=(), **signals):
        keys = sorted(signals)
        vals = tuple(signals[k] for k in keys)

        def one(state, step, x, extra_slot, sig_slot):
            fn = lambda xx: compute_fn(xx, *extra_slot)
            return self.policy.apply(state, step, x, fn,
                                     **dict(zip(keys, sig_slot)))

        return jax.vmap(one)(states, steps, xs, tuple(extra), vals)

    def want_compute(self, states, steps, xs, **signals):
        keys = sorted(signals)
        vals = tuple(signals[k] for k in keys)

        def one(state, step, x, sig_slot):
            w = self.policy.want_compute(state, step, x,
                                         **dict(zip(keys, sig_slot)))
            # `& step >= 0` ties constant predicates to the batched step so
            # vmap always sees a mapped output
            return jnp.logical_and(jnp.asarray(w), step >= 0)

        return jax.vmap(one)(states, steps, xs, vals)


# ----------------------------------------------------------------------
# schedule utilities (used by benchmarks + roofline)
# ----------------------------------------------------------------------

def compute_fraction(schedule: Sequence[bool]) -> float:
    """Fraction of steps doing full computation; the survey's acceleration
    factor is ~ 1/compute_fraction (its O(T/m) claim, §III-B)."""
    schedule = list(schedule)
    return sum(map(bool, schedule)) / max(len(schedule), 1)


def cache_state_bytes(state: PyTree) -> int:
    """Total bytes held by a cache state pytree (memory benchmark)."""
    leaves = jax.tree_util.tree_leaves(state)
    return int(sum(l.size * l.dtype.itemsize for l in leaves))
