"""Cache policy protocol.

The survey's unified cache operator (Eq. 14-15):

    C_t^l := F^l(x_t)                      (compute & store)
    F^l(x_{t+k}) ~= A(C, k)                (approximate for k = 1..N-1)

where A is identity reuse for static caching, a gated reuse for
timestep/layer-adaptive caching, and a polynomial forecast for predictive
caching ("Cache-Then-Forecast").

Every policy is a stateless object holding static hyper-parameters; the
mutable cache lives in a pytree `state` threaded through `apply`:

    y, state = policy.apply(state, step, x, compute_fn, **signals)

`compute_fn(x)` performs the real module forward.  `step` may be a Python
int (static scheduling — the branch is resolved at trace time and XLA sees
only the computations that actually happen: this is the mode used for the
roofline dry-runs) or a traced int32 (dynamic scheduling — the decision is
a `lax.cond` over runtime signals).
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

PyTree = Any
ComputeFn = Callable[[jnp.ndarray], jnp.ndarray]


def is_static_step(step) -> bool:
    """True when `step` is a concrete Python int (trace-time scheduling)."""
    return isinstance(step, int)


def cond_or_static(pred, true_fn, false_fn, *operands):
    """`lax.cond` that collapses to a Python branch for concrete predicates."""
    if isinstance(pred, bool):
        return true_fn(*operands) if pred else false_fn(*operands)
    return jax.lax.cond(pred, true_fn, false_fn, *operands)


def interval_pred(step, interval: int):
    """The shared `step % interval == 0` compute predicate, static or traced."""
    if is_static_step(step):
        return step % interval == 0
    return (jnp.asarray(step, jnp.int32) % interval) == 0


class CachePolicy:
    """Base class; subclasses implement init_state/apply."""

    name: str = "base"
    #: does approximate() return the cached value verbatim (static reuse)?
    is_predictive: bool = False
    #: does apply() threshold on signals["signal"] (TeaCache's modulated
    #: input)?  Engines may skip producing the signal when False.
    uses_signal: bool = False

    def init_state(self, shape, dtype=jnp.float32) -> Dict[str, Any]:
        raise NotImplementedError

    def apply(self, state, step, x, compute_fn: ComputeFn, **signals):
        raise NotImplementedError

    # ------------------------------------------------------------------
    # serving support: a traced predicate that mirrors the refresh
    # decision inside `apply` WITHOUT running compute_fn.  The serving
    # engine reads this back per slot each tick; when no slot wants a
    # compute it dispatches a cheap program whose compute branch is a
    # dummy, so the prediction must match `apply` exactly.  The base
    # implementation is conservative (always compute), which is always
    # correct but earns no skip ticks.
    # ------------------------------------------------------------------
    def want_compute(self, state, step, x, **signals):
        """Return a bool scalar: would `apply` take its compute branch?"""
        return jnp.asarray(True)

    # ------------------------------------------------------------------
    # serving support: the scalar the refresh decision thresholds on
    # (TeaCache's corrected accumulated signal distance, MagCache's
    # magnitude-decay error, ...).  The control plane's SignalTraceLog
    # records this per slot per tick; policies with a purely step-indexed
    # schedule have nothing to report and return 0.
    # ------------------------------------------------------------------
    def want_metric(self, state, step, x, **signals):
        """Return a float scalar: the signal the refresh decision is
        thresholding on this step (0.0 for schedule-only policies)."""
        return jnp.zeros((), jnp.float32)

    # ------------------------------------------------------------------
    # introspection used by benchmarks: how many full computes would a
    # static variant of this policy issue over T steps?
    # ------------------------------------------------------------------
    def static_schedule(self, num_steps: int):
        """Return list[bool] (compute?) if the policy is statically
        schedulable, else None."""
        return None

    def __repr__(self):  # pragma: no cover - debug aid
        return f"{type(self).__name__}()"


class NoCachePolicy(CachePolicy):
    """Always compute — the exact baseline every benchmark compares against."""

    name = "none"

    def init_state(self, shape, dtype=jnp.float32):
        return {}

    def apply(self, state, step, x, compute_fn, **signals):
        return compute_fn(x), state

    def static_schedule(self, num_steps: int):
        return [True] * num_steps
