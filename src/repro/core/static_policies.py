"""Static caching policies (survey §III-C).

Fixed, content-independent schedules:

  * FixedIntervalPolicy  — FORA: full compute every N steps, verbatim reuse
    in between (Eq. 14-15).
  * DeltaCachePolicy     — Δ-DiT: cache the residual F(x) - x instead of the
    absolute feature, so reuse at step t+k incorporates the fresh input:
    F(x_{t+k}) ~= x_{t+k} + (F(x_t) - x_t).
  * PABPolicy            — Pyramid Attention Broadcast: per-module-type
    broadcast ranges (a FixedInterval whose N depends on the module class).
  * FasterCacheCFG       — reuse of the unconditional CFG branch with a
    linearly increasing blend weight w(t).
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from .policy import CachePolicy, cond_or_static, interval_pred, is_static_step


class FixedIntervalPolicy(CachePolicy):
    """FORA-style: compute at steps {0, N, 2N, ...}, reuse otherwise."""

    name = "fora"

    def __init__(self, interval: int):
        assert interval >= 1
        self.interval = interval

    def init_state(self, shape, dtype=jnp.float32):
        return {"cache": jnp.zeros(shape, dtype)}

    def apply(self, state, step, x, compute_fn, **signals):
        def compute(state):
            y = compute_fn(x)
            return y, {"cache": y.astype(state["cache"].dtype)}

        def reuse(state):
            return state["cache"].astype(x.dtype), state

        return cond_or_static(interval_pred(step, self.interval),
                              compute, reuse, state)

    def want_compute(self, state, step, x, **signals):
        return jnp.asarray(interval_pred(step, self.interval))

    def static_schedule(self, num_steps: int):
        return [s % self.interval == 0 for s in range(num_steps)]


class DeltaCachePolicy(CachePolicy):
    """Δ-DiT residual caching: store F(x)-x, reuse as x' + Δ."""

    name = "delta_dit"

    def __init__(self, interval: int):
        assert interval >= 1
        self.interval = interval

    def init_state(self, shape, dtype=jnp.float32):
        return {"delta": jnp.zeros(shape, dtype)}

    def apply(self, state, step, x, compute_fn, **signals):
        def compute(state):
            y = compute_fn(x)
            return y, {"delta": (y - x).astype(state["delta"].dtype)}

        def reuse(state):
            return x + state["delta"].astype(x.dtype), state

        return cond_or_static(interval_pred(step, self.interval),
                              compute, reuse, state)

    def want_compute(self, state, step, x, **signals):
        return jnp.asarray(interval_pred(step, self.interval))

    def static_schedule(self, num_steps: int):
        return [s % self.interval == 0 for s in range(num_steps)]


class PABPolicy(FixedIntervalPolicy):
    """Pyramid Attention Broadcast: the broadcast range (=interval) is chosen
    per module *type*; spatial attention gets the smallest range, cross
    attention the largest.  Instantiate one PABPolicy per module with the
    range looked up from `ranges`."""

    name = "pab"

    RANGES = {"spatial_attn": 2, "temporal_attn": 4, "cross_attn": 6, "mlp": 4}

    def __init__(self, module_type: str, ranges: Dict[str, int] | None = None):
        ranges = dict(self.RANGES if ranges is None else ranges)
        super().__init__(ranges[module_type])
        self.module_type = module_type


class FasterCacheCFG(CachePolicy):
    """FasterCache's CFG-branch reuse.

    The unconditional branch output is cached; on reuse steps it is
    reconstructed as a blend of the two most recent cached outputs with a
    weight w(t) that increases linearly over the trajectory, preserving the
    slow drift of the unconditional stream (survey §III-C)."""

    name = "fastercache_cfg"

    def __init__(self, interval: int, num_steps: int):
        assert interval >= 1
        self.interval = interval
        self.num_steps = num_steps

    def init_state(self, shape, dtype=jnp.float32):
        return {
            "prev": jnp.zeros(shape, dtype),
            "prev2": jnp.zeros(shape, dtype),
        }

    def apply(self, state, step, x, compute_fn, **signals):
        def compute(state):
            y = compute_fn(x)
            return y, {"prev": y.astype(state["prev"].dtype), "prev2": state["prev"]}

        def reuse(state):
            # the trajectory-progress weight: serving passes it explicitly as
            # `cfg_w = step / (request.num_steps - 1)` because slots run
            # different step budgets against one shared policy instance
            if signals.get("cfg_w") is not None:
                w = jnp.asarray(signals["cfg_w"], x.dtype)
            elif is_static_step(step):
                w = jnp.asarray(step / max(self.num_steps - 1, 1), x.dtype)
            else:
                w = step.astype(x.dtype) / max(self.num_steps - 1, 1)
            # extrapolated blend: prev + w * (prev - prev2)
            y = state["prev"] + w * (state["prev"] - state["prev2"])
            return y.astype(x.dtype), state

        return cond_or_static(interval_pred(step, self.interval),
                              compute, reuse, state)

    def want_compute(self, state, step, x, **signals):
        return jnp.asarray(interval_pred(step, self.interval))

    def static_schedule(self, num_steps: int):
        return [s % self.interval == 0 for s in range(num_steps)]
