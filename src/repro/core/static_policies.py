"""Static caching policies (survey §III-C).

Fixed, content-independent schedules:

  * FixedIntervalPolicy  — FORA: full compute every N steps, verbatim reuse
    in between (Eq. 14-15).
  * DeltaCachePolicy     — Δ-DiT: cache the residual F(x) - x instead of the
    absolute feature, so reuse at step t+k incorporates the fresh input:
    F(x_{t+k}) ~= x_{t+k} + (F(x_t) - x_t).
  * PABPolicy            — Pyramid Attention Broadcast: per-module-type
    broadcast ranges (a FixedInterval whose N depends on the module class).
  * FasterCacheCFG       — reuse of the unconditional CFG branch with a
    linearly increasing blend weight w(t).
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from .policy import CachePolicy, cond_or_static, interval_pred, is_static_step


class FixedIntervalPolicy(CachePolicy):
    """FORA-style: compute at steps {0, N, 2N, ...}, reuse otherwise."""

    name = "fora"

    def __init__(self, interval: int):
        assert interval >= 1
        self.interval = interval

    def init_state(self, shape, dtype=jnp.float32):
        return {"cache": jnp.zeros(shape, dtype)}

    def apply(self, state, step, x, compute_fn, **signals):
        def compute(state):
            y = compute_fn(x)
            return y, {"cache": y.astype(state["cache"].dtype)}

        def reuse(state):
            return state["cache"].astype(x.dtype), state

        return cond_or_static(interval_pred(step, self.interval),
                              compute, reuse, state)

    def want_compute(self, state, step, x, **signals):
        return jnp.asarray(interval_pred(step, self.interval))

    def static_schedule(self, num_steps: int):
        return [s % self.interval == 0 for s in range(num_steps)]


class DeltaCachePolicy(CachePolicy):
    """Δ-DiT residual caching: store F(x)-x, reuse as x' + Δ."""

    name = "delta_dit"

    def __init__(self, interval: int):
        assert interval >= 1
        self.interval = interval

    def init_state(self, shape, dtype=jnp.float32):
        return {"delta": jnp.zeros(shape, dtype)}

    def apply(self, state, step, x, compute_fn, **signals):
        def compute(state):
            y = compute_fn(x)
            return y, {"delta": (y - x).astype(state["delta"].dtype)}

        def reuse(state):
            return x + state["delta"].astype(x.dtype), state

        return cond_or_static(interval_pred(step, self.interval),
                              compute, reuse, state)

    def want_compute(self, state, step, x, **signals):
        return jnp.asarray(interval_pred(step, self.interval))

    def static_schedule(self, num_steps: int):
        return [s % self.interval == 0 for s in range(num_steps)]


class PABPolicy(FixedIntervalPolicy):
    """Pyramid Attention Broadcast: the broadcast range (=interval) is chosen
    per module *type*; spatial attention gets the smallest range, cross
    attention the largest.  Instantiate one PABPolicy per module with the
    range looked up from `ranges`."""

    name = "pab"

    RANGES = {"spatial_attn": 2, "temporal_attn": 4, "cross_attn": 6, "mlp": 4}

    def __init__(self, module_type: str, ranges: Dict[str, int] | None = None):
        ranges = dict(self.RANGES if ranges is None else ranges)
        super().__init__(ranges[module_type])
        self.module_type = module_type


def lowpass(y, cutoff: float, axis: int = -2):
    """Low-frequency band of `y` along `axis` (FreqCa-style rfft mask)."""
    n = y.shape[axis]
    f = jnp.fft.rfft(y.astype(jnp.float32), axis=axis)
    k = jnp.arange(f.shape[axis])
    keep = (k <= max(int(cutoff * n // 2), 1)).astype(f.dtype)
    shape = [1] * y.ndim
    shape[axis] = f.shape[axis]
    return jnp.fft.irfft(f * keep.reshape(shape), n=n, axis=axis)


class FasterCacheCFG(CachePolicy):
    """FasterCache's CFG-branch reuse (survey §III-C).

    Two reconstruction modes for the unconditional branch between refreshes:

      "extrapolate" (default) — the uncond output itself is cached; reuse
        steps blend the two most recent cached outputs with a weight w(t)
        that increases linearly over the trajectory, preserving the slow
        drift of the unconditional stream.
      "lowfreq" — FasterCache's CFG residual observation: the cond and
        uncond outputs differ mostly in a LOW-frequency residual that drifts
        slowly across steps, while their high-frequency content is nearly
        shared.  Refresh steps cache the low band of (eps_cond - eps_uncond)
        (token-axis rfft, `cutoff`); reuse steps reconstruct the uncond
        output from the CURRENT conditional output minus that cached
        residual — eps_u ~= eps_c - lowpass(delta) — so the uncond branch
        tracks every step's fresh cond structure instead of going stale.
        Requires `signals["cond_out"]` (the cond-branch output this step);
        repro.diffusion.pipeline wires it through automatically.
    """

    name = "fastercache_cfg"

    def __init__(self, interval: int, num_steps: int,
                 mode: str = "extrapolate", cutoff: float = 0.25):
        assert interval >= 1
        assert mode in ("extrapolate", "lowfreq")
        self.interval = interval
        self.num_steps = num_steps
        self.mode = mode
        self.cutoff = float(cutoff)

    def init_state(self, shape, dtype=jnp.float32):
        if self.mode == "lowfreq":
            # one tensor regardless of history depth: the cached low band of
            # the cond-minus-uncond residual (half the extrapolate footprint)
            return {"delta_low": jnp.zeros(shape, jnp.float32)}
        return {
            "prev": jnp.zeros(shape, dtype),
            "prev2": jnp.zeros(shape, dtype),
        }

    def apply(self, state, step, x, compute_fn, **signals):
        if self.mode == "lowfreq":
            cond_out = signals.get("cond_out")
            if cond_out is None:
                raise ValueError(
                    "FasterCacheCFG(mode='lowfreq') needs signals['cond_out'] "
                    "(the conditional branch output this step)")

            def compute(state):
                y = compute_fn(x)
                delta = cond_out.astype(jnp.float32) - y.astype(jnp.float32)
                return y, {"delta_low": lowpass(delta, self.cutoff)}

            def reuse(state):
                y = cond_out.astype(jnp.float32) - state["delta_low"]
                return y.astype(x.dtype), state

            return cond_or_static(interval_pred(step, self.interval),
                                  compute, reuse, state)

        def compute(state):
            y = compute_fn(x)
            return y, {"prev": y.astype(state["prev"].dtype), "prev2": state["prev"]}

        def reuse(state):
            # the trajectory-progress weight: serving passes it explicitly as
            # `cfg_w = step / (request.num_steps - 1)` because slots run
            # different step budgets against one shared policy instance
            if signals.get("cfg_w") is not None:
                w = jnp.asarray(signals["cfg_w"], x.dtype)
            elif is_static_step(step):
                w = jnp.asarray(step / max(self.num_steps - 1, 1), x.dtype)
            else:
                w = step.astype(x.dtype) / max(self.num_steps - 1, 1)
            # extrapolated blend: prev + w * (prev - prev2)
            y = state["prev"] + w * (state["prev"] - state["prev2"])
            return y.astype(x.dtype), state

        return cond_or_static(interval_pred(step, self.interval),
                              compute, reuse, state)

    def want_compute(self, state, step, x, **signals):
        return jnp.asarray(interval_pred(step, self.interval))

    def static_schedule(self, num_steps: int):
        return [s % self.interval == 0 for s in range(num_steps)]
