"""repro.core — the survey's Diffusion Caching taxonomy as a JAX library.

Taxonomy map (survey Fig. 2):
  Static            : FixedIntervalPolicy (FORA), DeltaCachePolicy (Δ-DiT),
                      PABPolicy, FasterCacheCFG, DeepCache (structural —
                      see repro.diffusion.pipeline)
  Timestep-adaptive : TeaCachePolicy, MagCachePolicy, EasyCachePolicy
  Layer-adaptive    : BlockCachePolicy, ForesightPolicy, DBCacheStack
  Predictive        : PredictivePolicy (taylor=TaylorSeer, hermite=HiCache,
                      ab=AB-Cache, foca=FoCa, newton=beyond-paper),
                      FreqCaPolicy (+ CRF at MODEL granularity)
  Hybrid            : ClusCaPolicy, SpeCaPolicy
  Token-wise        : ToCaPolicy (Eq. 19-21)
  Learned           : LazyDiTPolicy + train_lazy_gate (Eq. 26-27, trained
                      in-framework on full trajectories, HarmoniCa-style)
"""
from .adaptive import (BlockCachePolicy, EasyCachePolicy, ForesightPolicy,
                       MagCachePolicy, TeaCachePolicy)
from .engine import (CachedModule, CachedStack, DBCacheStack,
                     SlotBatchedPolicy, cache_state_bytes, compute_fraction)
from .hybrid import ClusCaPolicy, SpeCaPolicy, kmeans
from .metrics import (cosine_sim, mag_ratio, psnr, rel_l1, rel_l1_block,
                      rel_l2, transform_rate)
from .learned import (LazyDiTPolicy, gate_score, init_gate,
                      lazy_trajectory_loss, train_lazy_gate)
from .policy import (CachePolicy, NoCachePolicy, cond_or_static, interval_pred,
                     is_static_step)
from .token import ToCaPolicy
from .predictive import (BASES, FreqCaPolicy, PredictivePolicy,
                         forecast_from_diffs, update_diff_stack)
from .static_policies import (DeltaCachePolicy, FasterCacheCFG,
                              FixedIntervalPolicy, PABPolicy, lowpass)
from .temporal import TemporalPABStack, TemporalTeaCachePolicy

def _require_gate(gate):
    if gate is None:
        raise ValueError(
            "make_policy('lazydit') needs trained gate params: pass "
            "gate={'w': ..., 'b': ...} (repro.core.learned.train_lazy_gate "
            "or repro.serving.control.fit_want_gate)")
    return gate


def _require_profile(profile):
    if profile is None:
        raise ValueError(
            "make_policy('blockcache') needs a calibration profile: pass "
            "profile=[rel-L1 change per step] (measure one with "
            "repro.serving.control.calibration_profile)")
    return profile


POLICY_REGISTRY = {
    "none": lambda **kw: NoCachePolicy(),
    "fora": lambda interval=2, **kw: FixedIntervalPolicy(interval),
    "delta_dit": lambda interval=2, **kw: DeltaCachePolicy(interval),
    "teacache": lambda delta=0.1, **kw: TeaCachePolicy(delta),
    "magcache": lambda delta=0.1, num_steps=50, **kw: MagCachePolicy(delta, num_steps=num_steps),
    "easycache": lambda tau=5.0, **kw: EasyCachePolicy(tau),
    "foresight": lambda gamma=1.0, **kw: ForesightPolicy(gamma),
    "taylorseer": lambda interval=4, order=2, **kw: PredictivePolicy(interval, order, "taylor"),
    "newtonseer": lambda interval=4, order=2, **kw: PredictivePolicy(interval, order, "newton"),
    "hicache": lambda interval=4, order=2, sigma=0.5, **kw: PredictivePolicy(interval, order, "hermite", sigma),
    "abcache": lambda interval=4, **kw: PredictivePolicy(interval, 2, "ab"),
    "foca": lambda interval=4, **kw: PredictivePolicy(interval, 2, "foca"),
    "freqca": lambda interval=4, cutoff=0.25, **kw: FreqCaPolicy(interval, cutoff),
    "toca": lambda interval=4, ratio=0.25, **kw: ToCaPolicy(interval, ratio),
    # learned want_compute gate (LazyDiT / HarmoniCa-style training): the
    # caller must supply trained gate params ({"w", "b"} from init_gate /
    # train_lazy_gate) — there is no sensible untrained default.  The
    # control plane (repro.serving.control) trains one from logged serving
    # traces and serves it through this entry.
    "lazydit": lambda gate=None, threshold=0.5, **kw:
        LazyDiTPolicy(_require_gate(gate), threshold),
    # calibrated static schedule ("Cache Me if You Can" Eq. 34-35; at model
    # granularity this is SmoothCache — repro.serving.control wraps it with
    # the calibration recorder).  The caller must supply the measured
    # rel-L1 profile; there is no sensible uncalibrated default.  Int-step
    # want_compute -> the serving engine hosts it on the zero-sync static
    # plan, which is what makes these candidates attractive to the online
    # tuner's re-pricing.
    "blockcache": lambda profile=None, delta=0.1, **kw:
        BlockCachePolicy(_require_profile(profile), delta),
    # PAB as a module-level policy: one instance per module TYPE, interval
    # looked up from its broadcast-range table (cross attention broadcast
    # over the longest range — text conditioning drifts slowest).  The
    # whole-stack form lives in STRUCTURAL_POLICIES["pab_video"]; this
    # entry serves engines/denoisers that gate one module type (and gives
    # the registry sweep a PAB representative).
    "pab": lambda module_type="spatial_attn", ranges=None, **kw:
        PABPolicy(module_type, ranges),
    "clusca": lambda interval=4, k=16, **kw: ClusCaPolicy(interval, k),
    "speca": lambda interval=4, tau=0.1, **kw: SpeCaPolicy(interval, tau=tau),
    # temporal-aware TeaCache for video latent clips: the input-side signal
    # distance is taken per frame and max-reduced, so motion concentrated in
    # one frame still refreshes the cache (repro.core.temporal).  `frames`
    # MUST match the clip's frame count — the serving engine (string path)
    # and DenoiseWorkload.make_policy inject cfg.dit_num_frames; only bare
    # make_policy calls fall back to this default.
    "teacache_video": lambda delta=0.1, frames=4, reduce="max", **kw:
        TemporalTeaCachePolicy(delta, frames, reduce=reduce),
    # CFG-branch reuse (survey §III-C).  Not a backbone gate: it caches the
    # *unconditional* stream and belongs in CachedDenoiser's `cfg_policy`
    # slot or DiffusionServingEngine's `cfg_policy` argument.  mode="lowfreq"
    # selects the low-frequency cond-residual reconstruction.
    "fastercache_cfg": lambda interval=4, num_steps=50, mode="extrapolate", **kw:
        FasterCacheCFG(interval, num_steps, mode=mode),
}

# Stack-structural methods complete the taxonomy map but are NOT CachePolicy
# instances: they own the layer loop itself (probe -> decide -> correct over
# block ranges) instead of gating one module's output behind the
# `apply(state, step, x, compute_fn)` protocol, so `make_policy` cannot
# construct them without a block_fn + layer count.  They are built directly:
#   dbcache   — DBCacheStack(block_fn, num_layers, front_n, back_n, threshold)
#   deepcache — CachedDenoiser(..., granularity="deepcache") splits the DiT
#               stack structurally (repro/diffusion/pipeline.py)
STRUCTURAL_POLICIES = {
    "dbcache": DBCacheStack,
    "deepcache": "repro.diffusion.pipeline.CachedDenoiser(granularity='deepcache')",
    # PAB over a factorized spatio-temporal stack: per-module-type broadcast
    # ranges (temporal attention reused over the longest range); built with
    # the video backbone's branch fns (repro.modalities wires it up)
    "pab_video": TemporalPABStack,
}


def make_policy(name: str, **kwargs) -> CachePolicy:
    if name in STRUCTURAL_POLICIES:
        raise KeyError(
            f"'{name}' is a stack-structural method, not a module-level "
            f"policy; see repro.core.STRUCTURAL_POLICIES for how to build it")
    if name not in POLICY_REGISTRY:
        raise KeyError(f"unknown cache policy '{name}'; "
                       f"available: {sorted(POLICY_REGISTRY)}")
    return POLICY_REGISTRY[name](**kwargs)
