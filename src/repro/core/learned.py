"""Learning-based caching (survey §III-D1/D2): LazyDiT + HarmoniCa-style
stepwise training.

LazyDiT (Eq. 26-27) prepends a linear predictor to each gated module that
estimates the similarity between this step's output and the cached one from
a first-order approximation  f(Y_{t-1}, Y_t) ~= <W, Z_t>  of the input
features; computation is skipped when the predicted similarity clears a
threshold.  The "lazy loss" (Eq. 27) rewards skipping, balanced against the
output-distillation MSE.

`train_lazy_gate` implements the HarmoniCa insight (SDT): the gate is
trained on FULL trajectories — sampling random single steps hides the error
accumulation the gate will face at inference — against the exact teacher
trajectory, with the IEPO-style balance between match quality and skip
reward.  Everything here is self-contained JAX (the published checkpoints
are irrelevant to the systems contribution; DESIGN §9).
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .metrics import cosine_sim
from .policy import CachePolicy


def init_gate(key, feat_dim: int):
    """Linear similarity predictor params: s = sigmoid(<w, mean_tokens(x)> + b)."""
    return {"w": jax.random.normal(key, (feat_dim,)) * 0.01,
            "b": jnp.zeros(())}


def gate_score(gate, x) -> jnp.ndarray:
    """Predicted cross-step similarity in [0, 1].  x: (..., T, D)."""
    z = jnp.mean(x.astype(jnp.float32), axis=tuple(range(x.ndim - 1)))
    return jax.nn.sigmoid(jnp.dot(gate["w"], z) + gate["b"])


class LazyDiTPolicy(CachePolicy):
    """Skip the module when the learned gate predicts similarity > threshold."""

    name = "lazydit"

    def __init__(self, gate, threshold: float = 0.5):
        self.gate = gate
        self.threshold = float(threshold)

    def init_state(self, shape, dtype=jnp.float32):
        return {"cache": jnp.zeros(shape, dtype),
                "n": jnp.zeros((), jnp.int32),
                "n_compute": jnp.zeros((), jnp.int32)}

    def apply(self, state, step, x, compute_fn, **signals):
        sim = gate_score(self.gate, x)
        refresh = jnp.logical_or(state["n"] == 0, sim <= self.threshold)

        def compute(state):
            y = compute_fn(x)
            return y, {"cache": y.astype(state["cache"].dtype),
                       "n": state["n"] + 1,
                       "n_compute": state["n_compute"] + 1}

        def reuse(state):
            return state["cache"].astype(x.dtype), {**state,
                                                    "n": state["n"] + 1}

        return jax.lax.cond(refresh, compute, reuse, state)

    def want_compute(self, state, step, x, **signals):
        """Traced mirror of the gate decision — the serving engine reads
        this per slot each tick, so a learned gate firing on one slot costs
        a 1-row compacted bucket instead of a whole-pool tick."""
        sim = gate_score(self.gate, x)
        return jnp.logical_or(state["n"] == 0, sim <= self.threshold)

    def want_metric(self, state, step, x, **signals):
        """The predicted cross-step similarity the threshold sees."""
        return gate_score(self.gate, x).astype(jnp.float32)


def lazy_trajectory_loss(gate, inputs: jnp.ndarray, outputs: jnp.ndarray,
                         *, rho: float = 0.1, threshold: float = 0.5):
    """HarmoniCa-style full-trajectory objective.

    inputs/outputs: (T, ..., D) module inputs and exact outputs along one
    denoising trajectory.  Simulates the gated rollout with a *soft* skip
    decision (sigmoid relaxation, differentiable), accumulating the cache
    exactly as inference would, and returns
        L = mean_t || y_hat_t - y_t ||^2  -  rho * mean_t s_t      (Eq. 27)
    """
    T = inputs.shape[0]

    def body(carry, io):
        cache = carry
        x_t, y_t = io
        s = gate_score(gate, x_t)                      # soft skip prob
        y_hat = s * cache + (1.0 - s) * y_t            # soft mixture
        new_cache = y_hat                              # carried forward
        err = jnp.mean((y_hat - y_t) ** 2)
        return new_cache, (err, s)

    cache0 = outputs[0]
    _, (errs, skips) = jax.lax.scan(body, cache0, (inputs[1:], outputs[1:]))
    return jnp.mean(errs) - rho * jnp.mean(skips)


def train_lazy_gate(key, inputs, outputs, *, steps: int = 200, lr: float = 0.05,
                    rho: float = 0.1):
    """Fit the gate on one (or a batch of) exact trajectories.

    Returns (gate, loss_history)."""
    gate = init_gate(key, inputs.shape[-1])
    loss_fn = lambda g: lazy_trajectory_loss(g, inputs, outputs, rho=rho)
    step_fn = jax.jit(jax.value_and_grad(loss_fn))
    hist = []
    for _ in range(steps):
        loss, grads = step_fn(gate)
        gate = jax.tree_util.tree_map(lambda p, g: p - lr * g, gate, grads)
        # repro-lint: disable-next-line=host-sync-in-hot-path -- offline training loop, not a tick path
        hist.append(float(loss))
    return gate, hist
