"""ToCa — token-wise feature caching (survey §III-C, Eq. 19-21).

Different tokens tolerate caching differently.  ToCa scores every token
from two perspectives and recomputes only the top-R% most cache-sensitive
tokens each skipped step, reusing the cached features for the rest:

  s1  temporal redundancy   — |x_t - x_prev| per token (stable tokens cache
                              well)
  s2  error propagation     — attention-received weight per token (heavily
                              attended tokens spread their cache error; we
                              use the feature norm as the attention-free
                              proxy the paper's V2 suggests)
  s3  cache staleness       — steps since this token was last recomputed
                              (Eq. 21's r_t dimension)
  s4  spatial prior         — uniform stride so every region refreshes

Score S(x_i) = Σ λ_j s_j(x_i) (Eq. 19); the LOWEST-scoring tokens are the
cache candidates (Eq. 20), i.e. we recompute the top scores.

TPU adaptation (DESIGN §2.2): the compute-subset is materialized with a
gather and merged back with a dense one-hot scatter-free `where` on a
padded token mask — no irregular scatter in the hot path.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from .policy import CachePolicy, cond_or_static, interval_pred


class ToCaPolicy(CachePolicy):
    """Token-wise caching for (..., T, D) features.

    On refresh steps (every `interval`) the whole module computes and the
    cache refills.  In between, the `ratio` most cache-sensitive tokens are
    recomputed through `subset_fn` (falling back to full compute when the
    module is not token-local) and the rest reuse the cache.
    """

    name = "toca"

    def __init__(self, interval: int = 4, ratio: float = 0.25,
                 lambdas: Sequence[float] = (1.0, 0.5, 0.5, 0.25)):
        assert 0.0 < ratio <= 1.0
        self.interval = interval
        self.ratio = ratio
        self.lambdas = tuple(float(l) for l in lambdas)

    def init_state(self, shape, dtype=jnp.float32):
        *lead, T, D = shape
        return {
            "cache": jnp.zeros(shape, dtype),
            "prev_in": jnp.zeros(shape, jnp.float32),
            "stale": jnp.zeros((*lead, T), jnp.float32),
            "n": jnp.zeros((), jnp.int32),
        }

    # ------------------------------------------------------------------
    def scores(self, state, x) -> jnp.ndarray:
        """(..., T) composite cache-sensitivity score (higher = recompute)."""
        xf = x.astype(jnp.float32)
        T = x.shape[-2]
        s1 = jnp.mean(jnp.abs(xf - state["prev_in"]), -1)     # temporal change
        s2 = jnp.linalg.norm(xf, axis=-1) / (x.shape[-1] ** 0.5)  # influence
        s3 = state["stale"]                                   # staleness
        stride = max(int(1.0 / self.ratio), 1)
        s4 = (jnp.arange(T) % stride == 0).astype(jnp.float32)
        s4 = jnp.broadcast_to(s4, s1.shape)
        l1, l2, l3, l4 = self.lambdas
        return l1 * s1 + l2 * s2 + l3 * s3 + l4 * s4

    def apply(self, state, step, x, compute_fn,
              subset_fn: Optional[Callable] = None, **signals):
        T = x.shape[-2]
        k = max(int(self.ratio * T), 1)
        xf = x.astype(jnp.float32)

        def full(state):
            y = compute_fn(x)
            return y, {
                "cache": y.astype(state["cache"].dtype),
                "prev_in": xf,
                "stale": jnp.zeros_like(state["stale"]),
                "n": state["n"] + 1,
            }

        def partial(state):
            sc = self.scores(state, x)                        # (..., T)
            thresh = -jnp.sort(-sc, axis=-1)[..., k - 1:k]
            recompute = sc >= thresh                          # (..., T) bool
            y_full = compute_fn(x)  # token-local modules could use subset_fn
            if subset_fn is not None:
                y_full = subset_fn(x, recompute)
            y = jnp.where(recompute[..., None], y_full,
                          state["cache"].astype(y_full.dtype))
            return y, {
                "cache": y.astype(state["cache"].dtype),
                "prev_in": xf,
                "stale": jnp.where(recompute, 0.0, state["stale"] + 1.0),
                "n": state["n"] + 1,
            }

        return cond_or_static(interval_pred(step, self.interval),
                              full, partial, state)

    def static_schedule(self, num_steps: int):
        # fraction view: full steps + ratio-weighted partial steps
        return [s % self.interval == 0 for s in range(num_steps)]
