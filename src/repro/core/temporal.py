"""Temporal-aware caching for latents with a frame axis (survey §IV, the
video-generation scenarios the caching literature was born in).

Image-latent policies treat the token axis as one undifferentiated bag; a
video clip's tokens carry a (frames, patches) factorization and the two axes
age differently across denoising steps — motion concentrates change in a few
frames while the background barely moves.  Two temporal specializations:

  * TemporalTeaCachePolicy — TeaCache whose input-side signal distance is
    computed PER FRAME and reduced across the frame axis (default: max), so
    a change concentrated in one frame refreshes the cache that a clip-mean
    rel-L1 would average away.  Model granularity, fully serving-compatible
    (uses_signal + want_compute), registered as "teacache_video".
  * TemporalPABStack — Pyramid Attention Broadcast over a factorized
    spatio-temporal block stack: each block's spatial-attention,
    temporal-attention and MLP branch outputs are cached and broadcast over
    PER-MODULE-TYPE ranges (PABPolicy.RANGES: spatial 2, temporal 4, mlp 4)
    — temporal attention drifts slowest across steps, so its output is
    reused over the longest range.  Stack-structural (owns the layer loop,
    like DBCacheStack), listed in STRUCTURAL_POLICIES as "pab_video".
"""
from __future__ import annotations

from typing import Callable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from .adaptive import TeaCachePolicy
from .policy import cond_or_static, interval_pred
from .static_policies import PABPolicy

_EPS = 1e-8


class TemporalTeaCachePolicy(TeaCachePolicy):
    """TeaCache with a per-frame signal reduction (frame-axis-aware Eq. 22).

    `frames` is the clip's frame count F; signals of shape (B, F*P, d) are
    viewed as (B, F, P*d) and the symmetric rel-L1 is taken per frame, then
    reduced across frames (`reduce`: "max" — any frame crossing the
    threshold refreshes — or "mean", which recovers a clip-level average).
    """

    name = "teacache_video"

    def __init__(self, delta: float, frames: int,
                 poly: Sequence[float] = (0.0, 1.0), reduce: str = "max"):
        assert frames >= 1
        assert reduce in ("max", "mean")
        super().__init__(delta, poly)
        self.frames = frames
        self.reduce = reduce

    def _signal_distance(self, sig, prev):
        F = self.frames
        s = sig.reshape(sig.shape[0], F, -1)
        p = prev.reshape(prev.shape[0], F, -1)
        num = jnp.sum(jnp.abs(s - p), axis=(0, 2))
        den = (jnp.sum(jnp.abs(s), axis=(0, 2)) +
               jnp.sum(jnp.abs(p), axis=(0, 2)) + _EPS)
        per_frame = num / den
        if self.reduce == "max":
            return jnp.max(per_frame)
        return jnp.mean(per_frame)


class TemporalPABStack:
    """PAB (survey §III-C) over a factorized spatio-temporal block stack.

    branch_fns: ordered mapping {module_type: fn} with
    fn(layer_params, x, *args) -> the block's gated residual BRANCH output
    (same shape as x); the block applies x += branch(x) in mapping order.
    Each branch output is cached per layer and recomputed only at its
    module-type broadcast range: `intervals[module_type]` steps
    (PABPolicy.RANGES by default, so temporal attention is broadcast across
    a longer range than spatial attention).  Step-indexed like every static
    policy — schedules resolve at trace time for concrete steps.
    """

    def __init__(self, branch_fns: Mapping[str, Callable], num_layers: int,
                 ranges: Optional[Mapping[str, int]] = None):
        assert num_layers >= 1 and branch_fns
        self.branch_fns = dict(branch_fns)
        self.num_layers = num_layers
        src = dict(PABPolicy.RANGES if ranges is None else ranges)
        self.intervals = {k: int(src[k]) for k in self.branch_fns}

    def init(self, shape, dtype=jnp.float32):
        one = {k: jnp.zeros(shape, dtype) for k in self.branch_fns}
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(
                a[None], (self.num_layers,) + a.shape).copy(), one)

    def _branch(self, name, params_l, step, x, cache, args):
        def compute(x, cache):
            o = self.branch_fns[name](params_l, x, *args)
            return o, o.astype(cache.dtype)

        def reuse(x, cache):
            return cache.astype(x.dtype), cache

        return cond_or_static(interval_pred(step, self.intervals[name]),
                              compute, reuse, x, cache)

    def __call__(self, states, step, x, stacked_params, *args):
        """states: per-layer per-branch caches (leading layer axis);
        x: (B, T, d).  Returns (y, new_states)."""

        def body(carry, inp):
            x = carry
            params_l, state_l = inp
            new_state = {}
            for name in self.branch_fns:
                o, new_state[name] = self._branch(name, params_l, step, x,
                                                  state_l[name], args)
                x = x + o
            return x, new_state

        return jax.lax.scan(body, x, (stacked_params, states))

    def static_schedule(self, num_steps: int):
        """Per-step fraction of branches computing (roofline introspection)."""
        n = len(self.branch_fns)
        return [sum(s % iv == 0 for iv in self.intervals.values()) / n
                for s in range(num_steps)]

    def compute_fraction(self, num_steps: int) -> float:
        """Fraction of branch evaluations that actually run over a
        trajectory — PAB's analogue of the survey's 1/speedup."""
        sched = self.static_schedule(num_steps)
        return sum(sched) / max(num_steps, 1)
