"""Noise schedules (survey §III-A).

Forward process (Eq. 2-4):  q(x_t|x_0) = N(sqrt(abar_t) x0, (1-abar_t) I).
Schedule construction runs in float64 for precision (the cosine alpha-bar
ratios and the cumprod are catastrophically lossy in f32), but every table
the class EXPOSES is float32: these tables are closed over by jit'd
samplers and gathered into every serving tick, so an f64 boundary here
leaks wide dtypes into device programs (the ir-dtype lint enforces the
f32 boundary repo-wide).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class NoiseSchedule:
    """Discrete-time DDPM schedule over T training steps."""
    betas: np.ndarray          # (T,) float32 (cast at construction)

    def __post_init__(self):
        # f32 at the boundary, whatever precision the constructor used
        object.__setattr__(self, "betas",
                           np.asarray(self.betas, np.float32))

    @property
    def T(self) -> int:
        return int(self.betas.shape[0])

    @property
    def alphas(self) -> np.ndarray:
        return (1.0 - self.betas).astype(np.float32)

    @property
    def alpha_bars(self) -> np.ndarray:
        # accumulate the product in f64 (a 1000-term f32 cumprod drifts),
        # then cast at the boundary like every other exposed table
        return np.cumprod(self.alphas, dtype=np.float64).astype(np.float32)

    def sigma(self, t):
        """sqrt(1 - abar_t) — noise std at step t."""
        return np.sqrt(1.0 - self.alpha_bars[t]).astype(np.float32)

    def q_sample(self, x0, t, eps):
        """Forward diffuse x0 to step t (Eq. 4). t: int array (B,)."""
        ab = jnp.asarray(self.alpha_bars, jnp.float32)[t]
        shape = (-1,) + (1,) * (x0.ndim - 1)
        return (jnp.sqrt(ab).reshape(shape) * x0
                + jnp.sqrt(1.0 - ab).reshape(shape) * eps)

    def spaced(self, num_steps: int) -> np.ndarray:
        """Evenly spaced sampling timesteps T-1 ... 0 (descending)."""
        return np.linspace(self.T - 1, 0, num_steps).round().astype(np.int64)


def linear_schedule(T: int = 1000, beta_min: float = 1e-4,
                    beta_max: float = 0.02) -> NoiseSchedule:
    return NoiseSchedule(np.linspace(beta_min, beta_max, T, dtype=np.float64))


def cosine_schedule(T: int = 1000, s: float = 8e-3) -> NoiseSchedule:
    """IDDPM cosine alpha-bar schedule (survey ref [56])."""
    steps = np.arange(T + 1, dtype=np.float64) / T
    abar = np.cos((steps + s) / (1 + s) * np.pi / 2) ** 2
    abar = abar / abar[0]
    betas = np.clip(1.0 - abar[1:] / abar[:-1], 0.0, 0.999)
    return NoiseSchedule(betas)


def rectified_flow_times(num_steps: int) -> np.ndarray:
    """Rectified-flow time grid 1 -> 0 (survey Eq. 10 / ref [65]).

    x_t = (1-t) x0 + t eps; the model regresses velocity v = eps - x0."""
    return np.linspace(1.0, 0.0, num_steps + 1)
