"""repro.diffusion — noise schedules, samplers, CFG and the cached pipeline.

This is the survey's home domain: every caching claim in the paper is made
on an iterative denoising trajectory.  The subpackage provides

  schedules  — DDPM beta schedules (linear/cosine), alpha-bar tables, and
               the rectified-flow linear path (survey §III-A, Eq. 1-10)
  samplers   — DDPM ancestral, DDIM, DPM-Solver++(2M), rectified-flow Euler
  pipeline   — CachedDenoiser: binds a cache policy (repro.core) to a DiT
               backbone at MODEL / BLOCK / DEEPCACHE granularity, with
               classifier-free guidance and the FasterCache CFG-delta trick
"""
from .schedules import (NoiseSchedule, cosine_schedule, linear_schedule,
                        rectified_flow_times)
from .samplers import (ddim_step, ddpm_step, dpmpp_2m_step, rf_euler_step,
                       sample)
from .pipeline import CachedDenoiser, cfg_denoise_fn

__all__ = [
    "NoiseSchedule", "linear_schedule", "cosine_schedule",
    "rectified_flow_times", "ddpm_step", "ddim_step", "dpmpp_2m_step",
    "rf_euler_step", "sample", "CachedDenoiser", "cfg_denoise_fn",
]
