"""Reverse-process samplers (survey §II-D, §III-A).

Every sampler is a pure single-step function

    x_{t-1}, extra = step(x_t, eps_hat, i, timesteps, sched, key, extra)

driven by the generic `sample()` loop.  The loop is a *Python* loop over the
step index so that cache policies with static schedules are resolved at
trace time (XLA sees only the computations that actually run — the property
the roofline dry-runs measure); wrap `sample` in `jax.jit` for production.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .schedules import NoiseSchedule


def _bshape(x):
    return (-1,) + (1,) * (x.ndim - 1)


# ----------------------------------------------------------------------
# DDPM ancestral step (Eq. 7/9)
# ----------------------------------------------------------------------

def ddpm_step(x, eps_hat, i, timesteps, sched: NoiseSchedule, key, extra):
    t = int(timesteps[i])
    t_next = int(timesteps[i + 1]) if i + 1 < len(timesteps) else -1
    ab_t = float(sched.alpha_bars[t])
    ab_n = float(sched.alpha_bars[t_next]) if t_next >= 0 else 1.0
    alpha = ab_t / ab_n
    beta = 1.0 - alpha
    mean = (x - beta / np.sqrt(1.0 - ab_t) * eps_hat) / np.sqrt(alpha)
    if t_next >= 0:
        sigma = np.sqrt(beta * (1.0 - ab_n) / (1.0 - ab_t))
        noise = jax.random.normal(key, x.shape, x.dtype)
        return mean + sigma * noise, extra
    return mean, extra


# ----------------------------------------------------------------------
# DDIM deterministic step (survey ref [54])
# ----------------------------------------------------------------------

def ddim_step(x, eps_hat, i, timesteps, sched: NoiseSchedule, key, extra):
    t = int(timesteps[i])
    t_next = int(timesteps[i + 1]) if i + 1 < len(timesteps) else -1
    ab_t = float(sched.alpha_bars[t])
    ab_n = float(sched.alpha_bars[t_next]) if t_next >= 0 else 1.0
    x0_hat = (x - np.sqrt(1.0 - ab_t) * eps_hat) / np.sqrt(ab_t)
    return np.sqrt(ab_n) * x0_hat + np.sqrt(1.0 - ab_n) * eps_hat, extra


# ----------------------------------------------------------------------
# DPM-Solver++(2M) (survey ref [58]) — multistep 2nd order, data prediction
# ----------------------------------------------------------------------

def _lambda(ab):  # log-SNR/2
    return 0.5 * np.log(ab / (1.0 - ab))


def dpmpp_2m_step(x, eps_hat, i, timesteps, sched: NoiseSchedule, key, extra):
    """extra carries the previous x0 prediction (None on first step)."""
    t = int(timesteps[i])
    t_next = int(timesteps[i + 1]) if i + 1 < len(timesteps) else -1
    ab_t = float(sched.alpha_bars[t])
    ab_n = float(sched.alpha_bars[t_next]) if t_next >= 0 else 1.0 - 1e-6
    x0_hat = (x - np.sqrt(1.0 - ab_t) * eps_hat) / np.sqrt(ab_t)

    lam_t, lam_n = _lambda(ab_t), _lambda(ab_n)
    h = lam_n - lam_t
    sig_t, sig_n = np.sqrt(1.0 - ab_t), np.sqrt(1.0 - ab_n)

    prev = extra.get("x0_prev") if isinstance(extra, dict) else None
    if prev is not None and extra.get("h_prev"):
        r = extra["h_prev"] / h
        D = (1.0 + 1.0 / (2.0 * r)) * x0_hat - (1.0 / (2.0 * r)) * prev
    else:
        D = x0_hat
    x_next = (sig_n / sig_t) * x - np.sqrt(ab_n) * np.expm1(-h) * D
    return x_next, {"x0_prev": x0_hat, "h_prev": h}


# ----------------------------------------------------------------------
# Rectified-flow Euler step (survey Eq. 10 / FLUX-style)
# ----------------------------------------------------------------------

def rf_euler_step(x, v_hat, i, times, sched, key, extra):
    """times: float grid 1 -> 0 (rectified_flow_times). v_hat = eps - x0."""
    dt = float(times[i + 1] - times[i])        # negative
    return x + dt * v_hat, extra


# ----------------------------------------------------------------------
# generic sampling loop
# ----------------------------------------------------------------------

def sample(denoise_fn: Callable, x_T, timesteps, sched: Optional[NoiseSchedule],
           step_fn=ddim_step, key=None, denoiser_state=None):
    """Run the reverse process.

    denoise_fn(state, i, x, t) -> (eps_hat, state)  — `i` is the Python step
    index (cache policies schedule on it), `t` the model-facing timestep.
    Returns (x_0, final denoiser state).
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    x = x_T
    extra: Any = {}
    n = len(timesteps) if step_fn is not rf_euler_step else len(timesteps) - 1
    for i in range(n):
        key, sub = jax.random.split(key)
        t = float(timesteps[i])
        t_vec = jnp.full((x.shape[0],), t, jnp.float32)
        eps_hat, denoiser_state = denoise_fn(denoiser_state, i, x, t_vec)
        x, extra = step_fn(x, eps_hat, i, timesteps, sched, sub, extra)
    return x, denoiser_state
