"""Discrete diffusion language model (LLaDA-style) with feature caching —
the survey's §IV-F application (dLLM-Cache) built on the zoo's transformer.

Generation is iterative mask-denoising: start from an all-[MASK] canvas,
at each of T steps run the (bidirectional) transformer over the full
canvas, then commit the highest-confidence fraction of still-masked
positions.  Each step is a full forward pass over the same canvas — exactly
the iterative-inference redundancy the survey's cache operator (Eq. 14-15)
exploits: adjacent steps differ in a few committed tokens, so logits evolve
smoothly and can be reused / forecast between full computations
(dLLM-Cache reports 8x speedups from this structure).

The mask token id is `vocab_size - 1` by convention.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import CachePolicy, NoCachePolicy
from repro.models import transformer


def dlm_forward(params, tokens, cfg):
    """Bidirectional forward (window=0, non-causal) for mask-denoising.

    Reuses the zoo transformer with causal masking disabled by passing
    positions that make every key visible: we simply run the causal model
    twice (left-to-right on tokens and on the reversed canvas) and average
    logits — a cheap bidirectionalization that needs no new weights."""
    logits_f, _ = transformer.forward(params, tokens, cfg)
    logits_b, _ = transformer.forward(params, tokens[:, ::-1], cfg)
    return 0.5 * (logits_f + logits_b[:, ::-1])


def dlm_generate(params, cfg, *, batch: int, seq_len: int, num_steps: int = 8,
                 policy: Optional[CachePolicy] = None, key=None,
                 temperature: float = 0.0):
    """Mask-denoising generation under an optional cache policy.

    Returns (tokens (B,S) int32, n_full_computes)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    policy = policy or NoCachePolicy()
    mask_id = cfg.vocab_size - 1
    canvas = jnp.full((batch, seq_len), mask_id, jnp.int32)
    try:   # TeaCache tracks the (B,S) occupancy signal separately
        state = policy.init_state((batch, seq_len, cfg.vocab_size),
                                  signal_shape=(batch, seq_len))
    except TypeError:
        state = policy.init_state((batch, seq_len, cfg.vocab_size))
    n_computed = 0

    for step in range(num_steps):
        computed = {"hit": False}

        def compute_fn(_x, _canvas=canvas):
            computed["hit"] = True
            return dlm_forward(params, _canvas, cfg)

        # signal = the canvas embedding occupancy (changes as tokens commit)
        sig = (canvas != mask_id).astype(jnp.float32)
        logits, state = policy.apply(
            state, step, canvas.astype(jnp.float32)[..., None]
            * jnp.ones((1, 1, cfg.vocab_size)), compute_fn,
            signal=sig)
        n_computed += int(computed["hit"])

        # commit the most confident still-masked fraction (cosine schedule)
        frac_keep = float(jnp.cos((step + 1) / num_steps * jnp.pi / 2))
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
        conf = jnp.max(probs, -1)
        pred = jnp.argmax(probs, -1).astype(jnp.int32)
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            pred = jax.random.categorical(
                sub, logits.astype(jnp.float32) / temperature, -1).astype(jnp.int32)

        still_masked = canvas == mask_id
        conf = jnp.where(still_masked, conf, -jnp.inf)
        n_mask = int(jnp.sum(still_masked[0]))
        n_commit = max(n_mask - int(frac_keep * seq_len), 1)
        # per-row top-n_commit confident positions
        thresh = -jnp.sort(-conf, axis=-1)[:, n_commit - 1:n_commit]
        commit = still_masked & (conf >= thresh)
        canvas = jnp.where(commit, pred, canvas)

    # any residual masks: fill greedily
    canvas = jnp.where(canvas == mask_id, pred, canvas)
    return canvas, n_computed
