"""CachedDenoiser — binds repro.core cache policies to a DiT backbone.

This is the integration point the whole survey is about: the denoiser is an
iterative map eps_hat = F(x_t, t, c) and the cache policy decides, per
(step, module), between COMPUTE / REUSE / FORECAST.

Modalities: every entry point here dispatches on the config — a plain
isotropic DiT (image latents, audio mel-spectrograms) when
`cfg.dit_num_frames == 0`, the factorized spatio-temporal video DiT
(repro.models.video_dit) otherwise.  Latents are always (B, cfg.dit_tokens,
cfg.dit_in_dim), so the cache/serving stack is modality-agnostic; only the
backbone forward and the TeaCache signal change underneath
(repro.modalities wraps this into named workload specs).

Granularities (survey Fig. 2 reuse-granularity axis):

  MODEL     — one policy gates the full backbone output.  TeaCache's
              input-side signal (the AdaLN-modulated first-block input,
              Eq. 22) is wired through automatically.  This granularity is
              also FreqCa's CRF memory trick: the cache holds one tensor
              regardless of depth (Eq. 52).
  BLOCK     — one policy state per DiT block threaded through the layer scan
              (FORA / Δ-DiT / TaylorSeer per-block operation).
  DEEPCACHE — structural split: the first `shallow_n` blocks always compute
              (DeepCache's "downsampling path"), the remaining deep section
              is gated as one unit (its "upsampling path").  The adaption of
              DeepCache's U-Net insight to the isotropic DiT stack follows
              Δ-DiT's front/rear analysis.
  PAB_VIDEO — video backbone only: Pyramid Attention Broadcast with
              per-module-type ranges — each block's spatial-attention,
              temporal-attention and MLP branch outputs cached and
              broadcast over different intervals (temporal the longest);
              repro.core.temporal.TemporalPABStack owns the layer loop.

Classifier-free guidance (cfg_scale > 0) doubles the compute; the
`cfg_policy` slot accepts FasterCacheCFG to reuse the unconditional branch
(survey §III-C), including its low-frequency cond-residual mode, which
receives the conditional output via `signals["cond_out"]`.  `null_embed`
carries negative-prompt conditioning: an arbitrary (d_model,) vector used
for the unconditional branch instead of the null-class embedding.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import (CachePolicy, CachedStack, NoCachePolicy,
                        TemporalPABStack)
from repro.models import dit, video_dit

PyTree = Any


def backbone_module(cfg):
    """The backbone module for this config's modality (dit | video_dit)."""
    return video_dit if cfg.dit_num_frames > 0 else dit


def backbone_fns(params, cfg):
    """(forward_fn, signal_fn) bound to params for this config's modality.

    forward_fn(xs, ts, labels, y_embed=None, txt_kv=None, txt_mask=None)
    -> eps — xs (B, T, D), ts (B,) float timesteps, labels (B,) int32 class
    conditioning, y_embed (B, d) optional conditioning-vector override
    (negative prompts), txt_kv/txt_mask the precomputed per-layer text K/V
    tables + key mask (text-enabled configs; see models.dit.text_kv).
    signal_fn(xs, ts, labels) -> the TeaCache modulated input signal
    (computed BEFORE the first block, so it is text-independent by
    construction — prompts never perturb the refresh decision).
    """
    mod = backbone_module(cfg)

    def forward_fn(xs, ts, labels, y_embed=None, txt_kv=None, txt_mask=None):
        return mod.forward(params, xs, ts.astype(jnp.float32),
                           labels.astype(jnp.int32), cfg, y_embed=y_embed,
                           txt_kv=txt_kv, txt_mask=txt_mask)

    def signal_fn(xs, ts, labels):
        h, c = mod.embed_patches(params, xs, ts.astype(jnp.float32),
                                 labels.astype(jnp.int32), cfg)
        return mod.modulated_signal(params, h, c, cfg)

    return forward_fn, signal_fn


def _null_embed_rows(params, nulls, null_vecs, null_mask):
    """Per-row unconditional conditioning: the null-class embedding, replaced
    by the request's negative-prompt vector where `null_mask` is set."""
    ce = params["class_embed"][nulls.astype(jnp.int32)]
    return jnp.where(null_mask[:, None], null_vecs.astype(ce.dtype), ce)


def _as_text(text, cfg):
    """Normalize prompt conditioning to (te (L, d) f32, tm (L,) bool).

    `text` is a repro.conditioning PromptEmbedding, an (embed, mask) pair,
    or None.  Embeddings are zeroed at masked positions — the invariant the
    cross-attention no-op branch relies on (models.dit.cross_attn_branch).
    """
    if text is None:
        return None
    if cfg.dit_text_len <= 0:
        raise ValueError(f"config '{cfg.name}' is not text-enabled "
                         f"(dit_text_len == 0) but a prompt was given")
    te, tm = (text.embed, text.mask) if hasattr(text, "embed") else text
    te = jnp.asarray(te, jnp.float32)
    tm = jnp.asarray(tm, bool)
    if te.ndim == 3:                      # batched (1, L, d) -> (L, d)
        te, tm = te[0], tm[0]
    if te.shape != (cfg.dit_text_len, cfg.d_model):
        raise ValueError(f"prompt embedding shape {te.shape} != "
                         f"({cfg.dit_text_len}, {cfg.d_model})")
    return jnp.where(tm[:, None], te, 0.0), tm


def _text_pooled(text):
    """The pooled (d_model,) view of a normalized (te, tm) pair — the
    vector the CFG negative-prompt (null-vec) path conditions on."""
    te, tm = text
    n = jnp.maximum(jnp.sum(tm), 1)
    return jnp.sum(te, axis=0) / n


class CachedDenoiser:
    """eps_hat = denoiser(state, i, x, t); state threads the cache pytrees."""

    def __init__(self, params, cfg, policy: Optional[CachePolicy] = None,
                 granularity: str = "model", shallow_n: int = 4,
                 cfg_scale: float = 0.0, cfg_policy: Optional[CachePolicy] = None,
                 class_label: int = 0, null_embed=None, text=None,
                 neg_text=None):
        assert granularity in ("model", "block", "deepcache", "pab_video")
        self.params = params
        self.cfg = cfg
        self.policy = policy or NoCachePolicy()
        self.granularity = granularity
        self.shallow_n = shallow_n
        self.cfg_scale = float(cfg_scale)
        self.cfg_policy = cfg_policy
        self.class_label = class_label
        # prompt conditioning (PromptEmbedding or (embed, mask); text-enabled
        # configs only): cross-attn K/V projected ONCE here — text is
        # step-invariant, so no denoise step ever recomputes it
        self._text = _as_text(text, cfg)
        self._neg = _as_text(neg_text, cfg)
        self._text_kv = (None if self._text is None else
                         dit.text_kv(params, self._text[0][None], cfg))
        self._neg_kv = (None if self._neg is None else
                        dit.text_kv(params, self._neg[0][None], cfg))
        # negative-prompt conditioning: an arbitrary (d_model,) vector for the
        # unconditional branch (None = the model's null-class embedding); a
        # neg_text prompt defaults it to the pooled prompt embedding — the
        # same convention the serving engine's null-vec tables use
        if null_embed is None and self._neg is not None:
            null_embed = _text_pooled(self._neg)
        self.null_embed = (None if null_embed is None
                           else jnp.asarray(null_embed, jnp.float32))
        self._mod = backbone_module(cfg)
        if granularity == "block":
            self._stack = CachedStack(
                lambda p, x, c: self._block(p, x, c),
                self.policy, cfg.num_layers)
        elif granularity == "pab_video":
            assert cfg.dit_num_frames > 0, \
                "pab_video granularity needs the factorized video backbone"
            self._stack = TemporalPABStack(video_dit.pab_branch_fns(cfg),
                                           cfg.num_layers)

    # -- text helpers ---------------------------------------------------
    def _text_rows(self, which, B):
        """(te, tm) broadcast to batch B; zero/empty rows when no prompt
        (text-enabled configs run the exact no-op branch then)."""
        if which is not None:
            te, tm = which
        else:
            te = jnp.zeros((self.cfg.dit_text_len, self.cfg.d_model),
                           jnp.float32)
            tm = jnp.zeros((self.cfg.dit_text_len,), bool)
        return (jnp.broadcast_to(te[None], (B,) + te.shape),
                jnp.broadcast_to(tm[None], (B,) + tm.shape))

    def _txt_kwargs(self, kv, which, B):
        """forward() kwargs for the precomputed-K/V path (model/deepcache
        granularity and the uncond branch — full-forward call sites)."""
        if kv is None:
            return {}
        tk, tv = kv
        _, tm = self._text_rows(which, B)
        return {"txt_kv": (jnp.broadcast_to(tk, (B,) + tk.shape[1:]),
                           jnp.broadcast_to(tv, (B,) + tv.shape[1:])),
                "txt_mask": tm}

    def _block(self, p, x, c):
        """One block under the cond-branch text conditioning.  Cache-stack
        scans broadcast their args across layers, so per-layer K/V is
        projected inline from the (step-invariant) prompt embeddings."""
        txt = None
        if self.cfg.dit_text_len > 0:
            te, tm = self._text_rows(self._text, x.shape[0])
            tk, tv = dit.cross_attn_kv(p["cross"], te.astype(x.dtype))
            txt = (tk, tv, tm)
        if self._mod is video_dit:
            return video_dit.video_block(p, x, c, self.cfg, txt=txt)
        return dit.dit_block(p, x, c, self.cfg, txt=txt)

    # ------------------------------------------------------------------
    def init_state(self, batch: int) -> PyTree:
        cfgm = self.cfg
        feat = (batch, cfgm.dit_tokens, cfgm.d_model)
        eps_shape = (batch, cfgm.dit_tokens, cfgm.dit_in_dim)
        if self.granularity == "model":
            try:  # TeaCache tracks an input-side signal of a different shape
                state = {"policy": self.policy.init_state(
                    eps_shape, signal_shape=feat)}
            except TypeError:
                state = {"policy": self.policy.init_state(eps_shape)}
        elif self.granularity in ("block", "pab_video"):
            state = {"policy": self._stack.init(feat)}
        else:  # deepcache: one cache over the deep section's hidden output
            state = {"policy": self.policy.init_state(feat)}
        if self.cfg_policy is not None:
            state["cfg"] = self.cfg_policy.init_state(eps_shape)
        return state

    # ------------------------------------------------------------------
    def _backbone(self, x_lat, t_vec, y, state, step):
        """One conditional forward under the configured granularity.

        Returns (eps_hat, new_policy_state)."""
        params, cfgm, mod = self.params, self.cfg, self._mod

        if self.granularity == "model":
            def compute_fn(lat):
                return mod.forward(params, lat, t_vec, y, cfgm,
                                   **self._txt_kwargs(self._text_kv,
                                                      self._text,
                                                      lat.shape[0]))

            # TeaCache's signal: timestep-modulated first-block input
            h, c = mod.embed_patches(params, x_lat, t_vec, y, cfgm)
            sig = mod.modulated_signal(params, h, c, cfgm)
            return self.policy.apply(state, step, x_lat, compute_fn,
                                     signal=sig)

        h, c = mod.embed_patches(params, x_lat, t_vec, y, cfgm)
        if self.granularity in ("block", "pab_video"):
            if self.granularity == "pab_video" and cfgm.dit_text_len > 0:
                # text-enabled PAB branch fns take (c, te, tm) broadcast args
                te, tm = self._text_rows(self._text, h.shape[0])
                h, new_state = self._stack(state, step, h, params["blocks"],
                                           c, te, tm)
            else:
                h, new_state = self._stack(state, step, h, params["blocks"],
                                           c)
            return mod.final_layer(params, h, c, cfgm), new_state

        # deepcache split
        F = self.shallow_n
        shallow = jax.tree_util.tree_map(lambda a: a[:F], params["blocks"])
        deep = jax.tree_util.tree_map(lambda a: a[F:], params["blocks"])

        def run(h, stacked):
            def body(h, p):
                return self._block(p, h, c), None
            h, _ = jax.lax.scan(body, h, stacked)
            return h

        h = run(h, shallow)
        h, new_state = self.policy.apply(state, step, h,
                                         lambda hh: run(hh, deep))
        return mod.final_layer(params, h, c, cfgm), new_state

    # ------------------------------------------------------------------
    def __call__(self, state, step, x_lat, t_vec):
        B = x_lat.shape[0]
        state = state if state is not None else self.init_state(B)
        y_cond = jnp.full((B,), self.class_label, jnp.int32)
        eps_c, pol_state = self._backbone(x_lat, t_vec, y_cond, state["policy"],
                                          step)
        new_state = {"policy": pol_state}

        if self.cfg_scale > 0.0:
            y_null = jnp.full((B,), self.cfg.dit_num_classes, jnp.int32)
            y_embed = (None if self.null_embed is None
                       else jnp.broadcast_to(self.null_embed[None],
                                             (B, self.cfg.d_model)))
            mod = self._mod

            def plain_uncond(lat):
                # uncond rows attend over the NEGATIVE prompt's K/V (zero
                # tables when none — the classic empty-prompt uncond branch)
                return mod.forward(self.params, lat, t_vec, y_null, self.cfg,
                                   y_embed=y_embed,
                                   **self._txt_kwargs(self._neg_kv,
                                                      self._neg,
                                                      lat.shape[0]))

            if self.cfg_policy is not None:
                # unconditional branch gated by the CFG policy; its compute_fn
                # runs a fresh (non-caching) backbone pass.  cond_out feeds
                # FasterCacheCFG's low-frequency residual reconstruction.
                eps_u, cstate = self.cfg_policy.apply(state["cfg"], step, x_lat,
                                                      plain_uncond,
                                                      cond_out=eps_c)
                new_state["cfg"] = cstate
            else:
                eps_u = plain_uncond(x_lat)
            eps_c = eps_u + self.cfg_scale * (eps_c - eps_u)

        return eps_c, new_state


def slot_denoise_fns(params, cfg, policy: CachePolicy):
    """Slot-parallel CachedDenoiser entry point (model granularity).

    The serving engine (repro.serving.diffusion) advances many concurrent
    requests, each at its own denoising step with its own cache state,
    through one compiled program.  The split that makes this fast:

      backbone_fn(xs, ts, labels) -> eps        plain SLOT-BATCHED forward —
          the slot axis IS the model's batch axis, so XLA sees the same
          program as uncached batched inference.  (Running the backbone
          inside vmap instead would thread a singleton batch dim through
          every matmul, which knocks XLA CPU off its fast paths.)
      apply_fn(state, step, x, t, label, y_full) -> (eps, state)   per-slot
          policy logic, vmapped by the engine.  `y_full` is this slot's row
          of backbone_fn's output; the compute branch selects it into the
          cache, other branches reuse/forecast.  Every repro.core policy
          calls compute_fn on exactly its input x, so precomputing F(x)
          outside the branch is semantics-preserving.  On skip ticks the
          engine passes zeros for y_full — ONLY safe when the policy's
          want_compute is False for every slot (lax.cond vmaps to a select,
          so the dummy branch's outputs are discarded).
      want_fn(state, step, x, t, label) -> bool   mirrors the policy's
          refresh decision without touching the backbone.

    x: (T, in_dim) latent tokens; t: scalar model-facing timestep; label:
    scalar int32 class conditioning.  The backbone is the config's modality
    backbone (image/audio DiT or factorized video DiT); TeaCache's
    input-side signal (the AdaLN-modulated first-block input, Eq. 22) is
    wired through when the policy declares `uses_signal`.
    """
    forward_fn, signal_fn = backbone_fns(params, cfg)

    def backbone_fn(xs, ts, labels, txt=None):
        """txt: the engine's per-slot text-table dict ({} / None = no text;
        an EMPTY dict contributes zero jit operand leaves, so text-free
        engines keep the exact pre-text program signature).  Cond rows
        attend over k/v/mask — K/V were projected once at admission."""
        if not txt:
            return forward_fn(xs, ts, labels)
        return forward_fn(xs, ts, labels, txt_kv=(txt["k"], txt["v"]),
                          txt_mask=txt["mask"])

    def _ctx(x, t, label):
        xb = x[None]
        t_vec = jnp.reshape(t, (1,)).astype(jnp.float32)
        y = jnp.reshape(label, (1,)).astype(jnp.int32)
        if not policy.uses_signal:       # skip-tick cost: don't embed
            return xb, {}
        return xb, {"signal": signal_fn(xb, t_vec, y)}

    def apply_fn(state, step, x, t, label, y_full):
        xb, sig = _ctx(x, t, label)
        eps, state = policy.apply(state, step, xb, lambda _: y_full[None],
                                  **sig)
        return eps[0], state

    def want_fn(state, step, x, t, label):
        xb, sig = _ctx(x, t, label)
        w = policy.want_compute(state, step, xb, **sig)
        # `& step >= 0` keeps constant predicates mapped under vmap
        return jnp.logical_and(jnp.asarray(w), step >= 0)

    return backbone_fn, apply_fn, want_fn


def slot_cfg_denoise_fns(params, cfg, policy: CachePolicy,
                         cfg_policy: Optional[CachePolicy] = None):
    """CFG-aware slot-parallel entry point for the serving engine.

    Extends `slot_denoise_fns` to guided requests: each slot carries a
    conditional cache state (the main `policy`) *and* an unconditional-branch
    state (`cfg_policy`, typically FasterCacheCFG; None means the uncond
    branch recomputes every step — naive two-branch serving).  The backbone
    still runs OUTSIDE vmap; on both-branch ticks the engine stacks cond and
    uncond rows into one 2S-row batch (slot axis == batch axis), so XLA sees
    a plain batched forward either way.

      backbone2_fn(xs, ts, labels, null_labels, null_vecs, null_mask)
          one 2S-row backbone pass over [cond rows; uncond rows], split back
          into the two S-row branch outputs.  `null_vecs` (S, d_model) with
          `null_mask` (S,) carry per-slot negative-prompt conditioning
          vectors that replace the null-class embedding on uncond rows.
      backbone_fn(xs, ts, labels) -> eps_c
          the S-row cond-only pass (from slot_denoise_fns), dispatched on
          ticks where every active slot reuses its cached uncond branch —
          this is where FasterCacheCFG's serving-level saving comes from.
      apply_fn(state, step, x, t, label, scale, cfg_w, y_c, y_u)
          per-slot (vmapped) policy logic over the combined state
          {"policy": ..., "cfg": ...}.  `scale` is the slot's cfg_scale
          (<= 0 means unguided: the uncond branch output is discarded via a
          select, never blended).  `cfg_w` is the slot's trajectory-progress
          weight step/(num_steps-1) — passed from the host because slots run
          different step budgets against one shared FasterCacheCFG instance.
          The cond-branch output is forwarded to the CFG policy as
          `cond_out` (FasterCacheCFG's low-frequency residual mode).
          On cond-only / skip ticks the engine passes zeros for the missing
          y_u / y_c rows — safe under the same rule as slot_denoise_fns:
          a dummy row may only reach a branch that the per-slot lax.cond
          (vmapped to a select) discards.
      want_cond_fn / want_uncond_fn
          traced mirrors of the two refresh decisions; `want_uncond_fn`
          additionally masks by the slot's `guided` flag so pure-unguided
          pools never dispatch the 2S-row program.
    """
    uncond_policy = cfg_policy if cfg_policy is not None else NoCachePolicy()
    forward_fn, _ = backbone_fns(params, cfg)
    backbone_fn, base_apply, base_want = slot_denoise_fns(params, cfg, policy)

    def backbone2_fn(xs, ts, labels, null_labels, null_vecs, null_mask,
                     txt=None):
        S = xs.shape[0]
        x2 = jnp.concatenate([xs, xs], axis=0)
        t2 = jnp.concatenate([ts, ts], axis=0).astype(jnp.float32)
        y2 = jnp.concatenate([labels, null_labels], axis=0).astype(jnp.int32)
        ce_c = params["class_embed"][labels.astype(jnp.int32)]
        ce_u = _null_embed_rows(params, null_labels, null_vecs, null_mask)
        kw = {}
        if txt:
            # cond rows attend the prompt's K/V, uncond rows the NEGATIVE
            # prompt's (nk/nv; all-masked when the request carries none)
            kw = {"txt_kv": (jnp.concatenate([txt["k"], txt["nk"]], axis=0),
                             jnp.concatenate([txt["v"], txt["nv"]], axis=0)),
                  "txt_mask": jnp.concatenate([txt["mask"], txt["nmask"]],
                                              axis=0)}
        eps = forward_fn(x2, t2, y2,
                         y_embed=jnp.concatenate([ce_c, ce_u], axis=0), **kw)
        return eps[:S], eps[S:]

    def apply_fn(state, step, x, t, label, scale, cfg_w, y_c, y_u):
        eps_c, pol_state = base_apply(state["policy"], step, x, t, label, y_c)
        eps_u, cfg_state = uncond_policy.apply(state["cfg"], step, x[None],
                                               lambda _: y_u[None],
                                               cfg_w=cfg_w,
                                               cond_out=eps_c[None])
        eps_u = eps_u[0]
        eps = jnp.where(scale > 0.0, eps_u + scale * (eps_c - eps_u), eps_c)
        return eps, {"policy": pol_state, "cfg": cfg_state}

    def want_cond_fn(state, step, x, t, label):
        return base_want(state["policy"], step, x, t, label)

    def want_uncond_fn(state, step, x, guided):
        w = uncond_policy.want_compute(state["cfg"], step, x[None])
        w = jnp.logical_and(jnp.asarray(w), guided)
        # `& step >= 0` keeps constant predicates mapped under vmap
        return jnp.logical_and(w, step >= 0)

    return backbone2_fn, backbone_fn, apply_fn, want_cond_fn, want_uncond_fn


def slot_compact_denoise_fns(params, cfg, policy: CachePolicy,
                             cfg_policy: Optional[CachePolicy] = None):
    """Row-compacted slot-parallel entry point for the serving engine.

    `slot_cfg_denoise_fns` runs the backbone over *whole-pool* batches: S cond
    rows, optionally doubled to 2S when any slot wants an uncond refresh.
    That makes tick cost all-or-nothing — one TeaCache slot firing drags every
    slot through the backbone.  This variant adds the gather/scatter pair that
    lets the engine dispatch the backbone over EXACTLY the rows whose per-slot
    policies want a compute this tick, padded to a power-of-two bucket so the
    jit program count stays bounded (one program per bucket size):

      compact_backbone_fn(xs, tvals, labels, nulls, null_vecs, null_mask,
                          row_slot, row_uncond, row_dest) -> (y_c, y_u)
          `row_slot` (B,) gathers each compacted row's latent/timestep from
          its source slot; `row_uncond` selects the null label (or the
          slot's negative-prompt vector, where `null_mask` is set) for
          uncond rows; the backbone runs over the compacted (B, T, D) batch;
          the scatter writes each row into a (2S+1)-row buffer at `row_dest`
          (cond row i -> i, uncond row i -> S + i, padding -> the 2S dump
          row) and splits it back into the S-row `y_c` / `y_u` layout the
          vmapped apply_fn expects.  Rows that were not gathered come back
          as zeros — safe under the standing invariant that a dummy row may
          only reach a branch the per-slot lax.cond (vmapped to a select)
          discards, i.e. the gather set must cover every row whose policy
          `want_compute` is True.
      apply_fn / want_cond_fn / want_uncond_fn
          unchanged from `slot_cfg_denoise_fns` — compaction only changes
          how y_c / y_u are produced, never the per-slot policy step.

    All index operands are traced values, so one jit program per bucket size
    B serves every gather pattern of that size.  B is static per program:
    the engine re-pads each tick's row set to the next power of two.
    """
    forward_fn, _ = backbone_fns(params, cfg)
    (backbone2_fn, backbone_fn, apply_fn, want_cond_fn,
     want_uncond_fn) = slot_cfg_denoise_fns(params, cfg, policy, cfg_policy)

    def compact_backbone_fn(xs, tvals, labels, nulls, null_vecs, null_mask,
                            txt, row_slot, row_uncond, row_dest):
        S, T, D = xs.shape
        xb = xs[row_slot]
        tb = tvals[row_slot].astype(jnp.float32)
        yb = jnp.where(row_uncond, nulls[row_slot],
                       labels[row_slot]).astype(jnp.int32)
        # negative-prompt rows: uncond rows of slots carrying a vector
        ce = _null_embed_rows(params, yb, null_vecs[row_slot],
                              jnp.logical_and(row_uncond,
                                              null_mask[row_slot]))
        kw = {}
        if txt:
            # per-row text tables: cond rows gather the slot's prompt K/V,
            # uncond rows its negative-prompt K/V
            sel = row_uncond[:, None, None, None]
            kw = {"txt_kv": (jnp.where(sel, txt["nk"][row_slot],
                                       txt["k"][row_slot]),
                             jnp.where(sel, txt["nv"][row_slot],
                                       txt["v"][row_slot])),
                  "txt_mask": jnp.where(row_uncond[:, None],
                                        txt["nmask"][row_slot],
                                        txt["mask"][row_slot])}
        eps = forward_fn(xb, tb, yb, y_embed=ce, **kw)
        # scatter: padding rows all land in the 2S dump row and are dropped
        buf = jnp.zeros((2 * S + 1, T, D), eps.dtype).at[row_dest].set(eps)
        return buf[:S], buf[S:2 * S]

    return (compact_backbone_fn, backbone2_fn, backbone_fn, apply_fn,
            want_cond_fn, want_uncond_fn)


def slot_want_fns(params, cfg, policy: CachePolicy,
                  cfg_policy: Optional[CachePolicy] = None):
    """Fused slot-batched want/metric pass for the serving engine's planner.

    The per-slot want predicates of `slot_cfg_denoise_fns` compute a
    signal-using policy's TeaCache signal on a SINGLETON batch inside vmap —
    the modulated-embed matmuls thread a batch-1 dim through XLA, and the
    engine paid two separate device syncs per tick (cond plan, then uncond
    plan).  This entry point fuses the whole plan into one program:

      want_all_fn(states, steps, xs, tvals, labels, guided)
          -> (want_cond, want_uncond, metric)     each (S,)

    The TeaCache signal is computed ONCE over the whole (S, T, D) slot batch
    outside vmap (slot axis == batch axis, same layout as the backbone
    call), then handed row-wise to the vmapped per-slot predicates.  The
    batched embed is row-independent, so each slot sees exactly the signal
    the singleton path produced.  `metric` is the per-slot
    `CachePolicy.want_metric` scalar (the value the refresh decision
    thresholds on — TeaCache's corrected accumulated distance, the LazyDiT
    gate score, 0 for schedule-only policies), which the control plane's
    SignalTraceLog records; it rides the same device round trip, so trace
    logging costs no extra sync."""
    uncond_policy = cfg_policy if cfg_policy is not None else NoCachePolicy()
    _, signal_fn = backbone_fns(params, cfg)

    def per_slot(state, step, x, sig, g):
        xb = x[None]
        kw = {"signal": sig[None]} if policy.uses_signal else {}
        wc = policy.want_compute(state["policy"], step, xb, **kw)
        wu = uncond_policy.want_compute(state["cfg"], step, xb)
        m = jnp.asarray(policy.want_metric(state["policy"], step, xb, **kw),
                        jnp.float32)
        # `& step >= 0` / `+ 0 * step` keep constant outputs mapped under
        # vmap (schedule-only policies return trace-constant predicates)
        wc = jnp.logical_and(jnp.asarray(wc), step >= 0)
        wu = jnp.logical_and(jnp.logical_and(jnp.asarray(wu), g), step >= 0)
        return wc, wu, m + 0.0 * step.astype(jnp.float32)

    def want_all_fn(states, steps, xs, tvals, labels, guided):
        if policy.uses_signal:
            sigs = signal_fn(xs, tvals.astype(jnp.float32),
                             labels.astype(jnp.int32))
        else:                            # dummy rows: per_slot never reads them
            sigs = jnp.zeros((xs.shape[0], 1, 1), jnp.float32)
        return jax.vmap(per_slot)(states, steps, xs, sigs, guided)

    return want_all_fn


def cfg_denoise_fn(params, cfg, cfg_scale: float, class_label: int = 0,
                   null_embed=None, text=None, neg_text=None):
    """Uncached CFG denoiser (the exact baseline): eps = e_u + s (e_c - e_u).

    `null_embed` (d_model,) replaces the null-class embedding with an
    arbitrary negative-prompt conditioning vector.  `text` / `neg_text`
    (PromptEmbedding or (embed, mask); text-enabled configs) condition the
    cond / uncond branch through cross-attention; K/V are projected once at
    construction, and a neg_text prompt defaults `null_embed` to its pooled
    embedding — the same convention CachedDenoiser and the engine use."""
    forward_fn, _ = backbone_fns(params, cfg)
    txt = _as_text(text, cfg)
    neg = _as_text(neg_text, cfg)
    txt_kv = None if txt is None else dit.text_kv(params, txt[0][None], cfg)
    neg_kv = None if neg is None else dit.text_kv(params, neg[0][None], cfg)
    if null_embed is None and neg is not None:
        null_embed = _text_pooled(neg)
    ne = None if null_embed is None else jnp.asarray(null_embed, jnp.float32)

    def _kw(kv, pair, B):
        if kv is None:
            return {}
        tk, tv = kv
        return {"txt_kv": (jnp.broadcast_to(tk, (B,) + tk.shape[1:]),
                           jnp.broadcast_to(tv, (B,) + tv.shape[1:])),
                "txt_mask": jnp.broadcast_to(pair[1][None],
                                             (B,) + pair[1].shape)}

    def fn(state, step, x, t_vec):
        B = x.shape[0]
        y_c = jnp.full((B,), class_label, jnp.int32)
        y_u = jnp.full((B,), cfg.dit_num_classes, jnp.int32)
        e_c = forward_fn(x, t_vec, y_c, **_kw(txt_kv, txt, B))
        if cfg_scale <= 0.0:
            return e_c, state
        ye = None if ne is None else jnp.broadcast_to(ne[None],
                                                      (B, cfg.d_model))
        e_u = forward_fn(x, t_vec, y_u, y_embed=ye, **_kw(neg_kv, neg, B))
        return e_u + cfg_scale * (e_c - e_u), state
    return fn
