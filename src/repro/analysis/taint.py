"""Function-scope device-value taint analysis.

The host-sync rule must tell `float(n)` on a Python int (fine) apart from
`float(metric)` on a jax array (a blocking device->host round trip).  A
type checker could do this with annotations; the codebase has none, so we
approximate with a deliberately simple, flow-insensitive taint pass per
function scope:

  Sources (expression produces a device value):
    * calls into jnp.* and device-producing jax.* namespaces
      (jax.random/lax/nn/numpy/scipy/image), jax.vmap(...)(...) etc.
    * calls of names that were assigned a transform result — `f =
      jax.jit(g)` makes every `f(...)` a device-producing call
  Propagation:
    * through names (a name EVER assigned a tainted value is tainted —
      flow-insensitive, so loops need no fixpoint over orderings),
      tuple-unpack, binary/unary/compare ops, subscripts, conditionals
    * through attribute access and method calls on tainted objects,
      except host metadata (.shape/.dtype/.ndim/.size)
  Sinks (clear the taint — the value is host-side afterwards):
    * jax.device_get, np.asarray/np.array, float/int/bool, .item(),
      .tolist()

False-negative bias is intentional: an unknown call (`self._decode(...)`)
is NOT treated as a source even when it returns device arrays, because
treating every unknown as a source would drown the report in noise.  The
rule catches the syncs whose device origin is visible in the same
function — which covers every hot-path sync this repo has shipped.
"""
from __future__ import annotations

import ast
from typing import Optional, Set

#: jax submodules whose calls produce device arrays
_JAX_DEVICE_NS = {"random", "lax", "nn", "numpy", "scipy", "image", "ops"}
#: jax.* callables whose RESULT is a device-producing callable
_JAX_TRANSFORMS = {"jit", "vmap", "pmap", "grad", "value_and_grad",
                   "checkpoint", "remat"}
#: jax.* namespaces/functions that stay host-side
_JAX_HOST = {"tree_util", "tree", "eval_shape", "ShapeDtypeStruct",
             "debug", "profiler", "device_get", "devices", "device_count",
             "local_device_count"}
#: attribute reads that return host metadata, not device values
_HOST_META_ATTRS = {"shape", "dtype", "ndim", "size", "itemsize", "name",
                    "sharding"}
#: methods whose result is host-side (they are also host-sync sinks)
_HOST_RESULT_METHODS = {"item", "tolist"}


def attr_chain(node: ast.AST) -> Optional[str]:
    """'jax.random.normal' for nested Attribute/Name chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class TaintScope:
    """Taint facts for one function (or module) scope."""

    def __init__(self, tainted: Set[str], callables: Set[str]):
        #: names holding (or having held) device values
        self.tainted = tainted
        #: names holding device-producing callables (jit/vmap results)
        self.device_callables = callables


def _is_device_call(call: ast.Call, scope: TaintScope) -> bool:
    """Does this call produce a device value?"""
    func = call.func
    chain = attr_chain(func)
    if chain:
        head, *rest = chain.split(".")
        if head == "jnp":
            return True
        if head == "jax":
            if not rest or rest[0] in _JAX_HOST:
                return False
            if rest[0] in _JAX_DEVICE_NS:
                return True
            if rest[0] in _JAX_TRANSFORMS:
                # jax.vmap(f)(x) — transform called, result NOT yet applied
                # produces a callable; the callable itself is handled below
                return False
        if chain in scope.device_callables:
            return True
    # jax.jit(f)(x) / jax.value_and_grad(f)(x): func is itself a call of a
    # transform — the application produces device values
    if isinstance(func, ast.Call):
        inner = attr_chain(func.func)
        if inner:
            parts = inner.split(".")
            if parts[0] == "jax" and len(parts) > 1 \
                    and parts[1] in _JAX_TRANSFORMS:
                return True
    # method call on a tainted object: x.sum(), x.astype(...)
    if isinstance(func, ast.Attribute):
        if func.attr in _HOST_RESULT_METHODS:
            return False
        if _expr_tainted(func.value, scope):
            return True
    return False


def _is_transform_call(call: ast.Call) -> bool:
    """Is this `jax.jit(...)`-style — result is a device-producing fn?"""
    chain = attr_chain(call.func)
    if not chain:
        return False
    parts = chain.split(".")
    return parts[0] == "jax" and len(parts) > 1 \
        and parts[1] in _JAX_TRANSFORMS


def _is_host_conversion(call: ast.Call) -> bool:
    """float()/int()/bool()/np.asarray()/np.array()/jax.device_get() —
    result is host-side regardless of the argument."""
    func = call.func
    if isinstance(func, ast.Name) and func.id in ("float", "int", "bool",
                                                  "str", "len"):
        return True
    chain = attr_chain(func)
    return chain in ("np.asarray", "np.array", "numpy.asarray",
                     "numpy.array", "jax.device_get")


def _expr_tainted(node: ast.AST, scope: TaintScope) -> bool:
    if isinstance(node, ast.Name):
        return node.id in scope.tainted
    if isinstance(node, ast.Call):
        if _is_host_conversion(node):
            return False
        return _is_device_call(node, scope)
    if isinstance(node, ast.Attribute):
        if node.attr in _HOST_META_ATTRS:
            return False
        return _expr_tainted(node.value, scope)
    if isinstance(node, ast.Subscript):
        return _expr_tainted(node.value, scope)
    if isinstance(node, ast.BinOp):
        return (_expr_tainted(node.left, scope)
                or _expr_tainted(node.right, scope))
    if isinstance(node, ast.UnaryOp):
        return _expr_tainted(node.operand, scope)
    if isinstance(node, ast.Compare):
        return (_expr_tainted(node.left, scope)
                or any(_expr_tainted(c, scope) for c in node.comparators))
    if isinstance(node, ast.IfExp):
        return (_expr_tainted(node.body, scope)
                or _expr_tainted(node.orelse, scope))
    if isinstance(node, (ast.Tuple, ast.List)):
        return any(_expr_tainted(e, scope) for e in node.elts)
    if isinstance(node, ast.Starred):
        return _expr_tainted(node.value, scope)
    return False


def _assign_targets(target: ast.AST):
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for el in target.elts:
            yield from _assign_targets(el)
    elif isinstance(target, ast.Starred):
        yield from _assign_targets(target.value)
    # attribute/subscript targets (self.x = ...) are not tracked


def build_scope(fn: ast.AST, parent: Optional[TaintScope] = None
                ) -> TaintScope:
    """Flow-insensitive fixpoint over one function body (nested function
    bodies excluded — they get their own scope seeded from this one)."""
    scope = TaintScope(set(parent.tainted) if parent else set(),
                       set(parent.device_callables) if parent else set())

    own_body = list(ast.iter_child_nodes(fn))

    def walk_no_nested(node):
        """Yield nodes in this scope, not descending into nested defs."""
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            yield from walk_no_nested(child)

    nodes = [n for top in own_body for n in walk_no_nested(top)]

    for _ in range(4):  # tiny fixpoint; chains are short
        changed = False
        for node in nodes:
            targets, value = (), None
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AugAssign):
                targets, value = (node.target,), node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = (node.target,), node.value
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                targets, value = (node.target,), node.iter
            elif isinstance(node, ast.withitem) and node.optional_vars:
                targets, value = (node.optional_vars,), node.context_expr
            elif isinstance(node, ast.NamedExpr):
                targets, value = (node.target,), node.value
            if value is None:
                continue
            names = list(_assign_targets(t) for t in targets)
            flat = [n for sub in names for n in sub]
            if not flat:
                continue
            if isinstance(value, ast.Call) and _is_transform_call(value):
                for n in flat:
                    if n not in scope.device_callables:
                        scope.device_callables.add(n)
                        changed = True
                continue
            if _expr_tainted(value, scope):
                for n in flat:
                    if n not in scope.tainted:
                        scope.tainted.add(n)
                        changed = True
        if not changed:
            break
    return scope


def expr_tainted(node: ast.AST, scope: TaintScope) -> bool:
    """Public wrapper: is this expression device-tainted in `scope`?"""
    return _expr_tainted(node, scope)
