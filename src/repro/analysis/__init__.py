"""repro.analysis — AST lint framework enforcing the serving stack's JAX
discipline.

The survey's training-free caching paradigm only pays off if the serving
hot loop stays free of silent performance and correctness hazards: one
hidden host sync per tick erases the row savings that row compaction and
TeaCache-style reuse buy, and a reused PRNG key makes "distinct" requests
produce identical samples.  The equivalence tests
(tests/test_serving_compaction.py) verify the contracts dynamically; this
package checks them statically, at review time, in CI.

Rules (each one module under `repro.analysis.rules`):

  host-sync-in-hot-path        float()/int()/bool()/.item()/.tolist()/
                               np.asarray()/jax.device_get() on device
                               values inside serving/ modalities/ core/ —
                               each is a blocking device->host round trip.
  clock-discipline             wall time in serving code must go through
                               repro.obs.clock (one clock source), never
                               time.time()/perf_counter()/monotonic().
  rng-key-reuse                the same PRNG key consumed by two or more
                               jax.random.* draws without an intervening
                               split — the PR-3 identical-default-seeds
                               bug class.
  jit-hygiene                  jax.jit sites with mutable default args,
                               closures over mutable module globals, or
                               jit-inside-a-loop recompilation hazards.
  pytree-registration          dataclass instances flowing into jitted
                               programs must be registered pytrees.
  policy-registry-conformance  import-time introspection: every
                               make_policy registry entry implements the
                               want_compute mirror-predicate +
                               reset-on-refill contract the compaction
                               engine assumes.

Usage:

  python -m repro.analysis                      # lint the repo, exit 1 on
                                                # unsuppressed findings
  python -m repro.analysis --rule clock-discipline
  python -m repro.analysis --json report.json   # machine-readable output
  repro-lint                                    # console entry point

Suppression: append `# repro-lint: disable=<rule>[,<rule>...]` to the
offending line (or `disable-next-line=` on the line above) with a short
justification.  Grandfathered findings live in `tools/lint_baseline.json`;
`--write-baseline` regenerates it.  See README "Static analysis".
"""
from .base import Finding, ProjectRule, Rule, all_rules, get_rule
from .runner import RunResult, run_analysis
from .report import to_json, to_text

__all__ = [
    "Finding", "Rule", "ProjectRule", "all_rules", "get_rule",
    "RunResult", "run_analysis", "to_json", "to_text",
]
