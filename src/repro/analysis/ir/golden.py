"""The golden mixed-modality session: the lint-time serving fixture the
ir-* rules (and the sentinel tests) share.

One cached context per process builds tiny image + video + prompted-t2i
engines (signal policies + a CFG branch + a PromptCache conditioner, so
the fused want pass, the uncond rows, every bucket program and the text
programs all exist), warms them with IR capture, runs `verify_programs`
over each, then serves a mixed guided/unguided/prompted queue through a
MixedModalityEngine under a RetraceSentinel — steady-state serving after
warmup must compile NOTHING, in-session prompt-cache misses included.

Tiny is load-bearing: the context compiles ~a dozen programs, so the
configs are reduced to 1 layer / 32 dims and the checks run in seconds
inside `repro-lint`.  The contracts checked are size-independent.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List

__all__ = ["GoldenContext", "golden_context", "build_golden_engines",
           "golden_requests"]


@dataclass
class GoldenContext:
    """Everything the ir-* rules consult, built once per process."""
    engines: Dict[str, object] = field(default_factory=dict)
    program_findings: List = field(default_factory=list)   # verify_programs
    retrace_count: int = -1             # -1 = session did not run
    retrace_names: List[str] = field(default_factory=list)
    sentinel_live: bool = False         # selftest: sentinel can see compiles
    requests_served: int = 0
    error: str = ""                     # non-empty = context build failed


def build_golden_engines() -> Dict[str, object]:
    """Tiny image + video + t2i engines with state-dependent policies and
    a CFG branch — the program-surface-maximizing configuration: want pass
    + every bucket + uncond rows + the text programs (prompt encoder,
    admission-time text_kv) all compile at warmup."""
    from repro.core import FasterCacheCFG
    from repro.modalities import get_modality, make_workload

    engines = {}
    for modality, policy in (("image", "teacache"),
                             ("video", "teacache_video"),
                             ("t2i", "teacache")):
        spec = get_modality(modality)
        extra = {"dit_text_len": 4} if spec.text else {}
        cfg = spec.config(smoke=True).reduced(
            num_layers=1, d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
            **extra)
        wl = make_workload(modality, cfg=cfg)
        kw = {"conditioner": wl.conditioner(seed=0)} if spec.text else {}
        engines[modality] = wl.engine(
            policy, slots=2, max_steps=6, cfg_policy=FasterCacheCFG(2, 6),
            **kw)
    return engines


def golden_requests(num_steps: int = 6):
    """A mixed queue: guided + unguided, image + video + prompted t2i,
    enough requests that slots refill mid-flight (the refill path must
    also be warm).  The t2i prompts include a fresh-at-admission prompt
    and a CFG negative prompt, so the sentinel proves the whole text path
    — encoder miss, K/V table rebuild — compiles nothing post-warmup."""
    from repro.serving.diffusion import DiffusionRequest
    reqs = []
    rid = 0
    for modality, n in (("image", 3), ("video", 2), ("t2i", 3)):
        for i in range(n):
            kw = {}
            if modality == "t2i":
                # distinct within the 4-token golden truncation, so the
                # session exercises real misses AND a repeat-prompt hit
                kw["prompt_tokens"] = ("cat", "dog")[i % 2]
                if i % 2 == 0:
                    kw["neg_prompt_tokens"] = "bad"
            reqs.append(DiffusionRequest(
                rid, num_steps=num_steps, seed=rid, class_label=i % 3,
                cfg_scale=2.0 if i % 2 == 0 else 0.0, modality=modality,
                **kw))
            rid += 1
    return reqs


@functools.lru_cache(maxsize=1)
def golden_context() -> GoldenContext:
    ctx = GoldenContext()
    try:
        from repro.modalities import MixedModalityEngine
        from .retrace import RetraceSentinel
        from .verify import verify_programs

        engines = build_golden_engines()
        ctx.engines = engines
        for eng in engines.values():
            eng.warmup(verify=True)
        for eng in engines.values():
            ctx.program_findings.extend(eng.ir_findings)

        # prove the sentinel's detection channels work BEFORE trusting a
        # zero from the session (run outside the session sentinel so the
        # probe compile is not counted against serving)
        ctx.sentinel_live = RetraceSentinel().selftest()

        mixed = MixedModalityEngine(engines)
        with RetraceSentinel() as sentinel:
            results = mixed.serve(golden_requests())
        ctx.retrace_count = sentinel.count
        ctx.retrace_names = list(sentinel.compiled_names)
        ctx.requests_served = len(results)
    except Exception as e:  # pragma: no cover - broken checkout
        ctx.error = repr(e)
    return ctx
