"""Jaxpr / lowered-HLO contract checks over captured ProgramIR.

These are the IR-ground-truth versions of contracts the AST layer can only
infer from source text:

  * host callbacks — a `pure_callback` / `io_callback` / `debug_callback`
    (or infeed/outfeed) primitive anywhere in a tick program means every
    dispatch round-trips to the host, silently serializing serving.
  * f64 / weak-type leaks — an f64 const or intermediate doubles memory
    traffic on the hot path; a weak-typed *output* re-promotes whatever
    downstream program consumes it.
  * donation aliasing — `donate_argnums` that fails to alias (shape/dtype
    mismatch between donated input and any output) silently no-ops: the
    "in-place" update still allocates.  The lowered StableHLO is the
    ground truth: actually-aliased args carry a `tf.aliasing_output`
    attribute.
  * const bloat — closed-over arrays become jaxpr consts baked into the
    executable.  An engine declares its model param leaves; any other
    const above the threshold is closure-capture bloat (a table that
    should have been an argument).

Every check returns `IRIssue`s — (category, message, file, line) tuples
the verify layer turns into registry Findings.  Issues carry the eqn's
user-frame source location when jax recorded one, else the program's
python def-site, so inline suppressions keep working.
"""
from __future__ import annotations

import re
from collections import Counter
from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple

__all__ = ["IRIssue", "iter_eqns", "find_host_callbacks", "find_f64",
           "find_const_bloat", "count_aliased_inputs", "check_donation",
           "donation_report", "DEFAULT_CONST_THRESHOLD"]

#: consts above this byte count that are not declared (model params) are
#: flagged as closure-capture bloat; small baked scalars/tables are normal
DEFAULT_CONST_THRESHOLD = 1 << 16        # 64 KiB

#: primitives whose presence in a serving program means a host round trip
HOST_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed"})


@dataclass(frozen=True)
class IRIssue:
    """One contract violation found in a program's IR."""
    category: str                # "host-callback" | "dtype" | ...
    message: str
    file: str = ""               # absolute source path when known
    line: int = 0


def _eqn_site(eqn) -> Tuple[str, int]:
    """User-code (file, line) of one jaxpr equation, when jax recorded a
    source_info trace for it (it usually did)."""
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info.traceback)
        if frame is not None:
            return frame.file_name, frame.start_line
    except Exception:
        pass
    return "", 0


def iter_eqns(closed_jaxpr) -> Iterator:
    """All equations of a ClosedJaxpr, recursing into sub-jaxprs (scan/
    cond/while bodies, inner pjit calls) — a callback hidden inside a
    lax.cond branch is still a callback."""
    stack = [closed_jaxpr.jaxpr]
    while stack:
        jaxpr = stack.pop()
        for eqn in jaxpr.eqns:
            yield eqn
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    stack.append(sub)


def _sub_jaxprs(value) -> List:
    """Extract inner jaxprs from an eqn param value (ClosedJaxpr, bare
    Jaxpr, or a list/tuple of either — `branches` of lax.cond)."""
    out = []
    vals = value if isinstance(value, (list, tuple)) else (value,)
    for v in vals:
        if hasattr(v, "eqns"):                       # bare Jaxpr
            out.append(v)
        elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):  # ClosedJaxpr
            out.append(v.jaxpr)
    return out


# ----------------------------------------------------------------------
def find_host_callbacks(closed_jaxpr) -> List[IRIssue]:
    """Host-callback / infeed / outfeed primitives anywhere in the
    program, sub-jaxprs included."""
    issues = []
    for eqn in iter_eqns(closed_jaxpr):
        name = eqn.primitive.name
        if name in HOST_CALLBACK_PRIMS:
            f, ln = _eqn_site(eqn)
            issues.append(IRIssue(
                "host-callback",
                f"host callback primitive '{name}' in a serving program — "
                f"every dispatch round-trips to the host", f, ln))
    return issues


_WIDE = ("float64", "complex128", "int64")


def find_f64(closed_jaxpr, *, check_weak_outputs: bool = True,
             allow_int64: bool = True) -> List[IRIssue]:
    """f64/c128 values in device code: consts, per-eqn outputs, and
    weak-typed program outputs.

    int64 is tolerated by default (index arithmetic lands there even with
    x64 disabled on some paths); float64 never is — with x64 disabled it
    can only enter via a closed-over f64 numpy table, exactly the
    schedule-table bug class."""
    issues = []
    wide = set(_WIDE) - ({"int64"} if allow_int64 else set())
    for i, c in enumerate(closed_jaxpr.consts):
        dt = str(getattr(c, "dtype", ""))
        if dt in wide:
            issues.append(IRIssue(
                "dtype",
                f"closed-over const #{i} is {dt} "
                f"(shape {tuple(getattr(c, 'shape', ()))}) — a host-side "
                f"wide-dtype table leaked into device code"))
    for eqn in iter_eqns(closed_jaxpr):
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            dt = str(getattr(aval, "dtype", ""))
            if dt in wide:
                f, ln = _eqn_site(eqn)
                issues.append(IRIssue(
                    "dtype",
                    f"'{eqn.primitive.name}' produces {dt} "
                    f"inside the program — wide-dtype promotion on the "
                    f"device path", f, ln))
                break                 # one issue per eqn is enough
    if check_weak_outputs:
        for i, var in enumerate(closed_jaxpr.jaxpr.outvars):
            aval = getattr(var, "aval", None)
            if getattr(aval, "weak_type", False):
                issues.append(IRIssue(
                    "dtype",
                    f"program output #{i} is weak-typed "
                    f"({getattr(aval, 'dtype', '?')}) — it will re-promote "
                    f"in whatever downstream program consumes it"))
    return issues


# ----------------------------------------------------------------------
def _const_spec(c) -> Tuple[Tuple[int, ...], str]:
    return (tuple(getattr(c, "shape", ())), str(getattr(c, "dtype", "")))


def _nbytes(c) -> int:
    nb = getattr(c, "nbytes", None)
    if nb is not None:
        return int(nb)
    size = getattr(c, "size", 0)
    item = getattr(getattr(c, "dtype", None), "itemsize", 1)
    return int(size) * int(item)


def find_const_bloat(closed_jaxpr, declared_specs=(),
                     threshold_bytes: int = DEFAULT_CONST_THRESHOLD
                     ) -> List[IRIssue]:
    """Closed-over consts above `threshold_bytes` that are NOT in the
    declared (shape, dtype-name) multiset — for an engine program the
    declared set is its model param leaves, so a flagged const is some
    other array baked into the executable instead of passed as an
    argument."""
    budget = Counter(tuple(s) if not isinstance(s, tuple) else s
                     for s in declared_specs)
    issues = []
    for i, c in enumerate(closed_jaxpr.consts):
        spec = _const_spec(c)
        if budget[spec] > 0:
            budget[spec] -= 1            # a declared (param) leaf
            continue
        nb = _nbytes(c)
        if nb > threshold_bytes:
            issues.append(IRIssue(
                "const-bloat",
                f"undeclared closed-over const #{i}: shape {spec[0]} "
                f"{spec[1]}, {nb} bytes (> {threshold_bytes}) baked into "
                f"the executable — pass it as an argument instead"))
    return issues


# ----------------------------------------------------------------------
# donation: the lowered StableHLO marks each actually-aliased argument
# with a `tf.aliasing_output = <n> : i32` arg attribute; counting those
# against the donated leaf count exposes silent no-op donations
_ALIAS_RE = re.compile(r"tf\.aliasing_output")


def count_aliased_inputs(lowered_text: str) -> int:
    """Number of program arguments the compiler actually aliased to an
    output (donated buffers that really update in place)."""
    return len(_ALIAS_RE.findall(lowered_text))


def donation_report(jitted, *args, **kwargs) -> dict:
    """Lower a jit'd-with-donation function on example args and report how
    many inputs actually aliased.  The caller compares `aliased` with the
    leaf count of what it donated."""
    text = jitted.lower(*args, **kwargs).as_text()
    return {"aliased": count_aliased_inputs(text)}


def check_donation(lowered_text: str, donated_leaves: int,
                   label: str = "program") -> Optional[IRIssue]:
    """None when every donated leaf aliased; an issue otherwise (including
    the claimed-but-zero case — donation that silently no-ops)."""
    if donated_leaves <= 0:
        return None
    aliased = count_aliased_inputs(lowered_text)
    if aliased >= donated_leaves:
        return None
    return IRIssue(
        "donation",
        f"{label}: donate_argnums claimed {donated_leaves} donated "
        f"buffer leaves but the compiled program aliases only {aliased} — "
        f"the un-aliased leaves still allocate (donation silently no-ops, "
        f"usually a pytree/argnum or shape/dtype mismatch)")
