"""Pallas kernel lints: structural checks on every `pl.pallas_call` in
src/repro/kernels/, without executing a single kernel.

The kernels ship with `interpret=True` on CPU, so a malformed BlockSpec
often *runs* (the interpreter is forgiving) and only explodes on a real
TPU.  These lints catch the TPU-fatal classes statically:

  * grid sanity — a tuple of positive ints;
  * BlockSpec arity — block_shape rank == operand rank, index_map takes
    exactly len(grid) args and returns one index per block dim;
  * divisibility — every integer block dim divides its operand dim (the
    kernels pad/cap so this must hold; a remainder tile is silent garbage
    on TPU);
  * dtype consistency — no f64 operands/outputs, and all floating
    operands of one call agree (an f32/bf16 mix inside one kernel is
    almost always an accidental upcast on the MXU path).

Mechanics: each kernel wrapper is invoked on representative driver shapes
with `pl.pallas_call` monkeypatched to *capture* (grid, specs, out_shape,
operand avals) and return zeros — the checks then run on the captured
call descriptions.  Findings anchor on the wrapper's call site inside
src/repro/kernels/.
"""
from __future__ import annotations

import inspect
import traceback
from contextlib import contextmanager
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from .jaxpr_checks import IRIssue

__all__ = ["PallasCallCapture", "intercept_pallas_calls", "check_capture",
           "lint_pallas_kernels", "KERNEL_DRIVERS"]


@dataclass
class PallasCallCapture:
    """One intercepted pl.pallas_call: everything the checks need."""
    kernel_name: str
    grid: object
    in_specs: Sequence
    out_specs: object
    out_shape: object
    operands: Tuple = ()               # ShapeDtypeStruct-likes per operand
    file: str = ""                     # call site inside kernels/
    line: int = 0


def _call_site() -> Tuple[str, int]:
    for frame in reversed(traceback.extract_stack()):
        fn = frame.filename.replace("\\", "/")
        if "/kernels/" in fn and not fn.endswith("pallas_lint.py"):
            return frame.filename, frame.lineno
    return "", 0


@contextmanager
def intercept_pallas_calls(records: List[PallasCallCapture]):
    """Monkeypatch jax.experimental.pallas.pallas_call to record each call
    and return correctly-shaped zeros instead of building the kernel —
    the wrappers run end to end with no Pallas lowering at all."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    real = pl.pallas_call

    def fake_pallas_call(kernel, *call_args, grid=None, in_specs=None,
                         out_specs=None, out_shape=None, **kwargs):
        cap = PallasCallCapture(
            kernel_name=getattr(kernel, "__name__", None) or getattr(
                getattr(kernel, "func", None), "__name__", "<kernel>"),
            grid=grid, in_specs=in_specs or (), out_specs=out_specs,
            out_shape=out_shape)
        cap.file, cap.line = _call_site()
        records.append(cap)

        def run(*operands):
            cap.operands = tuple(
                jax.ShapeDtypeStruct(o.shape, o.dtype) for o in operands)
            shapes = (out_shape if isinstance(out_shape, (list, tuple))
                      else [out_shape])
            outs = [jnp.zeros(s.shape, s.dtype) for s in shapes]
            return outs if isinstance(out_shape, (list, tuple)) else outs[0]

        return run

    pl.pallas_call = fake_pallas_call
    try:
        yield records
    finally:
        pl.pallas_call = real


# ----------------------------------------------------------------------
def _norm_grid(grid) -> Optional[Tuple[int, ...]]:
    if grid is None:
        return ()
    if isinstance(grid, int):
        return (grid,)
    try:
        return tuple(int(g) for g in grid)
    except (TypeError, ValueError):
        return None


def _norm_specs(specs) -> List:
    if specs is None:
        return []
    return list(specs) if isinstance(specs, (list, tuple)) else [specs]


def _check_spec(cap: PallasCallCapture, role: str, spec, operand,
                grid: Tuple[int, ...], issues: List[IRIssue]) -> None:
    name = cap.kernel_name

    def issue(msg):
        issues.append(IRIssue("pallas", f"{name}: {role}: {msg}",
                              cap.file, cap.line))

    block = getattr(spec, "block_shape", None)
    if block is None:                     # whole-array spec — nothing to do
        return
    shape = tuple(operand.shape)
    if len(block) != len(shape):
        issue(f"block_shape rank {len(block)} != operand rank "
              f"{len(shape)} (operand {shape})")
        return
    for d, (b, s) in enumerate(zip(block, shape)):
        if b is None:
            continue                      # squeezed singleton dim
        b = int(b)
        if b <= 0:
            issue(f"block dim {d} is {b} (must be positive)")
        elif s % b != 0:
            issue(f"block dim {d} = {b} does not divide operand dim "
                  f"{s} — the remainder tile is silent garbage on TPU")
    index_map = getattr(spec, "index_map", None)
    if index_map is None:
        return
    try:
        arity = len(inspect.signature(index_map).parameters)
    except (TypeError, ValueError):
        return
    if arity != len(grid):
        issue(f"index_map takes {arity} args but the grid has "
              f"{len(grid)} dims")
        return
    try:
        idx = index_map(*(0 for _ in grid))
    except Exception as e:
        issue(f"index_map raised on zero indices: {e!r}")
        return
    idx = idx if isinstance(idx, tuple) else (idx,)
    if len(idx) != len(block):
        issue(f"index_map returns {len(idx)} indices for a "
              f"{len(block)}-dim block_shape")


def check_capture(cap: PallasCallCapture) -> List[IRIssue]:
    """All structural checks over one captured pallas_call."""
    issues: List[IRIssue] = []
    name = cap.kernel_name
    grid = _norm_grid(cap.grid)
    if grid is None:
        issues.append(IRIssue(
            "pallas", f"{name}: grid {cap.grid!r} is not a tuple of ints",
            cap.file, cap.line))
        return issues
    if any(g <= 0 for g in grid):
        issues.append(IRIssue(
            "pallas", f"{name}: grid {grid} has a non-positive dim",
            cap.file, cap.line))

    in_specs = _norm_specs(cap.in_specs)
    if in_specs and len(in_specs) != len(cap.operands):
        issues.append(IRIssue(
            "pallas", f"{name}: {len(in_specs)} in_specs for "
            f"{len(cap.operands)} operands", cap.file, cap.line))
    for i, (spec, op) in enumerate(zip(in_specs, cap.operands)):
        _check_spec(cap, f"in_specs[{i}]", spec, op, grid, issues)

    out_shapes = (cap.out_shape if isinstance(cap.out_shape, (list, tuple))
                  else [cap.out_shape])
    out_specs = _norm_specs(cap.out_specs)
    for i, (spec, sh) in enumerate(zip(out_specs, out_shapes)):
        _check_spec(cap, f"out_specs[{i}]", spec, sh, grid, issues)

    # dtype consistency: no f64 anywhere; floating operand dtypes agree
    float_dtypes = set()
    for i, op in enumerate(tuple(cap.operands) + tuple(out_shapes)):
        dt = str(op.dtype)
        if dt in ("float64", "complex128"):
            issues.append(IRIssue(
                "pallas", f"{name}: operand/output #{i} is {dt} — wide "
                f"dtypes have no TPU tile layout", cap.file, cap.line))
        if dt.startswith(("float", "bfloat")):
            float_dtypes.add(dt)
    if len(float_dtypes) > 1:
        issues.append(IRIssue(
            "pallas", f"{name}: mixed floating dtypes "
            f"{sorted(float_dtypes)} in one kernel call — accidental "
            f"upcast on the MXU path", cap.file, cap.line))
    return issues


# ----------------------------------------------------------------------
# representative driver shapes per repo kernel: small but structurally
# faithful (GQA group > 1 for flash attention, multi-chunk scan for ssd,
# padded tail for forecast) so the specs exercise their real index maps
def _drive_flash_attention():
    import jax.numpy as jnp
    from repro.kernels.flash_attention.flash_attention import (
        flash_attention_pallas)
    q = jnp.zeros((2, 256, 4, 64), jnp.float32)
    kv = jnp.zeros((2, 256, 2, 64), jnp.float32)
    flash_attention_pallas(q, kv, kv, causal=True, block_q=128, block_k=128)


def _drive_forecast():
    import jax.numpy as jnp
    from repro.kernels.forecast.forecast import forecast_pallas
    diffs = jnp.zeros((4, 8, 8, 7), jnp.float32)    # pads 448 -> 512
    coeffs = jnp.zeros((4,), jnp.float32)
    forecast_pallas(diffs, coeffs, block_n=512)


def _drive_ssd():
    import jax.numpy as jnp
    from repro.kernels.ssd.ssd import ssd_pallas
    x = jnp.zeros((1, 128, 2, 8), jnp.float32)
    dt = jnp.zeros((1, 128, 2), jnp.float32)
    A = jnp.zeros((2,), jnp.float32)
    B_ = jnp.zeros((1, 128, 4), jnp.float32)
    ssd_pallas(x, dt, A, B_, B_, chunk=64)          # 2 chunks


KERNEL_DRIVERS = {
    "flash_attention": _drive_flash_attention,
    "forecast": _drive_forecast,
    "ssd": _drive_ssd,
}


def lint_pallas_kernels() -> List[IRIssue]:
    """Run every repo kernel's driver under interception and check each
    captured pallas_call.  A driver that errors (import break, wrapper
    crash) is itself a finding — a kernel the lint cannot reach is not a
    kernel the lint vouches for."""
    issues: List[IRIssue] = []
    for name, driver in sorted(KERNEL_DRIVERS.items()):
        records: List[PallasCallCapture] = []
        try:
            with intercept_pallas_calls(records):
                driver()
        except Exception as e:
            issues.append(IRIssue(
                "pallas", f"{name}: driver failed under interception "
                f"({e!r}) — kernel unlintable"))
            continue
        if not records:
            issues.append(IRIssue(
                "pallas", f"{name}: driver made no pallas_call — the "
                f"kernel entry point no longer reaches Pallas"))
        for cap in records:
            issues.extend(check_capture(cap))
    return issues
