"""Retrace sentinel: count jit cache misses (XLA backend compiles) inside
a scope.

The engine's whole performance model assumes `warmup()` compiles the
complete program set and steady-state serving never traces again — a
silent retrace (a shape-varying operand, a python-object hash change, a
new bucket size warmup missed) pays an XLA compile inside a live tick.
The AST layer cannot see this; the runtime can:

  * `jax.monitoring` fires one `/jax/core/compile/backend_compile_duration`
    duration event per *actual backend compile* — cache hits fire nothing.
    That count is authoritative.
  * jax logs "Compiling <fn> with global shapes..." per compile on the
    `jax._src.interpreters.pxla` logger; the sentinel attaches a handler
    to capture the names, so a failure says WHICH program retraced.

jax.monitoring has no public unregister, so one module-level listener is
registered on first use and fans out to a stack of active sentinels —
nesting works, and an inactive sentinel costs one set-membership check
per compile event (i.e. nothing at steady state, where no compiles
happen).
"""
from __future__ import annotations

import logging
from typing import List

__all__ = ["RetraceSentinel"]

_COMPILE_EVENT_SUFFIX = "backend_compile"
_PXLA_LOGGER = "jax._src.interpreters.pxla"

_ACTIVE: List["RetraceSentinel"] = []
_LISTENER_REGISTERED = False


def _on_duration(event: str, duration: float, **kwargs) -> None:
    if _COMPILE_EVENT_SUFFIX in event:
        for sentinel in _ACTIVE:
            sentinel._event_count += 1


def _ensure_listener() -> None:
    global _LISTENER_REGISTERED
    if _LISTENER_REGISTERED:
        return
    from jax import monitoring
    monitoring.register_event_duration_secs_listener(_on_duration)
    _LISTENER_REGISTERED = True


class _NameCapture(logging.Handler):
    """Collects the '<fn>' out of pxla's 'Compiling <fn> ...' records."""

    def __init__(self, sink: List[str]):
        super().__init__(level=logging.DEBUG)
        self.sink = sink

    def emit(self, record: logging.LogRecord) -> None:
        try:
            msg = record.getMessage()
        except Exception:
            return
        if msg.startswith("Compiling "):
            self.sink.append(msg.split()[1])


class RetraceSentinel:
    """Context manager counting jit cache misses in its scope.

    >>> with RetraceSentinel() as s:
    ...     session.tick()
    >>> s.count, s.compiled_names
    (0, [])

    `count` is the number of backend compiles (monitoring events — or the
    captured-name count if the logging channel saw more, so neither
    channel regressing can blind the sentinel); `compiled_names` best-
    effort names the programs that compiled.  `ok` is `count == 0`."""

    def __init__(self):
        self._event_count = 0
        self.compiled_names: List[str] = []
        self._handler = None
        self._prev_level = None
        self._prev_propagate = None

    def __enter__(self) -> "RetraceSentinel":
        _ensure_listener()
        logger = logging.getLogger(_PXLA_LOGGER)
        self._prev_level = logger.level
        self._prev_propagate = logger.propagate
        # pxla logs compile names at DEBUG; raise the logger for the scope
        # (the handler filters to 'Compiling ...' records only) without
        # propagating DEBUG spam to the root handlers, and restore on exit
        logger.setLevel(logging.DEBUG)
        logger.propagate = False
        self._handler = _NameCapture(self.compiled_names)
        logger.addHandler(self._handler)
        _ACTIVE.append(self)
        return self

    def __exit__(self, *exc) -> None:
        _ACTIVE.remove(self)
        logger = logging.getLogger(_PXLA_LOGGER)
        logger.removeHandler(self._handler)
        logger.setLevel(self._prev_level)
        logger.propagate = self._prev_propagate
        return None

    @property
    def count(self) -> int:
        return max(self._event_count, len(self.compiled_names))

    @property
    def ok(self) -> bool:
        return self.count == 0

    def selftest(self) -> bool:
        """True when the sentinel's channels actually detect a compile: a
        fresh jit function is dispatched under a nested sentinel, which
        must count >= 1.  Guards against a jax upgrade silently renaming
        the monitoring event AND the log message — a blind sentinel would
        otherwise report a vacuous zero forever."""
        import jax
        import jax.numpy as jnp
        with RetraceSentinel() as probe:
            # a fresh function object per call -> guaranteed cache miss
            jax.jit(lambda x: x * 2.0 + 1.0)(jnp.zeros((3, 5, 7)))
        return probe.count >= 1
