"""repro.analysis.ir — IR-level program verification.

Where `repro.analysis.rules` lints *source text*, this subpackage lints
the *compiled artifacts*: the jaxprs and lowered StableHLO of the
engine's warmup-compiled program set, the jit cache's steady-state
behavior under a golden serving session, and the structural validity of
every Pallas kernel call.  Source-level taint analysis is a heuristic;
the jaxpr is ground truth.

  jaxpr_checks   recursive eqn walks: host callbacks, f64/weak-type
                 leaks, const bloat, donation aliasing (lowered text)
  verify         `verify_programs(engine)` -> registry Findings over
                 every warmup-compiled program
  retrace        RetraceSentinel: count jit cache misses in a scope
                 (jax.monitoring backend-compile events + pxla compile
                 logs for program names)
  pallas_lint    grid/BlockSpec/index-map/dtype checks over every
                 pl.pallas_call in src/repro/kernels, via interception
  golden         the cached lint-time fixture: tiny image+video engines,
                 verified + served under the sentinel

Everything surfaces through the ordinary rule registry as the six
`ir-*` rules (`repro-lint --rule 'ir-*'`), and through
`engine.warmup(verify=True)` at runtime.
"""
from .jaxpr_checks import (DEFAULT_CONST_THRESHOLD, HOST_CALLBACK_PRIMS,
                           IRIssue, check_donation, count_aliased_inputs,
                           donation_report, find_const_bloat, find_f64,
                           find_host_callbacks, iter_eqns)
from .pallas_lint import (PallasCallCapture, check_capture,
                          intercept_pallas_calls, lint_pallas_kernels)
from .retrace import RetraceSentinel
from .verify import (issue_to_finding, param_leaf_specs, verify_programs,
                     verify_programs_by_key)

__all__ = [
    "DEFAULT_CONST_THRESHOLD", "HOST_CALLBACK_PRIMS", "IRIssue",
    "check_donation", "count_aliased_inputs", "donation_report",
    "find_const_bloat", "find_f64", "find_host_callbacks", "iter_eqns",
    "PallasCallCapture", "check_capture", "intercept_pallas_calls",
    "lint_pallas_kernels",
    "RetraceSentinel",
    "issue_to_finding", "param_leaf_specs", "verify_programs",
    "verify_programs_by_key",
]
