"""verify_programs: run every IR contract check over an engine's
warmup-compiled program set and return registry Findings.

The engine captures a ProgramIR per program (bucket sizes, dense tick
kinds, the fused want pass) either during `warmup(verify=True)` or on
demand via `engine._capture_program_ir()`; this module walks them:

  ir-host-callback   no pure_/io_/debug_callback or infeed/outfeed
  ir-dtype           no f64/c128 consts or intermediates, no weak-typed
                     outputs; also checks the engine's schedule tables
  ir-donation        donate_argnums claims actually alias (engine
                     programs donate nothing today, so this validates
                     the claim-vs-alias bookkeeping stays consistent)
  ir-const-bloat     consts == the declared model param leaves; any
                     other const > threshold is closure-capture bloat

Findings anchor on the eqn's user-frame source line when jax recorded
one (so `# repro-lint: disable=ir-*` inline suppressions work), else on
the program's python def-site.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Tuple

from ..base import Finding
from .jaxpr_checks import (DEFAULT_CONST_THRESHOLD, IRIssue, find_const_bloat,
                           find_f64, find_host_callbacks)

__all__ = ["verify_programs", "verify_programs_by_key", "issue_to_finding",
           "param_leaf_specs"]

_CATEGORY_RULE = {
    "host-callback": "ir-host-callback",
    "dtype": "ir-dtype",
    "donation": "ir-donation",
    "const-bloat": "ir-const-bloat",
    "pallas": "ir-pallas",
    "retrace": "ir-retrace",
}


def _repo_root(root: Optional[str]) -> str:
    if root:
        return root
    from ..runner import find_repo_root
    return find_repo_root()


def _read_line(root: str, relpath: str, line: int) -> str:
    try:
        with open(os.path.join(root, relpath), encoding="utf-8") as f:
            lines = f.read().splitlines()
        return lines[line - 1].strip() if 0 < line <= len(lines) else ""
    except OSError:
        return ""


def issue_to_finding(issue: IRIssue, root: str, *,
                     fallback_file: str = "", fallback_line: int = 0,
                     prefix: str = "") -> Finding:
    """IRIssue -> registry Finding, anchored on a repo-relative source
    line so fingerprints/suppressions behave exactly like AST findings."""
    file, line = issue.file, issue.line
    if not file:
        file, line = fallback_file, fallback_line
    rel = ""
    if file:
        try:
            rel = os.path.relpath(file, root).replace(os.sep, "/")
        except ValueError:
            rel = file.replace(os.sep, "/")
    if not rel or rel.startswith(".."):
        # source outside the repo (jax internals) — anchor on the repo
        # file the caller named, or a stable placeholder
        rel, line = fallback_file and os.path.relpath(
            fallback_file, root).replace(os.sep, "/") or "src/repro", 1
    rule = _CATEGORY_RULE.get(issue.category, f"ir-{issue.category}")
    return Finding(rule, rel, max(int(line), 1), 0,
                   (prefix + issue.message) if prefix else issue.message,
                   snippet=_read_line(root, rel, max(int(line), 1)))


def param_leaf_specs(params) -> Tuple[Tuple[tuple, str], ...]:
    """(shape, dtype-name) multiset of a param pytree's leaves — the
    consts an engine program is *supposed* to close over."""
    import jax
    return tuple(
        (tuple(getattr(leaf, "shape", ())), str(getattr(leaf, "dtype", "")))
        for leaf in jax.tree_util.tree_leaves(params))


def _engine_level_issues(engine) -> List[IRIssue]:
    """Checks on engine-owned host tables that feed the programs: the
    noise-schedule tables are gathered into every tick, so an f64 table
    re-promotes per-request DDIM coefficients off the f32 path."""
    issues = []
    sched = getattr(engine, "sched", None)
    for name in ("betas", "alpha_bars"):
        tab = getattr(sched, name, None)
        dt = str(getattr(tab, "dtype", ""))
        if dt == "float64":
            issues.append(IRIssue(
                "dtype",
                f"engine noise schedule table '{name}' is float64 — cast "
                f"to float32 at the NoiseSchedule boundary"))
    return issues


def verify_programs_by_key(engine, *, root: Optional[str] = None,
                           const_threshold: int = DEFAULT_CONST_THRESHOLD
                           ) -> Dict[object, List[Finding]]:
    """All IR findings for one engine, grouped by program key ("__engine__"
    for engine-level table checks).  Warms + captures IR as needed."""
    root = _repo_root(root)
    program_ir = engine._capture_program_ir()
    by_key: Dict[object, List[Finding]] = {}
    for key, ir in sorted(program_ir.items(), key=lambda kv: str(kv[0])):
        issues = []
        issues += find_host_callbacks(ir.jaxpr)
        issues += find_f64(ir.jaxpr)
        issues += find_const_bloat(ir.jaxpr, ir.declared_const_specs,
                                   const_threshold)
        # engine programs donate nothing today; an aliasing attr showing
        # up anyway would mean the jit wrappers grew donation the engine
        # does not account for — surface it rather than ignore it
        from .jaxpr_checks import count_aliased_inputs
        aliased = count_aliased_inputs(ir.lowered_text)
        if aliased:
            issues.append(IRIssue(
                "donation",
                f"program aliases {aliased} input(s) but the engine "
                f"declares no donation — buffer reuse the slot pool does "
                f"not account for"))
        if issues:
            by_key[key] = [
                issue_to_finding(i, root, fallback_file=ir.fn_file,
                                 fallback_line=ir.fn_line,
                                 prefix=f"[program {key!r}] ")
                for i in issues]
    eng_issues = _engine_level_issues(engine)
    if eng_issues:
        import inspect
        try:
            sched_file = inspect.getsourcefile(type(engine.sched))
            sched_line = inspect.getsourcelines(type(engine.sched))[1]
        except Exception:
            sched_file, sched_line = "", 0
        by_key["__engine__"] = [
            issue_to_finding(i, root, fallback_file=sched_file or "",
                             fallback_line=sched_line)
            for i in eng_issues]
    return by_key


def verify_programs(engine, *, root: Optional[str] = None,
                    const_threshold: int = DEFAULT_CONST_THRESHOLD
                    ) -> List[Finding]:
    """Flat list of IR findings over every warmup-compiled program of
    `engine` (plus engine-level table checks).  Empty == verified clean."""
    by_key = verify_programs_by_key(engine, root=root,
                                    const_threshold=const_threshold)
    return [f for _, fs in sorted(by_key.items(), key=lambda kv: str(kv[0]))
            for f in fs]
