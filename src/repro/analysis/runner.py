"""Walk files, dispatch rules, apply suppressions and the baseline."""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from .base import (Finding, ProjectRule, Rule, all_rules,
                   assign_fingerprints)
from .baseline import DEFAULT_BASELINE, Baseline
from .source import ModuleSource

_SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules"}


def find_repo_root(start: Optional[str] = None) -> str:
    """Nearest ancestor of `start` (default: cwd) that looks like this
    repo (has src/repro); falls back to the package's own checkout so
    `repro-lint` works from anywhere inside it."""
    cur = os.path.abspath(start or os.getcwd())
    while True:
        if os.path.isdir(os.path.join(cur, "src", "repro")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            break
        cur = parent
    # installed-package fallback: .../src/repro/analysis/runner.py -> repo
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def _walk_python_files(root: str, paths: Sequence[str]) -> List[str]:
    out = []
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(ap) and ap.endswith(".py"):
            out.append(ap)
        elif os.path.isdir(ap):
            for dirpath, dirnames, filenames in os.walk(ap):
                dirnames[:] = [d for d in sorted(dirnames)
                               if d not in _SKIP_DIRS
                               and not d.startswith(".")]
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        out.append(os.path.join(dirpath, fn))
    return out


@dataclass
class RunResult:
    """Everything one analysis run produced."""
    findings: List[Finding] = field(default_factory=list)   # actionable
    suppressed: List[Finding] = field(default_factory=list)
    baselined: List[Finding] = field(default_factory=list)
    stale_baseline: List[Dict] = field(default_factory=list)
    files_scanned: int = 0
    rules: List[str] = field(default_factory=list)
    root: str = ""

    @property
    def exit_code(self) -> int:
        return 1 if self.findings else 0


def run_analysis(root: Optional[str] = None,
                 paths: Optional[Sequence[str]] = None,
                 rules: Optional[Sequence[Rule]] = None,
                 baseline_path: Optional[str] = None,
                 force_scope: bool = False) -> RunResult:
    """Run `rules` (default: all registered) over `paths` (default:
    src/repro) under `root` (default: auto-detected repo root).

    force_scope=True applies every selected AST rule to every scanned file
    regardless of its `trees` scope — what fixture tests use to lint
    snippets living outside the real tree layout.

    Suppressed findings are filtered per line; baseline-matched findings
    are filtered by fingerprint; everything is reported in the result so
    the JSON artifact stays auditable."""
    root = os.path.abspath(root or find_repo_root())
    selected = list(rules if rules is not None else all_rules())
    paths = list(paths or [os.path.join("src", "repro")])

    ast_rules = [r for r in selected if not isinstance(r, ProjectRule)]
    project_rules = [r for r in selected if isinstance(r, ProjectRule)]

    raw: List[Finding] = []
    files = _walk_python_files(root, paths)
    modules: List[ModuleSource] = []
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        mod = ModuleSource.from_file(path, rel)
        modules.append(mod)
        if mod.parse_error is not None:
            e = mod.parse_error
            raw.append(Finding("syntax-error", rel, e.lineno or 1,
                               e.offset or 0, f"file does not parse: "
                               f"{e.msg}"))
            continue
        for rule in ast_rules:
            if force_scope or rule.applies_to(rel):
                raw.extend(rule.check_module(mod))

    for rule in project_rules:
        raw.extend(rule.check_project(root))

    raw.sort(key=lambda f: f.key())
    assign_fingerprints(raw)

    by_rel = {m.relpath: m for m in modules}
    kept, suppressed = [], []
    for f in raw:
        mod = by_rel.get(f.path)
        if mod is not None and mod.suppressed(f.line, f.rule):
            suppressed.append(f)
        else:
            kept.append(f)

    bl = Baseline.load(baseline_path if baseline_path is not None
                       else os.path.join(root, DEFAULT_BASELINE))
    actionable = [f for f in kept if not bl.match(f)]
    baselined = [f for f in kept if bl.match(f)]

    return RunResult(
        findings=actionable, suppressed=suppressed, baselined=baselined,
        stale_baseline=bl.stale(kept), files_scanned=len(files),
        rules=[r.id for r in selected], root=root)
