"""Command-line entry point: `python -m repro.analysis` / `repro-lint`.

Exit status: 0 when every finding is suppressed or baselined, 1 otherwise
(what the CI step keys on), 2 on usage errors.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from .base import all_rules, get_rule
from .baseline import Baseline
from .report import to_text, write_json
from .runner import find_repo_root, run_analysis


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro-lint",
        description="AST lint for the repro serving stack's JAX discipline "
                    "(host syncs, clock sources, PRNG keys, jit hygiene, "
                    "pytree registration, policy-registry contracts)")
    p.add_argument("paths", nargs="*",
                   help="files/dirs to lint (default: src/repro)")
    p.add_argument("--rule", action="append", dest="rules", metavar="ID",
                   help="run only this rule (repeatable; glob patterns "
                        "like 'ir-*' expand against registered ids)")
    p.add_argument("--root", default=None,
                   help="repo root (default: auto-detect from cwd)")
    p.add_argument("--baseline", default=None, metavar="FILE",
                   help="baseline file (default: tools/lint_baseline.json)")
    p.add_argument("--write-baseline", action="store_true",
                   help="grandfather current findings into the baseline "
                        "file and exit 0")
    p.add_argument("--json", default=None, metavar="FILE",
                   help="also write the full JSON report to FILE "
                        "('-' for stdout)")
    p.add_argument("--no-scope", action="store_true",
                   help="apply every rule to every file, ignoring per-rule "
                        "tree scoping (fixture/debug use)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule table and exit")
    p.add_argument("-q", "--quiet", action="store_true",
                   help="suppress the text report (exit status only)")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="also list suppressed findings")
    return p


def resolve_rules(patterns: List[str]) -> List:
    """Rule ids / glob patterns -> rule objects.  A pattern matching
    nothing is an error, not a silent no-op lint."""
    import fnmatch
    out, seen = [], set()
    for pat in patterns:
        if any(ch in pat for ch in "*?["):
            matched = [r for r in all_rules()
                       if fnmatch.fnmatchcase(r.id, pat)]
            if not matched:
                raise KeyError(f"--rule pattern '{pat}' matches no "
                               f"registered rule")
            for r in matched:
                if r.id not in seen:
                    seen.add(r.id)
                    out.append(r)
        else:
            r = get_rule(pat)
            if r.id not in seen:
                seen.add(r.id)
                out.append(r)
    return out


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for r in all_rules():
            print(f"{r.id:32s} {r.description}")
        return 0

    try:
        rules = resolve_rules(args.rules) if args.rules else None
    except KeyError as e:
        print(e.args[0], file=sys.stderr)
        return 2

    root = args.root or find_repo_root()
    result = run_analysis(root=root, paths=args.paths or None, rules=rules,
                          baseline_path=args.baseline,
                          force_scope=args.no_scope)

    if args.write_baseline:
        import os
        from .baseline import DEFAULT_BASELINE
        path = args.baseline or os.path.join(root, DEFAULT_BASELINE)
        # grandfather what is currently actionable on top of what is
        # already baselined, so rewriting is idempotent
        Baseline.write(path, result.findings + result.baselined,
                       justification="grandfathered; justify or fix")
        print(f"wrote {len(result.findings) + len(result.baselined)} "
              f"finding(s) to {path}")
        return 0

    if args.json == "-":
        import json as _json
        from .report import to_json
        print(_json.dumps(to_json(result), indent=2))
    elif args.json:
        write_json(result, args.json)

    if not args.quiet:
        print(to_text(result, verbose=args.verbose))
    return result.exit_code


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
