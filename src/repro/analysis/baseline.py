"""Checked-in baseline of grandfathered findings.

The baseline lets a new rule land while a known, justified finding is
still being worked off: matched findings don't fail the run but stay
visible in the JSON report.  Every entry must carry a `justification` —
an unexplained baseline entry is just a muted bug.

Matching is by content fingerprint (see base.assign_fingerprints), so the
baseline survives line-number drift but NOT edits to the offending line
itself: touching a grandfathered line re-surfaces its finding, which is
exactly when it should be fixed.

Entries whose fingerprint no longer matches anything are reported as
stale (the finding was fixed — delete the entry) without failing the run.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Sequence

from .base import Finding

#: default baseline location, relative to the repo root
DEFAULT_BASELINE = os.path.join("tools", "lint_baseline.json")


@dataclass
class Baseline:
    path: str = ""
    #: fingerprint -> entry dict ({"rule", "path", "fingerprint",
    #: "justification"})
    entries: Dict[str, Dict] = field(default_factory=dict)

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls(path=path)
        with open(path, encoding="utf-8") as f:
            data = json.load(f)
        entries = {}
        for e in data.get("findings", []):
            fp = e.get("fingerprint", "")
            if fp:
                entries[fp] = e
        return cls(path=path, entries=entries)

    def match(self, finding: Finding) -> bool:
        return finding.fingerprint in self.entries

    def stale(self, findings: Sequence[Finding]) -> List[Dict]:
        """Baseline entries no longer matched by any current finding."""
        live = {f.fingerprint for f in findings}
        return [e for fp, e in sorted(self.entries.items())
                if fp not in live]

    @staticmethod
    def write(path: str, findings: Sequence[Finding],
              justification: str = "grandfathered at baseline creation"
              ) -> None:
        data = {
            "comment": ("repro.analysis baseline — grandfathered findings. "
                        "Every entry needs a justification; prefer fixing "
                        "or an inline `# repro-lint: disable=` with a "
                        "reason. Regenerate: "
                        "python -m repro.analysis --write-baseline"),
            "findings": [
                {"rule": f.rule, "path": f.path, "snippet": f.snippet,
                 "fingerprint": f.fingerprint,
                 "justification": justification}
                for f in sorted(findings, key=lambda f: f.key())
            ],
        }
        with open(path, "w", encoding="utf-8") as f:
            json.dump(data, f, indent=2)
            f.write("\n")
