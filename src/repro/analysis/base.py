"""Rule protocol, findings, and the rule registry.

A rule is a stateless object with an `id`, a `trees` scope (repo-relative
path prefixes it lints — scoping is what keeps the host-sync rule out of
benchmarks/ where a blocking transfer is the whole point), and one of two
check surfaces:

  * `Rule.check_module(module)` — AST rules, called once per parsed file
    in scope.
  * `ProjectRule.check_project(root)` — whole-project rules (import-time
    introspection passes), called once per run.

Findings carry a content fingerprint (rule + path + normalized source
line + occurrence index) so the baseline survives line-number drift: an
unrelated edit above a grandfathered finding must not resurrect it.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from .source import ModuleSource


@dataclass
class Finding:
    """One rule violation at one source location."""
    rule: str
    path: str                   # repo-relative, "/" separators
    line: int
    col: int
    message: str
    snippet: str = ""           # the offending source line, stripped
    fingerprint: str = ""       # stable id for baseline matching

    def key(self):
        return (self.path, self.line, self.col, self.rule)

    def to_dict(self) -> Dict:
        return {
            "rule": self.rule, "path": self.path, "line": self.line,
            "col": self.col, "message": self.message,
            "snippet": self.snippet, "fingerprint": self.fingerprint,
        }


def _normalize(line: str) -> str:
    return " ".join(line.split())


def assign_fingerprints(findings: Sequence[Finding]) -> None:
    """Stable content fingerprints: hash(rule | path | normalized line |
    occurrence index among identical lines).  Line numbers are deliberately
    excluded so edits elsewhere in the file don't invalidate a baseline."""
    seen: Dict[tuple, int] = {}
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.col)):
        base = (f.rule, f.path, _normalize(f.snippet))
        occ = seen.get(base, 0)
        seen[base] = occ + 1
        raw = "|".join((f.rule, f.path, _normalize(f.snippet), str(occ)))
        f.fingerprint = hashlib.sha256(raw.encode()).hexdigest()[:16]


class Rule:
    """Base class for per-module AST rules."""

    #: rule id — what `# repro-lint: disable=<id>` and `--rule <id>` name
    id: str = "base"
    #: one-line description (rule table in README / --list-rules)
    description: str = ""
    #: why the rule matters for cached serving (README rationale column)
    rationale: str = ""
    #: repo-relative path prefixes this rule lints ("/" separators);
    #: empty = every linted file
    trees: Sequence[str] = ()

    def applies_to(self, relpath: str) -> bool:
        if not self.trees:
            return True
        return any(relpath.startswith(t) for t in self.trees)

    def check_module(self, module: ModuleSource) -> List[Finding]:
        raise NotImplementedError

    def finding(self, module: ModuleSource, line: int, col: int,
                message: str) -> Finding:
        return Finding(self.id, module.relpath, line, col, message,
                       snippet=module.line(line).strip())


class ProjectRule(Rule):
    """A rule that inspects the project as a whole (e.g. imports the
    policy registry) instead of walking per-file ASTs."""

    def check_module(self, module: ModuleSource) -> List[Finding]:
        return []

    def check_project(self, root: str) -> List[Finding]:
        raise NotImplementedError


_REGISTRY: Dict[str, Rule] = {}


def register(rule_cls):
    """Class decorator: instantiate and register a rule by its id."""
    rule = rule_cls()
    if rule.id in _REGISTRY:
        raise ValueError(f"duplicate rule id '{rule.id}'")
    _REGISTRY[rule.id] = rule
    return rule_cls


def all_rules() -> List[Rule]:
    from . import rules  # noqa: F401  (import populates the registry)
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


def get_rule(rule_id: str) -> Rule:
    from . import rules  # noqa: F401
    if rule_id not in _REGISTRY:
        raise KeyError(f"unknown rule '{rule_id}'; "
                       f"available: {sorted(_REGISTRY)}")
    return _REGISTRY[rule_id]
