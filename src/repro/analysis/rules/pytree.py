"""pytree-registration: dataclass instances handed to jitted callables.

A plain `@dataclass` is an opaque leaf to JAX: passing one into a jitted
function either throws at trace time or — worse, with static hashable
fields — silently retraces per instance.  Any dataclass that flows into a
jitted program must be registered (`jax.tree_util.register_dataclass`,
`register_pytree_node`, or the `register_pytree_node_class` decorator).

Heuristic scope: the rule fires when, within one module, it can see all
three of (a) the dataclass definition, (b) a jitted callable (a `jax.jit`
decorated def or a name assigned from `jax.jit(...)`), and (c) an
instance of (a) passed as an argument at a call of (b) — and no
registration for the class anywhere in the module.  Cross-module flows
are out of scope (bias to no false positives).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from ..base import Finding, Rule, register
from ..source import ModuleSource
from ..taint import attr_chain
from .host_sync import _direct_nested_defs, _iter_scope_nodes
from .jit_hygiene import _jit_decorator

_REGISTER_FNS = {"register_pytree_node", "register_pytree_with_keys",
                 "register_dataclass", "register_static",
                 "register_pytree_node_class",
                 "register_pytree_with_keys_class"}


def _is_dataclass_decorator(dec: ast.AST) -> bool:
    chain = attr_chain(dec.func if isinstance(dec, ast.Call) else dec)
    return chain in ("dataclass", "dataclasses.dataclass")


def _registration_decorator(dec: ast.AST) -> bool:
    chain = attr_chain(dec.func if isinstance(dec, ast.Call) else dec)
    return bool(chain) and chain.split(".")[-1] in _REGISTER_FNS


@register
class PytreeRegistrationRule(Rule):
    id = "pytree-registration"
    description = ("unregistered @dataclass instance passed into a jitted "
                   "callable")
    rationale = ("an unregistered dataclass is an opaque jit argument: "
                 "trace error at best, a silent per-instance retrace at "
                 "worst; register it as a pytree so jit sees its leaves")
    trees = ("src/repro/",)

    def check_module(self, module: ModuleSource) -> List[Finding]:
        tree = module.tree
        dataclasses: Set[str] = set()
        registered: Set[str] = set()
        jitted: Set[str] = set()

        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                if any(_is_dataclass_decorator(d)
                       for d in node.decorator_list):
                    dataclasses.add(node.name)
                if any(_registration_decorator(d)
                       for d in node.decorator_list):
                    registered.add(node.name)
            elif isinstance(node, ast.Call):
                chain = attr_chain(node.func)
                if (chain and chain.split(".")[-1] in _REGISTER_FNS
                        and node.args
                        and isinstance(node.args[0], ast.Name)):
                    registered.add(node.args[0].id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(_jit_decorator(d) for d in node.decorator_list):
                    jitted.add(node.name)
            elif isinstance(node, ast.Assign):
                if (isinstance(node.value, ast.Call)
                        and attr_chain(node.value.func) == "jax.jit"):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            jitted.add(t.id)

        unregistered = dataclasses - registered
        if not unregistered or not jitted:
            return []

        findings: List[Finding] = []
        self._visit_scope(module, tree, {}, unregistered, jitted, findings)
        findings.sort(key=lambda f: f.key())
        return findings

    def _visit_scope(self, module, owner, inherited, unregistered, jitted,
                     findings):
        # name -> dataclass class name, for `s = State(...)` assignments
        instances: Dict[str, str] = dict(inherited)
        for node in _iter_scope_nodes(owner):
            if isinstance(node, ast.Assign):
                cls = self._ctor_class(node.value, unregistered)
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        if cls is not None:
                            instances[t.id] = cls
                        else:
                            instances.pop(t.id, None)
        for node in _iter_scope_nodes(owner):
            if not isinstance(node, ast.Call):
                continue
            fname = None
            if isinstance(node.func, ast.Name) and node.func.id in jitted:
                fname = node.func.id
            if fname is None:
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                cls = self._ctor_class(arg, unregistered)
                if cls is None and isinstance(arg, ast.Name):
                    cls = instances.get(arg.id)
                if cls is not None:
                    findings.append(self.finding(
                        module, node.lineno, node.col_offset,
                        f"unregistered dataclass '{cls}' passed into "
                        f"jitted '{fname}'; register it with "
                        f"jax.tree_util.register_dataclass (or "
                        f"register_pytree_node) first"))
        for fn in _direct_nested_defs(owner):
            self._visit_scope(module, fn, instances, unregistered, jitted,
                              findings)

    @staticmethod
    def _ctor_class(node: ast.AST, unregistered: Set[str]):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id in unregistered):
            return node.func.id
        return None
