"""Rule implementations.  Importing this package populates the registry
(base._REGISTRY) — all_rules()/get_rule() trigger the import lazily."""
from . import clock          # noqa: F401
from . import host_sync      # noqa: F401
from . import ir_rules       # noqa: F401
from . import jit_hygiene    # noqa: F401
from . import policy_conformance  # noqa: F401
from . import pytree         # noqa: F401
from . import rng            # noqa: F401
