"""clock-discipline: wall-clock reads in serving/modalities code.

Successor of the old tools/check_clock.py regex script, now AST-based
(no false positives on `time.time` inside strings or comment prose).

The serving stack runs on an injected `clock` callable so simulations,
tests and the replay harness control time deterministically.  A stray
`time.time()` (or perf_counter/monotonic) in serving/ or modalities/
reads the REAL clock, which desynchronizes simulated traces and makes
latency accounting nondeterministic under test.
"""
from __future__ import annotations

import ast
from typing import List

from ..base import Finding, Rule, register
from ..source import ModuleSource
from ..taint import attr_chain

_BANNED = {"time.time", "time.perf_counter", "time.monotonic",
           "time.monotonic_ns", "time.perf_counter_ns", "time.time_ns"}


@register
class ClockRule(Rule):
    id = "clock-discipline"
    description = ("direct wall-clock read (time.time/perf_counter/"
                   "monotonic) instead of the injected clock")
    rationale = ("serving and modalities code must read time through the "
                 "injected clock callable so simulated traces, tests and "
                 "benchmarks stay deterministic; a raw time.time() "
                 "desynchronizes them from the virtual timeline")
    trees = ("src/repro/serving/", "src/repro/modalities/",
             "src/repro/conditioning/")

    def check_module(self, module: ModuleSource) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = attr_chain(node.func)
            if chain in _BANNED:
                findings.append(self.finding(
                    module, node.lineno, node.col_offset,
                    f"{chain}() reads the wall clock; use the injected "
                    f"`clock` callable so simulation/replay stay "
                    f"deterministic"))
        return findings
