"""host-sync-in-hot-path: blocking device->host transfers on tick paths.

Every `float(x)`, `.item()`, `np.asarray(x)` or `jax.device_get(x)` on a
device value stalls the Python thread until the device catches up — on
the serving tick path that serializes the pipeline and shows up directly
as req/s.  The engine's design confines host syncs to ONE priced
device_get per tick (`_plan_all`); this rule keeps it that way.

Fires only when the argument is provably device-tainted (see
analysis.taint) or, for `jax.device_get`, unconditionally — device_get
has no other purpose than a transfer, so every call site must either be
the priced sync (inline-suppressed with its justification) or a bug.
"""
from __future__ import annotations

import ast
from typing import List

from ..base import Finding, Rule, register
from ..source import ModuleSource
from ..taint import TaintScope, attr_chain, build_scope, expr_tainted

#: builtins that force a sync when handed a device value
_CONVERSIONS = {"float", "int", "bool"}
#: np entry points that copy device arrays to host
_NP_SINKS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
#: array methods that force a sync
_METHOD_SINKS = {"item", "tolist"}

_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _iter_scope_nodes(owner: ast.AST):
    """Nodes of `owner`'s scope, not descending into nested defs."""
    for child in ast.iter_child_nodes(owner):
        yield child
        if not isinstance(child, _DEFS):
            yield from _iter_scope_nodes(child)


def _direct_nested_defs(owner: ast.AST):
    """Function defs whose nearest enclosing scope is `owner`."""
    for node in _iter_scope_nodes(owner):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


@register
class HostSyncRule(Rule):
    id = "host-sync-in-hot-path"
    description = ("blocking device->host sync (float/int/bool/.item()/"
                   ".tolist()/np.asarray/jax.device_get on device values) "
                   "in tick-path code")
    rationale = ("each sync stalls the host until the device drains; the "
                 "serving design allows exactly one priced device_get per "
                 "tick, so any other sync silently serializes the pipeline "
                 "and caps req/s")
    trees = ("src/repro/serving/", "src/repro/modalities/",
             "src/repro/core/", "src/repro/conditioning/")

    def check_module(self, module: ModuleSource) -> List[Finding]:
        findings: List[Finding] = []
        self._visit_scope(module, module.tree, None, findings)
        findings.sort(key=lambda f: f.key())
        return findings

    def _visit_scope(self, module, owner, parent_scope, findings):
        scope = build_scope(owner, parent_scope)
        for node in _iter_scope_nodes(owner):
            if isinstance(node, ast.Call):
                f = self._check_call(module, node, scope)
                if f is not None:
                    findings.append(f)
        for fn in _direct_nested_defs(owner):
            self._visit_scope(module, fn, scope, findings)

    def _check_call(self, module, call: ast.Call, scope: TaintScope):
        chain = attr_chain(call.func)
        # unconditional: device_get IS a transfer
        if chain == "jax.device_get":
            return self.finding(
                module, call.lineno, call.col_offset,
                "jax.device_get forces a blocking device->host transfer; "
                "if this is the one priced per-tick sync, suppress with a "
                "justification")
        # x.item() / x.tolist() on a device value
        if (isinstance(call.func, ast.Attribute)
                and call.func.attr in _METHOD_SINKS
                and expr_tainted(call.func.value, scope)):
            return self.finding(
                module, call.lineno, call.col_offset,
                f".{call.func.attr}() on a device value blocks until the "
                f"device drains; keep it on-device or batch the transfer")
        args = list(call.args)
        if not args:
            return None
        # float(x) / int(x) / bool(x)
        if isinstance(call.func, ast.Name) and call.func.id in _CONVERSIONS:
            if expr_tainted(args[0], scope):
                return self.finding(
                    module, call.lineno, call.col_offset,
                    f"{call.func.id}() on a device value blocks until the "
                    f"device drains; keep it on-device (jnp) or batch the "
                    f"transfer")
        # np.asarray(x) / np.array(x)
        if chain in _NP_SINKS and expr_tainted(args[0], scope):
            return self.finding(
                module, call.lineno, call.col_offset,
                f"{chain}() on a device value copies it to host "
                f"synchronously; hoist out of the per-tick loop or "
                f"batch into one transfer")
        return None
