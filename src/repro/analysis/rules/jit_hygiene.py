"""jit-hygiene: patterns that silently defeat jax.jit.

Three sub-checks, one rule id:

  * mutable default args on a jitted function — the default is traced
    once and baked into the compiled program; later mutation is invisible
    to every cached executable.
  * jax.jit(...) inside a loop body — re-wrapping per iteration defeats
    the compile cache (a fresh wrapper means a fresh cache), so every
    iteration pays dispatch overhead or a retrace.  Hoist the wrapper.
  * a jitted function closing over a mutable module-level global — the
    global's VALUE is captured at trace time; mutating the list/dict
    later does not retrigger tracing, so the program keeps running with
    stale data.  Pass it as an argument (pytree) or mark it static.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from ..base import Finding, Rule, register
from ..source import ModuleSource
from ..taint import attr_chain

_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                     ast.DictComp, ast.SetComp)
_MUTABLE_CTORS = {"list", "dict", "set", "defaultdict", "OrderedDict",
                  "Counter", "deque"}


def _is_mutable_value(node: ast.AST) -> bool:
    if isinstance(node, _MUTABLE_LITERALS):
        return True
    if isinstance(node, ast.Call):
        chain = attr_chain(node.func)
        if chain and chain.split(".")[-1] in _MUTABLE_CTORS:
            return True
    return False


def _jit_decorator(dec: ast.AST) -> bool:
    """@jax.jit, @jax.jit(...), @partial(jax.jit, ...)."""
    chain = attr_chain(dec)
    if chain == "jax.jit":
        return True
    if isinstance(dec, ast.Call):
        fchain = attr_chain(dec.func)
        if fchain == "jax.jit":
            return True
        if fchain in ("partial", "functools.partial") and dec.args:
            return attr_chain(dec.args[0]) == "jax.jit"
    return False


def _bound_names(fn: ast.AST) -> Set[str]:
    """Names bound inside the function (params + assignment targets)."""
    out: Set[str] = set()
    a = fn.args
    for arg in (a.posonlyargs + a.args + a.kwonlyargs):
        out.add(arg.arg)
    if a.vararg:
        out.add(a.vararg.arg)
    if a.kwarg:
        out.add(a.kwarg.arg)
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)) and node is not fn:
            out.add(node.name)
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            out.add(node.id)
    return out


@register
class JitHygieneRule(Rule):
    id = "jit-hygiene"
    description = ("jax.jit misuse: mutable default args, jit() wrapped "
                   "inside a loop, jitted closure over a mutable module "
                   "global")
    rationale = ("jit bakes trace-time values into the compiled program "
                 "and keys its cache on the wrapper object — each of these "
                 "patterns either runs on stale data or recompiles every "
                 "iteration")
    trees = ("src/repro/",)

    def check_module(self, module: ModuleSource) -> List[Finding]:
        findings: List[Finding] = []
        tree = module.tree

        # module-level mutable globals (for the closure check)
        mutable_globals: Set[str] = set()
        for stmt in tree.body:
            targets = []
            if isinstance(stmt, ast.Assign):
                targets = stmt.targets
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                targets = [stmt.target]
            else:
                continue
            if _is_mutable_value(stmt.value):
                for t in targets:
                    if isinstance(t, ast.Name):
                        mutable_globals.add(t.id)

        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(_jit_decorator(d) for d in node.decorator_list):
                    self._check_jitted_fn(module, node, mutable_globals,
                                          findings)
            if isinstance(node, (ast.For, ast.AsyncFor, ast.While)):
                self._check_loop(module, node, findings)

        findings.sort(key=lambda f: f.key())
        return findings

    def _check_jitted_fn(self, module, fn, mutable_globals, findings):
        # mutable defaults
        a = fn.args
        for default in list(a.defaults) + [d for d in a.kw_defaults if d]:
            if _is_mutable_value(default):
                findings.append(self.finding(
                    module, default.lineno, default.col_offset,
                    f"jitted function '{fn.name}' has a mutable default "
                    f"argument; jit traces it once and never sees later "
                    f"mutation — use None + in-function init"))
        # closure over mutable module globals
        if not mutable_globals:
            return
        local = _bound_names(fn)
        reported: Set[str] = set()
        for node in ast.walk(fn):
            if (isinstance(node, ast.Name)
                    and isinstance(node.ctx, ast.Load)
                    and node.id in mutable_globals
                    and node.id not in local
                    and node.id not in reported):
                reported.add(node.id)
                findings.append(self.finding(
                    module, node.lineno, node.col_offset,
                    f"jitted function '{fn.name}' closes over mutable "
                    f"module global '{node.id}'; its value is baked in at "
                    f"trace time — pass it as an argument instead"))

    def _check_loop(self, module, loop, findings):
        for part in loop.body:
            for node in ast.walk(part):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)):
                    continue
                if (isinstance(node, ast.Call)
                        and attr_chain(node.func) == "jax.jit"):
                    findings.append(self.finding(
                        module, node.lineno, node.col_offset,
                        "jax.jit() called inside a loop body creates a "
                        "fresh wrapper (and compile-cache entry) every "
                        "iteration; hoist the jit out of the loop"))
