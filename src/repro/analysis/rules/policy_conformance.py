"""policy-registry-conformance: drive every make_policy entry through the
serving contract at import time.

The serving engine trusts three things about every policy it hosts:

  * `want_compute` mirrors `apply`'s refresh decision — at minimum, a
    FRESH state must want a compute (the cache is empty; reusing it would
    serve zeros), and `apply` at step 0 must actually run compute_fn.
  * reset-on-refill — `init_state` is a pure function of (shape, dtype):
    two refills produce identical states, so a slot refill fully isolates
    requests (no state bleed across the requests that share a slot).
  * `static_schedule`, when offered, is coherent: length == num_steps and
    step 0 computes (the engine's zero-sync static plan trusts it blindly).
  * pab-family `RANGES` tables name module TYPES that some registered DiT
    backbone actually exposes (`block_branches`): a range keyed on a
    module type no backbone has is a silent no-op — the policy claims to
    broadcast a branch that never exists.

This rule is not an AST pass: it imports `repro.core` and drives each
registry entry with small dummy inputs, so a policy merged without the
serving contract fails lint before it ever reaches an engine.  Findings
anchor on the entry's line in core/__init__.py.
"""
from __future__ import annotations

import os
from typing import Dict, List

from ..base import Finding, ProjectRule, register

def _dummy_kwargs(name: str) -> Dict:
    """Constructor kwargs that let every registry entry build: generic
    knobs all lambdas absorb via **kw, plus the two entries that refuse
    to default (lazydit's trained gate, blockcache's measured profile)."""
    import jax.numpy as jnp
    base = {"num_steps": 8, "frames": 2}
    if name == "lazydit":
        base["gate"] = {"w": jnp.zeros((4,), jnp.float32),
                       "b": jnp.zeros((), jnp.float32)}
    if name == "blockcache":
        base["profile"] = [0.0] * 8
    return base


def _entry_line(source_lines: List[str], name: str) -> int:
    needle = f'"{name}":'
    for i, line in enumerate(source_lines, 1):
        if needle in line:
            return i
    return 1


@register
class PolicyConformanceRule(ProjectRule):
    id = "policy-registry-conformance"
    description = ("make_policy registry entry violates the serving "
                   "contract (want_compute mirror, reset-on-refill, "
                   "static_schedule coherence)")
    rationale = ("the serving engine trusts want_compute to mirror apply "
                 "and init_state to be a pure refill; a policy that "
                 "breaks either serves stale zeros or bleeds state across "
                 "requests sharing a slot")

    REL_PATH = "src/repro/core/__init__.py"

    def check_project(self, root: str) -> List[Finding]:
        try:
            import jax.numpy as jnp
            import numpy as np
            from repro.core import CachePolicy, POLICY_REGISTRY, make_policy
        except Exception as e:  # pragma: no cover - broken checkout
            return [Finding(self.id, self.REL_PATH, 1, 0,
                            f"cannot import repro.core to introspect the "
                            f"policy registry: {e!r}")]

        src = os.path.join(root, self.REL_PATH)
        try:
            with open(src, encoding="utf-8") as f:
                lines = f.read().splitlines()
        except OSError:
            lines = []

        findings: List[Finding] = []

        def fail(name, msg):
            line = _entry_line(lines, name)
            snippet = lines[line - 1].strip() if lines else ""
            findings.append(Finding(self.id, self.REL_PATH, line, 0,
                                    f"policy '{name}': {msg}",
                                    snippet=snippet))

        # module types some registered DiT backbone exposes — the legal
        # key universe for pab-family RANGES tables
        exposed = None
        try:
            from repro.configs import ALL_ARCH_IDS, get_config
            from repro.diffusion.pipeline import backbone_module
            exposed = set()
            for arch in ALL_ARCH_IDS:
                cfg = get_config(arch)
                if cfg.is_dit:
                    exposed |= set(backbone_module(cfg).block_branches(cfg))
        except Exception as e:
            findings.append(Finding(
                self.id, self.REL_PATH, 1, 0,
                f"cannot enumerate backbone module types for the RANGES "
                f"conformance check: {e!r}"))

        x = jnp.ones((2, 4), jnp.float32)
        for name in sorted(POLICY_REGISTRY):
            try:
                policy = make_policy(name, **_dummy_kwargs(name))
            except Exception as e:
                fail(name, f"not constructible with generic kwargs "
                           f"(num_steps/frames/gate/profile): {e!r}")
                continue
            if not isinstance(policy, CachePolicy):
                fail(name, f"make_policy returned {type(policy).__name__}, "
                           f"not a CachePolicy")
                continue
            ranges = getattr(type(policy), "RANGES", None)
            if ranges and exposed is not None:
                unknown = sorted(set(ranges) - exposed)
                if unknown:
                    fail(name, f"RANGES names module types {unknown} that "
                               f"no registered DiT backbone exposes "
                               f"(block_branches union: {sorted(exposed)}) "
                               f"— those broadcast ranges can never serve "
                               f"a real branch")
            try:
                s1 = policy.init_state(x.shape)
                s2 = policy.init_state(x.shape)
            except Exception as e:
                fail(name, f"init_state(shape) raised: {e!r}")
                continue
            import jax
            same = jax.tree_util.tree_all(jax.tree_util.tree_map(
                lambda a, b: jnp.array_equal(jnp.asarray(a),
                                             jnp.asarray(b)), s1, s2))
            if not bool(same):
                fail(name, "init_state is not a pure refill: two calls "
                           "with the same shape produced different states "
                           "(slot refills would bleed state)")
            try:
                wc0 = policy.want_compute(s1, 0, x, signal=x)
            except Exception as e:
                fail(name, f"want_compute(fresh_state, step=0) raised: "
                           f"{e!r}")
                continue
            if not bool(np.asarray(wc0)):
                fail(name, "want_compute is False on a FRESH state at "
                           "step 0 — the engine would reuse an empty "
                           "cache and serve zeros")
            try:
                y, _ = policy.apply(s1, 0, x, lambda v: v * 2.0, signal=x)
            except Exception as e:
                fail(name, f"apply(fresh_state, step=0) raised: {e!r}")
                continue
            if not bool(np.allclose(np.asarray(y), 2.0 * np.asarray(x),
                                    atol=1e-5)):
                fail(name, "apply at step 0 did not run compute_fn "
                           "(output != compute_fn(x)) — want_compute's "
                           "mirror promise is broken on the first tick")
            try:
                wm = policy.want_metric(s1, 0, x, signal=x)
                float(np.asarray(wm))
            except Exception as e:
                fail(name, f"want_metric(fresh_state, step=0) is not a "
                           f"float scalar: {e!r}")
            try:
                sched = policy.static_schedule(8)
            except Exception as e:
                fail(name, f"static_schedule(8) raised: {e!r}")
                continue
            if sched is not None:
                if len(sched) != 8:
                    fail(name, f"static_schedule(8) returned "
                               f"{len(sched)} entries, expected 8")
                elif not sched[0]:
                    fail(name, "static_schedule()[0] is falsy — the "
                               "zero-sync static plan would skip the "
                               "first step against an empty cache")
        return findings
