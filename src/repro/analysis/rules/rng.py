"""rng-key-reuse: the same PRNG key consumed by two jax.random calls.

JAX keys are not stateful seeds: passing the same key to two sampling
calls yields CORRELATED (often identical) draws.  The bug class shipped
once already — per-slot noise that was supposed to be i.i.d. came out
identical across slots because one key fed every `jax.random.normal`.

The rule tracks key identities (a bare name, or `name[const]`) through
each function body in statement order: the first jax.random consumer of
an identity marks it consumed; a second consumer without an intervening
re-binding (`key, sub = jax.random.split(key)`) fires.  Loop bodies are
walked twice so a consume-without-resplit inside a loop is caught on the
second pass; if/else branches run on forked states that are union-merged
afterwards.

`jax.random.fold_in(key, data)` is deliberately NOT a consumer: deriving
many streams from one base key via fold_in with distinct data is the
recommended idiom (the engine's request_noise_key does exactly this).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from ..base import Finding, Rule, register
from ..source import ModuleSource
from ..taint import attr_chain

#: jax.random.* that create or derive keys without "using them up"
_NON_CONSUMERS = {"PRNGKey", "key", "fold_in", "key_data",
                  "wrap_key_data", "clone"}

_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


def _identity(node: ast.AST) -> Optional[str]:
    """Trackable key identity: `key` or `keys[0]` (constant index)."""
    if isinstance(node, ast.Name):
        return node.id
    if (isinstance(node, ast.Subscript)
            and isinstance(node.value, ast.Name)
            and isinstance(node.slice, ast.Constant)):
        return f"{node.value.id}[{node.slice.value!r}]"
    return None


def _is_random_call(call: ast.Call) -> Optional[str]:
    """Return the jax.random function name, or None."""
    chain = attr_chain(call.func)
    if not chain:
        return None
    parts = chain.split(".")
    if len(parts) >= 2 and parts[-2] == "random":
        return parts[-1]
    return None


def _reset_identity(name: str, state: Dict[str, int]) -> None:
    """Re-binding a name refreshes the key it holds (and any tracked
    subscripts rooted at it)."""
    for ident in [k for k in state
                  if k == name or k.startswith(name + "[")]:
        del state[ident]


def _assigned_names(stmt: ast.AST):
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    for t in targets:
        yield from _target_names(t)


def _target_names(target: ast.AST):
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for el in target.elts:
            yield from _target_names(el)
    elif isinstance(target, ast.Starred):
        yield from _target_names(target.value)
    elif isinstance(target, ast.Subscript):
        ident = _identity(target)
        if ident:
            yield ident


@register
class RngKeyReuseRule(Rule):
    id = "rng-key-reuse"
    description = ("same PRNG key consumed by two or more jax.random "
                   "calls without an intervening split")
    rationale = ("reusing a key makes 'independent' draws identical — "
                 "per-slot noise collapses to one stream; always thread "
                 "keys through jax.random.split (or fold_in with distinct "
                 "data)")
    trees = ("src/repro/",)

    def check_module(self, module: ModuleSource) -> List[Finding]:
        findings: List[Finding] = []
        # module top level, then every function body independently
        self._process_body(
            module, [s for s in module.tree.body
                     if not isinstance(s, _DEFS)], {}, findings)
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._process_body(module, node.body, {}, findings)
        uniq, seen = [], set()
        for f in sorted(findings, key=lambda f: f.key()):
            if f.key() not in seen:
                seen.add(f.key())
                uniq.append(f)
        return uniq

    # -- statement-order interpreter ------------------------------------

    def _process_body(self, module, body, state, findings):
        for stmt in body:
            self._process_stmt(module, stmt, state, findings)

    def _process_stmt(self, module, stmt, state, findings):
        if isinstance(stmt, _DEFS):
            return  # own pass, own state
        if isinstance(stmt, ast.If):
            self._process_expr(module, stmt.test, state, findings)
            s_then, s_else = dict(state), dict(state)
            self._process_body(module, stmt.body, s_then, findings)
            self._process_body(module, stmt.orelse, s_else, findings)
            state.clear()
            for s in (s_then, s_else):
                for k, v in s.items():
                    state[k] = min(state.get(k, v), v)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._process_expr(module, stmt.iter, state, findings)
            for name in _target_names(stmt.target):
                _reset_identity(name.split("[")[0], state)
            for _ in range(2):  # second pass catches loop-carried reuse
                self._process_body(module, stmt.body, state, findings)
                for name in _target_names(stmt.target):
                    _reset_identity(name.split("[")[0], state)
            self._process_body(module, stmt.orelse, state, findings)
            return
        if isinstance(stmt, ast.While):
            for _ in range(2):
                self._process_expr(module, stmt.test, state, findings)
                self._process_body(module, stmt.body, state, findings)
            self._process_body(module, stmt.orelse, state, findings)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._process_expr(module, item.context_expr, state,
                                   findings)
                if item.optional_vars is not None:
                    for name in _target_names(item.optional_vars):
                        _reset_identity(name.split("[")[0], state)
            self._process_body(module, stmt.body, state, findings)
            return
        if isinstance(stmt, ast.Try):
            self._process_body(module, stmt.body, state, findings)
            for handler in stmt.handlers:
                self._process_body(module, handler.body, dict(state),
                                   findings)
            self._process_body(module, stmt.orelse, state, findings)
            self._process_body(module, stmt.finalbody, state, findings)
            return
        # simple statement: evaluate expressions, then apply re-bindings
        self._process_expr(module, stmt, state, findings)
        for name in _assigned_names(stmt):
            _reset_identity(name.split("[")[0], state)

    def _process_expr(self, module, node, state, findings):
        calls = []
        for sub in ast.walk(node):
            if isinstance(sub, _DEFS + (ast.Lambda,)):
                continue
            if isinstance(sub, ast.Call):
                calls.append(sub)
        calls.sort(key=lambda c: (c.lineno, c.col_offset))
        for call in calls:
            fname = _is_random_call(call)
            if fname is None or fname in _NON_CONSUMERS:
                continue
            if not call.args:
                continue
            ident = _identity(call.args[0])
            if ident is None:
                continue
            first = state.get(ident)
            if first is not None:
                findings.append(self.finding(
                    module, call.lineno, call.col_offset,
                    f"PRNG key '{ident}' was already consumed at line "
                    f"{first}; reusing it makes the draws correlated — "
                    f"split (or fold_in with distinct data) first"))
            elif first is None:
                state[ident] = call.lineno
