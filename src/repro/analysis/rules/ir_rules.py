"""The six ir-* rules: IR-level verification surfaced through the
ordinary rule registry, so `repro-lint --rule 'ir-*'`, inline
suppressions, the fingerprinted baseline and JSON reports all apply to
compiled-artifact findings exactly as to AST findings.

Five of the rules share one cached golden context (repro.analysis.ir
.golden): tiny image+video engines warmed with IR capture, verified, and
served through a mixed session under the retrace sentinel — built once
per lint process.  Each rule then reports its slice of the findings.
ir-pallas drives the kernel lint separately (no engine involved), and
ir-donation additionally checks the training step's donate_argnums
against its lowered aliasing.
"""
from __future__ import annotations

import os
from typing import List

from ..base import Finding, ProjectRule, register

_ENGINE_REL = "src/repro/serving/diffusion/engine.py"
_TRAIN_REL = "src/repro/train/loop.py"


def _context_error_finding(rule_id: str, err: str) -> Finding:
    return Finding(rule_id, _ENGINE_REL, 1, 0,
                   f"golden lint context failed to build — IR contracts "
                   f"unverifiable: {err}")


def _program_findings(rule_id: str) -> List[Finding]:
    """This rule's slice of the golden context's verify_programs output."""
    from ..ir.golden import golden_context
    ctx = golden_context()
    if ctx.error:
        return [_context_error_finding(rule_id, ctx.error)]
    out = []
    for f in ctx.program_findings:
        if f.rule == rule_id:
            out.append(Finding(rule_id, f.path, f.line, f.col, f.message,
                               snippet=f.snippet))
    return out


@register
class IRHostCallbackRule(ProjectRule):
    id = "ir-host-callback"
    description = ("host callback / infeed / outfeed primitives in a "
                   "warmup-compiled serving program (jaxpr ground truth)")
    rationale = ("a pure_/io_/debug_callback in a tick program round-trips "
                 "to the host on every dispatch — the AST host-sync rule "
                 "sees source taint, this sees the actual primitive")

    def check_project(self, root: str) -> List[Finding]:
        return _program_findings(self.id)


@register
class IRDtypeRule(ProjectRule):
    id = "ir-dtype"
    description = ("float64 / weak-type leaks in compiled serving programs "
                   "and the engine's schedule tables")
    rationale = ("an f64 const or intermediate doubles hot-path memory "
                 "traffic; a weak-typed output re-promotes every "
                 "downstream consumer — with x64 disabled, f64 can only "
                 "enter via closed-over host numpy tables")

    def check_project(self, root: str) -> List[Finding]:
        return _program_findings(self.id)


@register
class IRConstBloatRule(ProjectRule):
    id = "ir-const-bloat"
    description = ("large closed-over constants baked into compiled "
                   "programs beyond the declared model param leaves")
    rationale = ("every undeclared baked const is duplicated per program "
                 "variant (one per bucket size) and invalidates the "
                 "executable when the host object changes — tables belong "
                 "in arguments")

    def check_project(self, root: str) -> List[Finding]:
        return _program_findings(self.id)


@register
class IRDonationRule(ProjectRule):
    id = "ir-donation"
    description = ("donate_argnums claims that the lowered program does "
                   "not actually alias (silent no-op donation)")
    rationale = ("un-aliased donation still allocates: the training step "
                 "would hold two copies of every param/opt leaf, and an "
                 "engine program aliasing buffers the slot pool still "
                 "references would corrupt live state")

    def check_project(self, root: str) -> List[Finding]:
        findings = _program_findings(self.id)
        findings.extend(self._check_train_step(root))
        return findings

    def _check_train_step(self, root: str) -> List[Finding]:
        """Drive the real training step exactly as train_loop jits it
        (donate_argnums=(0,)) and demand every TrainState leaf aliases."""
        try:
            import jax
            import jax.numpy as jnp
            from repro.configs import get_smoke_config
            from repro.diffusion import linear_schedule
            from repro.train.steps import (init_train_state,
                                           make_diffusion_train_step)
            from ..ir.jaxpr_checks import check_donation

            cfg = get_smoke_config("dit-xl").reduced(
                num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                d_ff=64)
            state = init_train_state(jax.random.PRNGKey(0), cfg)
            step_fn = make_diffusion_train_step(cfg, linear_schedule(50),
                                                total_steps=5)
            # jit exactly as train_loop does (loop.py donate=True default)
            step_fn = jax.jit(step_fn, donate_argnums=(0,))
            batch = {"latents": jnp.zeros((2, cfg.dit_tokens, cfg.dit_in_dim),
                                          jnp.float32),
                     "labels": jnp.zeros((2,), jnp.int32),
                     "key": jax.random.PRNGKey(1)}
            text = step_fn.lower(state, batch).as_text()
            leaves = len(jax.tree_util.tree_leaves(state))
            issue = check_donation(text, leaves,
                                   "train_loop step_fn donate_argnums=(0,)")
        except Exception as e:
            return [Finding(self.id, _TRAIN_REL, 1, 0,
                            f"cannot drive the training step's donation "
                            f"check: {e!r}")]
        if issue is None:
            return []
        line = _find_line(root, _TRAIN_REL, "donate_argnums")
        return [Finding(self.id, _TRAIN_REL, line, 0, issue.message,
                        snippet=_read_line(root, _TRAIN_REL, line))]


@register
class IRRetraceRule(ProjectRule):
    id = "ir-retrace"
    description = ("steady-state serving after engine.warmup() triggered "
                   "a jit recompile during the golden mixed session")
    rationale = ("warmup promises the complete program set; one silent "
                 "retrace pays an XLA compile inside a live tick — "
                 "latency SLAs and the autotuner's row pricing both "
                 "assume it never happens")

    def check_project(self, root: str) -> List[Finding]:
        from ..ir.golden import golden_context
        ctx = golden_context()
        if ctx.error:
            return [_context_error_finding(self.id, ctx.error)]
        line = _find_line(root, _ENGINE_REL, "def tick(self)")
        findings = []
        if not ctx.sentinel_live:
            findings.append(Finding(
                self.id, _ENGINE_REL, line, 0,
                "retrace sentinel selftest failed: neither the "
                "jax.monitoring backend-compile event nor the pxla "
                "compile log detected a known compile — the zero-"
                "recompile claim is unverifiable",
                snippet=_read_line(root, _ENGINE_REL, line)))
        if ctx.retrace_count != 0:
            names = ", ".join(sorted(set(ctx.retrace_names))) or "<unnamed>"
            findings.append(Finding(
                self.id, _ENGINE_REL, line, 0,
                f"golden mixed image+video session compiled "
                f"{ctx.retrace_count} program(s) AFTER warmup "
                f"(expected 0): {names}",
                snippet=_read_line(root, _ENGINE_REL, line)))
        return findings


@register
class IRPallasRule(ProjectRule):
    id = "ir-pallas"
    description = ("Pallas kernel structural lint: grid/BlockSpec "
                   "divisibility, index-map arity, dtype consistency")
    rationale = ("the kernels run under interpret=True on CPU, which "
                 "forgives malformed BlockSpecs that are fatal or silent "
                 "garbage on a real TPU — lint the call structure without "
                 "executing it")

    def check_project(self, root: str) -> List[Finding]:
        from ..ir import lint_pallas_kernels
        from ..ir.verify import issue_to_finding
        try:
            issues = lint_pallas_kernels()
        except Exception as e:
            return [Finding(self.id, "src/repro/kernels/__init__.py", 1, 0,
                            f"pallas lint crashed: {e!r}")]
        return [issue_to_finding(i, root,
                                 fallback_file=os.path.join(
                                     root, "src/repro/kernels/__init__.py"),
                                 fallback_line=1)
                for i in issues]


def _read_line(root: str, relpath: str, line: int) -> str:
    try:
        with open(os.path.join(root, relpath), encoding="utf-8") as f:
            lines = f.read().splitlines()
        return lines[line - 1].strip() if 0 < line <= len(lines) else ""
    except OSError:
        return ""


def _find_line(root: str, relpath: str, needle: str) -> int:
    try:
        with open(os.path.join(root, relpath), encoding="utf-8") as f:
            for i, text in enumerate(f.read().splitlines(), 1):
                if needle in text:
                    return i
    except OSError:
        pass
    return 1
