"""Parsed source files and inline suppressions.

`ModuleSource` bundles everything a rule needs about one file: the text,
split lines, the parsed AST, and the per-line suppression sets parsed from
`# repro-lint: disable=<rule>[,<rule>...]` comments.  Parsing happens once
per file per run regardless of how many rules inspect it.

Suppression grammar (the justification rides in the same comment, after
the rule list — keep one):

    x = float(metric)   # repro-lint: disable=host-sync-in-hot-path -- why
    # repro-lint: disable-next-line=rng-key-reuse -- why
    noise = jax.random.normal(key, shape)

`disable=all` suppresses every rule on that line (use sparingly).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, FrozenSet, Optional

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable(?P<next>-next-line)?\s*=\s*"
    r"(?P<rules>[A-Za-z0-9_-]+(?:\s*,\s*[A-Za-z0-9_-]+)*)")


def _parse_suppressions(lines) -> Dict[int, FrozenSet[str]]:
    """1-indexed line -> set of suppressed rule ids on that line."""
    out: Dict[int, set] = {}
    for i, line in enumerate(lines, 1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        rules = frozenset(r.strip() for r in m.group("rules").split(",")
                          if r.strip())
        target = i + 1 if m.group("next") else i
        out.setdefault(target, set()).update(rules)
    return {k: frozenset(v) for k, v in out.items()}


class ModuleSource:
    """One parsed Python file presented to AST rules."""

    def __init__(self, path: str, relpath: str, text: str):
        self.path = path
        #: repo-relative path with "/" separators (what scoping + baselines
        #: key on, so reports are machine-independent)
        self.relpath = relpath.replace("\\", "/")
        self.text = text
        self.lines = text.splitlines()
        self.tree: Optional[ast.AST] = None
        self.parse_error: Optional[SyntaxError] = None
        try:
            self.tree = ast.parse(text, filename=relpath)
        except SyntaxError as e:  # surfaced by the runner as a finding
            self.parse_error = e
        self._suppressions = _parse_suppressions(self.lines)

    @classmethod
    def from_file(cls, path: str, relpath: str) -> "ModuleSource":
        with open(path, encoding="utf-8") as f:
            return cls(path, relpath, f.read())

    def line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def suppressed(self, lineno: int, rule_id: str) -> bool:
        rules = self._suppressions.get(lineno)
        return bool(rules) and (rule_id in rules or "all" in rules)
