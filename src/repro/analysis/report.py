"""Human-readable text and machine-readable JSON reports."""
from __future__ import annotations

import json
from typing import Dict

from .runner import RunResult


def to_text(result: RunResult, verbose: bool = False) -> str:
    """clang/ruff-style text report: path:line:col: rule-id message."""
    lines = []
    for f in result.findings:
        lines.append(f"{f.path}:{f.line}:{f.col}: [{f.rule}] {f.message}")
        if f.snippet:
            lines.append(f"    {f.snippet}")
    if verbose and result.suppressed:
        lines.append("")
        lines.append(f"suppressed ({len(result.suppressed)}):")
        for f in result.suppressed:
            lines.append(f"  {f.path}:{f.line}: [{f.rule}] (inline disable)")
    if result.baselined:
        lines.append("")
        lines.append(f"baselined ({len(result.baselined)} grandfathered "
                     f"finding(s) — see tools/lint_baseline.json)")
    for e in result.stale_baseline:
        lines.append(f"stale baseline entry (fixed? delete it): "
                     f"{e.get('rule')} @ {e.get('path')} "
                     f"[{e.get('fingerprint')}]")
    n = len(result.findings)
    lines.append("")
    lines.append(
        f"repro-lint: {n} finding(s) in {result.files_scanned} file(s) "
        f"({len(result.suppressed)} suppressed, "
        f"{len(result.baselined)} baselined) — rules: "
        f"{', '.join(result.rules)}")
    if n == 0:
        lines.append("repro-lint: OK")
    return "\n".join(lines)


def to_json(result: RunResult) -> Dict:
    return {
        "version": 1,
        "root": result.root,
        "rules": result.rules,
        "files_scanned": result.files_scanned,
        "findings": [f.to_dict() for f in result.findings],
        "suppressed": [f.to_dict() for f in result.suppressed],
        "baselined": [f.to_dict() for f in result.baselined],
        "stale_baseline": result.stale_baseline,
        "exit_code": result.exit_code,
    }


def write_json(result: RunResult, path: str) -> None:
    import os
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(to_json(result), f, indent=2)
        f.write("\n")
