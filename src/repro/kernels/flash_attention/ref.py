"""Pure-jnp oracle for flash attention (GQA + causal + sliding window)."""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale=None):
    """q: (B, Sq, H, D); k/v: (B, Sk, KH, D); KH divides H.

    Assumes q occupies the last Sq positions of the Sk-long key sequence
    (Sq == Sk for self-attention)."""
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    group = H // KH
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Sq, KH, group, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) * scale
    q_pos = jnp.arange(Sq) + (Sk - Sq)
    k_pos = jnp.arange(Sk)
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return o.reshape(B, Sq, H, D).astype(q.dtype)
