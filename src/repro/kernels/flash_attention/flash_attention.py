"""Tiled online-softmax attention for TPU (Pallas).

TPU-native design (not a CUDA port):
  * grid = (B, KH, Sq/BQ); each program owns one (128-ish, D) Q tile for one
    KV head group, resident in VMEM.
  * K/V are streamed through VMEM in (BK, D) tiles by an inner fori_loop
    over `pl.load` slices of the full-(Sk) VMEM block — HBM->VMEM movement
    is expressed by the BlockSpec, tile iteration stays on-chip.
  * online softmax: running (m, l, acc) in f32 VREGs; one store per Q tile.
  * GQA: the `group` dimension is folded into the Q-tile rows (BQ rows hold
    BQ//group query positions x group heads) so the MXU matmul contraction
    is always (BQ, D) x (D, BK) — hardware-aligned when BQ, BK, D are
    multiples of 128/8.
  * causal + sliding-window masking from absolute positions computed off
    the grid indices; fully-masked K tiles are skipped by bounding the
    fori_loop, which is where the causal 2x FLOP saving comes from.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, BQ: int, BK: int, Sk: int,
               causal: bool, window: int, scale: float, q_offset: int):
    """One (b, kh, qi) program: q_ref (BQ, G, D); k/v_ref (Sk, D) streamed."""
    qi = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32) * scale          # (BQ, G, D)
    BQr, G, D = q.shape
    q2 = q.reshape(BQr * G, D)

    m = jnp.full((BQr * G,), NEG_INF, jnp.float32)
    l = jnp.zeros((BQr * G,), jnp.float32)
    acc = jnp.zeros((BQr * G, D), jnp.float32)

    q_pos = qi * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQr, G), 0) + q_offset
    q_pos = q_pos.reshape(BQr * G)

    # bound the KV walk: causal -> only tiles with k_start <= max(q_pos)
    if causal:
        hi = jnp.minimum((qi * BQ + BQ + q_offset + BK - 1) // BK, Sk // BK)
    else:
        hi = Sk // BK

    def body(ki, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.ds(ki * BK, BK), slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.ds(ki * BK, BK), slice(None))).astype(jnp.float32)
        s = q2 @ k.T                                    # (BQ*G, BK)
        k_pos = ki * BK + jax.lax.broadcasted_iota(jnp.int32, (1, BK), 1)
        ok = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            ok &= k_pos <= q_pos[:, None]
        if window > 0:
            ok &= (q_pos[:, None] - k_pos) < window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, hi, body, (m, l, acc))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out.reshape(BQr, G, D).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=0, scale=None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Sk, KH, D). Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    BQ = min(block_q, Sq)
    BK = min(block_k, Sk)
    assert Sq % BQ == 0 and Sk % BK == 0, (Sq, BQ, Sk, BK)
    q_offset = Sk - Sq               # q occupies the tail of the K sequence

    # (B, Sq, H, D) -> (B, KH, Sq, G, D): group dim rides with the Q tile
    qg = q.reshape(B, Sq, KH, G, D).transpose(0, 2, 1, 3, 4)
    kt = k.transpose(0, 2, 1, 3)     # (B, KH, Sk, D)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, KH, Sq // BQ)
    kern = functools.partial(_fa_kernel, BQ=BQ, BK=BK, Sk=Sk, causal=causal,
                             window=window, scale=scale, q_offset=q_offset)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, BQ, G, D), lambda b, h, i: (b, h, i, 0, 0)),
            pl.BlockSpec((None, None, Sk, D), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((None, None, Sk, D), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, BQ, G, D),
                               lambda b, h, i: (b, h, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, Sq, G, D), q.dtype),
        interpret=interpret,
    )(qg, kt, vt)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, Sq, H, D)
