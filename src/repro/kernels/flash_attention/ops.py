"""Public jit'd wrapper for the flash-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from .flash_attention import flash_attention_pallas
from .ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret", "use_kernel"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None, use_kernel=True):
    """Drop-in attention: Pallas kernel on TPU, interpret-mode on CPU."""
    if interpret is None:
        from repro.kernels import INTERPRET
        interpret = INTERPRET
    if not use_kernel:
        return attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)
