"""Public jit'd wrapper for the SSD scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from .ref import ssd_ref
from .ssd import ssd_pallas


@partial(jax.jit, static_argnames=("chunk", "interpret", "use_kernel"))
def ssd_scan(x, dt, A, B_, C_, *, chunk=64, interpret=None, use_kernel=True):
    """Mamba2 SSD scan. Returns (y, h_final). See ssd.py for layout."""
    if interpret is None:
        from repro.kernels import INTERPRET
        interpret = INTERPRET
    if not use_kernel:
        return ssd_ref(x, dt, A, B_, C_, chunk=chunk)
    return ssd_pallas(x, dt, A, B_, C_, chunk=chunk, interpret=interpret)
