"""Oracle for the SSD kernel: the pure-jnp chunked SSD from repro.models.ssm."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import ssd_chunked


def ssd_ref(x, dt, A, B_, C_, chunk: int = 64):
    """x: (b,s,h,p) f32; dt: (b,s,h) softplus'd; A: (h,) negative;
    B_, C_: (b,s,n).  Returns (y (b,s,h,p), h_final (b,h,p,n))."""
    return ssd_chunked(x.astype(jnp.float32), dt.astype(jnp.float32),
                       A.astype(jnp.float32), B_.astype(jnp.float32),
                       C_.astype(jnp.float32), chunk)
