"""Mamba2 SSD chunked scan (Pallas).

TPU-native formulation (DESIGN §6): the selective scan is recast as the
state-space-dual *matmul* form so the MXU does the heavy lifting:

  per chunk (L = chunk length, P = head dim, N = state dim):
    scores = (C B^T) ⊙ exp(segsum(dA))          (L,L)  — MXU + VPU mask
    Y_diag = scores @ (x ⊙ dt)                  (L,P)  — MXU
    Y_off  = (C ⊙ exp(cumsum dA)) @ h_prev^T    (L,P)  — MXU
    h_new  = h_prev ⊙ exp(Σ dA) + (x ⊙ decay dt)^T B   (P,N) — MXU

The inter-chunk state h lives in VMEM scratch and is carried across grid
steps: the TPU grid is executed sequentially with the last dimension
innermost, so for each (batch, head) program column the chunk index walks
0..nc-1 in order and the scratch acts as the recurrence register.  This is
the part a GPU implementation does with a separate kernel launch + global
memory round-trip; on TPU it is free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr,
                *, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[...].astype(jnp.float32)        # (L, P)
    dt = dt_ref[...].astype(jnp.float32)      # (L,)
    A = a_ref[0].astype(jnp.float32)          # scalar (negative)
    B = b_ref[...].astype(jnp.float32)        # (L, N)
    C = c_ref[...].astype(jnp.float32)        # (L, N)
    L = x.shape[0]

    dA = dt * A                               # (L,) <= 0
    cs = jnp.cumsum(dA)                       # inclusive
    # segsum decay matrix: exp(cs_i - cs_j + dA_j) for j <= i  ... note the
    # convention: contribution of token j to token i decays by
    # exp(sum_{k=j+1..i} dA_k) = exp(cs_i - cs_j)
    seg = cs[:, None] - cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    Lmat = jnp.where(jj <= ii, jnp.exp(seg), 0.0)

    scores = (C @ B.T) * Lmat                 # (L, L)
    xdt = x * dt[:, None]                     # (L, P)
    y = scores @ xdt                          # intra-chunk

    h_prev = h_scr[...]                       # (P, N)
    y = y + (C * jnp.exp(cs)[:, None]) @ h_prev.T

    chunk_decay = jnp.exp(cs[-1])
    decay_dt = jnp.exp(cs[-1] - cs) * dt      # (L,)
    h_new = h_prev * chunk_decay + (x * decay_dt[:, None]).T @ B
    h_scr[...] = h_new

    y_ref[...] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        hout_ref[...] = h_new.astype(hout_ref.dtype)


def ssd_pallas(x, dt, A, B_, C_, *, chunk: int = 64, interpret: bool = True):
    """x: (b,s,h,p); dt: (b,s,h); A: (h,); B_/C_: (b,s,n).

    Returns (y (b,s,h,p) f32, h_final (b,h,p,n) f32)."""
    b, s, h, p = x.shape
    n = B_.shape[-1]
    if s % chunk != 0:
        chunk = s
    nc = s // chunk

    xt = x.transpose(0, 2, 1, 3)              # (b,h,s,p)
    dtt = dt.transpose(0, 2, 1)               # (b,h,s)

    grid = (b, h, nc)
    y, h_fin = pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((None, None, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((None, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((None, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((None, None, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A, B_, C_)
    return y.transpose(0, 2, 1, 3), h_fin
