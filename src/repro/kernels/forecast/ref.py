"""Oracle for the forecast kernel = repro.core.predictive.forecast_from_diffs.

The kernel computes `out = sum_i coeffs[i] * diffs[i]` — the basis-agnostic
inner loop of every "Cache-Then-Forecast" method.  The coefficients are the
(order+1,) basis weights produced by `basis_coeffs` for Taylor (TaylorSeer
Eq. 42), contracted Hermite (HiCache Eq. 47), Newton and Adams-Bashforth."""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core.predictive import _hermite_poly


def basis_coeffs(order: int, u, basis: str = "taylor", sigma: float = 0.5,
                 n_valid=None):
    """(order+1,) float32 basis weights at normalized offset u."""
    u = jnp.asarray(u, jnp.float32)
    cs = []
    for i in range(order + 1):
        if basis == "taylor":
            c = u**i / math.factorial(i)
        elif basis == "newton":
            c = jnp.ones(())
            for j in range(i):
                c = c * (u + j)
            c = c / math.factorial(i)
        elif basis == "hermite":
            c = (jnp.ones(()) if i == 0 else
                 (sigma**i) * _hermite_poly(i, sigma * u) / math.factorial(i))
        elif basis == "ab":
            c = {0: jnp.ones(()), 1: u, 2: 0.5 * u}.get(i, jnp.zeros(()))
        else:
            raise ValueError(basis)
        if n_valid is not None:
            c = c * (jnp.asarray(n_valid) > i).astype(jnp.float32)
        cs.append(c)
    return jnp.stack(cs).astype(jnp.float32)


def forecast_ref(diffs, coeffs):
    """diffs: (m+1, ...); coeffs: (m+1,). Returns sum_i coeffs[i]*diffs[i]."""
    m1 = diffs.shape[0]
    flat = diffs.reshape(m1, -1).astype(jnp.float32)
    return jnp.tensordot(coeffs.astype(jnp.float32), flat,
                         axes=1).reshape(diffs.shape[1:]).astype(diffs.dtype)
