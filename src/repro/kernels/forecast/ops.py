"""Public jit'd wrapper for the forecast kernel."""
from __future__ import annotations

from functools import partial

import jax

from .forecast import forecast_pallas
from .ref import basis_coeffs, forecast_ref


@partial(jax.jit, static_argnames=("block_n", "interpret", "use_kernel"))
def forecast(diffs, coeffs, *, block_n=4096, interpret=None, use_kernel=True):
    """Fused `sum_i coeffs[i] * diffs[i]` (the Cache-Then-Forecast hot loop)."""
    if interpret is None:
        from repro.kernels import INTERPRET
        interpret = INTERPRET
    if not use_kernel:
        return forecast_ref(diffs, coeffs)
    return forecast_pallas(diffs, coeffs, block_n=block_n, interpret=interpret)
