from .ops import forecast

__all__ = ["forecast"]
