"""Fused forecast kernel (Pallas).

Why a kernel: on skipped steps predictive caching evaluates
out = sum_i c_i * diffs[i] over every cached feature.  Chained XLA ops
would stream the (m+1)-deep stack through HBM once per term; the fused
kernel reads each history tile once, accumulates the weighted sum in VREGs
and performs a single HBM write — the op becomes one-pass bandwidth-bound,
(m+1)x less traffic than the naive schedule.

Layout: features flattened to (m+1, N) with N padded to the (8,128)=1024
tile; grid walks N in BN-sized tiles; coefficients ride in as a tiny (m+1,)
operand broadcast to every program (SMEM-resident on real TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _forecast_kernel(c_ref, d_ref, o_ref, *, order1: int):
    c = c_ref[...].astype(jnp.float32)        # (m+1,)
    d = d_ref[...].astype(jnp.float32)        # (m+1, BN)
    acc = jnp.zeros((d.shape[1],), jnp.float32)
    for i in range(order1):                   # static unroll, stays in VREGs
        acc = acc + c[i] * d[i]
    o_ref[...] = acc.astype(o_ref.dtype)


def forecast_pallas(diffs, coeffs, *, block_n: int = 4096,
                    interpret: bool = True):
    """diffs: (m+1, ...) stack; coeffs: (m+1,). Fused weighted reduction."""
    m1 = diffs.shape[0]
    shape = diffs.shape[1:]
    flat = diffs.reshape(m1, -1)
    N = flat.shape[1]
    BN = min(block_n, N)
    pad = (-N) % BN
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    Np = flat.shape[1]

    out = pl.pallas_call(
        functools.partial(_forecast_kernel, order1=m1),
        grid=(Np // BN,),
        in_specs=[
            pl.BlockSpec((m1,), lambda i: (0,)),
            pl.BlockSpec((m1, BN), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((BN,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), diffs.dtype),
        interpret=interpret,
    )(coeffs, flat)
    return out[:N].reshape(shape)
