"""Pallas TPU kernels for the perf-critical hot spots.

  flash_attention — tiled online-softmax attention (prefill hot spot)
  forecast        — fused polynomial feature forecast (predictive caching's
                    per-skipped-step evaluation, §2.3 of DESIGN.md)
  ssd             — Mamba2 chunked state-space-dual scan (zamba2 hot spot)

Each module ships `<name>.py` (pl.pallas_call + BlockSpec), `ops.py` (jit'd
public wrapper choosing kernel vs reference) and `ref.py` (pure-jnp oracle).
This container is CPU-only: kernels run under interpret=True in tests; on a
real TPU set REPRO_PALLAS_INTERPRET=0.
"""
import os

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"

from .flash_attention.ops import flash_attention          # noqa: E402
from .forecast.ops import forecast                        # noqa: E402
from .ssd.ops import ssd_scan                             # noqa: E402

__all__ = ["flash_attention", "forecast", "ssd_scan", "INTERPRET"]
