"""repro.modalities — multi-modal denoise workloads for the cache stack.

The survey's subtitle promises *efficient multi-modal generation*; this
package is the modality layer that makes the claim testable end-to-end:

  spec     — ModalitySpec / DenoiseWorkload: image latents, video latent
             clips (frame axis, factorized spatio-temporal backbone), audio
             mel-spectrograms — each bound to a config + params and turned
             into the denoise workload the cache policies (repro.core), the
             cached pipeline (repro.diffusion) and the serving engine
             (repro.serving.diffusion) already know how to run.
  serving  — MixedModalityEngine: per-modality sub-pools (token shapes
             differ, so programs cannot be shared) interleaved tick-by-tick
             under one scheduler/telemetry umbrella, with per-modality row
             accounting (MixedTelemetry) and an autotune umbrella
             (autotune_pools).

Temporal-aware caching lives in repro.core.temporal (TemporalTeaCachePolicy
= "teacache_video" in the registry; TemporalPABStack = "pab_video" among
the structural policies), wired to the video backbone via
DenoiseWorkload.make_policy / .pab_stack.
"""
from .serving import MixedModalityEngine, MixedTelemetry, autotune_pools
from .spec import (MODALITIES, DenoiseWorkload, ModalitySpec, get_modality,
                   make_workload)

__all__ = [
    "MODALITIES", "ModalitySpec", "DenoiseWorkload", "get_modality",
    "make_workload",
    "MixedModalityEngine", "MixedTelemetry", "autotune_pools",
]
