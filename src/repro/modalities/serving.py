"""Mixed-modality serving: per-modality sub-pools under one umbrella.

Latent row shapes differ per modality (a video clip's token axis is
frames x patches, an audio latent's channel axis is the mel-bin count), so
one jit'd tick program cannot batch across modalities.  The mixed pool
therefore runs ONE DiffusionServingEngine per modality — each with its own
slots, policies, bucket programs and row accounting — and interleaves their
tick-granular ServeSessions round-robin under a single scheduler loop, so
image, video and audio requests make progress together and finish-order
telemetry is comparable across pools.

Row accounting extends PR 4's compaction buckets per modality: each
sub-pool's ServingTelemetry keeps its own backbone_rows_computed / padding /
saved counters (video rows are MUCH wider than image rows — they must never
be summed into one undifferentiated count without the per-modality split),
and MixedTelemetry reports both the per-modality breakdown and
token-weighted totals.

`warmup()` pre-compiles every sub-pool's bucket programs (one set per
modality shape) so the first mixed tick doesn't pay several XLA compiles
back to back.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence

from repro.obs.clock import monotonic
from repro.serving.diffusion import (SLA, DiffusionRequest, DiffusionResult,
                                     DiffusionServingEngine, ServingTelemetry,
                                     autotune)

from .spec import DenoiseWorkload


@dataclass
class MixedTelemetry:
    """Telemetry umbrella over the per-modality sub-pool telemetries."""
    pools: Dict[str, ServingTelemetry] = field(default_factory=dict)
    #: tokens per backbone row, per modality (row width — what makes raw
    #: row counts incomparable across pools)
    row_tokens: Dict[str, int] = field(default_factory=dict)
    elapsed_s: float = 0.0

    def by_modality(self) -> Dict[str, Dict[str, float]]:
        return {m: t.summary() for m, t in sorted(self.pools.items())}

    def summary(self) -> Dict[str, float]:
        per = self.by_modality()
        n = sum(s["requests"] for s in per.values())
        rows = {m: s["backbone_rows_computed"] for m, s in per.items()}
        saved = {m: s["backbone_rows_saved"] for m, s in per.items()}
        out = {
            "requests": n,
            "requests_preempted": sum(s["requests_preempted"]
                                      for s in per.values()),
            "elapsed_s": self.elapsed_s,
            "throughput_rps": (n / self.elapsed_s if self.elapsed_s > 0
                               else 0.0),
            "backbone_rows_computed": sum(rows.values()),
            "backbone_rows_saved": sum(saved.values()),
            # token-weighted: a video row is frames x patches wide, so raw
            # row counts under-state the video pool's share of the compute
            "backbone_tokens_computed": sum(
                rows[m] * self.row_tokens.get(m, 1) for m in rows),
            "backbone_tokens_saved": sum(
                saved[m] * self.row_tokens.get(m, 1) for m in saved),
            "rows_by_modality": rows,
            "rows_saved_by_modality": saved,
        }
        return out


class MixedModalityEngine:
    """Serve image + video + audio requests through per-modality sub-pools
    under one scheduler/telemetry umbrella.

    pools: {modality name: DiffusionServingEngine}.  Requests are routed by
    `DiffusionRequest.modality`; each sub-pool keeps its own slot count,
    cache policies and compaction buckets.  Every tick of the outer loop
    advances each non-idle sub-pool session once (round-robin), so a long
    video queue never starves the image pool and vice versa."""

    def __init__(self, pools: Mapping[str, DiffusionServingEngine]):
        if not pools:
            raise ValueError("MixedModalityEngine needs at least one pool")
        # one engine object per pool: sessions of one engine share its
        # per-slot tables and must never be interleaved
        if len({id(e) for e in pools.values()}) != len(pools):
            raise ValueError("each modality pool needs its own engine "
                             "instance (an engine hosts one session)")
        self.pools: Dict[str, DiffusionServingEngine] = dict(pools)
        #: MixedTelemetry of the most recent serve() call
        self.telemetry: Optional[MixedTelemetry] = None
        #: aggregated repro.analysis.ir findings from warmup(verify=True);
        #: None = never verified, [] = every sub-pool verified clean
        self.ir_findings: Optional[List] = None

    @classmethod
    def from_workloads(cls, workloads: Mapping[str, DenoiseWorkload],
                       policies: Optional[Mapping[str, object]] = None,
                       cfg_policies: Optional[Mapping[str, object]] = None,
                       conditioners: Optional[Mapping[str, object]] = None,
                       **engine_kw) -> "MixedModalityEngine":
        """One sub-pool per workload; `policies` / `cfg_policies` map
        modality -> policy (name or instance), defaulting to None.
        `conditioners` maps TEXT modalities to their PromptCache — per
        modality, never in engine_kw: a shared conditioner kwarg would be
        rejected by the non-text pools."""
        policies = dict(policies or {})
        cfg_policies = dict(cfg_policies or {})
        conditioners = dict(conditioners or {})
        return cls({
            name: wl.engine(policies.get(name),
                            cfg_policy=cfg_policies.get(name),
                            conditioner=conditioners.get(name), **engine_kw)
            for name, wl in workloads.items()})

    # ------------------------------------------------------------------
    def warmup(self, verify: bool = False) -> Dict[str, Dict]:
        """Pre-compile every sub-pool's tick programs (one bucket set per
        modality shape) so the first mixed tick runs at steady state.
        Returns {modality: program_profile} — each sub-pool's per-program
        compile-time / FLOPs cost cards (see engine.warmup).

        `verify=True` runs the repro.analysis.ir contract checks over
        every sub-pool's program set (see engine.warmup(verify=True));
        per-engine findings aggregate on `self.ir_findings`."""
        out = {m: eng.warmup(verify=verify) for m, eng in self.pools.items()}
        if verify:
            self.ir_findings = [
                f for _, eng in sorted(self.pools.items())
                for f in (eng.ir_findings or ())]
        return out

    # ------------------------------------------------------------------
    def serve(self, requests: Sequence[DiffusionRequest],
              max_ticks: Optional[int] = None,
              hooks: Optional[Mapping[str, Sequence]] = None,
              metrics=None) -> List[DiffusionResult]:
        """Route requests to their modality sub-pools and interleave the
        sessions until all are done; results come back in request order.
        `max_ticks` bounds the OUTER loop (each sub-pool advances at most
        that many ticks); cut-off requests are recorded as preempted in
        their pool's telemetry.  `hooks` maps modality -> TickHook list so
        a control plane can watch each sub-pool's ticks (each hook sees
        TickEvents tagged with that pool's modality).  `metrics` (a
        repro.obs MetricsRegistry) is shared across sub-pools — every
        sample carries a modality label, so one registry serves the whole
        mixed pool."""
        by_mod: Dict[str, List[DiffusionRequest]] = {}
        for r in requests:
            if r.modality not in self.pools:
                raise KeyError(f"request {r.request_id}: no pool for "
                               f"modality '{r.modality}' "
                               f"(pools: {sorted(self.pools)})")
            by_mod.setdefault(r.modality, []).append(r)

        t0 = monotonic()
        sessions: Dict[str, object] = {}
        try:
            hooks = dict(hooks or {})
            for m, rs in by_mod.items():
                sessions[m] = self.pools[m].start_session(
                    rs, hooks=hooks.get(m), modality=m, metrics=metrics)
            ticks = 0
            while any(not s.done for s in sessions.values()):
                for s in sessions.values():
                    if not s.done:
                        s.tick()
                ticks += 1
                if max_ticks is not None and ticks >= max_ticks:
                    break
        finally:
            # also on a failed tick: release every engine's session latch
            # (finish() is idempotent; unfinished requests -> preempted)
            for s in sessions.values():
                s.finish()

        results: Dict[int, DiffusionResult] = {}
        for s in sessions.values():
            for res in s.finish():
                results[res.request_id] = res
        self.telemetry = MixedTelemetry(
            pools={m: s.tele for m, s in sessions.items()},
            row_tokens={m: self.pools[m].tokens for m in sessions},
            elapsed_s=monotonic() - t0)
        return [results[r.request_id] for r in requests
                if r.request_id in results]


def autotune_pools(workloads: Mapping[str, DenoiseWorkload], sla: SLA,
                   num_steps: int = 16, extra_candidates: Optional[
                       Mapping[str, Sequence]] = None,
                   **kw) -> Dict[str, "object"]:
    """The autotune umbrella: one SLA-driven policy sweep per modality.

    Runs repro.serving.diffusion.autotune against each workload's backbone
    (the calibration reference is that modality's exact trajectory); video
    workloads automatically add a temporal candidate (teacache_video with
    the clip's frame count) on top of the default sweep.  Returns
    {modality: TunedPolicy}."""
    from repro.serving.diffusion.autotune import DEFAULT_CANDIDATES
    out = {}
    for name, wl in workloads.items():
        cands = list(DEFAULT_CANDIDATES)
        if wl.spec.temporal:
            cands.append(("teacache_video",
                          {"delta": 0.1, "frames": wl.frames}))
        if extra_candidates and name in extra_candidates:
            cands.extend(extra_candidates[name])
        out[name] = autotune(wl.params, wl.cfg, sla, candidates=cands,
                             num_steps=num_steps, **kw)
    return out
