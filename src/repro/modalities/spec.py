"""Modality specs and denoise workloads.

The survey's subtitle is *Toward Efficient Multi-Modal Generation*: the same
cache operator (Eq. 14-15) is claimed to accelerate image, video and audio
diffusion transformers alike (SmoothCache demonstrates exactly this
cross-modality sweep).  A ModalitySpec pins down what a modality IS for the
cache/serving stack:

  image — class-conditional latent patches, the plain isotropic DiT
          (dit-xl): tokens = spatial patches, channels = patchified latent.
  video — latent clips with a frame axis, the factorized spatio-temporal
          DiT (dit-video, repro.models.video_dit): tokens = frames x
          per-frame patches flattened, so the serving stack sees the same
          (B, T, D) rows; the frame structure lives in the backbone's
          factorized attention and in the temporal-aware policies
          (repro.core.temporal).
  audio — mel-spectrogram latents (dit-audio): tokens = mel time-frames,
          channels = mel bins, backbone = the plain DiT.  Nothing but the
          token semantics changes — which is the cross-modality claim.

`DenoiseWorkload` binds a spec to (cfg, params) and hands out the pieces
the rest of the stack consumes: a CachedDenoiser, a serving engine, the
exact CFG baseline, and modality-aware policy construction (temporal
policies need the clip's frame count).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax

from repro.configs import get_config
from repro.core import CachePolicy, TemporalPABStack, make_policy

PyTree = Any


@dataclass(frozen=True)
class ModalitySpec:
    """What a generation modality means to the cache/serving stack."""
    name: str
    arch_id: str            # repro.configs registry id of the backbone
    description: str
    #: does the latent carry a frame axis (factorized video backbone)?
    temporal: bool = False
    #: is the backbone text-conditioned (per-block cross-attention over
    #: prompt embeddings; requests may carry prompt_tokens)?
    text: bool = False

    def config(self, smoke: bool = False):
        from repro.configs import get_smoke_config
        return get_smoke_config(self.arch_id) if smoke \
            else get_config(self.arch_id)

    def validate(self, cfg) -> None:
        if not cfg.is_dit:
            raise ValueError(f"modality '{self.name}': config {cfg.name} is "
                             f"not a DiT")
        if self.temporal != (cfg.dit_num_frames > 0):
            raise ValueError(
                f"modality '{self.name}': temporal={self.temporal} but "
                f"cfg.dit_num_frames={cfg.dit_num_frames}")
        if self.text != (cfg.dit_text_len > 0):
            raise ValueError(
                f"modality '{self.name}': text={self.text} but "
                f"cfg.dit_text_len={cfg.dit_text_len}")


MODALITIES: Dict[str, ModalitySpec] = {
    "image": ModalitySpec(
        "image", "dit-xl",
        "class-conditional latent patches, isotropic DiT"),
    "video": ModalitySpec(
        "video", "dit-video",
        "latent clips (frames x patches), factorized spatio-temporal DiT",
        temporal=True),
    "audio": ModalitySpec(
        "audio", "dit-audio",
        "mel-spectrogram latents (time-frames x mel bins), isotropic DiT"),
    "t2i": ModalitySpec(
        "t2i", "dit-t2i",
        "text-to-image: latent patches + cross-attn over prompt embeddings",
        text=True),
    "t2v": ModalitySpec(
        "t2v", "dit-t2v",
        "text-to-video: factorized video DiT + cross-attn text conditioning",
        temporal=True, text=True),
}


def get_modality(name: str) -> ModalitySpec:
    if name not in MODALITIES:
        raise KeyError(f"unknown modality '{name}'; "
                       f"available: {sorted(MODALITIES)}")
    return MODALITIES[name]


@dataclass
class DenoiseWorkload:
    """A modality bound to concrete (cfg, params): everything the cache and
    serving layers need to denoise this modality end-to-end."""
    spec: ModalitySpec
    cfg: Any
    params: PyTree
    extras: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        self.spec.validate(self.cfg)

    # -- shapes ---------------------------------------------------------
    @property
    def tokens(self) -> int:
        return self.cfg.dit_tokens

    @property
    def latent_dim(self) -> int:
        return self.cfg.dit_in_dim

    @property
    def frames(self) -> int:
        return max(self.cfg.dit_num_frames, 1)

    def latent_shape(self, batch: int = 1):
        return (batch, self.tokens, self.latent_dim)

    def noise(self, key, batch: int = 1):
        return jax.random.normal(key, self.latent_shape(batch))

    # -- policies -------------------------------------------------------
    def make_policy(self, name: str, num_steps: int = 50,
                    **kw) -> CachePolicy:
        """Registry policy with modality-aware defaults: temporal policies
        (teacache_video) get this workload's frame count."""
        if self.spec.temporal:
            kw.setdefault("frames", self.frames)
        return make_policy(name, num_steps=num_steps, **kw)

    def pab_stack(self, ranges: Optional[Dict[str, int]] = None
                  ) -> TemporalPABStack:
        """The PAB-faithful broadcast over the factorized video backbone:
        per-module-type ranges, temporal attention reused over the longest
        one.  Video only (the image/audio DiT has no temporal branch)."""
        if not self.spec.temporal:
            raise ValueError(f"modality '{self.spec.name}' has no "
                             f"factorized temporal branches for PAB")
        from repro.models import video_dit
        return TemporalPABStack(video_dit.pab_branch_fns(self.cfg),
                                self.cfg.num_layers, ranges)

    # -- denoising entry points ----------------------------------------
    def denoiser(self, policy: Optional[CachePolicy] = None, **kw):
        """CachedDenoiser over this workload's backbone (single stream)."""
        from repro.diffusion.pipeline import CachedDenoiser
        return CachedDenoiser(self.params, self.cfg, policy, **kw)

    def cfg_denoise_fn(self, cfg_scale: float, class_label: int = 0,
                       null_embed=None, text=None, neg_text=None):
        """The exact (uncached) guided baseline for this modality."""
        from repro.diffusion.pipeline import cfg_denoise_fn
        return cfg_denoise_fn(self.params, self.cfg, cfg_scale, class_label,
                              null_embed, text=text, neg_text=neg_text)

    def conditioner(self, capacity: int = 128, seed: int = 0, metrics=None):
        """A PromptCache over a freshly initialised text encoder matched
        to this workload's config (text modalities only) — what the
        engine resolves DiffusionRequest.prompt_tokens through."""
        if not self.spec.text:
            raise ValueError(f"modality '{self.spec.name}' is not "
                             f"text-conditioned; no conditioner to build")
        from repro.conditioning import (PromptCache, init_text_encoder,
                                        text_encoder_config)
        tc = text_encoder_config(self.cfg)
        tparams = init_text_encoder(jax.random.PRNGKey(seed), tc)
        return PromptCache(tparams, tc, capacity=capacity, metrics=metrics,
                           name=self.spec.name)

    def engine(self, policy=None, **kw):
        """A single-modality DiffusionServingEngine over this backbone —
        one sub-pool of a mixed-modality pool."""
        from repro.serving.diffusion import DiffusionServingEngine
        return DiffusionServingEngine(self.params, self.cfg, policy, **kw)


def make_workload(name: str, cfg=None, params=None, *, smoke: bool = False,
                  seed: int = 0, perturb: bool = True) -> DenoiseWorkload:
    """Build a modality workload: registry spec + config + (fresh) params.

    cfg/params default to the spec's registered config (smoke variant when
    `smoke`) and freshly initialised weights; `perturb` replaces the
    AdaLN-zero-initialised leaves so an untrained backbone doesn't output
    exactly zero (repro.models.perturb_zero_init)."""
    from repro.models import init_params, perturb_zero_init
    spec = get_modality(name)
    cfg = cfg if cfg is not None else spec.config(smoke=smoke)
    if params is None:
        params = init_params(jax.random.PRNGKey(seed), cfg)
        if perturb:
            params = perturb_zero_init(params, seed)
    return DenoiseWorkload(spec, cfg, params)
