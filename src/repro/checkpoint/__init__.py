"""Dependency-free pytree checkpointing (no msgpack/orbax installed)."""
from .store import latest_step, load_pytree, restore, save, save_pytree

__all__ = ["save", "restore", "save_pytree", "load_pytree", "latest_step"]
