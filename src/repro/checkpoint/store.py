"""Pytree checkpoint store: .npz tensors + JSON treedef sidecar.

Layout:  <dir>/step_<n>/arrays.npz + meta.json.  Atomic via tmp+rename.
Works for any pytree of jnp/np arrays and python scalars (kept in meta).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        items.append((key, leaf))
    return items


def save_pytree(tree: PyTree, path: str):
    """Serialize a pytree of arrays to <path>.npz + <path>.json.

    bfloat16 (not a native numpy dtype) is stored as a uint16 bit-view with
    the true dtype recorded in the sidecar."""
    items = _flatten_with_paths(tree)
    arrays, dtypes = {}, {}
    for k, v in items:
        arr = np.asarray(v)
        dtypes[k] = str(arr.dtype)
        if arr.dtype.name == "bfloat16":
            arr = arr.view(np.uint16)
        arrays[k] = arr
    treedef = jax.tree_util.tree_structure(tree)
    tmp = path + ".tmp.npz"
    np.savez(tmp, **arrays)
    os.replace(tmp, path + ".npz")
    with open(path + ".json", "w") as f:
        json.dump({"treedef": str(treedef), "keys": [k for k, _ in items],
                   "dtypes": dtypes}, f)


def load_pytree(tree_like: PyTree, path: str) -> PyTree:
    """Restore into the structure of `tree_like` (shape/dtype donor)."""
    import jax.numpy as jnp
    data = np.load(path + ".npz")
    items = _flatten_with_paths(tree_like)
    leaves = []
    for key, ref in items:
        arr = data[key]
        assert arr.shape == tuple(ref.shape), (key, arr.shape, ref.shape)
        if jnp.dtype(ref.dtype).name == "bfloat16" and arr.dtype == np.uint16:
            arr = arr.view(jnp.bfloat16)
        leaves.append(jnp.asarray(arr).astype(ref.dtype))
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str, step: int, tree: PyTree, extra: Optional[dict] = None,
         keep: int = 3):
    """Save a training checkpoint; prunes to the most recent `keep`."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir)
    save_pytree(tree, os.path.join(tmp, "arrays"))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "extra": extra or {}}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    steps = sorted(_list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def _list_steps(ckpt_dir: str):
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like: PyTree, step: Optional[int] = None):
    """Returns (tree, step, extra) for `step` (default: latest)."""
    step = latest_step(ckpt_dir) if step is None else step
    assert step is not None, f"no checkpoints under {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    tree = load_pytree(tree_like, os.path.join(d, "arrays"))
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    return tree, step, meta.get("extra", {})
