"""Pure-JAX optimizers and LR schedules (no optax dependency)."""
from .adamw import (AdamWState, adamw_init, adamw_update, clip_by_global_norm,
                    cosine_warmup_schedule, global_norm)

__all__ = ["AdamWState", "adamw_init", "adamw_update", "global_norm",
           "clip_by_global_norm", "cosine_warmup_schedule"]
